//! Offline stand-in for the `anyhow` crate: the API subset `approxmul`
//! uses — [`Error`], [`Result`], the [`Context`] extension trait and the
//! `anyhow!` / `bail!` / `ensure!` macros — with the same semantics
//! (context chain printed by `{:#}`, blanket `From<E: std::error::Error>`).
//! The error is a plain string chain rather than a boxed dyn error; no
//! backtraces, no downcasting. Swap for the real crate by editing the
//! workspace manifest.

use std::fmt;

/// A string-chain error: the most recent context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full context chain, like real anyhow.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Like real anyhow, `Error` deliberately does **not** implement
/// `std::error::Error`, which is what makes this blanket conversion
/// (and therefore `?` on any std error) coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($args:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($args)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($args:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($args)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<u8> {
        let e = std::io::Error::other("boom");
        Err(e).context("reading widget")
    }

    #[test]
    fn context_chain_renders() {
        let e = fails_io().unwrap_err();
        assert_eq!(format!("{e}"), "reading widget");
        assert_eq!(format!("{e:#}"), "reading widget: boom");
        assert_eq!(e.root_cause(), "boom");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(format!("{}", f(3).unwrap_err()), "unlucky 3");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let e: Error = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }
}
