//! Offline stub of the `xla` PJRT bindings.
//!
//! Host-side pieces ([`Literal`] construction, reshape, typed readout)
//! are fully functional so literal-marshalling code and its tests work
//! without native XLA. Anything that needs the real runtime
//! ([`PjRtClient::cpu`], compilation, execution, tuple decomposition of
//! device results) returns an [`Error`] explaining that this is the
//! stub build — callers degrade gracefully (the integration tests
//! already skip when artifacts are absent). Point the workspace at the
//! real `xla` crate to run compiled graphs.

use std::fmt;

/// Stub error type (implements `std::error::Error`, so `?` converts it
/// into `anyhow::Error` at call sites).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: native XLA/PJRT backend not available in this build \
         (vendored stub — see vendor/xla)"
    ))
}

/// Element types of XLA literals (the subset the manifest can declare,
/// plus enough extras that match arms stay non-exhaustive).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[non_exhaustive]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    fn size_bytes(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy + 'static {
    const TY: ElementType;
}

macro_rules! native_type {
    ($($t:ty => $v:ident),* $(,)?) => {
        $(impl NativeType for $t {
            const TY: ElementType = ElementType::$v;
        })*
    };
}

native_type!(u8 => U8, i32 => S32, i64 => S64, u32 => U32, u64 => U64, f32 => F32, f64 => F64);

/// Shape of a (non-tuple) literal: element type + dimensions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side XLA literal: shape plus raw little-endian bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    shape: ArrayShape,
    data: Vec<u8>,
}

impl Literal {
    /// Build from raw bytes (single memcpy; the fast marshalling path).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Self> {
        let elems: usize = dims.iter().product();
        if data.len() != elems * ty.size_bytes() {
            return Err(Error(format!(
                "literal data is {} bytes, shape {dims:?} of {ty:?} needs {}",
                data.len(),
                elems * ty.size_bytes()
            )));
        }
        Ok(Literal {
            shape: ArrayShape { ty, dims: dims.iter().map(|&d| d as i64).collect() },
            data: data.to_vec(),
        })
    }

    /// Build a rank-1 literal from a typed slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Self {
        let bytes = unsafe {
            std::slice::from_raw_parts(
                values.as_ptr() as *const u8,
                std::mem::size_of_val(values),
            )
        };
        Literal {
            shape: ArrayShape { ty: T::TY, dims: vec![values.len() as i64] },
            data: bytes.to_vec(),
        }
    }

    /// Same data, new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Self> {
        let new_elems: i64 = dims.iter().product();
        let old_elems: i64 = self.shape.dims.iter().product();
        if new_elems != old_elems {
            return Err(Error(format!(
                "cannot reshape {:?} -> {dims:?}",
                self.shape.dims
            )));
        }
        Ok(Literal {
            shape: ArrayShape { ty: self.shape.ty, dims: dims.to_vec() },
            data: self.data.clone(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(self.shape.clone())
    }

    /// Read the elements out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        // Pred literals read out as u8, like the real bindings.
        let compatible = T::TY == self.shape.ty
            || (T::TY == ElementType::U8 && self.shape.ty == ElementType::Pred);
        if !compatible {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.shape.ty,
                T::TY
            )));
        }
        let size = std::mem::size_of::<T>();
        if size == 0 || self.data.len() % size != 0 {
            return Err(Error(format!(
                "literal byte length {} not a multiple of element size {size}",
                self.data.len()
            )));
        }
        Ok(self
            .data
            .chunks_exact(size)
            .map(|chunk| unsafe { std::ptr::read_unaligned(chunk.as_ptr() as *const T) })
            .collect())
    }

    /// Split a tuple literal into its elements. Tuples only come back
    /// from graph execution, which the stub cannot do.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::decompose_tuple"))
    }
}

/// Parsed HLO module (the stub only retains the text).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        std::fs::read_to_string(path)
            .map(|text| HloModuleProto { _text: text })
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. The stub cannot create one.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let values = [1.0f32, -2.5, 3.25];
        let lit = Literal::vec1(&values);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), values);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn untyped_construction_checks_length() {
        let bytes = [0u8; 12];
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::U32, &[3], &bytes)
                .unwrap();
        assert_eq!(lit.to_vec::<u32>().unwrap(), vec![0, 0, 0]);
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::U32,
            &[4],
            &bytes
        )
        .is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let lit = Literal::vec1(&[1i32, 2, 3, 4]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert!(lit.reshape(&[3]).is_err());
    }

    #[test]
    fn runtime_entry_points_fail_cleanly() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("stub"));
    }
}
