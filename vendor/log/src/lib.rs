//! Offline stand-in for the `log` crate facade: levels, `Record` /
//! `Metadata`, the [`Log`] trait, a process-global logger, and the
//! level macros. Matches the subset `approxmul`'s tiny env-filtered
//! logger uses (`set_logger` with a `&'static` logger — this stand-in,
//! like the real crate's no-std build, has no `set_boxed_logger`).

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a log record (ordered: `Error < Trace`).
#[repr(usize)]
#[derive(Clone, Copy, Debug, Hash, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Maximum-verbosity filter (`Off` disables everything).
#[repr(usize)]
#[derive(Clone, Copy, Debug, Hash, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record's origin.
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message arguments.
#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn args(&self) -> fmt::Arguments<'a> {
        self.args
    }
}

/// A log sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

/// Returned when [`set_logger`] is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger has already been set")
    }
}

impl std::error::Error for SetLoggerError {}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _metadata: &Metadata<'_>) -> bool {
        false
    }
    fn log(&self, _record: &Record<'_>) {}
    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the process-global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger, or a no-op sink if none was set.
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing — public because the macros expand in other crates.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    let metadata = Metadata { level, target };
    let sink = logger();
    if sink.enabled(&metadata) {
        sink.log(&Record { metadata, args });
    }
}

/// Log at an explicit level: `log!(Level::Info, "x = {x}")`.
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__log(lvl, ::std::module_path!(), ::std::format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_orderings() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(LevelFilter::Off < Level::Error);
        assert_eq!(Level::Warn.as_str(), "WARN");
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }

    #[test]
    fn unset_logger_is_nop() {
        // No logger installed in this test binary: must not panic.
        info!("into the void {}", 42);
        logger().flush();
    }
}
