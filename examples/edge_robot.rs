//! The paper's motivating scenario (§I): an offline mobile robot that
//! must keep improving its image classifier on the edge. New labelled
//! observations arrive in rounds; each round the robot fine-tunes its
//! CNN with approximate multipliers (cheap, battery-friendly) and we
//! track accuracy and the cumulative energy the approximate MAC array
//! saved vs an exact one, using the DRUM cost model.
//!
//! Run: `cargo run --release --example edge_robot`

use approxmul::config::{ErrorSampling, ExperimentConfig, MultiplierPolicy};
use approxmul::coordinator::Trainer;
use approxmul::costmodel::CostModel;
use approxmul::data::SyntheticCifar;
use approxmul::mult::MultSpec;
use approxmul::report::{pct, Table};
use approxmul::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::from_artifacts("artifacts")?;
    let model = engine.manifest().model("tiny")?;

    let rounds = 4u64;
    let per_round = 768usize;
    let test_n = 512usize.div_ceil(model.eval_batch) * model.eval_batch;

    // One world: the robot's whole deployment. The held-out benchmark
    // course is the tail; field observations stream in round by round.
    let mut gen = SyntheticCifar::for_input(
        model.input_hw,
        model.in_ch,
        model.num_classes,
        1_000_000,
    );
    gen.noise = 2.5; // keep the course hard enough that accuracy can grow
    let mut world = gen.generate(rounds as usize * per_round + test_n);
    world.normalize();
    let (stream, test) = world.split_tail(test_n)?;

    // On-edge training config: approximate multipliers at DRUM-6's
    // error level, resampled per step (hardware error is
    // data-dependent, not a fixed matrix).
    let mut cfg = ExperimentConfig::preset_tiny();
    cfg.epochs = 3; // per round
    cfg.policy =
        MultiplierPolicy::Approximate { mult: MultSpec::gaussian(0.018) };
    cfg.sampling = ErrorSampling::PerStep;

    let cm = CostModel::from_model(model, engine.manifest().paper.conv_time_share)?;
    let drum = CostModel::design("drum6")?;
    let gains = cm.system_gains(&drum);

    let mut t = Table::new(&[
        "round", "observations", "test acc", "cum. MACs (G)", "energy saved",
    ]);
    let mut total_macs = 0u64;
    let mut carry: Option<Vec<approxmul::tensor::Tensor>> = None;
    for round in 0..rounds {
        // This round's fresh field observations.
        let this_round = stream.slice(round as usize * per_round, per_round)?;

        let mut round_cfg = cfg.clone();
        round_cfg.tag = format!("edge-round{round}");
        round_cfg.train_examples = this_round.len();
        let mut trainer =
            Trainer::with_data(&engine, round_cfg, this_round, test.clone())?;
        if let Some(state) = carry.take() {
            trainer.restore_state(state)?; // continual learning: resume
        }
        let outcome = trainer.run()?;
        let steps = outcome.epochs_run * (per_round as u64 / model.batch as u64);
        total_macs += cm.training_macs(steps, model.batch as u64);
        carry = Some(trainer.session().state_tensors().to_vec());

        t.row(vec![
            round.to_string(),
            per_round.to_string(),
            pct(outcome.final_accuracy),
            format!("{:.2}", total_macs as f64 / 1e9),
            pct(gains.energy_saving),
        ]);
    }
    print!("{}", t.to_markdown());
    println!(
        "\ncontinual on-edge fine-tuning under approximate multipliers: \
         accuracy keeps improving across rounds while every training MAC \
         runs on hardware drawing {} less energy (DRUM-6 model).",
        pct(gains.energy_saving)
    );
    Ok(())
}
