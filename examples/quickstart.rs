//! Quickstart: load the AOT artifacts, train the `tiny` preset for a
//! few epochs under a simulated approximate multiplier (MRE ~3.6%, the
//! paper's test case 4), and evaluate with exact multipliers.
//!
//! Run: `cargo run --release --example quickstart`

use approxmul::config::{ExperimentConfig, MultiplierPolicy};
use approxmul::coordinator::Trainer;
use approxmul::error_model::ErrorConfig;
use approxmul::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // The engine owns the PJRT CPU client and the compiled-graph cache.
    let engine = Engine::from_artifacts("artifacts")?;
    println!("platform: {}", engine.platform_name());

    // Train case 4 of the paper's Table II: MRE ~3.6% / SD ~4.5%.
    let mut cfg = ExperimentConfig::preset_tiny();
    cfg.epochs = 6;
    cfg.policy = MultiplierPolicy::Approximate {
        error: ErrorConfig::from_mre(0.036),
    };
    cfg.tag = "quickstart".into();

    let mut trainer = Trainer::new(&engine, cfg)?;
    let mut hook = |r: &approxmul::metrics::EpochRecord| {
        println!(
            "epoch {}: train loss {:.4}, test acc {:.2}% (sigma {:.3})",
            r.epoch,
            r.train_loss,
            100.0 * r.test_acc,
            r.sigma
        );
    };
    let outcome = trainer.run_from(0, Some(&mut hook))?;

    println!(
        "\ntrained {} epochs in {:.1}s — final exact-multiplier accuracy {:.2}%",
        outcome.epochs_run,
        outcome.wall_secs,
        100.0 * outcome.final_accuracy
    );
    Ok(())
}
