//! Quickstart: train the `tiny` preset on the native backend for a few
//! epochs with a *bit-accurate* approximate multiplier (DRUM-6 — the
//! paper's reference design), then evaluate with exact multipliers. No
//! compiled artifacts or PJRT needed; every GEMM of the run goes
//! through the simulated DRUM-6 hardware.
//!
//! Run: `cargo run --release --example quickstart`

use approxmul::config::{ExperimentConfig, MultiplierPolicy};
use approxmul::coordinator::Trainer;
use approxmul::mult::MultSpec;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::preset_tiny();
    cfg.epochs = 6;
    cfg.policy = MultiplierPolicy::Approximate {
        mult: MultSpec::parse("drum6")?,
    };
    cfg.tag = "quickstart".into();

    let mut trainer = Trainer::native(cfg)?;
    println!("backend: {}", trainer.session().backend_kind());
    let mut hook = |r: &approxmul::metrics::EpochRecord| {
        println!(
            "epoch {}: train loss {:.4}, test acc {:.2}%",
            r.epoch,
            r.train_loss,
            100.0 * r.test_acc,
        );
    };
    let outcome = trainer.run_from(0, Some(&mut hook))?;

    println!(
        "\ntrained {} epochs under drum6 in {:.1}s — final exact-multiplier \
         accuracy {:.2}%",
        outcome.epochs_run,
        outcome.wall_secs,
        100.0 * outcome.final_accuracy
    );
    Ok(())
}
