//! End-to-end training driver for either backend.
//!
//! * `native` (default): trains through the pure-Rust backend where
//!   every GEMM runs on the bit-accurate multiplier engine — compares
//!   the exact baseline against DRUM-6 (the paper's reference design)
//!   with no PJRT or artifacts. This is the CI smoke path.
//! * `pjrt`: the original full-stack path — Rust coordinator -> PJRT ->
//!   AOT-compiled JAX graph -> Pallas error-injection kernel — against
//!   the paper's MRE ~1.4% Gaussian configuration (Table II case 2).
//!
//! Real CIFAR-10 is used when `data/cifar-10-batches-bin` exists and
//! the preset takes 32x32 input; otherwise the CIFAR surrogate.
//!
//! Run: `cargo run --release --example train_e2e [epochs] [backend] [preset]`
//! e.g. `cargo run --release --example train_e2e 2 native tiny`

use approxmul::config::{ExperimentConfig, MultiplierPolicy};
use approxmul::coordinator::Trainer;
use approxmul::data::cifar;
use approxmul::mult::MultSpec;
use approxmul::runtime::{BackendModel, Engine, NativeConfig};

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let epochs: u64 = args.next().map(|s| s.parse()).transpose()?.unwrap_or(6);
    let backend = args.next().unwrap_or_else(|| "native".to_string());
    let native = match backend.as_str() {
        "native" => true,
        "pjrt" => false,
        other => anyhow::bail!("backend {other:?} (native | pjrt)"),
    };
    let preset = args
        .next()
        .unwrap_or_else(|| if native { "tiny".to_string() } else { "small".to_string() });

    let engine = if native {
        None
    } else {
        Some(Engine::from_artifacts("artifacts")?)
    };
    let model: BackendModel = match &engine {
        Some(engine) => {
            println!("platform: {}", engine.platform_name());
            BackendModel::from_manifest(engine.manifest().model(&preset)?)
        }
        None => NativeConfig::preset(&preset)?.backend_model(),
    };

    let mut base = if preset == "small" {
        ExperimentConfig::preset_small()
    } else {
        let mut c = ExperimentConfig::preset_tiny();
        c.preset = preset.clone();
        c
    };
    base.epochs = epochs;

    // Real CIFAR-10 if present on disk and geometrically compatible
    // (DESIGN.md §5).
    let real = if model.input_hw == 32 {
        cifar::load_standard("data/cifar-10-batches-bin")?
    } else {
        None
    };
    if real.is_some() {
        println!("using real CIFAR-10 from data/cifar-10-batches-bin");
    } else {
        println!("using synthetic CIFAR surrogate");
    }

    // Native runs compare against the actual DRUM-6 design; PJRT runs
    // can only express the paper's Gaussian surrogate at DRUM-6's MRE.
    let approx_spec = if native {
        MultSpec::parse("drum6")?
    } else {
        MultSpec::gaussian_mre(0.014)
    };

    std::fs::create_dir_all("runs")?;
    let mut results = Vec::new();
    for (name, policy) in [
        ("exact", MultiplierPolicy::Exact),
        (
            "approx",
            MultiplierPolicy::Approximate { mult: approx_spec.clone() },
        ),
    ] {
        let mut cfg = base.clone();
        cfg.policy = policy;
        cfg.tag = format!("e2e-{backend}-{name}");
        println!(
            "\n=== {name} ({} epochs, {} examples, backend {backend}) ===",
            cfg.epochs, cfg.train_examples
        );
        let data = real.as_ref().map(|(train, test)| {
            let take_test =
                cfg.test_examples.div_ceil(model.eval_batch) * model.eval_batch;
            let mut train = train.clone();
            train.normalize();
            let mut test = test.clone();
            test.normalize();
            test.images.truncate(take_test * test.image_elems());
            test.labels.truncate(take_test);
            train.images.truncate(cfg.train_examples * train.image_elems());
            train.labels.truncate(cfg.train_examples);
            (train, test)
        });
        let mut trainer = match (&engine, data) {
            (Some(engine), Some((train, test))) => {
                Trainer::with_data(engine, cfg.clone(), train, test)?
            }
            (Some(engine), None) => Trainer::new(engine, cfg.clone())?,
            (None, Some((train, test))) => {
                Trainer::native_with_data(cfg.clone(), train, test)?
            }
            (None, None) => Trainer::native(cfg.clone())?,
        };
        let mut hook = |r: &approxmul::metrics::EpochRecord| {
            println!(
                "  epoch {:>2}: train loss {:.4} acc {:.3} | test acc {:.2}% | {:.1}s",
                r.epoch,
                r.train_loss,
                r.train_acc,
                100.0 * r.test_acc,
                r.wall_secs
            );
        };
        let outcome = trainer.run_from(0, Some(&mut hook))?;
        anyhow::ensure!(
            outcome.epochs_run == epochs,
            "expected {epochs} epochs, ran {}",
            outcome.epochs_run
        );
        let first = outcome.history.records.first().map(|r| r.train_loss);
        let last = outcome.history.records.last().map(|r| r.train_loss);
        if let (Some(first), Some(last)) = (first, last) {
            anyhow::ensure!(
                epochs < 2 || last < first,
                "{name}: train loss did not decrease ({first:.4} -> {last:.4})"
            );
        }
        let csv = format!("runs/e2e-{backend}-{name}.csv");
        outcome.history.save_csv(&csv)?;
        println!(
            "{name}: final acc {:.2}% in {:.1}s (loss curve -> {csv})",
            100.0 * outcome.final_accuracy,
            outcome.wall_secs
        );
        results.push((name, outcome));
    }

    let exact = &results[0].1;
    let approx = &results[1].1;
    println!(
        "\nsummary: exact {:.2}% vs {} {:.2}% — diff {:+.2} pts \
         (paper Table II case 2: -0.07 pts at 200 epochs)",
        100.0 * exact.final_accuracy,
        approx_spec.label(),
        100.0 * approx.final_accuracy,
        100.0 * (approx.final_accuracy - exact.final_accuracy)
    );
    Ok(())
}
