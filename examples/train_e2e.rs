//! End-to-end driver (EXPERIMENTS.md §e2e): trains the `small` VGG-style
//! preset (~1.2M params) for several hundred steps on the CIFAR
//! surrogate (or real CIFAR-10 if `data/cifar-10-batches-bin` exists),
//! through the full stack — Rust coordinator -> PJRT -> AOT-compiled
//! JAX graph -> Pallas error-injection kernel — and logs the loss
//! curve, comparing the exact baseline against the paper's MRE ~1.4%
//! configuration (Table II case 2).
//!
//! Run: `cargo run --release --example train_e2e [epochs]`

use approxmul::config::{ExperimentConfig, MultiplierPolicy};
use approxmul::coordinator::Trainer;
use approxmul::data::cifar;
use approxmul::error_model::ErrorConfig;
use approxmul::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let epochs: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(6);

    let engine = Engine::from_artifacts("artifacts")?;
    println!("platform: {}", engine.platform_name());

    let mut base = ExperimentConfig::preset_small();
    base.epochs = epochs;
    base.train_examples = 4096;
    base.test_examples = 1024;

    // Real CIFAR-10 if present on disk (DESIGN.md §5).
    let real = cifar::load_standard("data/cifar-10-batches-bin")?;
    if real.is_some() {
        println!("using real CIFAR-10 from data/cifar-10-batches-bin");
    } else {
        println!("using synthetic CIFAR surrogate (no dataset on disk)");
    }

    std::fs::create_dir_all("runs")?;
    let mut results = Vec::new();
    for (name, policy) in [
        ("exact", MultiplierPolicy::Exact),
        (
            "approx-mre1.4",
            MultiplierPolicy::Approximate { error: ErrorConfig::from_mre(0.014) },
        ),
    ] {
        let mut cfg = base.clone();
        cfg.policy = policy;
        cfg.tag = format!("e2e-{name}");
        println!("\n=== {name} ({} epochs, {} examples) ===", cfg.epochs, cfg.train_examples);
        let mut trainer = match &real {
            Some((train, test)) => {
                let model = engine.manifest().model(&cfg.preset)?;
                let mut train = train.clone();
                let take_test = cfg.test_examples.div_ceil(model.eval_batch) * model.eval_batch;
                train.normalize();
                let mut test = test.clone();
                test.normalize();
                test.images.truncate(take_test * test.image_elems());
                test.labels.truncate(take_test);
                train.images.truncate(cfg.train_examples * train.image_elems());
                train.labels.truncate(cfg.train_examples);
                Trainer::with_data(&engine, cfg.clone(), train, test)?
            }
            None => Trainer::new(&engine, cfg.clone())?,
        };
        let mut steps = 0u64;
        let mut hook = |r: &approxmul::metrics::EpochRecord| {
            println!(
                "  epoch {:>2}: train loss {:.4} acc {:.3} | test acc {:.2}% | {:.1}s",
                r.epoch,
                r.train_loss,
                r.train_acc,
                100.0 * r.test_acc,
                r.wall_secs
            );
        };
        let outcome = trainer.run_from(0, Some(&mut hook))?;
        steps += outcome.epochs_run * (base.train_examples as u64 / 64);
        let csv = format!("runs/e2e-{name}.csv");
        outcome.history.save_csv(&csv)?;
        println!(
            "{name}: final acc {:.2}% after ~{steps} steps in {:.1}s (loss curve -> {csv})",
            100.0 * outcome.final_accuracy,
            outcome.wall_secs
        );
        results.push((name, outcome));
    }

    let exact = &results[0].1;
    let approx = &results[1].1;
    println!(
        "\nsummary: exact {:.2}% vs approx(MRE~1.4%) {:.2}% — diff {:+.2} pts \
         (paper Table II case 2: -0.07 pts at 200 epochs)",
        100.0 * exact.final_accuracy,
        100.0 * approx.final_accuracy,
        100.0 * (approx.final_accuracy - exact.final_accuracy)
    );
    Ok(())
}
