//! The paper's §IV hybrid methodology, end to end: train with
//! approximate multipliers (MRE ~9.6%, the paper's hardest benign case)
//! and switch to exact multipliers for the final epochs, comparing
//! exact / fully-approximate / hybrid outcomes and the hardware gains
//! each schedule earns under the DRUM cost model.
//!
//! Run: `cargo run --release --example hybrid_training`

use approxmul::config::{ExperimentConfig, MultiplierPolicy};
use approxmul::coordinator::Trainer;
use approxmul::costmodel::CostModel;
use approxmul::mult::MultSpec;
use approxmul::report::{pct, Table};
use approxmul::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::from_artifacts("artifacts")?;
    let error = MultSpec::gaussian_mre(0.096);
    let epochs = 10u64;
    let switch = 7u64; // 70% approximate utilization

    let mut rows = Vec::new();
    for (name, policy) in [
        ("exact", MultiplierPolicy::Exact),
        ("approximate", MultiplierPolicy::Approximate { mult: error.clone() }),
        (
            "hybrid",
            MultiplierPolicy::Hybrid { mult: error.clone(), switch_epoch: switch },
        ),
    ] {
        let mut cfg = ExperimentConfig::preset_tiny();
        cfg.epochs = epochs;
        cfg.policy = policy.clone();
        cfg.tag = format!("hybrid-demo-{name}");
        println!("=== {name} ===");
        let mut trainer = Trainer::new(&engine, cfg.clone())?;
        let mut hook = |r: &approxmul::metrics::EpochRecord| {
            println!(
                "  epoch {:>2}: sigma {:.3} -> test acc {:.2}%",
                r.epoch,
                r.sigma,
                100.0 * r.test_acc
            );
        };
        let outcome = trainer.run_from(0, Some(&mut hook))?;
        rows.push((name, policy, outcome));
    }

    // Hardware gains for each schedule (vgg16-scale MAC profile — the
    // deployment target the paper argues for).
    let model = engine.manifest().model("vgg16")?;
    let cm = CostModel::from_model(model, engine.manifest().paper.conv_time_share)?;
    let drum = CostModel::design("drum6")?;

    let mut t = Table::new(&[
        "schedule", "final acc", "approx util", "train-time saving", "energy saving",
    ]);
    for (name, policy, outcome) in &rows {
        let util = policy.utilization(epochs);
        let gains = cm.hybrid_gains(&drum, (util * epochs as f64).round() as u32, epochs as u32);
        t.row(vec![
            name.to_string(),
            pct(outcome.final_accuracy),
            pct(util),
            pct(gains.time_saving),
            pct(gains.energy_saving),
        ]);
    }
    println!("\n{}", t.to_markdown());
    println!(
        "the hybrid row should match the exact row's accuracy while keeping \
         most of the approximate row's hardware gains (paper §IV)."
    );
    Ok(())
}
