//! Characterize the bit-accurate approximate-multiplier designs across
//! operand distributions, validating the paper's Gaussian error model
//! against real hardware behaviour (§III's DRUM mapping).
//!
//! Run: `cargo run --release --example characterize_multipliers`

use approxmul::mult::{characterize, standard_designs, GaussianModel, OperandDist};
use approxmul::report::Table;

fn main() -> anyhow::Result<()> {
    let dists = [
        OperandDist::Uniform16,
        OperandDist::Mantissa,
        OperandDist::Small,
    ];
    let n = 300_000;

    for dist in dists {
        println!("\n## operand distribution: {}", dist.name());
        let mut t = Table::new(&["design", "MRE", "SD", "bias", "MRE/SD"]);
        let mut designs = standard_designs();
        designs.push(Box::new(GaussianModel::new(0.01803, 99)));
        for d in &designs {
            let s = characterize(d.as_ref(), dist, n, 7);
            t.row(vec![
                d.name(),
                format!("{:.3}%", 100.0 * s.mre),
                format!("{:.3}%", 100.0 * s.sd),
                format!("{:+.3}%", 100.0 * s.mean_re),
                format!("{:.3}", s.gaussianity_ratio()),
            ]);
        }
        print!("{}", t.to_markdown());
    }

    println!(
        "\nreading guide:\n\
         * drum6 on uniform16 reproduces the published MRE ~1.47% with \
           near-zero bias — the paper's Table II case 2 mapping.\n\
         * MRE/SD ≈ 0.798 marks zero-mean-gaussian-like error (the \
           paper's model); mitchell/trunc are one-sided and violate it.\n\
         * the mantissa distribution is what float MACs actually feed \
           the multiplier — note how design error shifts there."
    );
    Ok(())
}
