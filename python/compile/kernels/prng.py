"""Counter-based PRNG primitives usable *inside* Pallas kernel bodies.

The approximate-multiplier error simulation needs per-element Gaussian
noise that is (a) deterministic in (seed, element index) so a training
step can be replayed bit-exactly from the Rust coordinator, and (b)
generatable inside a Pallas kernel without touching ``jax.random``
(whose keys cannot be threaded through ``pallas_call`` refs).

We implement Threefry-2x32 (the same core JAX uses) from scratch with
plain ``jnp`` integer ops, so the identical code path runs:

* inside Pallas kernel bodies (values read from refs are jnp arrays),
* in the pure-jnp reference oracle (``ref.py``),
* in the lowered L2 graph (it is just HLO integer arithmetic).

All functions are shape-polymorphic and dtype-strict (uint32 in/out).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# Threefry-2x32 rotation schedule (Salmon et al., SC'11), 20 rounds.
_ROTATIONS = (13, 15, 26, 6, 17, 29, 16, 24)
_PARITY = np.uint32(0x1BD11BDA)  # key-schedule parity constant

_U32 = np.uint32
# 1/2^32 as f32; maps uint32 -> [0, 1).
_INV_2_32 = np.float32(2.3283064365386963e-10)
_TWO_PI = np.float32(6.283185307179586)


def _rotl32(x: jnp.ndarray, r: int) -> jnp.ndarray:
    """Rotate-left a uint32 array by the static amount ``r``."""
    r = int(r)
    return (x << _U32(r)) | (x >> _U32(32 - r))


def threefry2x32(key0: jnp.ndarray, key1: jnp.ndarray,
                 ctr0: jnp.ndarray, ctr1: jnp.ndarray):
    """Threefry-2x32, 20 rounds.

    Args:
      key0, key1: uint32 scalars (or arrays broadcastable to the counters).
      ctr0, ctr1: uint32 counter arrays; the block is applied elementwise.

    Returns:
      ``(x0, x1)`` — two uint32 arrays of the counters' shape, the
      encrypted counter block. Bit-compatible with the reference
      Random123 implementation (validated against known-answer vectors
      in ``python/tests/test_prng.py``).
    """
    k0 = jnp.asarray(key0, _U32)
    k1 = jnp.asarray(key1, _U32)
    k2 = k0 ^ k1 ^ _PARITY
    x0 = jnp.asarray(ctr0, _U32) + k0
    x1 = jnp.asarray(ctr1, _U32) + k1

    ks = (k0, k1, k2)
    for block in range(5):
        for i in range(4):
            x0 = x0 + x1
            x1 = _rotl32(x1, _ROTATIONS[(block % 2) * 4 + i])
            x1 = x1 ^ x0
        # Key injection every 4 rounds.
        inj = block + 1
        x0 = x0 + ks[inj % 3]
        x1 = x1 + ks[(inj + 1) % 3] + _U32(inj)
    return x0, x1


def uniform_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """uint32 bits -> f32 uniform in the open interval (0, 1).

    Offsets by half an ulp of the grid so 0 is excluded (Box-Muller
    takes ``log(u)``).
    """
    return bits.astype(jnp.float32) * _INV_2_32 + np.float32(_INV_2_32 / 2)


def normal_pair(key0, key1, ctr0, ctr1):
    """Two independent standard-normal f32 arrays via Box-Muller.

    One Threefry block yields two uniforms, which Box-Muller turns into
    two normals — so the bit budget is 1 u32 per normal, same as JAX's
    native path.
    """
    b0, b1 = threefry2x32(key0, key1, ctr0, ctr1)
    u1 = uniform_from_bits(b0)
    u2 = uniform_from_bits(b1)
    r = jnp.sqrt(np.float32(-2.0) * jnp.log(u1))
    theta = _TWO_PI * u2
    return r * jnp.cos(theta), r * jnp.sin(theta)


def counter_normal(seed: jnp.ndarray, stream: jnp.ndarray,
                   base: jnp.ndarray, shape) -> jnp.ndarray:
    """Standard-normal f32 tensor of ``shape`` from (seed, stream, base).

    ``seed`` is the run/step seed (uint32 scalar), ``stream`` a per-layer
    / per-tile stream id, ``base`` the flat index of this tensor's first
    element within the stream (lets a tile of a larger tensor generate
    exactly its slice of the global noise field). All uint32 scalars.
    """
    n = 1
    for d in shape:
        n *= int(d)
    idx = jnp.arange(n, dtype=_U32) + jnp.asarray(base, _U32)
    z0, _ = normal_pair(jnp.asarray(seed, _U32), jnp.asarray(stream, _U32),
                        idx, jnp.zeros_like(idx))
    return z0.reshape(shape)
