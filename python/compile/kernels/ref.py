"""Pure-jnp oracles for the L1 kernels.

Every Pallas kernel in this package has an exact reference here, written
with no Pallas constructs, using the same Threefry stream derivation.
``python/tests`` asserts allclose between kernel and oracle across a
hypothesis sweep of shapes, seeds and sigmas; agreement must be
bit-level for the noise field (same counters -> same bits) and
float-associativity-level for reductions.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import prng


def ref_error_inject(w: jnp.ndarray, seed, stream, sigma) -> jnp.ndarray:
    """Oracle for ``error_inject``: w * (1 + sigma * eps).

    eps is indexed by the element's flat position in the (rows, cols)
    view used by the kernel (trailing dim = cols), which equals the flat
    position in ``w`` itself — row-major reshape preserves order.
    """
    w = jnp.asarray(w, jnp.float32)
    noise = prng.counter_normal(
        jnp.asarray(seed, jnp.uint32), jnp.asarray(stream, jnp.uint32),
        jnp.uint32(0), (w.size,)).reshape(w.shape)
    return w * (jnp.float32(1.0) + jnp.float32(sigma) * noise)


def ref_approx_matmul(x: jnp.ndarray, w: jnp.ndarray, seed, stream, sigma,
                      *, k_total=None, n_total=None) -> jnp.ndarray:
    """Oracle for ``approx_matmul``: per-product perturbed x @ w.

    The noise field is keyed by the global (row, k, col) product
    coordinate over the *padded* operand shapes the kernel saw; pass
    ``k_total``/``n_total`` to match a padded kernel invocation, else
    the unpadded dims are used (correct whenever no padding occurred).
    """
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    m, k = x.shape
    _, n = w.shape
    kt = k if k_total is None else int(k_total)
    nt = n if n_total is None else int(n_total)
    row = jnp.arange(m, dtype=jnp.uint32)[:, None, None]
    red = jnp.arange(k, dtype=jnp.uint32)[None, :, None]
    col = jnp.arange(n, dtype=jnp.uint32)[None, None, :]
    flat = (row * jnp.uint32(kt) + red) * jnp.uint32(nt) + col
    flat = jnp.broadcast_to(flat, (m, k, n))
    z, _ = prng.normal_pair(jnp.asarray(seed, jnp.uint32),
                            jnp.asarray(stream, jnp.uint32),
                            flat, jnp.zeros_like(flat))
    prod = x[:, :, None] * w[None, :, :]
    prod = prod * (jnp.float32(1.0) + jnp.float32(sigma) * z)
    return jnp.sum(prod, axis=1)


def ref_exact_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Exact-multiplier baseline (sigma = 0 limit of both kernels)."""
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(w, jnp.float32)
