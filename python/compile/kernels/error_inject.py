"""L1 Pallas kernel: weight-level approximate-multiplier error injection.

This is the *paper-faithful* simulation mode (ROBIO'19 §II-III): every
conv / dense layer's weight tensor is multiplied elementwise by an error
matrix ``(1 + eps)`` with ``eps ~ N(0, sigma)`` before it is used, in
both forward and backward passes. ``MRE = sigma * sqrt(2/pi)`` for the
zero-mean Gaussian model (every (MRE, SD) pair in the paper's Table II
satisfies this identity).

The noise is generated *inside* the kernel from a Threefry counter
stream keyed by ``(seed, layer_stream)``, so:

* the rust coordinator replays any step bit-exactly from (seed, stream);
* "fixed error matrix per run" (the paper's Figure-3 procedure) vs
  "resampled every step" (our ablation) is purely a question of what
  seed L3 feeds the graph — one artifact serves both;
* ``sigma = 0`` degenerates to an exact multiplier (the noise is still
  generated but multiplies by exactly 1.0; the dedicated exact artifact
  omits this kernel entirely).

TPU mapping (DESIGN.md §4): the weight tensor is streamed HBM->VMEM in
``block`` rows; noise is generated on-chip (8 u32 ALU ops/element), so
the kernel adds zero HBM traffic over the plain weight load.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import prng

# Rows per grid step. Weights are viewed as (rows, cols) with cols the
# trailing dim; 256 rows of a 512-wide f32 tensor = 512 KiB VMEM-resident
# block + same-shape noise scratch, comfortably inside the ~16 MiB VMEM
# budget with double buffering.
_DEFAULT_BLOCK_ROWS = 256


def _error_inject_kernel(w_ref, seed_ref, stream_ref, sigma_ref, o_ref,
                         *, cols: int):
    """o = w * (1 + sigma * N(0,1)); noise indexed by global element id."""
    w = w_ref[...]
    rows = w.shape[0]
    # Global flat index of this block's first element: grid step * block
    # elements. Noise must depend on the *global* index so the same
    # (seed, stream) reproduces the same error matrix regardless of the
    # block decomposition chosen at compile time.
    blk = pl.program_id(0)
    base = (blk * rows * cols).astype(jnp.uint32)
    noise = prng.counter_normal(
        seed_ref[0], stream_ref[0], base, (rows, cols))
    sigma = sigma_ref[0]
    o_ref[...] = w * (np.float32(1.0) + sigma * noise)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def error_inject(w: jnp.ndarray, seed: jnp.ndarray, stream: jnp.ndarray,
                 sigma: jnp.ndarray, *, block_rows: int = _DEFAULT_BLOCK_ROWS,
                 interpret: bool = True) -> jnp.ndarray:
    """Apply weight-level approximate-multiplier error to ``w``.

    Args:
      w: weight tensor, any shape, f32.
      seed: uint32 scalar — run seed (fixed mode) or step seed (resample).
      stream: uint32 scalar — unique per layer ("each network layer had a
        unique error matrix", §II).
      sigma: f32 scalar — Gaussian SD of the relative error. The paper's
        MRE relates as ``MRE = sigma * sqrt(2/pi)``.
      block_rows: grid block height (static).
      interpret: Pallas interpret mode (must stay True on CPU PJRT).

    Returns:
      ``w * (1 + sigma * eps)``, same shape/dtype as ``w``.
    """
    orig_shape = w.shape
    cols = orig_shape[-1] if len(orig_shape) >= 1 else 1
    flat = w.reshape((-1, cols)).astype(jnp.float32)
    rows = flat.shape[0]
    br = min(block_rows, rows)
    # Pad rows to a multiple of the block so the grid is exact.
    pad = (-rows) % br
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    padded_rows = flat.shape[0]

    seed = jnp.asarray(seed, jnp.uint32).reshape((1,))
    stream = jnp.asarray(stream, jnp.uint32).reshape((1,))
    sigma = jnp.asarray(sigma, jnp.float32).reshape((1,))

    out = pl.pallas_call(
        functools.partial(_error_inject_kernel, cols=cols),
        grid=(padded_rows // br,),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_rows, cols), jnp.float32),
        interpret=interpret,
    )(flat, seed, stream, sigma)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
