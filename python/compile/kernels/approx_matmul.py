"""L1 Pallas kernel: product-level approximate matmul.

Ablation of what real approximate-multiplier hardware does, vs the
paper's weight-level simulation shortcut: here **every scalar product**
``x[i,k] * w[k,j]`` inside the matmul is independently perturbed,

    acc[i,j] = sum_k x[i,k] * w[k,j] * (1 + sigma * eps[i,k,j])

with ``eps ~ N(0,1)`` from a Threefry counter stream. Summing K
independently-perturbed products concentrates the *relative* error of
the accumulated dot product by ~1/sqrt(K) when partial products have
similar magnitude — exactly the effect the weight-level model misses
(there the error is rank-1-correlated across the reduction). The
``benches/ablations.rs`` harness quantifies the gap.

Tiling: grid (M/bm, N/bn, K/bk) with a VMEM accumulator; on TPU the
(bm, bk) x (bk, bn) tile product targets the MXU and the eps tile is
generated on-chip (no HBM traffic). Interpret mode lowers the same
schedule to plain HLO for CPU PJRT.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import prng

_DEFAULT_BM = 32
_DEFAULT_BN = 32
_DEFAULT_BK = 32


def _approx_matmul_kernel(x_ref, w_ref, seed_ref, stream_ref, sigma_ref,
                          o_ref, *, n_total: int, k_total: int, bk: int):
    """One (bm, bn) output tile; grid dim 2 walks the K reduction.

    The output BlockSpec index map ignores ``k``, so ``o_ref`` revisits
    the same VMEM tile across the reduction — it doubles as the
    accumulator (standard Pallas reduction pattern, no scratch needed).
    """
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]          # (bm, bk)
    w = w_ref[...]          # (bk, bn)
    bm, bn = o_ref.shape

    # Per-product noise eps[ii, kk, jj] keyed by the *global* product
    # coordinate so the error field is independent of tile shape.
    # Global flat id = ((i*bm+ii) * k_total + (k*bk+kk)) * n_total + (j*bn+jj).
    ii = jax.lax.broadcasted_iota(jnp.uint32, (bm, bk, bn), 0)
    kk = jax.lax.broadcasted_iota(jnp.uint32, (bm, bk, bn), 1)
    jj = jax.lax.broadcasted_iota(jnp.uint32, (bm, bk, bn), 2)
    row = ii + jnp.uint32(i) * jnp.uint32(bm)
    red = kk + jnp.uint32(k) * jnp.uint32(bk)
    col = jj + jnp.uint32(j) * jnp.uint32(bn)
    flat = (row * jnp.uint32(k_total) + red) * jnp.uint32(n_total) + col
    z, _ = prng.normal_pair(seed_ref[0], stream_ref[0],
                            flat, jnp.zeros_like(flat))
    sigma = sigma_ref[0]

    # Perturbed partial products, reduced over the K tile.
    prod = x[:, :, None] * w[None, :, :]
    prod = prod * (np.float32(1.0) + sigma * z)
    o_ref[...] += jnp.sum(prod, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def approx_matmul(x: jnp.ndarray, w: jnp.ndarray, seed, stream, sigma, *,
                  bm: int = _DEFAULT_BM, bn: int = _DEFAULT_BN,
                  bk: int = _DEFAULT_BK, interpret: bool = True):
    """Product-level approximate ``x @ w``.

    Args:
      x: (M, K) f32.  w: (K, N) f32.
      seed, stream: uint32 scalars — Threefry key (run seed, layer id).
      sigma: f32 scalar relative-error SD (``MRE = sigma*sqrt(2/pi)``).
      bm, bn, bk: tile sizes (static). Shapes are zero-padded up to tile
        multiples; zero padding contributes zero products so the result
        is unaffected (property-tested).
      interpret: keep True on CPU PJRT.

    Returns:
      (M, N) f32, the approximately-multiplied product.
    """
    m, k_total = x.shape
    k2, n_total = w.shape
    assert k_total == k2, (x.shape, w.shape)
    bm_ = min(bm, m)
    bn_ = min(bn, n_total)
    bk_ = min(bk, k_total)
    pm, pn, pk = (-m) % bm_, (-n_total) % bn_, (-k_total) % bk_
    xp = jnp.pad(x.astype(jnp.float32), ((0, pm), (0, pk)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, pk), (0, pn)))
    mm, kk_ = xp.shape
    _, nn = wp.shape

    seed = jnp.asarray(seed, jnp.uint32).reshape((1,))
    stream = jnp.asarray(stream, jnp.uint32).reshape((1,))
    sigma = jnp.asarray(sigma, jnp.float32).reshape((1,))

    out = pl.pallas_call(
        functools.partial(_approx_matmul_kernel, n_total=nn, k_total=kk_,
                          bk=bk_),
        grid=(mm // bm_, nn // bn_, kk_ // bk_),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.float32),
        interpret=interpret,
    )(xp, wp, seed, stream, sigma)
    return out[:m, :n_total]
