"""The paper's Gaussian approximate-multiplier error model, shared constants.

The ROBIO'19 paper characterizes an approximate multiplier by its Mean
Relative Error (MRE) and the standard deviation (SD) of the relative
error, modelled as near-zero-mean Gaussian. For eps ~ N(0, sigma), the
mean of |eps| is ``sigma * sqrt(2/pi)`` (half-normal mean), so

    MRE = SD * sqrt(2/pi)  ≈  SD * 0.7979.

Every (MRE, SD) pair in the paper's Tables II/III satisfies this within
rounding (1.2/1.5, 1.4/1.8, 2.4/3.0, 3.6/4.5, 4.8/6.0, 9.6/12, 19.2/24,
38.2/48), confirming SD is the Gaussian sigma and MRE is derived. The
library therefore treats **sigma as the canonical knob** and derives MRE
for reporting. The same constants live in ``rust/src/error_model`` and
are cross-checked by tests on both sides.
"""

from __future__ import annotations

import math

# E[|N(0,1)|] — converts Gaussian sigma to MRE and back.
HALF_NORMAL_MEAN = math.sqrt(2.0 / math.pi)


def sigma_to_mre(sigma: float) -> float:
    """MRE of a zero-mean Gaussian relative error with SD ``sigma``."""
    return sigma * HALF_NORMAL_MEAN


def mre_to_sigma(mre: float) -> float:
    """Gaussian sigma whose half-normal mean equals ``mre``."""
    return mre / HALF_NORMAL_MEAN


# Table II test cases: (test_id, mre, sd, paper_accuracy_pct).
# mre/sd are fractions (0.012 == "~1.2%"). Case 0 is the exact baseline.
PAPER_TABLE2 = (
    (0, 0.000, 0.000, 93.60),
    (1, 0.012, 0.015, 93.59),
    (2, 0.014, 0.018, 93.53),
    (3, 0.024, 0.030, 93.35),
    (4, 0.036, 0.045, 93.23),
    (5, 0.048, 0.060, 93.11),
    (6, 0.096, 0.120, 93.00),
    (7, 0.192, 0.240, 92.23),
    (8, 0.382, 0.480, 65.65),
)

# Table III: (test_id, mre, approx_epochs, exact_epochs) of 200 total.
PAPER_TABLE3 = (
    (1, 0.012, 200, 0),
    (2, 0.014, 191, 9),
    (3, 0.024, 180, 20),
    (4, 0.036, 176, 24),
    (5, 0.048, 173, 27),
    (6, 0.096, 151, 49),
)

# Cited hardware numbers used by the cost model (DRUM [3] etc.):
# name -> (speed_gain, area_saving, power_saving, mre, sd), fractions.
PAPER_HW_DESIGNS = {
    "drum6": (0.47, 0.50, 0.59, 0.0147, 0.01803),
}

# Share of CNN compute spent in convolution (Cong & Xiao [12], §III).
CONV_TIME_SHARE = 0.907
