"""L2: VGG-style CNN forward/backward with approximate-multiplier error.

Reproduces the ROBIO'19 training setup (modified VGGNet of Liu & Deng
[8] for CIFAR-10: conv-BN-ReLU blocks + maxpool + dropout + 2 dense
layers, SGD with momentum / lr decay / L2 weight decay) as a purely
functional JAX program that is AOT-lowered to HLO by ``aot.py`` and then
driven exclusively from the Rust coordinator.

Error injection (the paper's contribution) is a first-class input of
the lowered graph: ``sigma`` (Gaussian SD of the relative multiplier
error) and ``seed_err`` are runtime scalars, so the Rust hybrid
controller flips approximate <-> exact multipliers at any epoch without
recompiling, and chooses fixed-per-run vs resampled-per-step error
matrices purely by what seed it feeds each step.

Three injection backends (``ModelConfig.inject``):

* ``pallas_weight``  — the paper-faithful mode: every conv/dense weight
  tensor is perturbed ``W*(1+sigma*eps)`` by the L1 Pallas kernel
  (``kernels/error_inject.py``) before use; backprop sees the same
  error matrix via a custom VJP (matches the Keras custom-layer setup).
* ``jnp_weight``     — bit-identical pure-jnp path (same Threefry
  counters); used to isolate Pallas overhead in ablations.
* ``pallas_product`` — per-scalar-product error inside a Pallas tiled
  matmul (``kernels/approx_matmul.py``); conv is lowered to im2col so
  every MAC goes through the approximate multiplier. This is what real
  hardware does and is our ablation of the paper's simulation shortcut.

Parameters / optimizer / BN state are flat lists of arrays with a
manifest-recorded order, because the Rust runtime marshals them as
positional PJRT literals.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import approx_matmul as am
from .kernels import error_inject as ei
from .kernels import prng
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Configuration


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture + training hyperparameters for one preset."""

    name: str
    input_hw: int                      # square input edge (CIFAR: 32)
    in_ch: int                         # input channels (RGB: 3)
    blocks: tuple                      # tuple of tuples of conv widths
    dense: tuple                       # hidden dense widths
    num_classes: int
    batch: int
    eval_batch: int
    dropout_conv: float = 0.3          # after every maxpool (paper: 30-50%)
    dropout_dense: float = 0.5         # before the classifier
    bn_momentum: float = 0.9
    bn_eps: float = 1e-5
    weight_decay: float = 5e-4         # paper Table I: L2 0.0005
    sgd_momentum: float = 0.9
    inject: str = "pallas_weight"      # see module docstring

    @property
    def conv_layers(self):
        """Flat (block, width) list of conv layers in forward order."""
        out = []
        for b, widths in enumerate(self.blocks):
            for w in widths:
                out.append((b, int(w)))
        return out


# Presets. ``tiny`` is the pytest/bench workhorse, ``small`` the e2e
# training preset, ``vgg16`` the paper's full architecture (lowered for
# artifact/MAC accounting; too large to train on CPU PJRT — DESIGN.md §5).
PRESETS = {
    "tiny": ModelConfig(
        name="tiny", input_hw=8, in_ch=3,
        blocks=((8,), (16,)), dense=(32,), num_classes=10,
        batch=16, eval_batch=64, dropout_conv=0.0, dropout_dense=0.0),
    "tiny_product": ModelConfig(
        name="tiny_product", input_hw=8, in_ch=3,
        blocks=((8,), (16,)), dense=(32,), num_classes=10,
        batch=16, eval_batch=64, dropout_conv=0.0, dropout_dense=0.0,
        inject="pallas_product"),
    "small": ModelConfig(
        name="small", input_hw=32, in_ch=3,
        blocks=((32, 32), (64, 64), (128, 128)), dense=(128,),
        num_classes=10, batch=64, eval_batch=256),
    "vgg16": ModelConfig(
        name="vgg16", input_hw=32, in_ch=3,
        blocks=((64, 64), (128, 128), (256, 256, 256),
                (512, 512, 512), (512, 512, 512)),
        dense=(512,), num_classes=10, batch=128, eval_batch=256),
}


# ---------------------------------------------------------------------------
# Parameter layout


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple
    init: str          # "he" | "zeros" | "ones"
    kind: str          # "conv_w" | "dense_w" | "bias" | "bn_gamma" | "bn_beta"
    layer: int         # error-stream id for weight tensors, -1 otherwise


def param_specs(cfg: ModelConfig):
    """Forward-order flat parameter layout (the manifest contract)."""
    specs = []
    ch = cfg.in_ch
    layer = 0
    for bi, widths in enumerate(cfg.blocks):
        for ci, w in enumerate(widths):
            p = f"conv{bi}_{ci}"
            specs.append(ParamSpec(f"{p}.w", (3, 3, ch, w), "he", "conv_w", layer))
            specs.append(ParamSpec(f"{p}.b", (w,), "zeros", "bias", -1))
            specs.append(ParamSpec(f"{p}.bn_gamma", (w,), "ones", "bn_gamma", -1))
            specs.append(ParamSpec(f"{p}.bn_beta", (w,), "zeros", "bn_beta", -1))
            ch = w
            layer += 1
    hw = cfg.input_hw // (2 ** len(cfg.blocks))
    feat = ch * hw * hw
    for di, w in enumerate(cfg.dense):
        p = f"dense{di}"
        specs.append(ParamSpec(f"{p}.w", (feat, w), "he", "dense_w", layer))
        specs.append(ParamSpec(f"{p}.b", (w,), "zeros", "bias", -1))
        specs.append(ParamSpec(f"{p}.bn_gamma", (w,), "ones", "bn_gamma", -1))
        specs.append(ParamSpec(f"{p}.bn_beta", (w,), "zeros", "bn_beta", -1))
        feat = w
        layer += 1
    specs.append(ParamSpec("classifier.w", (feat, cfg.num_classes), "he",
                           "dense_w", layer))
    specs.append(ParamSpec("classifier.b", (cfg.num_classes,), "zeros",
                           "bias", -1))
    return specs


def state_specs(cfg: ModelConfig):
    """BN running statistics, forward order: (name, shape, init)."""
    specs = []
    for bi, widths in enumerate(cfg.blocks):
        for ci, w in enumerate(widths):
            specs.append((f"conv{bi}_{ci}.bn_mean", (w,), "zeros"))
            specs.append((f"conv{bi}_{ci}.bn_var", (w,), "ones"))
    for di, w in enumerate(cfg.dense):
        specs.append((f"dense{di}.bn_mean", (w,), "zeros"))
        specs.append((f"dense{di}.bn_var", (w,), "ones"))
    return specs


def init_params(cfg: ModelConfig, seed) -> list:
    """He-normal init from the Threefry stream (reproducible from u32)."""
    out = []
    for i, s in enumerate(param_specs(cfg)):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, jnp.float32))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, jnp.float32))
        else:
            fan_in = int(np.prod(s.shape[:-1])) if len(s.shape) > 1 else s.shape[0]
            std = np.float32(np.sqrt(2.0 / fan_in))
            # stream 2000+i keeps init streams disjoint from error (0..L),
            # backprop (500+) and dropout (1000+) streams.
            z = prng.counter_normal(jnp.asarray(seed, jnp.uint32),
                                    jnp.uint32(2000 + i), jnp.uint32(0),
                                    s.shape)
            out.append(z * std)
    return out


def init_state(cfg: ModelConfig) -> list:
    return [jnp.zeros(sh, jnp.float32) if init == "zeros"
            else jnp.ones(sh, jnp.float32)
            for (_, sh, init) in state_specs(cfg)]


def init_opt(cfg: ModelConfig) -> list:
    return [jnp.zeros(s.shape, jnp.float32) for s in param_specs(cfg)]


# ---------------------------------------------------------------------------
# Error injection (custom VJPs so backprop multiplications err too)

_BWD_STREAM_OFFSET = 500    # product-mode backward matmul streams
_DROP_STREAM_OFFSET = 1000  # dropout streams
_INIT_STREAM_OFFSET = 2000  # init streams (see init_params)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _inject_weight(w, seed, stream, sigma, use_pallas):
    """W * (1 + sigma*eps): same eps in forward and gradient (paper §II)."""
    if use_pallas:
        return ei.error_inject(w, seed, stream, sigma)
    return kref.ref_error_inject(w, seed, stream, sigma)


def _inject_weight_fwd(w, seed, stream, sigma, use_pallas):
    out = _inject_weight(w, seed, stream, sigma, use_pallas)
    return out, (w, seed, stream, sigma)


def _inject_weight_bwd(use_pallas, res, g):
    w, seed, stream, sigma = res
    # d/dW [W*(1+e)] = (1+e) ⊙ g: regenerate the same error matrix. The
    # error therefore perturbs the weight-gradient exactly as the Keras
    # custom layer did ("during both backpropagation and forward
    # propagation").
    scaled = _inject_weight(g, seed, stream, sigma, use_pallas)
    return (scaled, None, None, None)


_inject_weight.defvjp(_inject_weight_fwd, _inject_weight_bwd)


@jax.custom_vjp
def _approx_mm(x, w, seed, stream, sigma):
    """Product-level approximate x @ w with approximate backward matmuls."""
    return am.approx_matmul(x, w, seed, stream, sigma)


def _approx_mm_fwd(x, w, seed, stream, sigma):
    return _approx_mm(x, w, seed, stream, sigma), (x, w, seed, stream, sigma)


def _approx_mm_bwd(res, g):
    x, w, seed, stream, sigma = res
    bstream = stream + jnp.uint32(_BWD_STREAM_OFFSET)
    # Backward matmuls run on the same approximate hardware, with their
    # own product-error fields (distinct streams per operand).
    dx = am.approx_matmul(g, w.T, seed, bstream, sigma)
    dw = am.approx_matmul(x.T, g, seed, bstream + jnp.uint32(1), sigma)
    return (dx, dw, None, None, None)


_approx_mm.defvjp(_approx_mm_fwd, _approx_mm_bwd)


# ---------------------------------------------------------------------------
# Layers


def _batchnorm_train(x, gamma, beta, mean_run, var_run, momentum, eps, axes):
    m = jnp.mean(x, axis=axes)
    v = jnp.var(x, axis=axes)
    xn = (x - m) / jnp.sqrt(v + np.float32(eps))
    new_mean = momentum * mean_run + (1.0 - momentum) * m
    new_var = momentum * var_run + (1.0 - momentum) * v
    return gamma * xn + beta, new_mean, new_var


def _batchnorm_eval(x, gamma, beta, mean_run, var_run, eps):
    xn = (x - mean_run) / jnp.sqrt(var_run + np.float32(eps))
    return gamma * xn + beta


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def _dropout(x, rate, seed, stream):
    if rate <= 0.0:
        return x
    keep = 1.0 - rate
    u_bits, _ = prng.threefry2x32(
        jnp.asarray(seed, jnp.uint32), jnp.uint32(stream),
        jax.lax.broadcasted_iota(jnp.uint32, (x.size,), 0),
        jnp.zeros((x.size,), jnp.uint32))
    u = prng.uniform_from_bits(u_bits).reshape(x.shape)
    mask = (u < np.float32(keep)).astype(jnp.float32)
    return x * mask / np.float32(keep)


def _im2col(x, kh=3, kw=3):
    """NHWC -> (N*H*W, kh*kw*C) SAME-padded patch matrix (stride 1)."""
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(xp[:, dy:dy + h, dx:dx + w, :])
    patches = jnp.concatenate(cols, axis=-1)        # (N,H,W,kh*kw*C)
    return patches.reshape(n * h * w, kh * kw * c)


def _conv(x, w, b, cfg: ModelConfig, seed_err, stream, sigma):
    """3x3 SAME conv through the configured approximate-multiplier path."""
    if cfg.inject == "pallas_product":
        n, h, ww, c = x.shape
        kh, kw, cin, cout = w.shape
        patches = _im2col(x, kh, kw)                # (N*H*W, 9C)
        wmat = w.reshape(kh * kw * cin, cout)
        out = _approx_mm(patches, wmat, seed_err, jnp.uint32(stream), sigma)
        out = out.reshape(n, h, ww, cout)
    else:
        wq = _inject_weight(w, seed_err, jnp.uint32(stream), sigma,
                            cfg.inject == "pallas_weight")
        out = jax.lax.conv_general_dilated(
            x, wq, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def _dense_layer(x, w, b, cfg: ModelConfig, seed_err, stream, sigma):
    if cfg.inject == "pallas_product":
        out = _approx_mm(x, w, seed_err, jnp.uint32(stream), sigma)
    else:
        wq = _inject_weight(w, seed_err, jnp.uint32(stream), sigma,
                            cfg.inject == "pallas_weight")
        out = x @ wq
    return out + b


# ---------------------------------------------------------------------------
# Forward pass


def forward(cfg: ModelConfig, params: Sequence, state: Sequence, x,
            *, train: bool, seed_err, seed_drop, sigma):
    """Logits + updated BN state.

    ``sigma`` f32 scalar: 0 => exact multipliers. ``seed_err`` u32: keep
    constant across steps for the paper's fixed-error-matrix procedure,
    or feed the step index for the resampling ablation.
    """
    p = iter(range(len(params)))
    s = iter(range(len(state)))
    new_state = list(state)
    mom = np.float32(cfg.bn_momentum)

    def next_p(k):
        return [params[next(p)] for _ in range(k)]

    layer = 0
    h = x
    for bi, widths in enumerate(cfg.blocks):
        for _ci, _w in enumerate(widths):
            w, b, gamma, beta = next_p(4)
            h = _conv(h, w, b, cfg, seed_err, layer, sigma)
            im, iv = next(s), next(s)
            if train:
                h, nm, nv = _batchnorm_train(
                    h, gamma, beta, state[im], state[iv], mom, cfg.bn_eps,
                    axes=(0, 1, 2))
                new_state[im], new_state[iv] = nm, nv
            else:
                h = _batchnorm_eval(h, gamma, beta, state[im], state[iv],
                                    cfg.bn_eps)
            h = jax.nn.relu(h)
            layer += 1
        h = _maxpool2(h)
        if train:
            h = _dropout(h, cfg.dropout_conv, seed_drop,
                         _DROP_STREAM_OFFSET + bi)
    h = h.reshape(h.shape[0], -1)
    for _di, _w in enumerate(cfg.dense):
        w, b, gamma, beta = next_p(4)
        h = _dense_layer(h, w, b, cfg, seed_err, layer, sigma)
        im, iv = next(s), next(s)
        if train:
            h, nm, nv = _batchnorm_train(
                h, gamma, beta, state[im], state[iv], mom, cfg.bn_eps,
                axes=(0,))
            new_state[im], new_state[iv] = nm, nv
        else:
            h = _batchnorm_eval(h, gamma, beta, state[im], state[iv],
                                cfg.bn_eps)
        h = jax.nn.relu(h)
        layer += 1
    if train:
        h = _dropout(h, cfg.dropout_dense, seed_drop,
                     _DROP_STREAM_OFFSET + 99)
    w, b = next_p(2)
    logits = _dense_layer(h, w, b, cfg, seed_err, layer, sigma)
    return logits, new_state


# ---------------------------------------------------------------------------
# Loss / steps


def _loss_from_logits(cfg, params, logits, y):
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(y, cfg.num_classes, dtype=jnp.float32)
    ce = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    # L2 on conv/dense weights only (Keras kernel_regularizer semantics).
    wd = np.float32(cfg.weight_decay)
    l2 = sum(jnp.sum(params[i] ** 2)
             for i, s in enumerate(param_specs(cfg))
             if s.kind in ("conv_w", "dense_w"))
    return ce + wd * l2, ce


def train_step(cfg: ModelConfig, params, state, opt, x, y,
               seed_err, seed_drop, sigma, lr):
    """One SGD-with-momentum step under simulated approximate multipliers.

    Returns (params', state', opt', loss, accuracy). Lowered once by
    aot.py; every epoch-level decision (lr schedule, hybrid multiplier
    switch, error resampling) lives in the Rust coordinator, which just
    varies the scalar inputs.
    """
    def loss_fn(ps):
        logits, new_state = forward(
            cfg, ps, state, x, train=True,
            seed_err=seed_err, seed_drop=seed_drop, sigma=sigma)
        total, ce = _loss_from_logits(cfg, ps, logits, y)
        acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return total, (new_state, ce, acc)

    grads, (new_state, ce, acc) = jax.grad(
        loss_fn, has_aux=True)(list(params))
    mom = np.float32(cfg.sgd_momentum)
    new_opt = [mom * v + g for v, g in zip(opt, grads)]
    new_params = [p - lr * v for p, v in zip(params, new_opt)]
    return new_params, new_state, new_opt, ce, acc


def eval_step(cfg: ModelConfig, params, state, x, y):
    """Exact-multiplier inference (paper removes error layers for test)."""
    logits, _ = forward(cfg, params, state, x, train=False,
                        seed_err=jnp.uint32(0), seed_drop=jnp.uint32(0),
                        sigma=jnp.float32(0.0))
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(y, cfg.num_classes, dtype=jnp.float32)
    loss_sum = -jnp.sum(onehot * logp)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
    return loss_sum, correct


# ---------------------------------------------------------------------------
# MAC accounting (consumed by the Rust cost model via the manifest)


def layer_table(cfg: ModelConfig):
    """Per-layer output shapes / params / MACs (Figure-1 reproduction)."""
    rows = []
    hw = cfg.input_hw
    ch = cfg.in_ch
    for bi, widths in enumerate(cfg.blocks):
        for ci, w in enumerate(widths):
            macs = hw * hw * 3 * 3 * ch * w
            nparams = 3 * 3 * ch * w + 3 * w
            rows.append({"name": f"conv{bi}_{ci}", "type": "conv3x3",
                         "out": [hw, hw, w], "params": nparams,
                         "macs": macs})
            ch = w
        hw //= 2
        rows.append({"name": f"pool{bi}", "type": "maxpool2",
                     "out": [hw, hw, ch], "params": 0, "macs": 0})
    feat = ch * hw * hw
    for di, w in enumerate(cfg.dense):
        rows.append({"name": f"dense{di}", "type": "dense",
                     "out": [w], "params": feat * w + 3 * w,
                     "macs": feat * w})
        feat = w
    rows.append({"name": "classifier", "type": "dense",
                 "out": [cfg.num_classes],
                 "params": feat * cfg.num_classes + cfg.num_classes,
                 "macs": feat * cfg.num_classes})
    return rows
