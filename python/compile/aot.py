"""AOT lowering: JAX train/eval/init graphs -> HLO text + manifest.json.

This is the single point where Python runs in the system's lifecycle
(``make artifacts``). Each entry point is jitted, lowered to StableHLO,
converted to an XlaComputation and dumped as **HLO text** — not a
serialized ``HloModuleProto``: jax >= 0.5 emits 64-bit instruction ids
that the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

``manifest.json`` records, for every artifact, the positional
input/output tensor specs (name, shape, dtype) plus the model's
parameter/state layout and per-layer MAC table. The Rust runtime
(rust/src/runtime/manifest.rs) treats this file as the ABI contract
with the compiled graphs; nothing else crosses the language boundary.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .error_model import (CONV_TIME_SHARE, PAPER_HW_DESIGNS, PAPER_TABLE2,
                          PAPER_TABLE3, sigma_to_mre)

# Presets lowered by default. vgg16 lowers too (same code path) but its
# HLO is ~100 MB of text and CPU PJRT cannot train it in reasonable
# time; enable with --full for artifact-completeness runs.
DEFAULT_PRESETS = ("tiny", "tiny_product", "small")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(name, arr_like):
    shape = tuple(int(d) for d in arr_like.shape)
    return {"name": name, "shape": list(shape),
            "dtype": str(arr_like.dtype)}


def _scalar(name, dtype):
    return {"name": name, "shape": [], "dtype": dtype}


def lower_preset(cfg: M.ModelConfig, outdir: str):
    """Lower train/eval/init for one preset; return manifest entries."""
    pspecs = M.param_specs(cfg)
    sspecs = M.state_specs(cfg)
    params0 = M.init_params(cfg, 0)
    state0 = M.init_state(cfg)
    opt0 = M.init_opt(cfg)
    np_, ns_ = len(params0), len(state0)

    x_spec = jax.ShapeDtypeStruct(
        (cfg.batch, cfg.input_hw, cfg.input_hw, cfg.in_ch), jnp.float32)
    y_spec = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    ex_spec = jax.ShapeDtypeStruct(
        (cfg.eval_batch, cfg.input_hw, cfg.input_hw, cfg.in_ch), jnp.float32)
    ey_spec = jax.ShapeDtypeStruct((cfg.eval_batch,), jnp.int32)
    u32 = jax.ShapeDtypeStruct((), jnp.uint32)
    f32 = jax.ShapeDtypeStruct((), jnp.float32)

    def train_flat(*args):
        params = list(args[:np_])
        state = list(args[np_:np_ + ns_])
        opt = list(args[np_ + ns_:2 * np_ + ns_])
        x, y, seed_err, seed_drop, sigma, lr = args[2 * np_ + ns_:]
        new_p, new_s, new_o, loss, acc = M.train_step(
            cfg, params, state, opt, x, y, seed_err, seed_drop, sigma, lr)
        return tuple(new_p) + tuple(new_s) + tuple(new_o) + (loss, acc)

    def eval_flat(*args):
        params = list(args[:np_])
        state = list(args[np_:np_ + ns_])
        x, y = args[np_ + ns_:]
        loss_sum, correct = M.eval_step(cfg, params, state, x, y)
        return (loss_sum, correct)

    def init_flat(seed):
        p = M.init_params(cfg, seed)
        s = M.init_state(cfg)
        o = M.init_opt(cfg)
        return tuple(p) + tuple(s) + tuple(o)

    param_shapes = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params0]
    state_shapes = [jax.ShapeDtypeStruct(s.shape, s.dtype) for s in state0]
    opt_shapes = [jax.ShapeDtypeStruct(o.shape, o.dtype) for o in opt0]

    entries = {}
    jobs = [
        ("train", train_flat,
         param_shapes + state_shapes + opt_shapes
         + [x_spec, y_spec, u32, u32, f32, f32]),
        ("eval", eval_flat,
         param_shapes + state_shapes + [ex_spec, ey_spec]),
        ("init", init_flat, [u32]),
    ]
    for kind, fn, in_shapes in jobs:
        lowered = jax.jit(fn, keep_unused=True).lower(*in_shapes)
        text = to_hlo_text(lowered)
        fname = f"{kind}_{cfg.name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        # Input name lists mirror the positional convention.
        if kind == "train":
            inputs = ([_spec(f"param:{p.name}", a) for p, a in
                       zip(pspecs, params0)]
                      + [_spec(f"state:{n}", a) for (n, _, _), a in
                         zip(sspecs, state0)]
                      + [_spec(f"opt:{p.name}", a) for p, a in
                         zip(pspecs, opt0)]
                      + [_spec("x", x_spec), _spec("y", y_spec),
                         _scalar("seed_err", "uint32"),
                         _scalar("seed_drop", "uint32"),
                         _scalar("sigma", "float32"),
                         _scalar("lr", "float32")])
            outputs = ([_spec(f"param:{p.name}", a) for p, a in
                        zip(pspecs, params0)]
                       + [_spec(f"state:{n}", a) for (n, _, _), a in
                          zip(sspecs, state0)]
                       + [_spec(f"opt:{p.name}", a) for p, a in
                          zip(pspecs, opt0)]
                       + [_scalar("loss", "float32"),
                          _scalar("acc", "float32")])
        elif kind == "eval":
            inputs = ([_spec(f"param:{p.name}", a) for p, a in
                       zip(pspecs, params0)]
                      + [_spec(f"state:{n}", a) for (n, _, _), a in
                         zip(sspecs, state0)]
                      + [_spec("x", ex_spec), _spec("y", ey_spec)])
            outputs = [_scalar("loss_sum", "float32"),
                       _scalar("correct", "int32")]
        else:
            inputs = [_scalar("seed", "uint32")]
            outputs = ([_spec(f"param:{p.name}", a) for p, a in
                        zip(pspecs, params0)]
                       + [_spec(f"state:{n}", a) for (n, _, _), a in
                          zip(sspecs, state0)]
                       + [_spec(f"opt:{p.name}", a) for p, a in
                          zip(pspecs, opt0)])
        entries[kind] = {"file": fname, "inputs": inputs,
                         "outputs": outputs,
                         "sha256": hashlib.sha256(
                             text.encode()).hexdigest()}
        print(f"  lowered {fname}: {len(text)} chars, "
              f"{len(inputs)} inputs, {len(outputs)} outputs",
              file=sys.stderr)

    total_params = sum(int(np.prod(p.shape)) for p in pspecs)
    return {
        "preset": cfg.name,
        "inject": cfg.inject,
        "batch": cfg.batch,
        "eval_batch": cfg.eval_batch,
        "input_hw": cfg.input_hw,
        "in_ch": cfg.in_ch,
        "num_classes": cfg.num_classes,
        "weight_decay": cfg.weight_decay,
        "sgd_momentum": cfg.sgd_momentum,
        "total_params": total_params,
        "params": [{"name": p.name, "shape": list(p.shape),
                    "kind": p.kind, "layer": p.layer} for p in pspecs],
        "state": [{"name": n, "shape": list(sh)} for (n, sh, _) in sspecs],
        "layers": M.layer_table(cfg),
        "entries": entries,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--presets", default=",".join(DEFAULT_PRESETS))
    ap.add_argument("--full", action="store_true",
                    help="also lower the vgg16 preset (large HLO)")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    presets = [p for p in args.presets.split(",") if p]
    if args.full and "vgg16" not in presets:
        presets.append("vgg16")

    manifest = {
        "format": 1,
        "paper": {
            "title": "Deep Learning Training with Simulated Approximate "
                     "Multipliers",
            "doi": "10.1109/ROBIO49542.2019.8961780",
            "table2": [list(r) for r in PAPER_TABLE2],
            "table3": [list(r) for r in PAPER_TABLE3],
            "hw_designs": {k: list(v) for k, v in PAPER_HW_DESIGNS.items()},
            "conv_time_share": CONV_TIME_SHARE,
        },
        "models": {},
    }
    for name in presets:
        cfg = M.PRESETS[name]
        print(f"lowering preset {name} (inject={cfg.inject})",
              file=sys.stderr)
        manifest["models"][name] = lower_preset(cfg, args.outdir)

    # vgg16 always contributes its layer table (cost model needs the
    # paper-scale MAC breakdown) even when its HLO is not lowered.
    if "vgg16" not in manifest["models"]:
        cfg = M.PRESETS["vgg16"]
        manifest["models"]["vgg16"] = {
            "preset": "vgg16", "inject": cfg.inject, "batch": cfg.batch,
            "eval_batch": cfg.eval_batch, "input_hw": cfg.input_hw,
            "in_ch": cfg.in_ch, "num_classes": cfg.num_classes,
            "weight_decay": cfg.weight_decay,
            "sgd_momentum": cfg.sgd_momentum,
            "total_params": sum(int(np.prod(p.shape))
                                for p in M.param_specs(cfg)),
            "params": [{"name": p.name, "shape": list(p.shape),
                        "kind": p.kind, "layer": p.layer}
                       for p in M.param_specs(cfg)],
            "state": [{"name": n, "shape": list(sh)}
                      for (n, sh, _) in M.state_specs(cfg)],
            "layers": M.layer_table(cfg),
            "entries": {},
        }

    path = os.path.join(args.outdir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
