"""Pytest bootstrap: make `compile.*` importable regardless of the
directory pytest is invoked from."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
