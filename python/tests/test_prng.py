"""Threefry / Box-Muller correctness: our from-scratch counter RNG must
match JAX's native threefry2x32 bit-for-bit and produce sound normals."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import prng


class TestThreefryKnownAnswer:
    def test_matches_jax_native_threefry(self):
        """Bit-exact vs jax's own threefry2x32 on random keys/counters."""
        from jax._src import prng as jprng
        rs = np.random.RandomState(0)
        for _ in range(10):
            k0, k1 = rs.randint(0, 2**32, 2, dtype=np.uint32)
            n = int(rs.randint(1, 257))
            ctr = rs.randint(0, 2**32, 2 * n, dtype=np.uint32)
            key = jnp.array([k0, k1], dtype=jnp.uint32)
            expect = jprng.threefry_2x32(key, jnp.asarray(ctr))
            x0, x1 = prng.threefry2x32(
                jnp.uint32(k0), jnp.uint32(k1),
                jnp.asarray(ctr[:n]), jnp.asarray(ctr[n:]))
            got = jnp.concatenate([x0, x1])
            assert (got == expect).all(), "threefry mismatch vs jax native"

    def test_zero_key_zero_counter_stable(self):
        """Pinned output: regressions in the round structure must fail."""
        x0, x1 = prng.threefry2x32(jnp.uint32(0), jnp.uint32(0),
                                   jnp.zeros(1, jnp.uint32),
                                   jnp.zeros(1, jnp.uint32))
        from jax._src import prng as jprng
        expect = jprng.threefry_2x32(jnp.zeros(2, jnp.uint32),
                                     jnp.zeros(2, jnp.uint32))
        assert int(x0[0]) == int(expect[0]) and int(x1[0]) == int(expect[1])


class TestUniform:
    def test_open_interval(self):
        bits = jnp.asarray(
            np.array([0, 1, 2**31, 2**32 - 1], dtype=np.uint32))
        u = prng.uniform_from_bits(bits)
        assert (u > 0).all() and (u < 1.0 + 1e-6).all()

    @given(seed=st.integers(0, 2**32 - 1))
    @settings(max_examples=10, deadline=None)
    def test_mean_half(self, seed):
        ctr = jnp.arange(4096, dtype=jnp.uint32)
        b0, _ = prng.threefry2x32(jnp.uint32(seed), jnp.uint32(0),
                                  ctr, jnp.zeros_like(ctr))
        u = prng.uniform_from_bits(b0)
        assert abs(float(u.mean()) - 0.5) < 0.02


class TestNormal:
    def test_moments(self):
        z = prng.counter_normal(jnp.uint32(7), jnp.uint32(1),
                                jnp.uint32(0), (200000,))
        assert abs(float(z.mean())) < 0.01
        assert abs(float(z.std()) - 1.0) < 0.01
        # kurtosis of N(0,1) is 3
        k = float(jnp.mean(z**4)) / float(jnp.var(z)) ** 2
        assert abs(k - 3.0) < 0.1

    def test_half_normal_mean_is_mre_ratio(self):
        """The paper's MRE/SD = sqrt(2/pi) identity (DESIGN.md §1)."""
        z = prng.counter_normal(jnp.uint32(3), jnp.uint32(9),
                                jnp.uint32(0), (200000,))
        ratio = float(jnp.abs(z).mean()) / float(z.std())
        assert abs(ratio - math.sqrt(2 / math.pi)) < 0.01

    @given(seed=st.integers(0, 2**32 - 1),
           stream=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_deterministic(self, seed, stream):
        seed = np.uint32(seed)
        a = prng.counter_normal(jnp.uint32(seed), jnp.uint32(stream),
                                jnp.uint32(0), (64,))
        b = prng.counter_normal(jnp.uint32(seed), jnp.uint32(stream),
                                jnp.uint32(0), (64,))
        assert (a == b).all()

    def test_streams_decorrelated(self):
        a = prng.counter_normal(jnp.uint32(1), jnp.uint32(0),
                                jnp.uint32(0), (50000,))
        b = prng.counter_normal(jnp.uint32(1), jnp.uint32(1),
                                jnp.uint32(0), (50000,))
        corr = float(jnp.corrcoef(a, b)[0, 1])
        assert abs(corr) < 0.02

    def test_base_offset_slices_global_field(self):
        """counter_normal(base=k) == counter_normal(base=0)[k:] — the
        property the Pallas grid decomposition relies on."""
        full = prng.counter_normal(jnp.uint32(5), jnp.uint32(2),
                                   jnp.uint32(0), (128,))
        part = prng.counter_normal(jnp.uint32(5), jnp.uint32(2),
                                   jnp.uint32(32), (96,))
        assert (full[32:] == part).all()
