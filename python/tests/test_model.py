"""L2 model semantics: shapes, training signal, error-mode equivalences,
BN/dropout behaviour, gradient correctness of the custom VJPs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]


def _data(cfg, seed=0, n=None):
    n = n or cfg.batch
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.rand(n, cfg.input_hw, cfg.input_hw, cfg.in_ch),
                    jnp.float32)
    y = jnp.asarray(rs.randint(0, cfg.num_classes, n), jnp.int32)
    return x, y


def _learnable_data(cfg, seed=0, n=None):
    """Class-dependent means: a task the tiny net can actually learn."""
    n = n or cfg.batch
    rs = np.random.RandomState(seed)
    y = rs.randint(0, cfg.num_classes, n)
    base = rs.rand(cfg.num_classes, cfg.input_hw, cfg.input_hw, cfg.in_ch)
    x = base[y] + 0.1 * rs.randn(n, cfg.input_hw, cfg.input_hw, cfg.in_ch)
    return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)


@pytest.fixture(scope="module")
def jitted():
    step = jax.jit(lambda p, s, o, x, y, se, sd, sig, lr:
                   M.train_step(CFG, p, s, o, x, y, se, sd, sig, lr))
    ev = jax.jit(lambda p, s, x, y: M.eval_step(CFG, p, s, x, y))
    return step, ev


class TestLayout:
    def test_param_specs_shapes_match_init(self):
        params = M.init_params(CFG, 0)
        specs = M.param_specs(CFG)
        assert len(params) == len(specs)
        for p, s in zip(params, specs):
            assert tuple(p.shape) == tuple(s.shape), s.name

    def test_state_specs_match_init(self):
        state = M.init_state(CFG)
        specs = M.state_specs(CFG)
        assert len(state) == len(specs)
        for st_, (_, sh, _) in zip(state, specs):
            assert tuple(st_.shape) == tuple(sh)

    def test_unique_error_streams_per_layer(self):
        """Paper §II: each layer has a unique error matrix."""
        layers = [s.layer for s in M.param_specs(CFG) if s.layer >= 0]
        assert len(layers) == len(set(layers))

    def test_init_deterministic_in_seed(self):
        a = M.init_params(CFG, 123)
        b = M.init_params(CFG, 123)
        c = M.init_params(CFG, 124)
        for x, y in zip(a, b):
            assert (x == y).all()
        assert any(float(jnp.abs(x - y).max()) > 0
                   for x, y in zip(a, c))

    def test_vgg16_param_count_matches_scale(self):
        """Liu-Deng CIFAR-VGG is ~15M params (vs 138M full VGG16)."""
        total = sum(int(np.prod(s.shape))
                    for s in M.param_specs(M.PRESETS["vgg16"]))
        assert 14e6 < total < 17e6


class TestForward:
    def test_logit_shape(self):
        params, state = M.init_params(CFG, 0), M.init_state(CFG)
        x, _ = _data(CFG)
        logits, new_state = M.forward(
            CFG, params, state, x, train=True, seed_err=jnp.uint32(0),
            seed_drop=jnp.uint32(0), sigma=jnp.float32(0.0))
        assert logits.shape == (CFG.batch, CFG.num_classes)
        assert len(new_state) == len(state)

    def test_bn_state_updates_only_in_train(self):
        params, state = M.init_params(CFG, 0), M.init_state(CFG)
        x, _ = _data(CFG)
        _, st_train = M.forward(CFG, params, state, x, train=True,
                                seed_err=jnp.uint32(0),
                                seed_drop=jnp.uint32(0),
                                sigma=jnp.float32(0.0))
        _, st_eval = M.forward(CFG, params, state, x, train=False,
                               seed_err=jnp.uint32(0),
                               seed_drop=jnp.uint32(0),
                               sigma=jnp.float32(0.0))
        assert any(float(jnp.abs(a - b).max()) > 0
                   for a, b in zip(st_train, state))
        for a, b in zip(st_eval, state):
            assert (a == b).all()

    def test_sigma_zero_weight_modes_agree(self):
        """pallas_weight and jnp_weight are bit-identical backends."""
        cfg_j = M.ModelConfig(**{**CFG.__dict__, "name": "tiny_jnp",
                                 "inject": "jnp_weight"})
        params, state = M.init_params(CFG, 3), M.init_state(CFG)
        x, _ = _data(CFG)
        la, _ = M.forward(CFG, params, state, x, train=False,
                          seed_err=jnp.uint32(1), seed_drop=jnp.uint32(0),
                          sigma=jnp.float32(0.1))
        lb, _ = M.forward(cfg_j, params, state, x, train=False,
                          seed_err=jnp.uint32(1), seed_drop=jnp.uint32(0),
                          sigma=jnp.float32(0.1))
        np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-5)

    def test_error_changes_logits(self):
        params, state = M.init_params(CFG, 0), M.init_state(CFG)
        x, _ = _data(CFG)
        l0, _ = M.forward(CFG, params, state, x, train=False,
                          seed_err=jnp.uint32(1), seed_drop=jnp.uint32(0),
                          sigma=jnp.float32(0.0))
        l1, _ = M.forward(CFG, params, state, x, train=False,
                          seed_err=jnp.uint32(1), seed_drop=jnp.uint32(0),
                          sigma=jnp.float32(0.3))
        assert float(jnp.abs(l0 - l1).max()) > 1e-3

    def test_fixed_seed_reproduces_error_matrix(self):
        """Same seed_err -> identical perturbed forward (paper's fixed
        per-run error-matrix procedure relies on this)."""
        params, state = M.init_params(CFG, 0), M.init_state(CFG)
        x, _ = _data(CFG)
        l0, _ = M.forward(CFG, params, state, x, train=False,
                          seed_err=jnp.uint32(5), seed_drop=jnp.uint32(0),
                          sigma=jnp.float32(0.2))
        l1, _ = M.forward(CFG, params, state, x, train=False,
                          seed_err=jnp.uint32(5), seed_drop=jnp.uint32(0),
                          sigma=jnp.float32(0.2))
        np.testing.assert_array_equal(l0, l1)


class TestTraining:
    def test_loss_decreases_exact(self, jitted):
        step, _ = jitted
        params, state, opt = (M.init_params(CFG, 0), M.init_state(CFG),
                              M.init_opt(CFG))
        x, y = _learnable_data(CFG, 1)
        losses = []
        for i in range(30):
            params, state, opt, loss, _ = step(
                params, state, opt, x, y, jnp.uint32(1), jnp.uint32(i),
                jnp.float32(0.0), jnp.float32(0.05))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.5, losses[::10]

    def test_loss_decreases_with_moderate_error(self, jitted):
        """Paper claim: training converges under MRE ~ a few percent."""
        step, _ = jitted
        params, state, opt = (M.init_params(CFG, 0), M.init_state(CFG),
                              M.init_opt(CFG))
        x, y = _learnable_data(CFG, 1)
        losses = []
        for i in range(30):
            params, state, opt, loss, _ = step(
                params, state, opt, x, y, jnp.uint32(1), jnp.uint32(i),
                jnp.float32(0.045), jnp.float32(0.05))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.6, losses[::10]

    def test_huge_error_degrades_more(self, jitted):
        """Monotonicity that drives Table II's collapse row."""
        step, _ = jitted
        x, y = _learnable_data(CFG, 1)

        def final_loss(sigma):
            # Resampled error (seed_err = step) — a fixed error matrix is
            # absorbed by single-batch memorization, so the damage signal
            # needs fresh noise per step (see EXPERIMENTS.md ablations).
            params, state, opt = (M.init_params(CFG, 0), M.init_state(CFG),
                                  M.init_opt(CFG))
            for i in range(25):
                params, state, opt, loss, _ = step(
                    params, state, opt, x, y, jnp.uint32(i + 1),
                    jnp.uint32(i), jnp.float32(sigma), jnp.float32(0.05))
            return float(loss)

        assert final_loss(0.48) > 3 * final_loss(0.0)

    def test_step_is_deterministic(self, jitted):
        step, _ = jitted
        params, state, opt = (M.init_params(CFG, 0), M.init_state(CFG),
                              M.init_opt(CFG))
        x, y = _data(CFG)
        a = step(params, state, opt, x, y, jnp.uint32(1), jnp.uint32(2),
                 jnp.float32(0.1), jnp.float32(0.01))
        b = step(params, state, opt, x, y, jnp.uint32(1), jnp.uint32(2),
                 jnp.float32(0.1), jnp.float32(0.01))
        for u, v in zip(a[0], b[0]):
            np.testing.assert_array_equal(u, v)

    def test_eval_counts(self, jitted):
        _, ev = jitted
        params, state = M.init_params(CFG, 0), M.init_state(CFG)
        x, y = _data(CFG, n=CFG.eval_batch)
        loss_sum, correct = ev(params, state, x, y)
        assert 0 <= int(correct) <= CFG.eval_batch
        assert float(loss_sum) > 0


class TestGradients:
    def test_inject_weight_vjp_is_scaled_identity(self):
        """grad of sum(inject(w)) must be exactly (1 + sigma*eps)."""
        from compile.model import _inject_weight
        w = jnp.asarray(np.random.RandomState(0).randn(16, 8), jnp.float32)
        sigma = jnp.float32(0.1)
        seed, stream = jnp.uint32(3), jnp.uint32(2)
        g = jax.grad(lambda w_: jnp.sum(
            _inject_weight(w_, seed, stream, sigma, False)))(w)
        from compile.kernels import ref
        eps_field = ref.ref_error_inject(jnp.ones_like(w), seed, stream,
                                         sigma)
        np.testing.assert_allclose(g, eps_field, rtol=1e-5, atol=1e-6)

    def test_product_mode_grads_finite(self):
        cfg = M.PRESETS["tiny_product"]
        params, state, opt = (M.init_params(cfg, 0), M.init_state(cfg),
                              M.init_opt(cfg))
        x, y = _data(cfg)
        new_p, _, _, loss, _ = M.train_step(
            cfg, params, state, opt, x, y, jnp.uint32(1), jnp.uint32(2),
            jnp.float32(0.1), jnp.float32(0.01))
        assert bool(jnp.isfinite(loss))
        for p in new_p:
            assert bool(jnp.isfinite(p).all())

    def test_product_mode_exact_limit_matches_weight_mode(self):
        """sigma=0: product-mode (im2col+pallas matmul) must equal the
        lax.conv weight-mode forward — validates the im2col lowering."""
        cfg_p = M.PRESETS["tiny_product"]
        params, state = M.init_params(cfg_p, 0), M.init_state(cfg_p)
        x, _ = _data(cfg_p)
        lp, _ = M.forward(cfg_p, params, state, x, train=False,
                          seed_err=jnp.uint32(0), seed_drop=jnp.uint32(0),
                          sigma=jnp.float32(0.0))
        lw, _ = M.forward(CFG, params, state, x, train=False,
                          seed_err=jnp.uint32(0), seed_drop=jnp.uint32(0),
                          sigma=jnp.float32(0.0))
        np.testing.assert_allclose(lp, lw, rtol=1e-3, atol=1e-3)


class TestLayerTable:
    def test_macs_positive_and_conv_dominates(self):
        """Cong & Xiao [12]: conv ~90% of compute — holds for vgg16."""
        rows = M.layer_table(M.PRESETS["vgg16"])
        conv = sum(r["macs"] for r in rows if r["type"] == "conv3x3")
        total = sum(r["macs"] for r in rows)
        assert conv / total > 0.9

    def test_param_total_consistent(self):
        for preset in ("tiny", "small", "vgg16"):
            cfg = M.PRESETS[preset]
            table = sum(r["params"] for r in M.layer_table(cfg))
            # layer_table counts (w, b, bn gamma/beta) = params specs sum
            spec_total = sum(int(np.prod(s.shape))
                             for s in M.param_specs(cfg))
            assert table == spec_total, preset
