"""Pallas kernels vs pure-jnp oracles (the core L1 correctness signal).

Hypothesis sweeps shapes, seeds and sigmas; every case must agree with
ref.py. Tolerances are float32-reduction-level only — the noise bits
themselves must match exactly (same Threefry counters on both sides).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import approx_matmul as am
from compile.kernels import error_inject as ei
from compile.kernels import ref


def _rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape),
                       jnp.float32)


class TestErrorInject:
    @given(rows=st.integers(1, 300), cols=st.integers(1, 65),
           seed=st.integers(0, 2**32 - 1), sigma=st.floats(0.0, 0.5))
    @settings(max_examples=25, deadline=None)
    def test_matches_oracle(self, rows, cols, seed, sigma):
        w = _rand((rows, cols), 0)
        seed = np.uint32(seed)
        out = ei.error_inject(w, seed, 3, sigma, block_rows=64)
        expect = ref.ref_error_inject(w, seed, 3, sigma)
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)

    def test_sigma_zero_is_identity(self):
        w = _rand((37, 11), 1)
        out = ei.error_inject(w, 9, 0, 0.0)
        np.testing.assert_allclose(out, w, rtol=0, atol=0)

    def test_4d_tensor(self):
        """Conv weights (kh,kw,cin,cout) go through the same kernel."""
        w = _rand((3, 3, 16, 32), 2)
        out = ei.error_inject(w, 5, 7, 0.1)
        expect = ref.ref_error_inject(w, 5, 7, 0.1)
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)

    def test_block_decomposition_invariant(self):
        """Same (seed, stream) -> same error field for any block_rows."""
        w = _rand((256, 32), 3)
        a = ei.error_inject(w, 11, 2, 0.05, block_rows=32)
        b = ei.error_inject(w, 11, 2, 0.05, block_rows=256)
        np.testing.assert_allclose(a, b, rtol=0, atol=0)

    def test_streams_differ(self):
        w = jnp.ones((64, 64), jnp.float32)
        a = ei.error_inject(w, 1, 0, 0.1)
        b = ei.error_inject(w, 1, 1, 0.1)
        assert float(jnp.abs(a - b).max()) > 1e-3

    def test_empirical_mre_matches_sigma(self):
        """Measured MRE of the injected error == sigma*sqrt(2/pi)."""
        w = jnp.ones((400, 400), jnp.float32)
        sigma = 0.045  # paper test case 4 (MRE ~3.6%, SD ~4.5%)
        out = ei.error_inject(w, 42, 0, sigma)
        rel = jnp.abs(out - 1.0)
        mre = float(rel.mean())
        assert abs(mre - sigma * np.sqrt(2 / np.pi)) < 0.0005
        assert abs(float(rel.std()) - sigma * np.sqrt(1 - 2 / np.pi)) < 0.001


class TestApproxMatmul:
    @given(m=st.integers(1, 40), k=st.integers(1, 40),
           n=st.integers(1, 40), seed=st.integers(0, 2**32 - 1),
           sigma=st.floats(0.0, 0.3))
    @settings(max_examples=20, deadline=None)
    def test_matches_oracle(self, m, k, n, seed, sigma):
        x = _rand((m, k), 1)
        w = _rand((k, n), 2)
        bm = bn = bk = 16
        seed = np.uint32(seed)
        out = am.approx_matmul(x, w, seed, 4, sigma, bm=bm, bn=bn, bk=bk)
        kt = k + ((-k) % min(bk, k))
        nt = n + ((-n) % min(bn, n))
        expect = ref.ref_approx_matmul(x, w, seed, 4, sigma,
                                       k_total=kt, n_total=nt)
        np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)

    def test_sigma_zero_is_exact(self):
        x = _rand((33, 47), 3)
        w = _rand((47, 21), 4)
        out = am.approx_matmul(x, w, 7, 1, 0.0)
        np.testing.assert_allclose(out, x @ w, rtol=1e-5, atol=1e-5)

    def test_tile_invariance_when_unpadded(self):
        """Exact-divisor tilings see the same global noise field."""
        x = _rand((64, 64), 5)
        w = _rand((64, 64), 6)
        a = am.approx_matmul(x, w, 9, 2, 0.05, bm=16, bn=16, bk=16)
        b = am.approx_matmul(x, w, 9, 2, 0.05, bm=32, bn=32, bk=32)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_error_concentration_vs_weight_level(self):
        """Product-level relative error on the *output* shrinks ~1/sqrt(K)
        relative to the per-product sigma (DESIGN.md ablation claim)."""
        k = 256
        x = jnp.abs(_rand((8, k), 7)) + 0.5   # same-sign products
        w = jnp.abs(_rand((k, 8), 8)) + 0.5
        sigma = 0.1
        exact = x @ w
        approx = am.approx_matmul(x, w, 3, 1, sigma)
        rel = float(jnp.abs((approx - exact) / exact).mean())
        # uncorrelated per-product noise -> output MRE well under sigma
        assert rel < sigma / 3

    def test_matmul_grad_finite(self):
        """Padding contributes zero products (documented invariant)."""
        x = _rand((5, 9), 9)      # forces padding at every tile dim
        w = _rand((9, 7), 10)
        out = am.approx_matmul(x, w, 2, 3, 0.2, bm=4, bn=4, bk=4)
        assert bool(jnp.isfinite(out).all())
