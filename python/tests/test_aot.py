"""AOT artifact contract: manifest vs HLO text vs model layout.

These tests run against the artifacts/ directory produced by
``make artifacts`` (skipped if absent, e.g. unit-only runs).
"""

import json
import os
import re

import numpy as np
import pytest

from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestManifest:
    def test_paper_tables_embedded(self, manifest):
        t2 = manifest["paper"]["table2"]
        assert len(t2) == 9
        assert t2[0][3] == 93.60
        assert t2[8][3] == 65.65
        t3 = manifest["paper"]["table3"]
        assert len(t3) == 6
        assert all(a + e == 200 for (_, _, a, e) in t3)

    def test_models_present(self, manifest):
        for preset in ("tiny", "tiny_product", "small", "vgg16"):
            assert preset in manifest["models"]

    def test_entry_files_exist_and_are_hlo(self, manifest):
        for name, m in manifest["models"].items():
            for kind, e in m["entries"].items():
                path = os.path.join(ART, e["file"])
                assert os.path.exists(path), e["file"]
                with open(path) as f:
                    head = f.read(4096)
                assert "HloModule" in head, e["file"]
                assert "ENTRY" in open(path).read(), e["file"]

    def test_train_io_symmetry(self, manifest):
        """Outputs 0..N-1 of train must mirror inputs (state threading)."""
        for name, m in manifest["models"].items():
            if "train" not in m["entries"]:
                continue
            e = m["entries"]["train"]
            n_state = len(m["params"]) * 2 + len(m["state"])
            ins = e["inputs"][:n_state]
            outs = e["outputs"][:n_state]
            for i, o in zip(ins, outs):
                assert i["name"] == o["name"]
                assert i["shape"] == o["shape"]

    def test_param_shapes_match_model(self, manifest):
        for preset in ("tiny", "small"):
            cfg = M.PRESETS[preset]
            specs = M.param_specs(cfg)
            mp = manifest["models"][preset]["params"]
            assert len(mp) == len(specs)
            for a, b in zip(mp, specs):
                assert a["name"] == b.name
                assert tuple(a["shape"]) == tuple(b.shape)

    def test_total_params(self, manifest):
        for preset, m in manifest["models"].items():
            total = sum(int(np.prod(p["shape"])) for p in m["params"])
            assert total == m["total_params"]

    def test_scalar_inputs_trailing(self, manifest):
        e = manifest["models"]["tiny"]["entries"]["train"]
        names = [i["name"] for i in e["inputs"][-4:]]
        assert names == ["seed_err", "seed_drop", "sigma", "lr"]

    def test_hlo_parameter_count_matches_manifest(self, manifest):
        """The HLO ENTRY signature must take exactly the manifest inputs."""
        for preset in ("tiny", "small"):
            e = manifest["models"][preset]["entries"]["train"]
            text = open(os.path.join(ART, e["file"])).read()
            n_params = len(set(re.findall(r"parameter\((\d+)\)", text)))
            assert n_params == len(e["inputs"]), preset
