//! Bench: ablations of the paper's modelling choices (DESIGN.md §3).
//!
//! 1. **Weight-level vs product-level error** — the paper perturbs the
//!    weight matrix once; real hardware perturbs every scalar product.
//!    Trains the `tiny` (weight) vs `tiny_product` (per-product Pallas
//!    matmul) presets at matched sigma and compares damage.
//! 2. **Fixed vs per-step error matrices** — the paper's Figure-3
//!    procedure fixes the error field per run; hardware error varies
//!    with data. Same preset, both sampling modes.
//!
//! `cargo bench ablations`.

use approxmul::config::{ErrorSampling, ExperimentConfig, MultiplierPolicy};
use approxmul::coordinator::Trainer;
use approxmul::mult::MultSpec;
use approxmul::report::{pct, Table};
use approxmul::runtime::Engine;

fn run_case(
    engine: &Engine,
    preset: &str,
    sigma: f64,
    sampling: ErrorSampling,
    tag: &str,
) -> anyhow::Result<f64> {
    let mut cfg = ExperimentConfig::preset_tiny();
    cfg.preset = preset.to_string();
    cfg.epochs = 8;
    cfg.train_examples = 1024;
    cfg.test_examples = 512;
    cfg.sampling = sampling;
    cfg.tag = tag.to_string();
    cfg.policy = if sigma == 0.0 {
        MultiplierPolicy::Exact
    } else {
        MultiplierPolicy::Approximate { mult: MultSpec::gaussian(sigma) }
    };
    let outcome = Trainer::new(engine, cfg)?.run()?;
    Ok(outcome.final_accuracy)
}

fn main() -> anyhow::Result<()> {
    let engine = Engine::from_artifacts("artifacts")?;
    let sigma = 0.12; // MRE ~9.6% — strong enough to see differences

    println!("# ablation 1: weight-level (paper) vs product-level (hardware) error\n");
    let mut t = Table::new(&["injection", "sigma", "final acc", "note"]);
    let base_w = run_case(&engine, "tiny", 0.0, ErrorSampling::FixedPerRun, "ab1-w0")?;
    let w = run_case(&engine, "tiny", sigma, ErrorSampling::FixedPerRun, "ab1-w")?;
    let base_p =
        run_case(&engine, "tiny_product", 0.0, ErrorSampling::FixedPerRun, "ab1-p0")?;
    let p = run_case(&engine, "tiny_product", sigma, ErrorSampling::FixedPerRun, "ab1-p")?;
    t.row(vec!["weight-level".into(), "0".into(), pct(base_w), "exact baseline".into()]);
    t.row(vec!["weight-level".into(), format!("{sigma}"), pct(w), "paper's model".into()]);
    t.row(vec!["product-level".into(), "0".into(), pct(base_p), "exact baseline".into()]);
    t.row(vec![
        "product-level".into(),
        format!("{sigma}"),
        pct(p),
        "per-MAC noise, concentrates ~1/sqrt(K)".into(),
    ]);
    print!("{}", t.to_markdown());
    println!(
        "\nexpected: product-level damage <= weight-level damage at equal sigma \
         (reduction averaging) — quantifies how conservative the paper's \
         simulation shortcut is.\n"
    );

    println!("# ablation 2: fixed (paper) vs per-step error matrices\n");
    let mut t = Table::new(&["sampling", "sigma", "final acc"]);
    let fixed = run_case(&engine, "tiny", sigma, ErrorSampling::FixedPerRun, "ab2-f")?;
    let fresh = run_case(&engine, "tiny", sigma, ErrorSampling::PerStep, "ab2-s")?;
    t.row(vec!["fixed per run".into(), format!("{sigma}"), pct(fixed)]);
    t.row(vec!["per step".into(), format!("{sigma}"), pct(fresh)]);
    print!("{}", t.to_markdown());
    println!(
        "\nfixed error matrices can be *learned around* (the network adapts to \
         a static perturbation); per-step resampling behaves like gradient \
         noise. Both matter when mapping Table II to real hardware."
    );
    Ok(())
}
