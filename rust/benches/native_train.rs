//! Bench: native-backend train-step throughput per multiplier design —
//! exact vs the Gaussian surrogate vs bit-accurate DRUM-6 vs its
//! LUT-accelerated backend, on the `tiny` and `small` presets.
//! Quantifies what simulating a hardware design costs relative to
//! exact training, and how much the decompose-once prepared GEMM and
//! the ApproxTrain-style LUT claw back. Emits `BENCH_native_train.json`
//! via the benchkit JSON helpers so the perf trajectory is tracked
//! across PRs (see BENCH_history.md); rows carry `"simd"` for A/B
//! comparisons across scalar and `--features simd` builds of the same
//! SHA. `cargo bench native_train`.

use approxmul::benchkit::{fmt_dur, save_json, Bench};
use approxmul::data::SyntheticCifar;
use approxmul::json::{object, Value};
use approxmul::mult::MultSpec;
use approxmul::runtime::session::StepInputs;
use approxmul::runtime::{Backend, NativeBackend, TrainSession};

/// (preset, specs, warmup, samples) — the `small` preset is the
/// speed-target workload (ROADMAP: interactive-speed native training),
/// benched with fewer samples because one step is large.
const CASES: &[(&str, &[&str], usize, usize)] = &[
    // `sdrum6` is the signed-pipeline row: same DRUM core, sign routed
    // through the design — its cost vs `drum6` is the price of the
    // signed kernel. `lut8:drum6` is the flat-table row: under
    // `--features simd` its GEMM inner loop is the vectorized table
    // gather, so comparing it across scalar/simd runs of the same SHA
    // isolates the flat-table kernel's win.
    (
        "tiny",
        &["exact", "gaussian:0.045", "drum6", "lut8:drum6", "lut12:drum6", "sdrum6"],
        2,
        10,
    ),
    ("small", &["exact", "drum6"], 1, 3),
];

fn main() -> anyhow::Result<()> {
    let mut json_rows: Vec<Value> = Vec::new();
    println!("# native train-step throughput\n");
    let mut t = approxmul::report::Table::new(&[
        "preset", "design", "step median", "steps/s", "samples/s", "vs exact",
    ]);

    for &(preset, specs, warmup, samples) in CASES {
        let mut exact_median = None;
        for &spec_str in specs {
            let spec = MultSpec::parse(spec_str)?;
            let approx = !spec.is_exact();
            let sigma = spec.sigma() as f32;
            let backend = NativeBackend::new(preset, spec)?;
            let model = backend.model().clone();
            let mut session = TrainSession::with_backend(Box::new(backend), 42)?;

            let mut ds = SyntheticCifar::for_input(
                model.input_hw,
                model.in_ch,
                model.num_classes,
                7,
            )
            .generate(model.batch);
            ds.normalize();
            let (x, y) = ds.gather_batch(&(0..model.batch).collect::<Vec<_>>())?;

            let mut bench = Bench::new(warmup, samples);
            let mut step = 0u32;
            bench.run(&format!("{preset}/{spec_str} train step"), || {
                step += 1;
                let s = session
                    .step(
                        x.clone(),
                        y.clone(),
                        StepInputs {
                            seed_err: 1,
                            seed_drop: step,
                            sigma,
                            lr: 0.01,
                            approx,
                            step: 0,
                        },
                    )
                    .unwrap();
                std::hint::black_box(s.loss);
            });
            let median = bench.results().last().unwrap().median();
            let steps_per_s = 1.0 / median.as_secs_f64().max(1e-12);
            let samples_per_s = steps_per_s * model.batch as f64;
            let base = *exact_median.get_or_insert(median);
            t.row(vec![
                preset.to_string(),
                spec_str.to_string(),
                fmt_dur(median),
                format!("{steps_per_s:.2}"),
                format!("{samples_per_s:.1}"),
                format!(
                    "{:.2}x",
                    median.as_secs_f64() / base.as_secs_f64().max(1e-12)
                ),
            ]);
            json_rows.push(object([
                ("design", Value::from(spec_str)),
                ("preset", Value::from(preset)),
                ("median_step_ms", (median.as_secs_f64() * 1e3).into()),
                ("steps_per_s", steps_per_s.into()),
                ("samples_per_s", samples_per_s.into()),
                ("batch", model.batch.into()),
                ("simd", cfg!(feature = "simd").into()),
            ]));
        }
    }
    print!("{}", t.to_markdown());

    save_json("BENCH_native_train.json", &Value::Array(json_rows))?;
    println!("\nthroughput rows -> BENCH_native_train.json");
    Ok(())
}
