//! Bench: native-backend train-step throughput per multiplier design —
//! exact vs the Gaussian surrogate vs bit-accurate DRUM-6 vs its
//! LUT-accelerated backend. Quantifies what simulating a hardware
//! design costs relative to exact training, and how much of that the
//! ApproxTrain-style LUT claws back. Emits `BENCH_native_train.json`
//! via the benchkit JSON helpers so the perf trajectory is tracked
//! across PRs. `cargo bench native_train`.

use approxmul::benchkit::{fmt_dur, save_json, Bench};
use approxmul::data::SyntheticCifar;
use approxmul::json::{object, Value};
use approxmul::mult::MultSpec;
use approxmul::runtime::session::StepInputs;
use approxmul::runtime::{Backend, NativeBackend, TrainSession};

const PRESET: &str = "tiny";

fn main() -> anyhow::Result<()> {
    let specs = ["exact", "gaussian:0.045", "drum6", "lut12:drum6"];
    let mut json_rows: Vec<Value> = Vec::new();
    println!("# native train-step throughput ({PRESET} preset)\n");
    let mut t = approxmul::report::Table::new(&[
        "design", "step median", "steps/s", "vs exact",
    ]);
    let mut exact_median = None;

    for spec_str in specs {
        let spec = MultSpec::parse(spec_str)?;
        let approx = !spec.is_exact();
        let sigma = spec.sigma() as f32;
        let backend = NativeBackend::new(PRESET, spec)?;
        let model = backend.model().clone();
        let mut session = TrainSession::with_backend(Box::new(backend), 42)?;

        let mut ds = SyntheticCifar::for_input(
            model.input_hw,
            model.in_ch,
            model.num_classes,
            7,
        )
        .generate(model.batch);
        ds.normalize();
        let (x, y) = ds.gather_batch(&(0..model.batch).collect::<Vec<_>>())?;

        let mut bench = Bench::new(2, 10);
        let mut step = 0u32;
        bench.run(&format!("{spec_str} train step"), || {
            step += 1;
            let s = session
                .step(
                    x.clone(),
                    y.clone(),
                    StepInputs {
                        seed_err: 1,
                        seed_drop: step,
                        sigma,
                        lr: 0.01,
                        approx,
                    },
                )
                .unwrap();
            std::hint::black_box(s.loss);
        });
        let median = bench.results()[0].median();
        let steps_per_s = 1.0 / median.as_secs_f64().max(1e-12);
        let base = *exact_median.get_or_insert(median);
        t.row(vec![
            spec_str.to_string(),
            fmt_dur(median),
            format!("{steps_per_s:.2}"),
            format!("{:.2}x", median.as_secs_f64() / base.as_secs_f64().max(1e-12)),
        ]);
        json_rows.push(object([
            ("design", Value::from(spec_str)),
            ("preset", Value::from(PRESET)),
            ("median_step_ms", (median.as_secs_f64() * 1e3).into()),
            ("steps_per_s", steps_per_s.into()),
            ("batch", model.batch.into()),
        ]));
    }
    print!("{}", t.to_markdown());

    save_json("BENCH_native_train.json", &Value::Array(json_rows))?;
    println!("\nthroughput rows -> BENCH_native_train.json");
    Ok(())
}
