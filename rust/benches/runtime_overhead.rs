//! Bench: L3 runtime hot path — train-step latency (exact vs
//! error-injected), eval latency, and the coordinator-side overhead
//! (batch assembly + literal marshalling) as a fraction of step time.
//! This is the §Perf baseline for the L3 optimization pass.
//! `cargo bench runtime_overhead`.

use approxmul::benchkit::{fmt_dur, Bench};
use approxmul::data::augment::Augment;
use approxmul::data::batcher::Batcher;
use approxmul::data::SyntheticCifar;
use approxmul::runtime::session::StepInputs;
use approxmul::runtime::{tensor_to_literal, Engine, TrainSession};
use approxmul::tensor::Tensor;

/// The pre-optimization literal construction (three copies: as_f32,
/// vec1, reshape) — kept here so the §Perf before/after is measured
/// in-process rather than remembered.
fn tensor_to_literal_naive(t: &Tensor) -> anyhow::Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let v = t.as_f32()?;
    let lit = xla::Literal::vec1(&v);
    Ok(lit.reshape(&dims)?)
}

fn main() -> anyhow::Result<()> {
    let engine = Engine::from_artifacts("artifacts")?;

    // Marshalling A/B on a small-preset-sized parameter set (~0.55M f32).
    {
        let tensors: Vec<Tensor> = (0..16)
            .map(|i| {
                Tensor::from_f32(&[256, 128], vec![i as f32; 256 * 128]).unwrap()
            })
            .collect();
        let mut b = Bench::micro();
        b.run("marshal: naive as_f32+vec1+reshape (16x32k f32)", || {
            for t in &tensors {
                std::hint::black_box(tensor_to_literal_naive(t).unwrap());
            }
        });
        b.run("marshal: raw untyped_data single copy  (16x32k f32)", || {
            for t in &tensors {
                std::hint::black_box(tensor_to_literal(t).unwrap());
            }
        });
        println!("\n# literal marshalling A/B (EXPERIMENTS.md §Perf)\n");
        print!("{}", b.report());
    }

    for preset in ["tiny", "small"] {
        let model = engine.manifest().model(preset)?;
        let mut ds = SyntheticCifar::for_input(
            model.input_hw,
            model.in_ch,
            model.num_classes,
            9,
        )
        .generate(model.batch * 4);
        ds.normalize();
        let mut session = TrainSession::new(&engine, preset, 1)?;

        let mut b = if preset == "small" { Bench::heavy() } else { Bench::micro() };

        // Coordinator-side work only: shuffle + augment + tensor build.
        b.run(&format!("{preset}: batch assembly"), || {
            let mut batcher = Batcher::new(&ds, model.batch, 3, 0, Augment::default());
            let (x, y) = batcher.next().unwrap().unwrap();
            std::hint::black_box((x.len(), y.len()));
        });

        // Full step, exact multipliers.
        let mut batcher = Batcher::new(&ds, model.batch, 3, 0, Augment::none());
        let (x, y) = batcher.next()?.unwrap();
        let mut step = 0u32;
        b.run(&format!("{preset}: train step sigma=0"), || {
            step += 1;
            let s = session
                .step(
                    x.clone(),
                    y.clone(),
                    StepInputs {
                        seed_err: 1,
                        seed_drop: step,
                        sigma: 0.0,
                        lr: 0.01,
                        approx: false,
                        step: 0,
                    },
                )
                .unwrap();
            std::hint::black_box(s.loss);
        });

        // Full step, error-injected (paper case 4).
        b.run(&format!("{preset}: train step sigma=0.045"), || {
            step += 1;
            let s = session
                .step(
                    x.clone(),
                    y.clone(),
                    StepInputs {
                        seed_err: 1,
                        seed_drop: step,
                        sigma: 0.045,
                        lr: 0.01,
                        approx: true,
                        step: 0,
                    },
                )
                .unwrap();
            std::hint::black_box(s.loss);
        });

        // Eval batch.
        let mut eds = SyntheticCifar::for_input(
            model.input_hw,
            model.in_ch,
            model.num_classes,
            10,
        )
        .generate(model.eval_batch);
        eds.normalize();
        let (ex, ey) = eds.gather_batch(&(0..model.eval_batch).collect::<Vec<_>>())?;
        b.run(&format!("{preset}: eval batch"), || {
            let s = session.eval_batch(ex.clone(), ey.clone()).unwrap();
            std::hint::black_box(s.correct);
        });

        println!("\n# runtime hot path: {preset}\n");
        print!("{}", b.report());
        let results = b.results();
        let assembly = results[0].median();
        let exact = results[1].median();
        println!(
            "coordinator overhead (assembly/step): {:.2}% ({} / {})",
            100.0 * assembly.as_secs_f64() / exact.as_secs_f64().max(1e-12),
            fmt_dur(assembly),
            fmt_dur(exact),
        );
        let inj = results[2].median();
        println!(
            "error-injection overhead: {:+.2}% ({} vs {})",
            100.0 * (inj.as_secs_f64() / exact.as_secs_f64().max(1e-12) - 1.0),
            fmt_dur(inj),
            fmt_dur(exact),
        );
    }
    Ok(())
}
