//! Bench: bit-accurate approximate-multiplier designs — error
//! statistics (the §III DRUM mapping) and simulation throughput of
//! each design on this host. `cargo bench multipliers`.

use approxmul::benchkit::{throughput, Bench};
use approxmul::mult::{characterize, standard_designs, GaussianModel, OperandDist};
use approxmul::report::Table;
use approxmul::rng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    // 1. Error statistics table (uniform16: the DRUM paper's setting).
    let mut designs = standard_designs();
    designs.push(Box::new(GaussianModel::new(0.01803, 7)));
    let mut t = Table::new(&["design", "MRE", "SD", "bias", "MRE/SD"]);
    for d in &designs {
        let s = characterize(d.as_ref(), OperandDist::Uniform16, 300_000, 7);
        t.row(vec![
            d.name(),
            format!("{:.3}%", 100.0 * s.mre),
            format!("{:.3}%", 100.0 * s.sd),
            format!("{:+.3}%", 100.0 * s.mean_re),
            format!("{:.3}", s.gaussianity_ratio()),
        ]);
    }
    println!("# multiplier designs: error statistics (uniform16)\n");
    print!("{}", t.to_markdown());
    println!("\nDRUM-6 published: MRE 1.47% SD 1.803% (ICCAD'15).\n");

    // 2. Simulation throughput.
    let mut rng = Xoshiro256::new(1);
    let ops: Vec<(u32, u32)> =
        (0..1_000_000).map(|_| (rng.next_u32() | 1, rng.next_u32() | 1)).collect();
    let mut b = Bench::micro();
    for d in &designs {
        let name = format!("{} 1M mults", d.name());
        b.run(&name, || {
            let mut acc = 0u64;
            for &(a, x) in &ops {
                acc = acc.wrapping_add(d.mul(a, x));
            }
            std::hint::black_box(acc);
        });
    }
    println!("# simulation throughput\n");
    print!("{}", b.report());
    for s in b.results() {
        println!(
            "{:<32} {:>8.1} M mult/s",
            s.name,
            throughput(s.median(), 1_000_000) / 1e6
        );
    }
    Ok(())
}
