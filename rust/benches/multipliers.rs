//! Bench: bit-accurate approximate-multiplier designs — error
//! statistics (the §III DRUM mapping) and simulation throughput of the
//! three host paths per design:
//!
//! * `scalar` — one virtual `mul` call per element (the pre-PR-1
//!   baseline, kept as the comparison anchor);
//! * `batch`  — one virtual `mul_batch` call per slice (monomorphized,
//!   auto-vectorizable inner loop);
//! * `lut`    — the ApproxTrain-style 8-bit table backend.
//!
//! Batch outputs are asserted bit-identical to scalar per design; LUT
//! outputs are asserted bit-identical where its contract guarantees it
//! (DRUM-k with k strictly below the 8-bit table width on any
//! operands; every deterministic design on 8-bit operands). The signed
//! subsystem gets the same treatment: `sdrum6` / `booth8` / `sroba`
//! rows over scalar, batch, and `slut8` paths (rows carry
//! `"signed": true`). Emits `BENCH_multipliers.json` with M mult/s per
//! (design, dist, path) so the perf trajectory is tracked across PRs;
//! every row carries `"simd"` (was the binary built with
//! `--features simd`?) so scalar and simd runs of the same SHA are
//! unambiguous in A/B comparisons. `cargo bench multipliers`.

use approxmul::benchkit::{save_json, throughput, Bench};
use approxmul::json::{object, Value};
use approxmul::mult::signed::{
    self, characterize_signed, sample_signed, SignedLut, SignedMultiplier,
};
use approxmul::mult::{
    characterize, standard_designs, GaussianModel, LutMultiplier, Multiplier,
    OperandDist,
};
use approxmul::report::Table;
use approxmul::rng::Xoshiro256;

const N_OPS: usize = 1_000_000;
const LUT_BITS: u32 = 8;

/// One named bench row per design family that registers a
/// `simd_kernel()` in `mult/`. detlint's C1 lint cross-checks every
/// such family against the design lists in `tests/simd_parity.rs`
/// *and* against a named row here; `main` asserts each entry below is
/// actually benched, so the roster cannot drift from the harness.
const SIMD_KERNEL_BENCH_ROWS: &[&str] = &[
    "exact", "drum6", "trunc8", "mitchell", "lut8:drum6", "sexact", "sdrum6", "booth8",
    "slut8:sdrum6",
];

fn operands(dist: OperandDist, seed: u64) -> (Vec<u32>, Vec<u32>) {
    let mut rng = Xoshiro256::new(seed);
    let mut a = Vec::with_capacity(N_OPS);
    let mut b = Vec::with_capacity(N_OPS);
    for _ in 0..N_OPS {
        a.push(dist.sample(&mut rng));
        b.push(dist.sample(&mut rng));
    }
    (a, b)
}

fn signed_operands(dist: OperandDist, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Xoshiro256::new(seed);
    let mut a = Vec::with_capacity(N_OPS);
    let mut b = Vec::with_capacity(N_OPS);
    for _ in 0..N_OPS {
        a.push(sample_signed(dist, &mut rng));
        b.push(sample_signed(dist, &mut rng));
    }
    (a, b)
}

fn main() -> anyhow::Result<()> {
    // 1. Error statistics table (uniform16: the DRUM paper's setting) —
    //    now computed by the parallel characterize harness.
    let mut designs = standard_designs();
    designs.push(Box::new(GaussianModel::new(0.01803, 7)));
    let mut t = Table::new(&["design", "MRE", "SD", "bias", "MRE/SD"]);
    for d in &designs {
        let s = characterize(d.as_ref(), OperandDist::Uniform16, 300_000, 7);
        t.row(vec![
            d.name(),
            format!("{:.3}%", 100.0 * s.mre),
            format!("{:.3}%", 100.0 * s.sd),
            format!("{:+.3}%", 100.0 * s.mean_re),
            format!("{:.3}", s.gaussianity_ratio()),
        ]);
    }
    println!("# multiplier designs: error statistics (uniform16)\n");
    print!("{}", t.to_markdown());
    println!("\nDRUM-6 published: MRE 1.47% SD 1.803% (ICCAD'15).\n");

    // 2. Simulation throughput: scalar vs batch vs LUT per design/dist.
    let dists = [OperandDist::Uniform16, OperandDist::Mantissa, OperandDist::Small];
    let mut json_rows: Vec<Value> = Vec::new();
    for dist in dists {
        let (a, b) = operands(dist, 1);
        let mut out_scalar = vec![0u64; N_OPS];
        let mut out = vec![0u64; N_OPS];
        println!("# simulation throughput — {} operands\n", dist.name());
        let mut summary =
            Table::new(&["design", "scalar M/s", "batch M/s", "lut M/s", "batch x", "lut x"]);
        for d in &designs {
            // LUT noise tables are frozen at construction, which is the
            // point: the same backend contract ApproxTrain uses.
            let lut = LutMultiplier::new(d.as_ref(), LUT_BITS)?;
            let mut bench = Bench::new(1, 7);
            bench.run(&format!("{} scalar {}", d.name(), dist.name()), || {
                for i in 0..N_OPS {
                    out_scalar[i] = d.mul(a[i], b[i]);
                }
                std::hint::black_box(&out_scalar);
            });
            bench.run(&format!("{} batch  {}", d.name(), dist.name()), || {
                d.mul_batch(&a, &b, &mut out);
                std::hint::black_box(&out);
            });
            // Bit-identity: batch must equal scalar everywhere. (The
            // Gaussian model is stateful, so its paths draw different
            // noise; identity is pinned separately in tests/mult_batch.)
            if !d.name().starts_with("gauss") {
                assert_eq!(out_scalar, out, "{}: batch != scalar", d.name());
            }
            bench.run(&format!("{} lut{LUT_BITS}  {}", d.name(), dist.name()), || {
                lut.mul_batch(&a, &b, &mut out);
                std::hint::black_box(&out);
            });
            // drum8 is excluded: at k == table width DRUM's forced
            // steering bit is lost inside the table (see mult::lut).
            let lut_exact_here = matches!(d.name().as_str(), "drum4" | "drum6")
                || (dist == OperandDist::Small && !d.name().starts_with("gauss"));
            if lut_exact_here {
                assert_eq!(out_scalar, out, "{}: lut != scalar on {}", d.name(), dist.name());
            }

            let results = bench.results();
            let mps: Vec<f64> = results
                .iter()
                .map(|s| throughput(s.median(), N_OPS as u64) / 1e6)
                .collect();
            summary.row(vec![
                d.name(),
                format!("{:.1}", mps[0]),
                format!("{:.1}", mps[1]),
                format!("{:.1}", mps[2]),
                format!("{:.2}x", mps[1] / mps[0]),
                format!("{:.2}x", mps[2] / mps[0]),
            ]);
            json_rows.push(object([
                ("design", Value::from(d.name())),
                ("dist", dist.name().into()),
                ("scalar_mps", mps[0].into()),
                ("batch_mps", mps[1].into()),
                ("lut_mps", mps[2].into()),
                ("lut_bits", (LUT_BITS as usize).into()),
                ("lut_bit_identical", lut_exact_here.into()),
                ("simd", cfg!(feature = "simd").into()),
                ("n_ops", N_OPS.into()),
            ]));
        }
        print!("{}", summary.to_markdown());
        println!();
    }

    // 3. Signed designs: error statistics + scalar vs batch vs signed
    //    LUT throughput, same three host paths over the signed domain.
    let signed_designs: Vec<Box<dyn SignedMultiplier>> = vec![
        Box::new(signed::SignedExact),
        Box::new(signed::SignedDrum::new(6)?),
        Box::new(signed::Booth::new(8)?),
        Box::new(signed::SignedRoba),
    ];

    // The bench half of the C1 pin: every roster name must be a row this
    // harness actually runs (design names, or the LUT/SLUT wrappers built
    // around them at LUT_BITS).
    let mut benched: Vec<String> = designs.iter().map(|d| d.name()).collect();
    benched.extend(designs.iter().map(|d| format!("lut{LUT_BITS}:{}", d.name())));
    benched.extend(signed_designs.iter().map(|d| d.name()));
    benched.extend(signed_designs.iter().map(|d| format!("slut{LUT_BITS}:{}", d.name())));
    for row in SIMD_KERNEL_BENCH_ROWS {
        assert!(
            benched.iter().any(|n| n == row),
            "SIMD_KERNEL_BENCH_ROWS entry `{row}` is not benched by any design above"
        );
    }

    let mut t = Table::new(&["design", "MRE", "SD", "bias", "MRE/SD"]);
    for d in &signed_designs {
        let s = characterize_signed(d.as_ref(), OperandDist::Uniform16, 300_000, 7);
        t.row(vec![
            d.name(),
            format!("{:.3}%", 100.0 * s.mre),
            format!("{:.3}%", 100.0 * s.sd),
            format!("{:+.3}%", 100.0 * s.mean_re),
            format!("{:.3}", s.gaussianity_ratio()),
        ]);
    }
    println!(
        "# signed multiplier designs: error statistics (uniform16 magnitudes, \
         random signs)\n"
    );
    print!("{}", t.to_markdown());
    println!();

    for dist in dists {
        let (a, b) = signed_operands(dist, 2);
        let mut out_scalar = vec![0i64; N_OPS];
        let mut out = vec![0i64; N_OPS];
        println!("# signed simulation throughput — {} operands\n", dist.name());
        let mut summary = Table::new(&[
            "design", "scalar M/s", "batch M/s", "slut M/s", "batch x", "slut x",
        ]);
        for d in &signed_designs {
            let slut = SignedLut::new(d.as_ref(), LUT_BITS)?;
            let mut bench = Bench::new(1, 7);
            bench.run(&format!("{} scalar {}", d.name(), dist.name()), || {
                for i in 0..N_OPS {
                    out_scalar[i] = d.mul(a[i], b[i]);
                }
                std::hint::black_box(&out_scalar);
            });
            bench.run(&format!("{} batch  {}", d.name(), dist.name()), || {
                d.mul_batch(&a, &b, &mut out);
                std::hint::black_box(&out);
            });
            // Bit-identity: batch must equal scalar everywhere (all
            // signed designs are stateless).
            assert_eq!(out_scalar, out, "{}: batch != scalar", d.name());
            bench.run(&format!("{} slut{LUT_BITS}  {}", d.name(), dist.name()), || {
                slut.mul_batch(&a, &b, &mut out);
                std::hint::black_box(&out);
            });
            // The slut contract holds over arbitrary operands only for
            // dynamic-range designs with k strictly below the table's
            // magnitude field (7 bits at slut8): sdrum6 qualifies.
            let slut_exact_here = d.name() == "sdrum6";
            if slut_exact_here {
                assert_eq!(out_scalar, out, "{}: slut != scalar on {}", d.name(), dist.name());
            }

            let results = bench.results();
            let mps: Vec<f64> = results
                .iter()
                .map(|s| throughput(s.median(), N_OPS as u64) / 1e6)
                .collect();
            summary.row(vec![
                d.name(),
                format!("{:.1}", mps[0]),
                format!("{:.1}", mps[1]),
                format!("{:.1}", mps[2]),
                format!("{:.2}x", mps[1] / mps[0]),
                format!("{:.2}x", mps[2] / mps[0]),
            ]);
            json_rows.push(object([
                ("design", Value::from(d.name())),
                ("dist", dist.name().into()),
                ("scalar_mps", mps[0].into()),
                ("batch_mps", mps[1].into()),
                ("lut_mps", mps[2].into()),
                ("lut_bits", (LUT_BITS as usize).into()),
                ("lut_bit_identical", slut_exact_here.into()),
                ("signed", true.into()),
                ("simd", cfg!(feature = "simd").into()),
                ("n_ops", N_OPS.into()),
            ]));
        }
        print!("{}", summary.to_markdown());
        println!();
    }

    save_json("BENCH_multipliers.json", &Value::Array(json_rows))?;
    println!("throughput rows -> BENCH_multipliers.json");
    Ok(())
}
