//! Bench harness for **paper Table II**: inference accuracy after
//! training under simulated approximate-multiplier error, one training
//! run per error configuration, plus wall-time accounting per case.
//!
//! Scaled to the `tiny` preset / synthetic data so the full 9-case
//! sweep completes in minutes on CPU PJRT; the *shape* of the table
//! (benign small error, graceful degradation, collapse at MRE≈38%) is
//! the reproduction target (DESIGN.md §6). `cargo bench table2`.

use approxmul::config::ExperimentConfig;
use approxmul::coordinator::Sweep;
use approxmul::error_model::paper_table2_specs;
use approxmul::report::{diff_pct, pct, Table};
use approxmul::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let engine = Engine::from_artifacts("artifacts")?;
    let mut cfg = ExperimentConfig::preset_tiny();
    cfg.epochs = 8;
    cfg.train_examples = 1536;
    cfg.test_examples = 512;
    cfg.tag = "bench-t2".into();

    let cases = paper_table2_specs();
    let sweep = Sweep::new(&engine, cfg);
    let rows = sweep.run(&cases, |id, row| {
        eprintln!(
            "case {id}: {} -> {} ({:.1}s)",
            row.config.label(),
            pct(row.accuracy),
            row.wall_secs
        );
    })?;

    let mut t = Table::new(&[
        "Test ID", "MRE", "SD", "acc (ours)", "diff (ours)", "acc (paper)",
        "diff (paper)", "secs",
    ]);
    for r in &rows {
        let paper = r.paper_accuracy.unwrap_or(0.0);
        t.row(vec![
            r.test_id.to_string(),
            format!("~{:.1}%", 100.0 * r.config.mre()),
            format!("~{:.1}%", 100.0 * r.config.sigma()),
            pct(r.accuracy),
            if r.test_id == 0 { "N/A".into() } else { diff_pct(r.diff_from_exact) },
            pct(paper),
            if r.test_id == 0 { "N/A".into() } else { diff_pct(paper - 0.936) },
            format!("{:.1}", r.wall_secs),
        ]);
    }
    println!("\n# Table II reproduction (tiny preset, synthetic data)\n");
    print!("{}", t.to_markdown());
    println!(
        "\nshape holds: {} | total {:.1}s",
        Sweep::shape_holds(&rows),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
