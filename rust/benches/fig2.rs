//! Bench harness for **paper Figure 2**: the 500-bin histogram of a
//! sample error matrix (MRE ≈ 3.6%, SD ≈ 4.5%). Verifies the realized
//! statistics against the targets and times matrix generation (the
//! host-side twin of the in-graph Threefry path). `cargo bench fig2`.

use approxmul::benchkit::{fmt_dur, throughput, Bench};
use approxmul::error_model::{sigma_to_mre, ErrorMatrix};
use approxmul::report::{ascii_histogram, histogram_csv};

fn main() -> anyhow::Result<()> {
    let sigma = 0.045; // paper Figure 2's configuration
    let n = 1_000_000;
    let m = ErrorMatrix::generate(42, 0, sigma, n);

    println!("# Figure 2 reproduction\n");
    println!(
        "target: MRE {:.2}% SD {:.2}% | measured: MRE {:.3}% SD {:.3}% ({n} samples)",
        100.0 * sigma_to_mre(sigma),
        100.0 * sigma,
        100.0 * m.measured_mre(),
        100.0 * m.measured_sd(),
    );
    let (edges, counts) = m.histogram(500, -0.2, 0.2);
    println!("\n500-bin histogram (terminal rendering, grouped):\n");
    print!("{}", ascii_histogram(&edges, &counts, 60, 25));
    std::fs::create_dir_all("runs")?;
    std::fs::write("runs/fig2.csv", histogram_csv(&edges, &counts))?;
    println!("\nfull-resolution CSV -> runs/fig2.csv");

    // Gaussianity check at the tails (zero-mean, symmetric).
    let left: u64 = counts[..250].iter().sum();
    let right: u64 = counts[250..].iter().sum();
    let asym = (left as f64 - right as f64).abs() / n as f64;
    println!("left/right asymmetry: {:.4} (0 = symmetric)", asym);
    assert!(asym < 0.01, "error matrix is not symmetric");

    // Generation throughput (host-side error-field reconstruction).
    let mut b = Bench::micro();
    let s = b.run("ErrorMatrix::generate 1M elems", || {
        let m = ErrorMatrix::generate(43, 1, sigma, 1_000_000);
        std::hint::black_box(m.factors.len());
    });
    println!(
        "\ngeneration: median {} ({:.1} M elems/s)",
        fmt_dur(s.median()),
        throughput(s.median(), 1_000_000) / 1e6
    );
    print!("{}", b.report());
    Ok(())
}
