//! Bench harness for **paper Table III / Figure 4**: the hybrid
//! switch-epoch search. Runs the exact baseline, one checkpointed
//! approximate run per error case, then binary-searches the maximal
//! approximate utilization whose exact tail still reaches the target
//! accuracy. `cargo bench table3`.

use approxmul::config::ExperimentConfig;
use approxmul::coordinator::HybridSearch;
use approxmul::error_model::paper_table2_specs;
use approxmul::report::{pct, Table};
use approxmul::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let engine = Engine::from_artifacts("artifacts")?;
    let mut cfg = ExperimentConfig::preset_tiny();
    cfg.epochs = 10;
    cfg.train_examples = 1536;
    cfg.test_examples = 512;
    cfg.out_dir = "runs/bench-t3".into();
    cfg.tag = "bench-t3".into();

    let mut search = HybridSearch::new(&engine, cfg.clone());
    // At this scale run-to-run noise is far larger than the paper's
    // 0.02%; use a tolerance at our noise floor (see EXPERIMENTS.md).
    search.tolerance = 0.01;

    eprintln!("baseline (exact) run...");
    let baseline = search.baseline()?;
    eprintln!("baseline accuracy {}", pct(baseline.final_accuracy));

    // Paper cases 2 (MRE~1.4%), 4 (~3.6%), 6 (~9.6%), 7 (~19.2%).
    let cases: Vec<_> = paper_table2_specs()
        .into_iter()
        .filter(|(id, _, _)| [2, 4, 6, 7].contains(id))
        .collect();

    let paper_util: std::collections::BTreeMap<u32, f64> = engine
        .manifest()
        .paper
        .table3
        .iter()
        .map(|&(id, _, a, e)| (id, a as f64 / (a + e) as f64))
        .collect();

    let mut t = Table::new(&[
        "Test ID", "MRE", "approx", "exact", "util (ours)", "util (paper)",
        "acc", "evals",
    ]);
    for (id, config, _) in cases {
        eprintln!("case {id}: approximate run {}...", config.label());
        let (approx, tag) = search.approx_run(&config)?;
        let o =
            search.search(&config, baseline.final_accuracy, &tag, approx.final_accuracy)?;
        eprintln!(
            "  -> {}/{} epochs approx (util {})",
            o.approx_epochs,
            cfg.epochs,
            pct(o.utilization)
        );
        t.row(vec![
            id.to_string(),
            format!("~{:.1}%", 100.0 * config.mre()),
            o.approx_epochs.to_string(),
            o.exact_epochs.to_string(),
            pct(o.utilization),
            paper_util.get(&id).map(|u| pct(*u)).unwrap_or_else(|| "-".into()),
            pct(o.accuracy),
            o.evaluations.to_string(),
        ]);
    }
    println!("\n# Table III reproduction (tiny preset, {} epochs)\n", cfg.epochs);
    print!("{}", t.to_markdown());
    println!(
        "\nexpected shape: utilization decreases with MRE, stays high (>~50%) \
         through MRE~9.6%. total {:.1}s",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
