//! Fixture: D1v2 — audited iteration over a hash-typed field.

pub struct Cache {
    // detlint: allow(D1) -- fixture: keyed lookup cache, audited
    map: std::collections::HashMap<u32, u64>,
}

impl Cache {
    pub fn sum(&self) -> u64 {
        let mut acc = 0;
        // detlint: allow(D1v2) -- fixture: order-insensitive integer sum, audited
        for v in self.map.values() {
            acc += v;
        }
        acc
    }
}
