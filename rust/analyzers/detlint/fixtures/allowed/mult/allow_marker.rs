// detlint fixture: both findings below are suppressed by well-formed
// allow markers — the scan must report two suppressions (with their
// reasons), zero violations, and zero stale markers.

pub struct Cache {
    // detlint: allow(D1) -- lookup-only cache keyed by spec name, never iterated
    map: std::collections::HashMap<u32, u32>,
}

pub fn round_half_up(x: f32) -> u32 {
    (x + 0.5) as u32 // detlint: allow(S1) -- fixture: range proven by caller
}
