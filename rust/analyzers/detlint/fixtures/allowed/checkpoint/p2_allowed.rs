//! Fixture: P2 suppressed — a masked index into a fixed-size table
//! cannot go out of bounds.

pub fn crc_step(table: &[u32; 256], crc: u32, b: u8) -> u32 {
    // detlint: allow(P2) -- fixture: index masked to 0xFF into a 256-entry table
    (crc >> 8) ^ table[((crc ^ b as u32) & 0xFF) as usize]
}
