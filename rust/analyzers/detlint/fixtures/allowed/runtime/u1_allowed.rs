//! Fixture: U1 suppressed with an audited reason.

pub fn read(ptr: *const u8) -> u8 {
    unsafe { *ptr } // detlint: allow(U1) -- fixture: caller-audited raw read
}
