//! Fixture: ordered-container iteration is deterministic and clean.

pub fn sum(m: &std::collections::BTreeMap<u32, u64>) -> u64 {
    let mut acc = 0;
    for v in m.values() {
        acc += v;
    }
    acc
}
