// detlint fixture: the idiomatic deterministic shapes — ordered map,
// explicit fixed-order accumulation loop — must scan clean.

use std::collections::BTreeMap;

pub fn ordered_sum(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &x in xs {
        acc += x;
    }
    acc
}

pub fn keyed(map: &BTreeMap<u32, u32>, k: u32) -> Option<u32> {
    map.get(&k).copied()
}
