//! Fixture: checked access with a typed error is the spine contract.

pub fn header(bytes: &[u8]) -> Result<&[u8], String> {
    bytes
        .get(..4)
        .ok_or_else(|| format!("truncated header: {} < 4 bytes", bytes.len()))
}
