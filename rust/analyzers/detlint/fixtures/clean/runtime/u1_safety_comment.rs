//! Fixture: a SAFETY comment directly above the unsafe block is the
//! contract.

pub fn read(ptr: *const u8) -> u8 {
    // SAFETY: fixture — the caller guarantees ptr is valid for one byte;
    // the read copies it out without retaining the pointer.
    unsafe { *ptr }
}
