//! Fixture: bench row names (the mitchell family is missing).

pub fn rows() -> Vec<&'static str> {
    vec!["exact", "sexact"]
}
