//! Fixture: C1 — a registered kernel family with no parity pin and no
//! bench row.

pub struct Widget;

impl Widget {
    pub fn simd_kernel(&self) -> Option<UnsignedKernel> {
        Some(UnsignedKernel::Mitchell { bits: 8 })
    }
}
