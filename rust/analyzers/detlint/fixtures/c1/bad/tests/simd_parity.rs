//! Fixture: the parity suite's design lists (the mitchell family is
//! missing, so the registration in mult/widget.rs must fire C1).

const DESIGNS: &[&str] = &["exact"];
const SIGNED_DESIGNS: &[&str] = &["sexact"];
