//! Fixture: parity design lists covering the drum family.

const DESIGNS: &[&str] = &["exact", "drum6"];
