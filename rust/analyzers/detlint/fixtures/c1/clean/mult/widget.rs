//! Fixture: C1 — a fully pinned registration (parity entry + bench row
//! both present).

pub struct Widget;

impl Widget {
    pub fn simd_kernel(&self) -> Option<UnsignedKernel> {
        Some(UnsignedKernel::Drum { k: 6 })
    }
}
