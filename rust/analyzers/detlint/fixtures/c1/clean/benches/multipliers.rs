//! Fixture: bench row names covering the drum family.

pub fn rows() -> Vec<&'static str> {
    vec!["exact", "drum6"]
}
