//! Fixture: parity design lists without the booth family.

const DESIGNS: &[&str] = &["exact"];
