//! Fixture: bench row names without the booth family.

pub fn rows() -> Vec<&'static str> {
    vec!["exact"]
}
