//! Fixture: C1 suppressed — the registration is acknowledged as
//! unpinned, with an audited reason.

pub struct Widget;

impl Widget {
    // detlint: allow(C1) -- fixture: parity pin lands in a tracked follow-on
    pub fn simd_kernel(&self) -> Option<SignedKernel> {
        Some(SignedKernel::Booth { k: 8 })
    }
}
