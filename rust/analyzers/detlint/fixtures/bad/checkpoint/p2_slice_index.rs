//! Fixture: P2 — a panicking index in the resilience spine turns a
//! classifiable fault (short buffer) into an abort.

pub fn first_byte(bytes: &[u8]) -> u8 {
    bytes[0]
}
