// detlint fixture: P1 must fire exactly once on the `.unwrap()` below.

pub fn load_meta(bytes: &[u8]) -> u32 {
    let arr: [u8; 4] = bytes[..4].try_into().unwrap();
    u32::from_le_bytes(arr)
}
