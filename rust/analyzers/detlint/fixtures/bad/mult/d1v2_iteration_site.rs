//! Fixture: D1v2 — iterating a HashMap-typed binding leaks hash order
//! into a trajectory module, even when the type mention itself was
//! allowed for keyed lookup.

pub fn order_leak() -> u64 {
    // detlint: allow(D1) -- fixture: the binding is allowed, the iteration is not
    let table: std::collections::HashMap<u32, u64> = Default::default();
    let mut acc = 0u64;
    for (_k, v) in &table {
        acc += v;
    }
    acc
}
