// detlint fixture: D1 must fire exactly once on the HashMap below.
// (Fixtures are scanned as text, never compiled.)

pub fn lookup(table: &std::collections::HashMap<u32, u32>, key: u32) -> Option<u32> {
    table.get(&key).copied()
}
