// detlint fixture: S1 must fire exactly once on the float->int `as`
// cast below (the `0.5` literal is the float evidence).

pub fn quantize(x: f32, scale: f32) -> u32 {
    (x * scale + 0.5) as u32
}
