//! Fixture: U1 — an unsafe block with no adjacent safety comment.

pub fn as_bytes(words: &[u32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(words.as_ptr() as *const u8, words.len() * 4) }
}
