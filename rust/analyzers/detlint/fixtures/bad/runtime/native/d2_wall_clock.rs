// detlint fixture: D2 must fire exactly once on the wall-clock read
// below. The import is deliberately absent — `std::time` in a `use`
// would be a second D2 hit, and this corpus pins exactly-once firing.

pub fn step_with_stray_timing(x: f32) -> f32 {
    let t0 = Instant::now();
    let y = x * 2.0;
    let _ = t0;
    y
}
