// detlint fixture: D3 must fire exactly once on the float `.sum()`
// reduction below (f32 in the statement window is the float evidence).

pub fn loss_total(xs: &[f32]) -> f32 {
    let total: f32 = xs.iter().sum();
    total
}
