//! detlint — determinism-and-resilience lints for the approxmul tree.
//!
//! The reproduction's methodology rests on source-level invariants that
//! `rustc` cannot enforce: bit-identical trajectories (rollback replay,
//! thread-invariant GEMM, hybrid-switch comparability), panic-free
//! recovery paths, byte-stable emitted artifacts, and scalar≡SIMD
//! bit-identity. This crate makes those conventions machine-checked
//! with an expression-aware analysis engine (still no `syn`, no
//! dependencies): a real token stream with byte spans, delimiter tree
//! matching, and a per-file binding table (let / fn-arg / struct-field
//! bindings with their declared types).
//!
//! Rules:
//!
//! * **D1** — no `HashMap`/`HashSet` *mention* in trajectory/artifact
//!   modules. Keyed lookup is fine but must carry an audit marker.
//! * **D1v2** — no *iteration* over a binding whose type resolved to
//!   `HashMap`/`HashSet` (`for`, `.iter()`, `.keys()`, `.values()`,
//!   `.drain()`, ...) in those modules: the site where hash order
//!   actually leaks into a trajectory or an emitted file.
//! * **D2** — no `Instant::now`/`SystemTime`/`std::time` in step-math
//!   modules (wall-clock reads make replay diverge; `benchkit` is
//!   exempt by scope).
//! * **D3** — no raw `std::thread::spawn` outside `parallel/`, and no
//!   float `.sum()`/float-accumulator `fold` reductions in the numeric
//!   spine.
//! * **P1** — no `unwrap()`/`expect()`/panic-family macros in the
//!   resilience spine (`checkpoint`, the coordinator's health/recovery/
//!   trainer, `testkit/faults`).
//! * **P2** — no panicking slice/array indexing (`x[i]`) in the
//!   resilience spine. Index expressions are disambiguated from type
//!   and attribute brackets by expression context; `.get()` plus a
//!   typed error is the contract there.
//! * **S1** — no unchecked `as` float→int casts in `mult/`
//!   bit-decomposition paths; `mult::cast` is the single audited
//!   crossing.
//! * **U1** — every `unsafe` must be immediately preceded by a
//!   `// SAFETY:` comment (same line, or contiguous comment lines
//!   directly above).
//! * **C1** — cross-file SIMD-parity coverage: every design family
//!   registering a `simd_kernel()` descriptor in `mult/` must appear in
//!   the `tests/simd_parity.rs` design lists and carry a named bench
//!   row, so a new kernel cannot land without its bit-identity pin.
//!
//! Scan profiles keep the rule set honest per tree region: `fixtures/`
//! scans like the mirrored `src/` tree, `rust/tests/**` runs
//! D1/D1v2/D3/U1 everywhere but drops D2/P1/P2/S1 (tests may read
//! wall-clock and unwrap), and detlint's own sources dogfood
//! D1/D1v2/D3/U1.
//!
//! Suppression is explicit and auditable:
//! `// detlint: allow(<rule>[, <rule>...]) -- <reason>` on the
//! offending line, or alone on the line above it. Markers without a
//! reason, with unknown rule names, or that suppress nothing are
//! reported (the first two fail the run; stale markers warn, or fail
//! under `--strict-stale`). A `--baseline <report.json>` ratchet
//! grandfathers previously recorded violations by (rule, path,
//! message), so new findings fail while legacy ones burn down.

use std::collections::{BTreeMap, BTreeSet};

/// All known rule identifiers, in report order.
pub const RULE_IDS: [&str; 9] =
    ["D1", "D1v2", "D2", "D3", "P1", "P2", "S1", "U1", "C1"];

/// Path scopes, as `/`-separated segment sequences matched anywhere in
/// a file's path. `runtime/native` matches `rust/src/runtime/native/x.rs`
/// but not `rust/src/runtime/engine.rs`. The special scope `"*"`
/// matches every path.
const D1_SCOPE: &[&str] = &[
    "mult",
    "runtime",
    "coordinator",
    "rng",
    "tensor",
    "data",
    "config",
    "metrics",
    "benchkit",
    "report",
    "json",
    "checkpoint",
    "serve",
];
const D2_SCOPE: &[&str] =
    &["mult", "runtime/native", "rng", "tensor", "data", "coordinator", "serve"];
/// Modules allowed to spawn threads (the deterministic fork-join
/// substrate every parallel caller routes through).
const D3_SPAWN_EXEMPT: &[&str] = &["parallel"];
const D3_REDUCE_SCOPE: &[&str] = &["mult", "runtime/native", "tensor", "data", "rng", "serve"];
const P1_SCOPE: &[&str] = &[
    "checkpoint",
    "coordinator/health.rs",
    "coordinator/recovery.rs",
    "coordinator/trainer.rs",
    "testkit/faults.rs",
    "serve",
];
const P2_SCOPE: &[&str] = P1_SCOPE;
const S1_SCOPE: &[&str] = &["mult"];
const U1_SCOPE: &[&str] = &["*"];
const C1_SCOPE: &[&str] = &["mult"];
const ALL_SCOPE: &[&str] = &["*"];

/// Static description of one rule (for `--list-rules` and docs).
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    pub id: &'static str,
    /// `deny` rules fail the run; `warn` rules only report.
    pub severity: &'static str,
    pub scope: &'static [&'static str],
    pub summary: &'static str,
    pub rationale: &'static str,
}

pub const RULES: [RuleInfo; 9] = [
    RuleInfo {
        id: "D1",
        severity: "deny",
        scope: D1_SCOPE,
        summary: "no HashMap/HashSet in trajectory or artifact modules",
        rationale: "hash iteration order is per-process random; iterating one leaks \
                    that order into trajectories or emitted files. Use BTreeMap/BTreeSet, \
                    or annotate a lookup-only use.",
    },
    RuleInfo {
        id: "D1v2",
        severity: "deny",
        scope: D1_SCOPE,
        summary: "no iteration over HashMap/HashSet-typed bindings in trajectory \
                  or artifact modules",
        rationale: "type-level D1 can be suppressed for keyed lookup; this rule tracks \
                    the binding to its iteration sites (for / .iter() / .keys() / \
                    .values() / .drain()), where hash order actually leaks.",
    },
    RuleInfo {
        id: "D2",
        severity: "deny",
        scope: D2_SCOPE,
        summary: "no Instant::now/SystemTime/std::time in step-math modules",
        rationale: "wall-clock reads in the step path break bit-identical rollback \
                    replay. benchkit is exempt by scope; backoff delays and throughput \
                    telemetry carry audit markers.",
    },
    RuleInfo {
        id: "D3",
        severity: "deny",
        scope: D3_REDUCE_SCOPE,
        summary: "no raw thread::spawn outside parallel/; no float sum/fold \
                  reductions in the numeric spine",
        rationale: "ad-hoc threading and reassociated float reductions make results \
                    depend on scheduling. Use parallel::par_map/par_chunks_mut and the \
                    k-ordered GEMM kernels; annotate sequential fixed-order sums.",
    },
    RuleInfo {
        id: "P1",
        severity: "deny",
        scope: P1_SCOPE,
        summary: "no unwrap/expect/panic-family in the resilience spine",
        rationale: "the watchdog's contract is that every fault surfaces as a typed \
                    error it can classify and recover from; a panic escalates a \
                    recoverable fault into an abort.",
    },
    RuleInfo {
        id: "P2",
        severity: "deny",
        scope: P2_SCOPE,
        summary: "no panicking slice/array indexing in the resilience spine",
        rationale: "`x[i]` panics on a short or corrupt buffer, turning a classifiable \
                    fault (e.g. a truncated checkpoint) into an abort; use \
                    .get()/.get_mut() and raise a typed error.",
    },
    RuleInfo {
        id: "S1",
        severity: "deny",
        scope: S1_SCOPE,
        summary: "no unchecked `as` float->int casts in mult/ decomposition paths",
        rationale: "bare float->int `as` casts saturate/truncate silently and have \
                    caused bit-domain bugs; route through the audited helpers in \
                    mult::cast.",
    },
    RuleInfo {
        id: "U1",
        severity: "deny",
        scope: U1_SCOPE,
        summary: "every `unsafe` must be immediately preceded by a `// SAFETY:` comment",
        rationale: "an unsafe block encodes a proof obligation the compiler cannot \
                    check; the SAFETY comment is where that proof lives, and drift \
                    between code and proof is how UB ships.",
    },
    RuleInfo {
        id: "C1",
        severity: "deny",
        scope: C1_SCOPE,
        summary: "every simd_kernel() registration needs a simd_parity.rs design \
                  entry and a named bench row",
        rationale: "the scalar<->SIMD bit-identity claim only holds for kernels pinned \
                    by the parity suite; a registered kernel family without its parity \
                    entry and bench row is an unverified fast path.",
    },
];

/// One rule violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub message: String,
}

/// One used `detlint: allow` marker (the audit trail).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Suppression {
    pub rule: String,
    pub path: String,
    pub line: usize,
    pub reason: String,
}

/// A malformed or stale marker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MarkerProblem {
    pub path: String,
    pub line: usize,
    pub message: String,
}

/// Aggregated scan results.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    pub violations: Vec<Violation>,
    pub suppressions: Vec<Suppression>,
    /// Malformed markers: fail the run (an unparseable suppression is
    /// worse than a violation — it silently suppresses nothing).
    pub marker_problems: Vec<MarkerProblem>,
    /// Markers that suppressed nothing: warn only (fail under
    /// `--strict-stale`).
    pub stale_markers: Vec<MarkerProblem>,
    /// Violations matched against a `--baseline` report: reported for
    /// visibility, but do not fail the run (the ratchet).
    pub grandfathered: Vec<Violation>,
}

impl Report {
    pub fn merge(&mut self, other: Report) {
        self.files_scanned += other.files_scanned;
        self.violations.extend(other.violations);
        self.suppressions.extend(other.suppressions);
        self.marker_problems.extend(other.marker_problems);
        self.stale_markers.extend(other.stale_markers);
        self.grandfathered.extend(other.grandfathered);
    }

    /// True when the run should exit nonzero (before `--strict-stale`,
    /// which the CLI layers on top).
    pub fn failed(&self) -> bool {
        !self.violations.is_empty() || !self.marker_problems.is_empty()
    }

    /// Move every violation matching a baseline entry (by rule, path,
    /// message — line numbers drift and are ignored) into
    /// `grandfathered`. Each baseline entry grandfathers at most one
    /// violation, so *adding* a second identical finding still fails.
    pub fn apply_baseline(&mut self, baseline: &[(String, String, String)]) {
        let mut budget: BTreeMap<(&str, &str, &str), usize> = BTreeMap::new();
        for (r, p, m) in baseline {
            *budget.entry((r.as_str(), p.as_str(), m.as_str())).or_insert(0) += 1;
        }
        let mut kept = Vec::new();
        for v in std::mem::take(&mut self.violations) {
            let key = (v.rule, v.path.as_str(), v.message.as_str());
            match budget.get_mut(&key) {
                Some(n) if *n > 0 => {
                    *n -= 1;
                    self.grandfathered.push(v);
                }
                _ => kept.push(v),
            }
        }
        self.violations = kept;
    }
}

// --------------------------------------------------------------------------
// Lexing: a real token stream with byte spans. Comments are collected
// separately (line comments only — they carry the allow markers and the
// SAFETY audit trail).
// --------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TokKind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
}

#[derive(Debug, Clone, Copy)]
struct Tok {
    kind: TokKind,
    pos: usize,
    end: usize,
    line: usize,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn find_byte(hay: &[u8], from: usize, needle: u8) -> Option<usize> {
    hay.iter().skip(from).position(|&b| b == needle).map(|p| p + from)
}

struct Lexed {
    toks: Vec<Tok>,
    /// `(line, text)` of every `//` comment.
    comments: Vec<(usize, String)>,
    line_starts: Vec<usize>,
}

fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let n = b.len();
    let mut line_starts = vec![0usize];
    for (i, &c) in b.iter().enumerate() {
        if c == b'\n' {
            line_starts.push(i + 1);
        }
    }
    let line_of = |pos: usize| -> usize {
        match line_starts.binary_search(&pos) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    };
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == b' ' || c == b'\t' || c == b'\r' || c == b'\n' {
            i += 1;
            continue;
        }
        // Line comment.
        if b[i..].starts_with(b"//") {
            let j = find_byte(b, i, b'\n').unwrap_or(n);
            comments.push((line_of(i), String::from_utf8_lossy(&b[i..j]).into_owned()));
            i = j;
            continue;
        }
        // Block comment (nested, per Rust). Not recorded: markers and
        // SAFETY audits are line-comment-only by contract.
        if b[i..].starts_with(b"/*") {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j..].starts_with(b"/*") {
                    depth += 1;
                    j += 2;
                } else if b[j..].starts_with(b"*/") {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        let left_bound = i == 0 || !is_ident(b[i - 1]);
        // Raw (and byte-raw) strings: r"..", r#".."#, br"..", br#".."#.
        // `r`/`br` followed by hashes but no quote is a raw identifier
        // (r#fn) — fall through in that case.
        if left_bound && (c == b'r' || (c == b'b' && b[i..].starts_with(b"br"))) {
            let mut k = if c == b'b' { i + 2 } else { i + 1 };
            let mut hashes = 0usize;
            while k < n && b[k] == b'#' {
                hashes += 1;
                k += 1;
            }
            if k < n && b[k] == b'"' {
                let mut j = k + 1;
                let end;
                loop {
                    match find_byte(b, j, b'"') {
                        Some(q) => {
                            let mut h = 0usize;
                            while h < hashes && q + 1 + h < n && b[q + 1 + h] == b'#' {
                                h += 1;
                            }
                            if h == hashes {
                                end = q + 1 + hashes;
                                break;
                            }
                            j = q + 1;
                        }
                        None => {
                            end = n;
                            break;
                        }
                    }
                }
                toks.push(Tok { kind: TokKind::Str, pos: i, end, line: line_of(i) });
                i = end;
                continue;
            }
        }
        // Plain and byte strings. An escape always consumes the next
        // byte, which also handles `\`-newline string continuations.
        let is_str = c == b'"' || (left_bound && c == b'b' && i + 1 < n && b[i + 1] == b'"');
        if is_str {
            let q0 = if c == b'b' { i + 1 } else { i };
            let mut j = q0 + 1;
            while j < n {
                match b[j] {
                    b'\\' => j += 2,
                    b'"' => {
                        j += 1;
                        break;
                    }
                    _ => j += 1,
                }
            }
            let j = j.min(n);
            toks.push(Tok { kind: TokKind::Str, pos: i, end: j, line: line_of(i) });
            i = j;
            continue;
        }
        // Char literal vs lifetime: '\...' and 'x' are literals;
        // anything else is a lifetime token.
        if c == b'\'' {
            if i + 1 < n && b[i + 1] == b'\\' {
                let j = find_byte(b, i + 2, b'\'').map(|p| p + 1).unwrap_or(n);
                toks.push(Tok { kind: TokKind::Char, pos: i, end: j, line: line_of(i) });
                i = j;
                continue;
            }
            if i + 2 < n && b[i + 2] == b'\'' {
                toks.push(Tok { kind: TokKind::Char, pos: i, end: i + 3, line: line_of(i) });
                i += 3;
                continue;
            }
            let mut j = i + 1;
            while j < n && is_ident(b[j]) {
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Lifetime, pos: i, end: j, line: line_of(i) });
            i = j;
            continue;
        }
        // Number: digits, then ident-ish chars (hex digits, suffixes),
        // then an optional `.digits` fraction (but not `0..4` ranges).
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n && is_ident(b[j]) {
                j += 1;
            }
            if j + 1 < n && b[j] == b'.' && b[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && is_ident(b[j]) {
                    j += 1;
                }
            }
            toks.push(Tok { kind: TokKind::Num, pos: i, end: j, line: line_of(i) });
            i = j;
            continue;
        }
        if is_ident(c) {
            let mut j = i + 1;
            while j < n && is_ident(b[j]) {
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Ident, pos: i, end: j, line: line_of(i) });
            i = j;
            continue;
        }
        toks.push(Tok { kind: TokKind::Punct, pos: i, end: i + 1, line: line_of(i) });
        i += 1;
    }
    Lexed { toks, comments, line_starts }
}

// --------------------------------------------------------------------------
// File context: tokens + delimiter tree + test mask + line bookkeeping.
// --------------------------------------------------------------------------

struct Fx<'a> {
    src: &'a str,
    toks: Vec<Tok>,
    /// Partner index for each `( ) [ ] { }` punct token.
    partner: Vec<Option<usize>>,
    /// Token is inside a `#[cfg(test)]` / `#[test]` region.
    mask: Vec<bool>,
    comments: Vec<(usize, String)>,
    /// 1-indexed; `line_has_code[l]` = some token starts or continues
    /// on line `l`.
    line_has_code: Vec<bool>,
    n_lines: usize,
}

impl<'a> Fx<'a> {
    fn new(src: &'a str) -> Fx<'a> {
        let Lexed { toks, comments, line_starts } = lex(src);
        let n_lines = line_starts.len();
        let line_of = |pos: usize| -> usize {
            match line_starts.binary_search(&pos) {
                Ok(i) => i + 1,
                Err(i) => i,
            }
        };
        let mut line_has_code = vec![false; n_lines + 2];
        for t in &toks {
            let a = t.line;
            let b = line_of(t.end.saturating_sub(1).max(t.pos));
            for l in a..=b.min(n_lines) {
                line_has_code[l] = true;
            }
        }
        let mut fx = Fx {
            src,
            toks,
            partner: Vec::new(),
            mask: Vec::new(),
            comments,
            line_has_code,
            n_lines,
        };
        fx.partner = fx.match_delims();
        fx.mask = fx.test_mask();
        fx
    }

    fn text(&self, i: usize) -> &str {
        let t = &self.toks[i];
        &self.src[t.pos..t.end]
    }

    fn ident_is(&self, i: usize, s: &str) -> bool {
        self.toks.get(i).is_some_and(|t| t.kind == TokKind::Ident) && self.text(i) == s
    }

    fn punct_is(&self, i: usize, c: u8) -> bool {
        self.toks
            .get(i)
            .is_some_and(|t| t.kind == TokKind::Punct && self.src.as_bytes()[t.pos] == c)
    }

    fn match_delims(&self) -> Vec<Option<usize>> {
        let mut partner = vec![None; self.toks.len()];
        let mut stack: Vec<(u8, usize)> = Vec::new();
        for (i, t) in self.toks.iter().enumerate() {
            if t.kind != TokKind::Punct {
                continue;
            }
            match self.src.as_bytes()[t.pos] {
                c @ (b'(' | b'[' | b'{') => stack.push((c, i)),
                c @ (b')' | b']' | b'}') => {
                    let open = match c {
                        b')' => b'(',
                        b']' => b'[',
                        _ => b'{',
                    };
                    while let Some((oc, oi)) = stack.pop() {
                        if oc == open {
                            partner[oi] = Some(i);
                            partner[i] = Some(oi);
                            break;
                        }
                    }
                }
                _ => {}
            }
        }
        partner
    }

    /// Mask tokens under `#[cfg(test)]` / `#[test]` attributes: the
    /// attribute's item (up to the matching `}` of its first brace, or
    /// a terminating `;`) plays by different rules.
    fn test_mask(&self) -> Vec<bool> {
        let mut mask = vec![false; self.toks.len()];
        let n = self.toks.len();
        let mut i = 0usize;
        while i < n {
            let attr_end = if self.punct_is(i, b'#') && self.punct_is(i + 1, b'[') {
                if self.ident_is(i + 2, "test") && self.punct_is(i + 3, b']') {
                    Some(i + 3)
                } else if self.ident_is(i + 2, "cfg")
                    && self.punct_is(i + 3, b'(')
                    && self.ident_is(i + 4, "test")
                    && self.punct_is(i + 5, b')')
                    && self.punct_is(i + 6, b']')
                {
                    Some(i + 6)
                } else {
                    None
                }
            } else {
                None
            };
            if let Some(e) = attr_end {
                let mut j = e + 1;
                let mut end = n;
                while j < n {
                    if self.punct_is(j, b';') {
                        end = j + 1;
                        break;
                    }
                    if self.punct_is(j, b'{') {
                        end = self.partner[j].map(|p| p + 1).unwrap_or(n);
                        break;
                    }
                    j += 1;
                }
                for m in &mut mask[i..end.min(n)] {
                    *m = true;
                }
                i = e + 1;
                continue;
            }
            i += 1;
        }
        mask
    }

    /// Index of the first token of the statement containing token `i`
    /// (the token after the previous `;`, `{`, or `}`).
    fn stmt_start(&self, i: usize) -> usize {
        let mut j = i;
        while j > 0 {
            let p = j - 1;
            if self.punct_is(p, b';') || self.punct_is(p, b'{') || self.punct_is(p, b'}') {
                break;
            }
            j -= 1;
        }
        j
    }

    /// Heuristic: does the token range `[a, b)` mention float
    /// arithmetic? Word `f32`/`f64` or a float literal counts; the
    /// bit-domain constructors `f32::from_bits`/`f64::from_bits` are
    /// ignored (they take integers).
    fn float_evidence(&self, a: usize, b: usize) -> bool {
        for i in a..b.min(self.toks.len()) {
            match self.toks[i].kind {
                TokKind::Ident => {
                    let t = self.text(i);
                    if (t == "f32" || t == "f64")
                        && !(self.punct_is(i + 1, b':')
                            && self.punct_is(i + 2, b':')
                            && self.ident_is(i + 3, "from_bits"))
                    {
                        return true;
                    }
                }
                TokKind::Num => {
                    let t = self.text(i).as_bytes();
                    if t.windows(3).any(|w| {
                        w[0].is_ascii_digit() && w[1] == b'.' && w[2].is_ascii_digit()
                    }) {
                        return true;
                    }
                }
                _ => {}
            }
        }
        false
    }
}

// --------------------------------------------------------------------------
// Allow markers.
// --------------------------------------------------------------------------

struct Marker {
    /// Line the comment sits on.
    line: usize,
    /// Line the marker applies to (same line, or the next one for a
    /// comment-only line).
    target: usize,
    rules: Vec<String>,
    reason: String,
}

/// `Some(Err(..))` = a detlint marker that failed to parse; `None` = not
/// a marker at all. A marker must be the *whole* comment (after the
/// `//`/`///`/`//!` introducer): prose that merely mentions
/// `detlint: allow(...)` mid-sentence is not a marker, so docs — these
/// docs included — can describe the syntax without tripping the parser.
fn parse_marker(text: &str) -> Option<Result<(Vec<String>, String), String>> {
    let t = text.trim_start_matches(|c| c == '/' || c == '!').trim_start();
    let rest = t.strip_prefix("detlint:")?.trim_start();
    let rest = match rest.strip_prefix("allow(") {
        Some(r) => r,
        None => return Some(Err("expected `allow(<rules>)` after `detlint:`".into())),
    };
    let close = match rest.find(')') {
        Some(c) => c,
        None => return Some(Err("unclosed `allow(`".into())),
    };
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if rules.is_empty() {
        return Some(Err("empty rule list in `allow()`".into()));
    }
    for r in &rules {
        if !RULE_IDS.contains(&r.as_str()) {
            return Some(Err(format!("unknown rule `{r}` in allow marker")));
        }
    }
    let tail = rest[close + 1..].trim_start();
    let reason = match tail.strip_prefix("--") {
        Some(r) => r.trim().to_string(),
        None => return Some(Err("marker missing `-- <reason>`".into())),
    };
    if reason.is_empty() {
        return Some(Err("marker missing `-- <reason>`".into()));
    }
    Some(Ok((rules, reason)))
}

// --------------------------------------------------------------------------
// Scope matching and scan profiles.
// --------------------------------------------------------------------------

/// Does `path` fall under any of `scopes`? A scope is a `/`-separated
/// run of path segments matched anywhere in the (normalized) path; the
/// special scope `"*"` matches everything.
pub fn in_scope(path: &str, scopes: &[&str]) -> bool {
    if scopes.contains(&"*") {
        return true;
    }
    let norm = path.replace('\\', "/");
    let segs: Vec<&str> = norm.split('/').filter(|s| !s.is_empty()).collect();
    scopes.iter().any(|scope| {
        let want: Vec<&str> = scope.split('/').collect();
        !want.is_empty()
            && segs.len() >= want.len()
            && segs.windows(want.len()).any(|w| w == want.as_slice())
    })
}

/// Which rule set a file is scanned under, by tree region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    /// The mirrored `src/` layout: all rules at their native scopes.
    Default,
    /// `rust/tests/**`: D1/D1v2/D3/U1 everywhere in the file; D2/P1/
    /// P2/S1 off (tests may read wall-clock and unwrap).
    Tests,
    /// detlint's own sources: dogfood D1/D1v2/D3/U1 everywhere.
    Analyzer,
}

/// Profile precedence: a `fixtures` segment wins (fixture corpora
/// mirror the src tree even under `analyzers/`), then `analyzers`,
/// then `tests`.
pub fn profile_for(path: &str) -> Profile {
    let norm = path.replace('\\', "/");
    let segs: Vec<&str> = norm.split('/').filter(|s| !s.is_empty()).collect();
    if segs.contains(&"fixtures") {
        Profile::Default
    } else if segs.contains(&"analyzers") {
        Profile::Analyzer
    } else if segs.contains(&"tests") {
        Profile::Tests
    } else {
        Profile::Default
    }
}

/// Effective scope per rule under a profile; `None` = rule off.
fn rule_scope(profile: Profile, rule: &str) -> Option<&'static [&'static str]> {
    match profile {
        Profile::Default => Some(match rule {
            "D1" | "D1v2" => D1_SCOPE,
            "D2" => D2_SCOPE,
            "D3" => D3_REDUCE_SCOPE,
            "P1" => P1_SCOPE,
            "P2" => P2_SCOPE,
            "S1" => S1_SCOPE,
            "U1" => U1_SCOPE,
            "C1" => C1_SCOPE,
            _ => return None,
        }),
        Profile::Tests | Profile::Analyzer => match rule {
            "D1" | "D1v2" | "D3" | "U1" => Some(ALL_SCOPE),
            _ => None,
        },
    }
}

// --------------------------------------------------------------------------
// Binding table: let / fn-arg / struct-field bindings with their
// declared (or RHS-inferred) types.
// --------------------------------------------------------------------------

#[derive(Debug)]
struct Binding {
    name: String,
    ty: String,
    pos: usize,
}

fn contains_word(hay: &str, word: &str) -> bool {
    let hb = hay.as_bytes();
    let mut from = 0usize;
    while let Some(p) = hay[from..].find(word).map(|p| p + from) {
        let before_ok = p == 0 || !is_ident(hb[p - 1]);
        let after = p + word.len();
        let after_ok = after >= hb.len() || !is_ident(hb[after]);
        if before_ok && after_ok {
            return true;
        }
        from = p + 1;
    }
    false
}

/// A single-`:` punct (not part of `::`).
fn lone_colon(fx: &Fx, i: usize) -> bool {
    fx.punct_is(i, b':')
        && !fx.punct_is(i + 1, b':')
        && !(i > 0 && fx.punct_is(i - 1, b':'))
}

fn collect_bindings(fx: &Fx) -> Vec<Binding> {
    let n = fx.toks.len();
    let mut out: Vec<Binding> = Vec::new();
    // One parameter or field segment: `... name : ty...`.
    let mut push_segment = |fx: &Fx, a: usize, b: usize, out: &mut Vec<Binding>| {
        let mut colon = None;
        let mut depth = 0i32;
        let mut angle = 0i32;
        for i in a..b {
            if fx.toks[i].kind == TokKind::Punct {
                match fx.src.as_bytes()[fx.toks[i].pos] {
                    b'(' | b'[' | b'{' => depth += 1,
                    b')' | b']' | b'}' => depth -= 1,
                    b'<' => angle += 1,
                    b'>' => angle -= 1,
                    _ => {}
                }
            }
            if depth == 0 && angle == 0 && lone_colon(fx, i) {
                colon = Some(i);
                break;
            }
        }
        let Some(c) = colon else { return };
        // Name: last ident before the colon (skips `pub`, `mut`, ...).
        let mut name = None;
        for i in (a..c).rev() {
            if fx.toks[i].kind == TokKind::Ident {
                let t = fx.text(i);
                if t != "mut" && t != "ref" {
                    name = Some((t.to_string(), fx.toks[i].pos));
                }
                break;
            }
        }
        let Some((name, pos)) = name else { return };
        let ty: String = (c + 1..b).map(|i| fx.text(i)).collect();
        out.push(Binding { name, ty, pos });
    };
    // Split `[open+1, close)` into comma segments at depth 0.
    let split_segments = |fx: &Fx, open: usize, close: usize, out: &mut Vec<Binding>,
                          push: &mut dyn FnMut(&Fx, usize, usize, &mut Vec<Binding>)| {
        let mut seg = open + 1;
        let mut depth = 0i32;
        let mut angle = 0i32;
        let mut i = open + 1;
        while i <= close {
            let boundary =
                i == close || (depth == 0 && angle <= 0 && fx.punct_is(i, b','));
            if boundary {
                if seg < i {
                    push(fx, seg, i, out);
                }
                seg = i + 1;
                if fx.punct_is(i, b',') {
                    angle = angle.max(0);
                }
            } else if fx.toks[i].kind == TokKind::Punct {
                match fx.src.as_bytes()[fx.toks[i].pos] {
                    b'(' | b'[' | b'{' => depth += 1,
                    b')' | b']' | b'}' => depth -= 1,
                    b'<' => angle += 1,
                    b'>' => angle -= 1,
                    _ => {}
                }
            }
            i += 1;
        }
    };
    let mut i = 0usize;
    while i < n {
        // `let [mut] name: Ty = ...` / `let [mut] name = <rhs>;`
        if fx.ident_is(i, "let") {
            let mut j = i + 1;
            if fx.ident_is(j, "mut") {
                j += 1;
            }
            if fx.toks.get(j).is_some_and(|t| t.kind == TokKind::Ident) {
                let name = fx.text(j).to_string();
                let pos = fx.toks[j].pos;
                let k = j + 1;
                if lone_colon(fx, k) {
                    let mut ty = String::new();
                    let mut m = k + 1;
                    let mut angle = 0i32;
                    while m < n {
                        if angle <= 0 && (fx.punct_is(m, b'=') || fx.punct_is(m, b';')) {
                            break;
                        }
                        if fx.punct_is(m, b'<') {
                            angle += 1;
                        } else if fx.punct_is(m, b'>') {
                            angle -= 1;
                        }
                        ty.push_str(fx.text(m));
                        m += 1;
                    }
                    out.push(Binding { name, ty, pos });
                } else if fx.punct_is(k, b'=') && !fx.punct_is(k + 1, b'=') {
                    // RHS inference: a hash container constructor names
                    // its type on the right-hand side.
                    let mut m = k + 1;
                    let mut depth = 0i32;
                    let mut ty = String::new();
                    while m < n {
                        if depth == 0 && fx.punct_is(m, b';') {
                            break;
                        }
                        if fx.toks[m].kind == TokKind::Punct {
                            match fx.src.as_bytes()[fx.toks[m].pos] {
                                b'(' | b'[' | b'{' => depth += 1,
                                b')' | b']' | b'}' => depth -= 1,
                                _ => {}
                            }
                        } else if fx.toks[m].kind == TokKind::Ident
                            && (fx.text(m) == "HashMap" || fx.text(m) == "HashSet")
                        {
                            ty = fx.text(m).to_string();
                        }
                        m += 1;
                    }
                    if !ty.is_empty() {
                        out.push(Binding { name, ty, pos });
                    }
                }
            }
            i += 1;
            continue;
        }
        // `fn name(params...)`
        if fx.ident_is(i, "fn") {
            let mut j = i + 1;
            let mut angle = 0i32;
            while j < n {
                if fx.punct_is(j, b'<') {
                    angle += 1;
                } else if fx.punct_is(j, b'>') {
                    angle -= 1;
                } else if angle <= 0
                    && (fx.punct_is(j, b'{') || fx.punct_is(j, b';'))
                {
                    break;
                } else if angle <= 0 && fx.punct_is(j, b'(') {
                    if let Some(close) = fx.partner[j] {
                        split_segments(fx, j, close, &mut out, &mut push_segment);
                    }
                    break;
                }
                j += 1;
            }
            i += 1;
            continue;
        }
        // `struct Name { fields... }`
        if fx.ident_is(i, "struct")
            && fx.toks.get(i + 1).is_some_and(|t| t.kind == TokKind::Ident)
        {
            let mut j = i + 2;
            let mut angle = 0i32;
            while j < n {
                if fx.punct_is(j, b'<') {
                    angle += 1;
                } else if fx.punct_is(j, b'>') {
                    angle -= 1;
                } else if angle <= 0
                    && (fx.punct_is(j, b';') || fx.punct_is(j, b'('))
                {
                    break; // unit or tuple struct
                } else if angle <= 0 && fx.punct_is(j, b'{') {
                    if let Some(close) = fx.partner[j] {
                        split_segments(fx, j, close, &mut out, &mut push_segment);
                    }
                    break;
                }
                j += 1;
            }
            i += 1;
            continue;
        }
        i += 1;
    }
    out
}

fn resolve<'b>(bindings: &'b [Binding], name: &str, pos: usize) -> Option<&'b Binding> {
    let mut before: Option<&Binding> = None;
    let mut after: Option<&Binding> = None;
    for b in bindings.iter().filter(|b| b.name == name) {
        if b.pos <= pos {
            if before.is_none_or(|x| b.pos >= x.pos) {
                before = Some(b);
            }
        } else if after.is_none_or(|x| b.pos < x.pos) {
            after = Some(b);
        }
    }
    before.or(after)
}

fn hash_typed(b: &Binding) -> bool {
    contains_word(&b.ty, "HashMap") || contains_word(&b.ty, "HashSet")
}

// --------------------------------------------------------------------------
// Per-file analysis.
// --------------------------------------------------------------------------

struct Candidate {
    pos: usize,
    line: usize,
    rule: &'static str,
    message: String,
}

/// Everything a single file contributes to a scan. Cross-file rules
/// (C1) and stale-marker accounting resolve in [`finalize`].
struct FileAnalysis {
    path: String,
    violations: Vec<Violation>,
    suppressions: Vec<Suppression>,
    marker_problems: Vec<MarkerProblem>,
    markers: Vec<Marker>,
    used: BTreeSet<(usize, String)>,
    allow: BTreeMap<usize, BTreeMap<String, String>>,
    /// `(family, line)` of each `simd_kernel()` registration.
    registrations: Vec<(String, usize)>,
    parity_seen: bool,
    parity_families: BTreeSet<String>,
    bench_seen: bool,
    bench_families: BTreeSet<String>,
}

const INT_TYPES: [&str; 12] = [
    "i8", "i16", "i32", "i64", "i128", "isize", "u8", "u16", "u32", "u64", "u128",
    "usize",
];

/// Keywords that can directly precede a `[` without forming an index
/// expression (`return [..]`, `match [..]`, ...).
const NON_INDEX_KEYWORDS: [&str; 28] = [
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn",
    "else", "enum", "extern", "fn", "for", "if", "impl", "in", "let", "loop",
    "match", "mod", "move", "mut", "pub", "ref", "return", "use", "where",
];

const ITER_METHODS: [&str; 9] = [
    "drain", "into_iter", "into_keys", "into_values", "iter", "iter_mut", "keys",
    "values", "values_mut",
];

/// The leading lowercase-letter run of a design spec names its family
/// (`"lut8:drum6"` -> `lut`, `"sdrum6"` -> `sdrum`).
fn design_family(spec: &str) -> String {
    spec.bytes()
        .take_while(|b| b.is_ascii_lowercase())
        .map(|b| b as char)
        .collect()
}

/// Literal content of a string token (quotes, `b`/`r` prefixes and raw
/// hashes stripped).
fn str_content<'a>(fx: &Fx<'a>, i: usize) -> &'a str {
    let t = fx.text(i);
    let Some(a) = t.find('"') else { return "" };
    let Some(b) = t.rfind('"') else { return "" };
    if b > a { &fx.src[fx.toks[i].pos + a + 1..fx.toks[i].pos + b] } else { "" }
}

fn kernel_family(kernel_enum: &str, variant: &str) -> Option<&'static str> {
    Some(match (kernel_enum, variant) {
        ("UnsignedKernel", "Exact") => "exact",
        ("UnsignedKernel", "Drum") => "drum",
        ("UnsignedKernel", "Trunc") => "trunc",
        ("UnsignedKernel", "Mitchell") => "mitchell",
        ("UnsignedKernel", "Flat") => "lut",
        ("SignedKernel", "Exact") => "sexact",
        ("SignedKernel", "SDrum") => "sdrum",
        ("SignedKernel", "Booth") => "booth",
        ("SignedKernel", "Flat") => "slut",
        _ => return None,
    })
}

fn analyze_file(path: &str, src: &str) -> FileAnalysis {
    let fx = Fx::new(src);
    let profile = profile_for(path);
    let on = |rule: &str| -> bool {
        rule_scope(profile, rule).is_some_and(|s| in_scope(path, s))
    };

    // Markers.
    let mut marker_problems: Vec<MarkerProblem> = Vec::new();
    let mut markers: Vec<Marker> = Vec::new();
    for (line, text) in &fx.comments {
        match parse_marker(text) {
            None => {}
            Some(Err(msg)) => marker_problems.push(MarkerProblem {
                path: path.to_string(),
                line: *line,
                message: msg,
            }),
            Some(Ok((rules, reason))) => {
                let target = if !fx.line_has_code[*line] { *line + 1 } else { *line };
                markers.push(Marker { line: *line, target, rules, reason });
            }
        }
    }
    let mut allow: BTreeMap<usize, BTreeMap<String, String>> = BTreeMap::new();
    for m in &markers {
        let entry = allow.entry(m.target).or_default();
        for r in &m.rules {
            entry.insert(r.clone(), m.reason.clone());
        }
    }

    let n = fx.toks.len();
    let mut cands: Vec<Candidate> = Vec::new();
    let push = |cands: &mut Vec<Candidate>, i: usize, rule: &'static str, msg: String| {
        cands.push(Candidate {
            pos: fx.toks[i].pos,
            line: fx.toks[i].line,
            rule,
            message: msg,
        });
    };

    let bindings = if on("D1v2") { collect_bindings(&fx) } else { Vec::new() };
    let mut d1v2_seen: BTreeSet<(usize, String)> = BTreeSet::new();
    let mut d1v2_site = |cands: &mut Vec<Candidate>, i: usize, name: &str, ty: &str| {
        if !d1v2_seen.insert((fx.toks[i].line, name.to_string())) {
            return;
        }
        cands.push(Candidate {
            pos: fx.toks[i].pos,
            line: fx.toks[i].line,
            rule: "D1v2",
            message: format!(
                "iteration over hash-ordered binding `{name}` (type `{ty}`) leaks \
                 per-process order into a trajectory/artifact module (use \
                 BTreeMap/BTreeSet, or restructure to keyed lookup)"
            ),
        });
    };

    for i in 0..n {
        if fx.mask[i] {
            continue;
        }
        let kind = fx.toks[i].kind;
        if kind == TokKind::Ident {
            let t = fx.text(i);
            // D1: any HashMap/HashSet mention.
            if on("D1") && (t == "HashMap" || t == "HashSet") {
                push(&mut cands, i, "D1", format!(
                    "hash-ordered container `{t}` in a trajectory/artifact module \
                     (iteration order leaks; use BTreeMap/BTreeSet or annotate a \
                     lookup-only use)"
                ));
            }
            // D2: wall-clock reads.
            if on("D2") {
                let pat = if t == "Instant"
                    && fx.punct_is(i + 1, b':')
                    && fx.punct_is(i + 2, b':')
                    && fx.ident_is(i + 3, "now")
                {
                    Some("Instant::now")
                } else if t == "SystemTime" {
                    Some("SystemTime")
                } else if t == "std"
                    && fx.punct_is(i + 1, b':')
                    && fx.punct_is(i + 2, b':')
                    && fx.ident_is(i + 3, "time")
                {
                    Some("std::time")
                } else {
                    None
                };
                if let Some(pat) = pat {
                    push(&mut cands, i, "D2", format!(
                        "wall-clock `{pat}` in a step-math module (breaks bit-identical \
                         replay; move timing out of the step path or annotate \
                         telemetry-only use)"
                    ));
                }
            }
            // D3: raw thread::spawn outside parallel/.
            if t == "thread"
                && fx.punct_is(i + 1, b':')
                && fx.punct_is(i + 2, b':')
                && fx.ident_is(i + 3, "spawn")
                && !in_scope(path, D3_SPAWN_EXEMPT)
            {
                push(&mut cands, i, "D3", "raw `thread::spawn` outside parallel/ (use \
                      parallel::par_map / par_chunks_mut, which keep results \
                      thread-count invariant)".into());
            }
            // D3: float reductions.
            if on("D3") && i > 0 && fx.punct_is(i - 1, b'.') {
                if t == "sum" {
                    let turbofish = fx.punct_is(i + 1, b':')
                        && fx.punct_is(i + 2, b':')
                        && fx.punct_is(i + 3, b'<')
                        && (fx.ident_is(i + 4, "f32") || fx.ident_is(i + 4, "f64"));
                    let bare = fx.punct_is(i + 1, b'(')
                        && fx.punct_is(i + 2, b')')
                        && fx.float_evidence(fx.stmt_start(i), i);
                    if turbofish || bare {
                        push(&mut cands, i - 1, "D3", "float `.sum()` reduction in the \
                              numeric spine (must be sequential in a fixed order — \
                              annotate why this one is, or route through the k-ordered \
                              kernels)".into());
                    }
                }
                if t == "fold" && fx.punct_is(i + 1, b'(') {
                    let close = fx.partner[i + 1].unwrap_or(n);
                    if fx.float_evidence(i + 2, close) {
                        push(&mut cands, i - 1, "D3", "float-accumulator `.fold(..)` \
                              reduction in the numeric spine (order-sensitive; annotate \
                              or restructure)".into());
                    }
                }
            }
            // P1: panic family.
            if on("P1") {
                if i > 0 && fx.punct_is(i - 1, b'.') {
                    if t == "unwrap" && fx.punct_is(i + 1, b'(') && fx.punct_is(i + 2, b')') {
                        push(&mut cands, i - 1, "P1", "`unwrap()` in the resilience \
                              spine (typed errors are the contract here: a panic turns \
                              a recoverable fault into an abort)".into());
                    }
                    if t == "expect" && fx.punct_is(i + 1, b'(') {
                        push(&mut cands, i - 1, "P1", "`expect(` in the resilience \
                              spine (typed errors are the contract here: a panic turns \
                              a recoverable fault into an abort)".into());
                    }
                }
                if matches!(t, "panic" | "unreachable" | "todo" | "unimplemented")
                    && fx.punct_is(i + 1, b'!')
                    && fx.toks[i + 1].pos == fx.toks[i].end
                {
                    push(&mut cands, i, "P1", format!(
                        "`{t}!` in the resilience spine (raise a typed error instead)"
                    ));
                }
            }
            // S1: float->int `as` casts.
            if on("S1")
                && t == "as"
                && fx.toks.get(i + 1).is_some_and(|x| x.kind == TokKind::Ident)
                && INT_TYPES.contains(&fx.text(i + 1))
                && fx.float_evidence(fx.stmt_start(i), i)
            {
                push(&mut cands, i, "S1", format!(
                    "float->int `as {}` cast in a mult/ decomposition path (silently \
                     saturates/truncates; use the checked helpers in mult::cast)",
                    fx.text(i + 1)
                ));
            }
            // U1: unsafe without a SAFETY comment.
            if on("U1") && t == "unsafe" {
                let l = fx.toks[i].line;
                let has_safety = |line: usize| {
                    fx.comments
                        .iter()
                        .any(|(cl, c)| *cl == line && c.contains("SAFETY:"))
                };
                let mut ok = has_safety(l);
                if !ok {
                    let mut k = l.saturating_sub(1);
                    while k >= 1 && !fx.line_has_code[k] {
                        if !fx.comments.iter().any(|(cl, _)| *cl == k) {
                            break; // blank line: not "immediately preceded"
                        }
                        if has_safety(k) {
                            ok = true;
                            break;
                        }
                        k -= 1;
                    }
                }
                if !ok {
                    push(&mut cands, i, "U1", "`unsafe` without an immediately \
                          preceding `// SAFETY:` comment (state the proof obligation \
                          the compiler cannot check)".into());
                }
            }
            // D1v2: iteration sites over hash-typed bindings.
            if on("D1v2") && in_scope(path, rule_scope(profile, "D1v2").unwrap_or(&[])) {
                // `for <pat> in <expr> {`
                if t == "for" && !fx.punct_is(i + 1, b'<') {
                    let mut depth = 0i32;
                    let mut j = i + 1;
                    let mut in_idx = None;
                    while j < n {
                        if fx.toks[j].kind == TokKind::Punct {
                            match fx.src.as_bytes()[fx.toks[j].pos] {
                                b'(' | b'[' => depth += 1,
                                b')' | b']' => depth -= 1,
                                b'{' | b';' if depth == 0 => break,
                                _ => {}
                            }
                        } else if depth == 0 && fx.ident_is(j, "in") {
                            in_idx = Some(j);
                            break;
                        }
                        j += 1;
                    }
                    if let Some(start) = in_idx {
                        let mut depth = 0i32;
                        let mut j = start + 1;
                        while j < n {
                            if fx.toks[j].kind == TokKind::Punct {
                                match fx.src.as_bytes()[fx.toks[j].pos] {
                                    b'(' | b'[' => depth += 1,
                                    b')' | b']' => depth -= 1,
                                    b'{' if depth == 0 => break,
                                    _ => {}
                                }
                            } else if fx.toks[j].kind == TokKind::Ident {
                                let name = fx.text(j);
                                let dotted = j > 0 && fx.punct_is(j - 1, b'.');
                                let self_field = dotted && fx.ident_is(j - 2, "self");
                                if name != "self" && (!dotted || self_field) {
                                    if let Some(b) = resolve(&bindings, name, fx.toks[j].pos)
                                    {
                                        if hash_typed(b) {
                                            let ty = b.ty.clone();
                                            d1v2_site(&mut cands, j, name, &ty);
                                        }
                                    }
                                }
                            }
                            j += 1;
                        }
                    }
                }
                // `<receiver>.iter()/.keys()/...`
                if ITER_METHODS.contains(&t)
                    && i > 0
                    && fx.punct_is(i - 1, b'.')
                    && fx.punct_is(i + 1, b'(')
                    && i >= 2
                    && fx.toks[i - 2].kind == TokKind::Ident
                {
                    let name = fx.text(i - 2);
                    let plain = i < 3 || !fx.punct_is(i - 3, b'.');
                    let self_field = !plain && i >= 4 && fx.ident_is(i - 4, "self");
                    if name != "self" && (plain || self_field) {
                        if let Some(b) = resolve(&bindings, name, fx.toks[i - 2].pos) {
                            if hash_typed(b) {
                                let ty = b.ty.clone();
                                d1v2_site(&mut cands, i - 2, name, &ty);
                            }
                        }
                    }
                }
            }
        }
        // P2: panicking index expressions.
        if kind == TokKind::Punct
            && on("P2")
            && fx.punct_is(i, b'[')
            && i > 0
        {
            let p = i - 1;
            let indexy = match fx.toks[p].kind {
                TokKind::Ident => !NON_INDEX_KEYWORDS.contains(&fx.text(p)),
                TokKind::Punct => matches!(fx.src.as_bytes()[fx.toks[p].pos], b')' | b']' | b'?'),
                _ => false,
            };
            if indexy {
                push(&mut cands, i, "P2", "panicking slice/array index `[..]` in the \
                      resilience spine (a short or corrupt buffer must surface as a \
                      typed fault, not an abort; use .get()/.get_mut())".into());
            }
        }
    }

    // C1 facts: simd_kernel registrations, parity design lists, bench
    // row names.
    let mut registrations: Vec<(String, usize)> = Vec::new();
    if on("C1") {
        for i in 0..n {
            if !fx.ident_is(i, "fn") || !fx.ident_is(i + 1, "simd_kernel") || fx.mask[i] {
                continue;
            }
            let mut body_open = None;
            let mut j = i + 2;
            while j < n {
                if fx.punct_is(j, b'{') {
                    body_open = Some(j);
                    break;
                }
                if fx.punct_is(j, b';') {
                    break; // trait method declaration without a body
                }
                j += 1;
            }
            let Some(open) = body_open else { continue };
            let close = fx.partner[open].unwrap_or(n);
            for k in open..close {
                let ke = fx.text(k);
                if fx.toks[k].kind == TokKind::Ident
                    && (ke == "UnsignedKernel" || ke == "SignedKernel")
                    && fx.punct_is(k + 1, b':')
                    && fx.punct_is(k + 2, b':')
                    && fx.toks.get(k + 3).is_some_and(|t| t.kind == TokKind::Ident)
                {
                    if let Some(fam) = kernel_family(ke, fx.text(k + 3)) {
                        registrations.push((fam.to_string(), fx.toks[i].line));
                        break;
                    }
                }
            }
        }
    }
    let norm = path.replace('\\', "/");
    let is_parity_file = norm.rsplit('/').next() == Some("simd_parity.rs");
    let mut parity_families: BTreeSet<String> = BTreeSet::new();
    if is_parity_file {
        for i in 0..n {
            if !(fx.ident_is(i, "DESIGNS") || fx.ident_is(i, "SIGNED_DESIGNS")) {
                continue;
            }
            // Collect every string literal up to the end of this item.
            let mut depth = 0i32;
            let mut j = i + 1;
            while j < n {
                if fx.toks[j].kind == TokKind::Punct {
                    match fx.src.as_bytes()[fx.toks[j].pos] {
                        b'(' | b'[' | b'{' => depth += 1,
                        b')' | b']' | b'}' => depth -= 1,
                        b';' if depth == 0 => break,
                        _ => {}
                    }
                } else if fx.toks[j].kind == TokKind::Str {
                    let fam = design_family(str_content(&fx, j));
                    if !fam.is_empty() {
                        parity_families.insert(fam);
                    }
                }
                j += 1;
            }
        }
    }
    let is_bench_file = in_scope(path, &["benches"]);
    let mut bench_families: BTreeSet<String> = BTreeSet::new();
    if is_bench_file {
        for i in 0..n {
            if fx.toks[i].kind == TokKind::Str {
                let fam = design_family(str_content(&fx, i));
                if !fam.is_empty() {
                    bench_families.insert(fam);
                }
            }
        }
    }

    // Resolve candidates against allow markers (test-masked tokens were
    // never candidates).
    cands.sort_by(|a, b| (a.pos, a.rule).cmp(&(b.pos, b.rule)));
    let mut violations = Vec::new();
    let mut suppressions = Vec::new();
    let mut used: BTreeSet<(usize, String)> = BTreeSet::new();
    for c in cands {
        if let Some(rules) = allow.get(&c.line) {
            if let Some(reason) = rules.get(c.rule) {
                used.insert((c.line, c.rule.to_string()));
                suppressions.push(Suppression {
                    rule: c.rule.to_string(),
                    path: path.to_string(),
                    line: c.line,
                    reason: reason.clone(),
                });
                continue;
            }
        }
        violations.push(Violation {
            rule: c.rule,
            path: path.to_string(),
            line: c.line,
            message: c.message,
        });
    }

    FileAnalysis {
        path: path.to_string(),
        violations,
        suppressions,
        marker_problems,
        markers,
        used,
        allow,
        registrations,
        parity_seen: is_parity_file,
        parity_families,
        bench_seen: is_bench_file,
        bench_families,
    }
}

// --------------------------------------------------------------------------
// Finalize: cross-file C1 resolution, stale markers, deterministic
// ordering.
// --------------------------------------------------------------------------

fn rule_index(rule: &str) -> usize {
    RULE_IDS.iter().position(|r| *r == rule).unwrap_or(RULE_IDS.len())
}

fn finalize(mut files: Vec<FileAnalysis>) -> Report {
    let parity_seen = files.iter().any(|f| f.parity_seen);
    let bench_seen = files.iter().any(|f| f.bench_seen);
    let mut parity: BTreeSet<String> = BTreeSet::new();
    let mut bench: BTreeSet<String> = BTreeSet::new();
    for f in &files {
        parity.extend(f.parity_families.iter().cloned());
        bench.extend(f.bench_families.iter().cloned());
    }
    let mut report = Report { files_scanned: files.len(), ..Report::default() };
    for f in &mut files {
        // C1 resolves only when the scan set actually contains the
        // parity suite — a lone `mult/` file carries no coverage facts.
        for (family, line) in std::mem::take(&mut f.registrations) {
            let mut gaps: Vec<&str> = Vec::new();
            if parity_seen && !parity.contains(&family) {
                gaps.push("the simd_parity.rs design lists");
            }
            if bench_seen && !bench.contains(&family) {
                gaps.push("a named bench row");
            }
            if gaps.is_empty() {
                continue;
            }
            let message = format!(
                "design family `{family}` registers a simd_kernel() but is missing \
                 from {} (the scalar<->SIMD bit-identity pin)",
                gaps.join(" and ")
            );
            if let Some(reason) = f.allow.get(&line).and_then(|m| m.get("C1")).cloned() {
                f.used.insert((line, "C1".to_string()));
                f.suppressions.push(Suppression {
                    rule: "C1".to_string(),
                    path: f.path.clone(),
                    line,
                    reason,
                });
            } else {
                f.violations.push(Violation {
                    rule: "C1",
                    path: f.path.clone(),
                    line,
                    message,
                });
            }
        }
        for m in &f.markers {
            for r in &m.rules {
                if !f.used.contains(&(m.target, r.clone())) {
                    report.stale_markers.push(MarkerProblem {
                        path: f.path.clone(),
                        line: m.line,
                        message: format!("stale marker: allow({r}) suppressed nothing"),
                    });
                }
            }
        }
        report.violations.append(&mut f.violations);
        report.suppressions.append(&mut f.suppressions);
        report.marker_problems.append(&mut f.marker_problems);
    }
    report
        .violations
        .sort_by(|a, b| {
            (a.path.as_str(), a.line, rule_index(a.rule), a.message.as_str())
                .cmp(&(b.path.as_str(), b.line, rule_index(b.rule), b.message.as_str()))
        });
    report
        .suppressions
        .sort_by(|a, b| {
            (a.path.as_str(), a.line, a.rule.as_str())
                .cmp(&(b.path.as_str(), b.line, b.rule.as_str()))
        });
    report
        .marker_problems
        .sort_by(|a, b| (a.path.as_str(), a.line).cmp(&(b.path.as_str(), b.line)));
    report
        .stale_markers
        .sort_by(|a, b| (a.path.as_str(), a.line).cmp(&(b.path.as_str(), b.line)));
    report
}

// --------------------------------------------------------------------------
// Public scan entry points.
// --------------------------------------------------------------------------

/// Scan one file's source. `path` is used for scoping and reporting;
/// scope matching is segment-based, so both repo-relative and absolute
/// paths work. Cross-file coverage (C1) only resolves when the scan
/// set includes the parity suite, so a single-file scan never raises
/// it.
pub fn scan_source(path: &str, src: &str) -> Report {
    finalize(vec![analyze_file(path, src)])
}

/// Scan a set of `(path, source)` pairs as one project: cross-file
/// rules see the whole set.
pub fn scan_sources(files: &[(String, String)]) -> Report {
    finalize(files.iter().map(|(p, s)| analyze_file(p, s)).collect())
}

/// Scan files and directory trees (only `.rs` files), in sorted path
/// order per argument so output is deterministic. All paths form one
/// project for cross-file rules.
pub fn scan_paths(paths: &[std::path::PathBuf]) -> std::io::Result<Report> {
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    for p in paths {
        let mut batch = Vec::new();
        collect_rs_files(p, &mut batch)?;
        batch.sort();
        files.extend(batch);
    }
    let mut analyses = Vec::new();
    for f in files {
        let src = std::fs::read_to_string(&f)?;
        let rel = f.to_string_lossy().replace('\\', "/");
        analyses.push(analyze_file(&rel, &src));
    }
    Ok(finalize(analyses))
}

/// Scan a single file or directory tree.
pub fn scan_path(path: &std::path::Path) -> std::io::Result<Report> {
    scan_paths(std::slice::from_ref(&path.to_path_buf()))
}

fn collect_rs_files(
    path: &std::path::Path,
    out: &mut Vec<std::path::PathBuf>,
) -> std::io::Result<()> {
    let meta = std::fs::metadata(path)?;
    if meta.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<std::path::PathBuf> = Vec::new();
    for entry in std::fs::read_dir(path)? {
        entries.push(entry?.path());
    }
    entries.sort();
    for e in entries {
        let m = std::fs::metadata(&e)?;
        if m.is_dir() {
            collect_rs_files(&e, out)?;
        } else if e.extension().is_some_and(|x| x == "rs") {
            out.push(e);
        }
    }
    Ok(())
}

// --------------------------------------------------------------------------
// Baseline parsing: just enough JSON to read back a `--json` report.
// --------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

struct JParser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> JParser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("baseline JSON: expected `{}` at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        let Some(&c) = self.b.get(self.i) else {
            return Err("baseline JSON: unexpected end of input".into());
        };
        match c {
            b'{' => {
                self.i += 1;
                let mut out = Vec::new();
                self.ws();
                if self.b.get(self.i) == Some(&b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                loop {
                    self.ws();
                    let key = match self.value()? {
                        Json::Str(s) => s,
                        _ => return Err("baseline JSON: object key must be a string".into()),
                    };
                    self.expect(b':')?;
                    out.push((key, self.value()?));
                    self.ws();
                    match self.b.get(self.i) {
                        Some(&b',') => self.i += 1,
                        Some(&b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(out));
                        }
                        _ => return Err("baseline JSON: expected `,` or `}`".into()),
                    }
                }
            }
            b'[' => {
                self.i += 1;
                let mut out = Vec::new();
                self.ws();
                if self.b.get(self.i) == Some(&b']') {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                loop {
                    out.push(self.value()?);
                    self.ws();
                    match self.b.get(self.i) {
                        Some(&b',') => self.i += 1,
                        Some(&b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(out));
                        }
                        _ => return Err("baseline JSON: expected `,` or `]`".into()),
                    }
                }
            }
            b'"' => {
                self.i += 1;
                let mut s = String::new();
                while self.i < self.b.len() {
                    match self.b[self.i] {
                        b'"' => {
                            self.i += 1;
                            return Ok(Json::Str(s));
                        }
                        b'\\' => {
                            let e = self.b.get(self.i + 1).copied().unwrap_or(b'"');
                            self.i += 2;
                            match e {
                                b'n' => s.push('\n'),
                                b't' => s.push('\t'),
                                b'r' => s.push('\r'),
                                b'u' => {
                                    let hex: String = self
                                        .b
                                        .get(self.i..self.i + 4)
                                        .map(|h| String::from_utf8_lossy(h).into_owned())
                                        .unwrap_or_default();
                                    self.i += 4;
                                    if let Ok(cp) = u32::from_str_radix(&hex, 16) {
                                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                                    }
                                }
                                other => s.push(other as char),
                            }
                        }
                        other => {
                            // Copy the full UTF-8 sequence through.
                            let start = self.i;
                            self.i += 1;
                            while self.i < self.b.len()
                                && other >= 0x80
                                && self.b[self.i] & 0xC0 == 0x80
                            {
                                self.i += 1;
                            }
                            s.push_str(&String::from_utf8_lossy(&self.b[start..self.i]));
                        }
                    }
                }
                Err("baseline JSON: unterminated string".into())
            }
            b't' if self.b[self.i..].starts_with(b"true") => {
                self.i += 4;
                Ok(Json::Bool(true))
            }
            b'f' if self.b[self.i..].starts_with(b"false") => {
                self.i += 5;
                Ok(Json::Bool(false))
            }
            b'n' if self.b[self.i..].starts_with(b"null") => {
                self.i += 4;
                Ok(Json::Null)
            }
            _ => {
                let start = self.i;
                while self.i < self.b.len()
                    && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
                {
                    self.i += 1;
                }
                let txt = std::str::from_utf8(&self.b[start..self.i])
                    .map_err(|_| "baseline JSON: bad number".to_string())?;
                txt.parse::<f64>()
                    .map(Json::Num)
                    .map_err(|_| format!("baseline JSON: bad number `{txt}`"))
            }
        }
    }
}

/// Parse a detlint `--json` report into `(rule, path, message)` baseline
/// entries. Both `violations` and (already-)`grandfathered` entries
/// count, so re-baselining from a ratcheted run is stable.
pub fn parse_baseline(text: &str) -> Result<Vec<(String, String, String)>, String> {
    let mut p = JParser { b: text.as_bytes(), i: 0 };
    let root = p.value()?;
    let Json::Obj(fields) = root else {
        return Err("baseline JSON: root must be an object".into());
    };
    let mut out = Vec::new();
    for (key, val) in &fields {
        if key != "violations" && key != "grandfathered" {
            continue;
        }
        let Json::Arr(items) = val else {
            return Err(format!("baseline JSON: `{key}` must be an array"));
        };
        for item in items {
            let Json::Obj(f) = item else {
                return Err(format!("baseline JSON: `{key}` entries must be objects"));
            };
            let get = |name: &str| -> Option<String> {
                f.iter().find(|(k, _)| k == name).and_then(|(_, v)| match v {
                    Json::Str(s) => Some(s.clone()),
                    _ => None,
                })
            };
            match (get("rule"), get("path"), get("message")) {
                (Some(r), Some(p), Some(m)) => out.push((r, p, m)),
                _ => {
                    return Err(format!(
                        "baseline JSON: `{key}` entry missing rule/path/message"
                    ))
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(path: &str, src: &str) -> Report {
        scan_source(path, src)
    }

    #[test]
    fn comments_and_strings_are_blanked() {
        let src = "// HashMap in a comment is fine\nfn f() -> &'static str { \"HashMap\" }\n";
        let r = scan("rust/src/mult/mod.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn raw_strings_and_chars_are_blanked() {
        // The '{' char literal must not desync delimiter matching, and
        // the raw string's HashMap must not count as a type mention.
        let src = "fn f() { let s = r#\"HashMap\"#; let c = '{'; \
                   let m: std::collections::HashMap<u8, u8> = Default::default(); \
                   let _ = (s, c, m); }\n";
        let r = scan("rust/src/mult/mod.rs", src);
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].rule, "D1");
        assert_eq!(r.violations[0].line, 1);
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let src = "struct S<'a> { x: &'a str }\nfn f<'b>(y: &'b [u8]) -> &'b [u8] { y }\n";
        let r = scan("rust/src/mult/mod.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn d1_out_of_scope_is_ignored() {
        let src = "use std::collections::HashMap;\nfn f(m: &HashMap<u8, u8>) -> Option<&u8> { m.get(&0) }\n";
        assert!(scan("rust/src/parallel/mod.rs", src).violations.is_empty());
        let r = scan("rust/src/mult/mod.rs", src);
        assert_eq!(r.violations.iter().filter(|v| v.rule == "D1").count(), 2);
    }

    #[test]
    fn d2_scope_exempts_benchkit() {
        let src = "fn t() -> std::time::Instant { std::time::Instant::now() }\n";
        assert!(scan("rust/src/benchkit/mod.rs", src).violations.is_empty());
        let r = scan("rust/src/runtime/native/mod.rs", src);
        assert!(r.violations.iter().any(|v| v.rule == "D2"));
        assert!(scan("rust/src/runtime/engine.rs", src).violations.is_empty());
    }

    #[test]
    fn d3_spawn_everywhere_but_parallel() {
        let src = "fn go() { std::thread::spawn(|| {}); }\n";
        assert!(scan("rust/src/parallel/pool.rs", src).violations.is_empty());
        let r = scan("rust/src/report/mod.rs", src);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].rule, "D3");
    }

    #[test]
    fn d3_float_sum_needs_float_evidence() {
        let int_sum = "fn s(xs: &[u32]) -> u32 { xs.iter().sum() }\n";
        assert!(scan("rust/src/tensor/mod.rs", int_sum).violations.is_empty());
        let float_sum = "fn s(xs: &[f32]) -> f32 { let t: f32 = xs.iter().sum(); t }\n";
        assert_eq!(scan("rust/src/tensor/mod.rs", float_sum).violations.len(), 1);
        let turbofish = "fn s(xs: &[u8]) -> f64 { xs.iter().map(|&x| x as f64).sum::<f64>() }\n";
        assert_eq!(scan("rust/src/tensor/mod.rs", turbofish).violations.len(), 1);
        let float_fold = "fn s(xs: &[f32]) -> f32 { xs.iter().fold(0.0f32, |a, b| a + b) }\n";
        assert_eq!(scan("rust/src/tensor/mod.rs", float_fold).violations.len(), 1);
        let welford = "fn s(xs: &[u32]) -> u32 { xs.iter().fold(0u32, |a, b| a.max(*b)) }\n";
        assert!(scan("rust/src/tensor/mod.rs", welford).violations.is_empty());
    }

    #[test]
    fn p1_fires_in_spine_only_outside_tests() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n";
        assert!(scan("rust/src/mult/mod.rs", src).violations.is_empty());
        let r = scan("rust/src/checkpoint/mod.rs", src);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].rule, "P1");
        let masked = "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u8>) -> u8 { x.unwrap() }\n}\n";
        assert!(scan("rust/src/checkpoint/mod.rs", masked).violations.is_empty());
    }

    #[test]
    fn test_attr_on_fn_is_masked() {
        let src = "#[test]\nfn t() { Some(1).unwrap(); }\nfn live(x: Option<u8>) -> u8 { x.unwrap() }\n";
        let r = scan("rust/src/checkpoint/mod.rs", src);
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].line, 3);
    }

    #[test]
    fn s1_flags_float_casts_not_bit_casts() {
        let bad = "fn q(x: f64) -> u64 { (x * 0.5) as u64 }\n";
        let r = scan("rust/src/mult/drum.rs", bad);
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].rule, "S1");
        let repack = "fn r(bits: u32) -> u32 { f32::from_bits(bits).to_bits() }\n";
        assert!(scan("rust/src/mult/drum.rs", repack).violations.is_empty());
    }

    #[test]
    fn allow_marker_suppresses_and_records() {
        let src = "// detlint: allow(D1) -- lookup-only, never iterated\n\
                   use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u8, u8>) -> Option<&u8> { m.get(&0) } // detlint: allow(D1) -- lookup-only param\n";
        let r = scan("rust/src/mult/mod.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(r.suppressions.len(), 2);
        assert_eq!(r.suppressions[0].reason, "lookup-only, never iterated");
        assert!(r.stale_markers.is_empty());
        assert!(!r.failed());
    }

    #[test]
    fn same_line_marker_works() {
        let src = "fn t() { std::thread::spawn(|| {}); } // detlint: allow(D3) -- fixture: audited\n";
        let r = scan("rust/src/report/mod.rs", src);
        assert!(r.violations.is_empty());
        assert_eq!(r.suppressions.len(), 1);
        assert_eq!(r.suppressions[0].rule, "D3");
    }

    #[test]
    fn malformed_markers_are_problems() {
        let src = "// detlint: allow(D9) -- no such rule\n\
                   // detlint: allow(D1)\n\
                   // detlint: deny(D1) -- wrong verb\n\
                   fn f() {}\n";
        let r = scan("rust/src/mult/mod.rs", src);
        assert_eq!(r.marker_problems.len(), 3, "{:?}", r.marker_problems);
        assert!(r.failed());
    }

    #[test]
    fn stale_marker_warns() {
        let src = "// detlint: allow(D1) -- nothing here anymore\nfn f() {}\n";
        let r = scan("rust/src/mult/mod.rs", src);
        assert!(r.violations.is_empty());
        assert_eq!(r.stale_markers.len(), 1);
        assert!(!r.failed());
    }

    #[test]
    fn string_continuation_escape_keeps_line_numbers() {
        let src = "fn f() -> String { format!(\"a\\\n   b\") }\nuse std::collections::HashMap; // detlint: allow(D1) -- fixture: line check\n";
        let r = scan("rust/src/mult/mod.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(r.suppressions.len(), 1);
        assert_eq!(r.suppressions[0].line, 3);
    }

    #[test]
    fn scope_matching_is_segment_based() {
        assert!(in_scope("rust/src/runtime/native/mod.rs", &["runtime/native"]));
        assert!(!in_scope("rust/src/runtime/engine.rs", &["runtime/native"]));
        assert!(in_scope("rust/src/coordinator/health.rs", &["coordinator/health.rs"]));
        assert!(!in_scope("rust/src/multitool/mod.rs", &["mult"]));
        assert!(in_scope("anything/at/all.rs", &["*"]));
    }

    #[test]
    fn rules_table_is_consistent() {
        assert_eq!(RULES.len(), RULE_IDS.len());
        for (rule, id) in RULES.iter().zip(RULE_IDS.iter()) {
            assert_eq!(rule.id, *id);
            assert!(!rule.summary.is_empty());
            assert!(!rule.rationale.is_empty());
            assert!(!rule.scope.is_empty());
        }
    }

    // ---- v2: binding tracking, expression context, cross-file rules ----

    #[test]
    fn d1v2_flags_iteration_sites_not_lookups() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u32, u64>) -> u64 {\n\
                   \x20   let mut acc = 0u64;\n\
                   \x20   for (_k, v) in m.iter() {\n\
                   \x20       acc += *v;\n\
                   \x20   }\n\
                   \x20   acc + m.get(&0).copied().unwrap_or(0)\n\
                   }\n";
        let r = scan("rust/src/runtime/engine.rs", src);
        let d1v2: Vec<_> = r.violations.iter().filter(|v| v.rule == "D1v2").collect();
        assert_eq!(d1v2.len(), 1, "{:?}", r.violations);
        assert_eq!(d1v2[0].line, 4);
    }

    #[test]
    fn d1v2_ignores_ordered_containers() {
        let src = "use std::collections::BTreeMap;\n\
                   fn f(m: &BTreeMap<u32, u64>) -> u64 {\n\
                   \x20   let mut acc = 0;\n\
                   \x20   for v in m.values() {\n\
                   \x20       acc += *v;\n\
                   \x20   }\n\
                   \x20   acc\n\
                   }\n";
        assert!(scan("rust/src/runtime/engine.rs", src).violations.is_empty());
    }

    #[test]
    fn d1v2_tracks_struct_fields_through_self() {
        let src = "use std::collections::HashMap;\n\
                   // detlint: allow(D1) -- fixture: lookup table under test\n\
                   struct C { map: HashMap<u32, u64> }\n\
                   impl C {\n\
                   \x20   fn leak(&self) -> u64 { self.map.values().sum::<u64>() }\n\
                   }\n";
        let r = scan("rust/src/runtime/engine.rs", src);
        let d1v2: Vec<_> = r.violations.iter().filter(|v| v.rule == "D1v2").collect();
        assert_eq!(d1v2.len(), 1, "{:?}", r.violations);
        assert_eq!(d1v2[0].line, 5);
    }

    #[test]
    fn p2_flags_index_expressions_not_type_brackets() {
        let bad = "pub fn first(bytes: &[u8]) -> u8 { bytes[0] }\n";
        let r = scan("rust/src/checkpoint/mod.rs", bad);
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].rule, "P2");
        let clean = "#[derive(Clone)]\npub struct B { v: [u8; 4] }\n\
                     pub fn first(bytes: &[u8]) -> Option<u8> { bytes.get(0).copied() }\n";
        assert!(scan("rust/src/checkpoint/mod.rs", clean).violations.is_empty());
        let chained = "fn f(rows: &[Vec<u8>]) -> u8 { rows[0][1] }\n";
        assert_eq!(scan("rust/src/checkpoint/mod.rs", chained).violations.len(), 2);
        assert!(scan("rust/src/mult/mod.rs", bad).violations.is_empty());
    }

    #[test]
    fn u1_requires_adjacent_safety_comment() {
        let bare = "fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        let r = scan("rust/src/runtime/mod.rs", bare);
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert_eq!(r.violations[0].rule, "U1");
        let same_line = "fn f(p: *const u8) -> u8 { unsafe { *p } } // SAFETY: caller keeps p valid\n";
        assert!(scan("rust/src/runtime/mod.rs", same_line).violations.is_empty());
        let above = "fn f(p: *const u8) -> u8 {\n\
                     \x20   // SAFETY: caller keeps p valid for reads;\n\
                     \x20   // the deref copies one byte.\n\
                     \x20   unsafe { *p }\n\
                     }\n";
        assert!(scan("rust/src/runtime/mod.rs", above).violations.is_empty());
        let gapped = "fn f(p: *const u8) -> u8 {\n\
                      \x20   // SAFETY: too far away\n\
                      \n\
                      \x20   unsafe { *p }\n\
                      }\n";
        assert_eq!(scan("rust/src/runtime/mod.rs", gapped).violations.len(), 1);
    }

    #[test]
    fn c1_needs_parity_and_bench_coverage() {
        let reg = "pub fn simd_kernel(&self) -> Option<K> { Some(UnsignedKernel::Mitchell { bits: 8 }) }\n";
        // Alone, the scan set has no parity/bench facts: C1 stays quiet.
        assert!(scan("rust/src/mult/mitchell.rs", reg).violations.is_empty());
        let parity = "const DESIGNS: &[&str] = &[\"exact\", \"drum6\"];\n\
                      const SIGNED_DESIGNS: &[&str] = &[\"sexact\"];\n";
        let bench = "fn rows() -> Vec<&'static str> { vec![\"exact\", \"drum6\"] }\n";
        let files = vec![
            ("rust/src/mult/mitchell.rs".to_string(), reg.to_string()),
            ("rust/tests/simd_parity.rs".to_string(), parity.to_string()),
            ("rust/benches/multipliers.rs".to_string(), bench.to_string()),
        ];
        let r = scan_sources(&files);
        let c1: Vec<_> = r.violations.iter().filter(|v| v.rule == "C1").collect();
        assert_eq!(c1.len(), 1, "{:?}", r.violations);
        assert!(c1[0].message.contains("mitchell"));
        let parity2 = "const DESIGNS: &[&str] = &[\"exact\", \"mitchell\"];\n";
        let bench2 = "fn rows() -> Vec<&'static str> { vec![\"exact\", \"mitchell\"] }\n";
        let files2 = vec![
            ("rust/src/mult/mitchell.rs".to_string(), reg.to_string()),
            ("rust/tests/simd_parity.rs".to_string(), parity2.to_string()),
            ("rust/benches/multipliers.rs".to_string(), bench2.to_string()),
        ];
        assert!(scan_sources(&files2).violations.is_empty());
    }

    #[test]
    fn baseline_grandfathers_matching_violations() {
        let src = "use std::collections::HashMap;\n";
        let mut r = scan("rust/src/mult/mod.rs", src);
        assert_eq!(r.violations.len(), 1);
        let msg = r.violations[0].message.clone();
        let baseline = vec![("D1".to_string(), "rust/src/mult/mod.rs".to_string(), msg)];
        r.apply_baseline(&baseline);
        assert!(r.violations.is_empty());
        assert_eq!(r.grandfathered.len(), 1);
        assert!(!r.failed());
    }

    #[test]
    fn parse_baseline_reads_json_reports() {
        let json = "{\"files_scanned\": 1, \"violations\": [{\"rule\": \"D1\", \
                    \"path\": \"a.rs\", \"line\": 3, \"message\": \"m \\\"x\\\"\"}], \
                    \"grandfathered\": [], \"ok\": false}";
        let entries = parse_baseline(json).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].0, "D1");
        assert_eq!(entries[0].2, "m \"x\"");
        assert!(parse_baseline("not json").is_err());
    }

    #[test]
    fn profiles_mask_rules_by_tree_region() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n";
        assert!(scan("rust/tests/checkpoint_suite.rs", src).violations.is_empty());
        let hash = "use std::collections::HashMap;\n";
        assert_eq!(scan("rust/tests/misc.rs", hash).violations.len(), 1);
        assert_eq!(scan("rust/analyzers/detlint/src/lib.rs", hash).violations.len(), 1);
        assert_eq!(
            profile_for("rust/analyzers/detlint/fixtures/bad/mult/x.rs"),
            Profile::Default
        );
    }
}
