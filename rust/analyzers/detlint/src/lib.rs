//! detlint — determinism-and-resilience lints for the approxmul tree.
//!
//! The reproduction's methodology rests on source-level invariants that
//! `rustc` cannot enforce: bit-identical trajectories (rollback replay,
//! thread-invariant GEMM, hybrid-switch comparability), panic-free
//! recovery paths, and byte-stable emitted artifacts. This crate makes
//! those conventions machine-checked with a lightweight line/token-level
//! scanner (no `syn`, no dependencies):
//!
//! * **D1** — no `HashMap`/`HashSet` in trajectory/artifact modules.
//!   Hash iteration order is seeded per process; one stray `for` over a
//!   hash map leaks that order into a trajectory or an emitted file.
//!   Keyed lookup is fine, but must carry an audit marker so the
//!   "never iterated" claim is reviewed, not assumed.
//! * **D2** — no `Instant::now`/`SystemTime`/`std::time` in step-math
//!   modules. Wall-clock reads in the step path make replay diverge.
//!   `benchkit` is exempt by scope (it exists to time things); backoff
//!   and throughput telemetry carry audit markers.
//! * **D3** — no raw `std::thread::spawn` outside `parallel/`, and no
//!   float `.sum()`/float-accumulator `fold` reductions in the numeric
//!   spine. Reductions there must be sequential in a fixed order (or go
//!   through the k-ordered kernels); annotated exceptions document why
//!   a site is deterministic.
//! * **P1** — no `unwrap()`/`expect()`/panic-family macros in the
//!   resilience spine (`checkpoint`, the coordinator's health/recovery/
//!   trainer, `testkit/faults`). Typed errors are the contract there: a
//!   panic turns a recoverable fault into an abort.
//! * **S1** — no unchecked `as` float→int casts in `mult/`
//!   bit-decomposition paths; the checked helpers in `mult::cast` are
//!   the single audited crossing.
//!
//! Suppression is explicit and auditable:
//! `// detlint: allow(<rule>[, <rule>...]) -- <reason>` on the
//! offending line, or alone on the line above it. Markers without a
//! reason, with unknown rule names, or that suppress nothing are
//! reported (the first two fail the run; stale markers warn).
//!
//! Scanning is text-based on purpose: it has no false negatives from
//! conditional compilation, runs in milliseconds with no toolchain
//! beyond `rustc`, and its few heuristics (statement-window float
//! evidence for bare `.sum()`/`as` casts) are pinned by the fixture
//! corpus under `fixtures/`.

use std::collections::{BTreeMap, BTreeSet};

/// All known rule identifiers, in report order.
pub const RULE_IDS: [&str; 5] = ["D1", "D2", "D3", "P1", "S1"];

/// Path scopes, as `/`-separated segment sequences matched anywhere in
/// a file's path. `runtime/native` matches `rust/src/runtime/native/x.rs`
/// but not `rust/src/runtime/engine.rs`.
const D1_SCOPE: &[&str] = &[
    "mult",
    "runtime",
    "coordinator",
    "rng",
    "tensor",
    "data",
    "config",
    "metrics",
    "benchkit",
    "report",
    "json",
    "checkpoint",
];
const D2_SCOPE: &[&str] = &["mult", "runtime/native", "rng", "tensor", "data", "coordinator"];
/// Modules allowed to spawn threads (the deterministic fork-join
/// substrate every parallel caller routes through).
const D3_SPAWN_EXEMPT: &[&str] = &["parallel"];
const D3_REDUCE_SCOPE: &[&str] = &["mult", "runtime/native", "tensor", "data", "rng"];
const P1_SCOPE: &[&str] = &[
    "checkpoint",
    "coordinator/health.rs",
    "coordinator/recovery.rs",
    "coordinator/trainer.rs",
    "testkit/faults.rs",
];
const S1_SCOPE: &[&str] = &["mult"];

/// Static description of one rule (for `--list-rules` and docs).
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    pub id: &'static str,
    /// `deny` rules fail the run; `warn` rules only report.
    pub severity: &'static str,
    pub scope: &'static [&'static str],
    pub summary: &'static str,
    pub rationale: &'static str,
}

pub const RULES: [RuleInfo; 5] = [
    RuleInfo {
        id: "D1",
        severity: "deny",
        scope: D1_SCOPE,
        summary: "no HashMap/HashSet in trajectory or artifact modules",
        rationale: "hash iteration order is per-process random; iterating one leaks \
                    that order into trajectories or emitted files. Use BTreeMap/BTreeSet, \
                    or annotate a lookup-only use.",
    },
    RuleInfo {
        id: "D2",
        severity: "deny",
        scope: D2_SCOPE,
        summary: "no Instant::now/SystemTime/std::time in step-math modules",
        rationale: "wall-clock reads in the step path break bit-identical rollback \
                    replay. benchkit is exempt by scope; backoff delays and throughput \
                    telemetry carry audit markers.",
    },
    RuleInfo {
        id: "D3",
        severity: "deny",
        scope: D3_REDUCE_SCOPE,
        summary: "no raw thread::spawn outside parallel/; no float sum/fold \
                  reductions in the numeric spine",
        rationale: "ad-hoc threading and reassociated float reductions make results \
                    depend on scheduling. Use parallel::par_map/par_chunks_mut and the \
                    k-ordered GEMM kernels; annotate sequential fixed-order sums.",
    },
    RuleInfo {
        id: "P1",
        severity: "deny",
        scope: P1_SCOPE,
        summary: "no unwrap/expect/panic-family in the resilience spine",
        rationale: "the watchdog's contract is that every fault surfaces as a typed \
                    error it can classify and recover from; a panic escalates a \
                    recoverable fault into an abort.",
    },
    RuleInfo {
        id: "S1",
        severity: "deny",
        scope: S1_SCOPE,
        summary: "no unchecked `as` float->int casts in mult/ decomposition paths",
        rationale: "bare float->int `as` casts saturate/truncate silently and have \
                    caused bit-domain bugs; route through the audited helpers in \
                    mult::cast.",
    },
];

/// One rule violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub message: String,
}

/// One used `detlint: allow` marker (the audit trail).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Suppression {
    pub rule: String,
    pub path: String,
    pub line: usize,
    pub reason: String,
}

/// A malformed or stale marker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MarkerProblem {
    pub path: String,
    pub line: usize,
    pub message: String,
}

/// Aggregated scan results.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    pub violations: Vec<Violation>,
    pub suppressions: Vec<Suppression>,
    /// Malformed markers: fail the run (an unparseable suppression is
    /// worse than a violation — it silently suppresses nothing).
    pub marker_problems: Vec<MarkerProblem>,
    /// Markers that suppressed nothing: warn only.
    pub stale_markers: Vec<MarkerProblem>,
}

impl Report {
    pub fn merge(&mut self, other: Report) {
        self.files_scanned += other.files_scanned;
        self.violations.extend(other.violations);
        self.suppressions.extend(other.suppressions);
        self.marker_problems.extend(other.marker_problems);
        self.stale_markers.extend(other.stale_markers);
    }

    /// True when the run should exit nonzero.
    pub fn failed(&self) -> bool {
        !self.violations.is_empty() || !self.marker_problems.is_empty()
    }
}

// --------------------------------------------------------------------------
// Lexing: blank comments/strings/chars out of the source so pattern
// matching never fires inside literals, while keeping byte offsets (and
// therefore line numbers) intact.
// --------------------------------------------------------------------------

struct Blanked {
    /// Same length as the input; comment and literal bytes replaced by
    /// spaces (newlines kept, so line structure is preserved).
    code: Vec<u8>,
    /// `(line, text)` of every `//` comment, for marker parsing.
    comments: Vec<(usize, String)>,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn find_byte(hay: &[u8], from: usize, needle: u8) -> Option<usize> {
    hay.iter().skip(from).position(|&b| b == needle).map(|p| p + from)
}

fn find_from(hay: &[u8], from: usize, needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() || from > hay.len() - needle.len() {
        return None;
    }
    (from..=hay.len() - needle.len()).find(|&i| &hay[i..i + needle.len()] == needle)
}

fn blank_range(out: &mut [u8], a: usize, b: usize) {
    let b = b.min(out.len());
    if a >= b {
        return;
    }
    for slot in &mut out[a..b] {
        if *slot != b'\n' {
            *slot = b' ';
        }
    }
}

fn count_newlines(bytes: &[u8], a: usize, b: usize) -> usize {
    let b = b.min(bytes.len());
    if a >= b {
        return 0;
    }
    bytes[a..b].iter().filter(|&&c| c == b'\n').count()
}

fn blank(src: &str) -> Blanked {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        // Line comment.
        if b[i..].starts_with(b"//") {
            let j = find_byte(b, i, b'\n').unwrap_or(n);
            comments.push((line, String::from_utf8_lossy(&b[i..j]).into_owned()));
            blank_range(&mut out, i, j);
            i = j;
            continue;
        }
        // Block comment (nested, per Rust).
        if b[i..].starts_with(b"/*") {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j..].starts_with(b"/*") {
                    depth += 1;
                    j += 2;
                } else if b[j..].starts_with(b"*/") {
                    depth -= 1;
                    j += 2;
                } else {
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            blank_range(&mut out, i, j);
            i = j;
            continue;
        }
        let left_bound = i == 0 || !is_ident(b[i - 1]);
        // Raw (and byte-raw) strings: r"..", r#".."#, br"..", br#".."#.
        // `r`/`br` followed by hashes but no quote is a raw identifier
        // (r#fn) — fall through in that case.
        if left_bound && (c == b'r' || (c == b'b' && b[i..].starts_with(b"br"))) {
            let mut k = if c == b'b' { i + 2 } else { i + 1 };
            let mut hashes = 0usize;
            while k < n && b[k] == b'#' {
                hashes += 1;
                k += 1;
            }
            if k < n && b[k] == b'"' {
                let mut j = k + 1;
                let end;
                loop {
                    match find_byte(b, j, b'"') {
                        Some(q) => {
                            let mut h = 0usize;
                            while h < hashes && q + 1 + h < n && b[q + 1 + h] == b'#' {
                                h += 1;
                            }
                            if h == hashes {
                                end = q + 1 + hashes;
                                break;
                            }
                            j = q + 1;
                        }
                        None => {
                            end = n;
                            break;
                        }
                    }
                }
                line += count_newlines(b, i, end);
                blank_range(&mut out, i, end);
                i = end;
                continue;
            }
        }
        // Plain and byte strings.
        let str_open = if c == b'"' {
            Some(i)
        } else if left_bound && c == b'b' && i + 1 < n && b[i + 1] == b'"' {
            Some(i + 1)
        } else {
            None
        };
        if let Some(q0) = str_open {
            let mut j = q0 + 1;
            while j < n {
                match b[j] {
                    // An escape always consumes the next byte; a
                    // string-continuation escape consumes a newline,
                    // which must still be counted.
                    b'\\' => {
                        if j + 1 < n && b[j + 1] == b'\n' {
                            line += 1;
                        }
                        j += 2;
                    }
                    b'"' => {
                        j += 1;
                        break;
                    }
                    b'\n' => {
                        line += 1;
                        j += 1;
                    }
                    _ => j += 1,
                }
            }
            let j = j.min(n);
            blank_range(&mut out, i, j);
            i = j;
            continue;
        }
        // Char literal vs lifetime: '\...' and 'x' are literals (this
        // also neutralizes '{' / ';' so brace/statement tracking on the
        // blanked text stays correct); anything else is a lifetime.
        if c == b'\'' {
            if i + 1 < n && b[i + 1] == b'\\' {
                let j = find_byte(b, i + 2, b'\'').map(|p| p + 1).unwrap_or(n);
                blank_range(&mut out, i, j);
                i = j;
                continue;
            }
            if i + 2 < n && b[i + 2] == b'\'' {
                blank_range(&mut out, i, i + 3);
                i += 3;
                continue;
            }
            i += 1;
            continue;
        }
        i += 1;
    }
    Blanked { code: out, comments }
}

// --------------------------------------------------------------------------
// Test-region masking: code under `#[cfg(test)]` / `#[test]` plays by
// different rules (unwraps and HashSets in tests are fine).
// --------------------------------------------------------------------------

fn test_mask(code: &[u8]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    for pat in [&b"#[cfg(test)]"[..], &b"#[test]"[..]] {
        let mut from = 0usize;
        while let Some(p) = find_from(code, from, pat) {
            from = p + pat.len();
            let nb = find_byte(code, from, b'{');
            let ns = find_byte(code, from, b';');
            let end = match (nb, ns) {
                (None, None) => code.len(),
                (None, Some(s)) => s + 1,
                (Some(brace), Some(s)) if s < brace => s + 1,
                (Some(brace), _) => {
                    let mut depth = 0usize;
                    let mut j = brace;
                    let mut end = code.len();
                    while j < code.len() {
                        match code[j] {
                            b'{' => depth += 1,
                            b'}' => {
                                depth -= 1;
                                if depth == 0 {
                                    end = j + 1;
                                    break;
                                }
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                    end
                }
            };
            for m in &mut mask[p..end.min(mask.len())] {
                *m = true;
            }
        }
    }
    mask
}

// --------------------------------------------------------------------------
// Allow markers.
// --------------------------------------------------------------------------

struct Marker {
    /// Line the comment sits on.
    line: usize,
    /// Line the marker applies to (same line, or the next one for a
    /// comment-only line).
    target: usize,
    rules: Vec<String>,
    reason: String,
}

/// `Some(Err(..))` = a detlint marker that failed to parse; `None` = not
/// a marker at all. A marker must be the *whole* comment (after the
/// `//`/`///`/`//!` introducer): prose that merely mentions
/// `detlint: allow(...)` mid-sentence is not a marker, so docs — these
/// docs included — can describe the syntax without tripping the parser.
fn parse_marker(text: &str) -> Option<Result<(Vec<String>, String), String>> {
    let t = text.trim_start_matches(|c| c == '/' || c == '!').trim_start();
    let rest = t.strip_prefix("detlint:")?.trim_start();
    let rest = match rest.strip_prefix("allow(") {
        Some(r) => r,
        None => return Some(Err("expected `allow(<rules>)` after `detlint:`".into())),
    };
    let close = match rest.find(')') {
        Some(c) => c,
        None => return Some(Err("unclosed `allow(`".into())),
    };
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if rules.is_empty() {
        return Some(Err("empty rule list in `allow()`".into()));
    }
    for r in &rules {
        if !RULE_IDS.contains(&r.as_str()) {
            return Some(Err(format!("unknown rule `{r}` in allow marker")));
        }
    }
    let tail = rest[close + 1..].trim_start();
    let reason = match tail.strip_prefix("--") {
        Some(r) => r.trim().to_string(),
        None => return Some(Err("marker missing `-- <reason>`".into())),
    };
    if reason.is_empty() {
        return Some(Err("marker missing `-- <reason>`".into()));
    }
    Some(Ok((rules, reason)))
}

// --------------------------------------------------------------------------
// Scope matching.
// --------------------------------------------------------------------------

/// Does `path` fall under any of `scopes`? A scope is a `/`-separated
/// run of path segments matched anywhere in the (normalized) path.
pub fn in_scope(path: &str, scopes: &[&str]) -> bool {
    let norm = path.replace('\\', "/");
    let segs: Vec<&str> = norm.split('/').filter(|s| !s.is_empty()).collect();
    scopes.iter().any(|scope| {
        let want: Vec<&str> = scope.split('/').collect();
        !want.is_empty()
            && segs.len() >= want.len()
            && segs.windows(want.len()).any(|w| w == want.as_slice())
    })
}

// --------------------------------------------------------------------------
// Pattern helpers.
// --------------------------------------------------------------------------

fn bounded(code: &[u8], start: usize, end: usize) -> bool {
    let before_ok = start == 0 || !is_ident(code[start - 1]);
    let after_ok = end >= code.len() || !is_ident(code[end]);
    before_ok && after_ok
}

fn find_word_all(code: &[u8], word: &[u8]) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = find_from(code, from, word) {
        if bounded(code, p, p + word.len()) {
            out.push(p);
        }
        from = p + 1;
    }
    out
}

fn find_all(code: &[u8], pat: &[u8]) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = find_from(code, from, pat) {
        out.push(p);
        from = p + 1;
    }
    out
}

/// Start of the statement containing `pos` (after the previous `;`,
/// `{`, or `}` in the blanked code).
fn stmt_start(code: &[u8], pos: usize) -> usize {
    code[..pos]
        .iter()
        .rposition(|&c| c == b';' || c == b'{' || c == b'}')
        .map(|p| p + 1)
        .unwrap_or(0)
}

/// Heuristic: does this code slice mention float arithmetic? Word
/// `f32`/`f64` or a float literal counts; the bit-domain constructors
/// `f32::from_bits`/`f64::from_bits` are ignored (they take integers).
fn float_evidence(text: &[u8]) -> bool {
    let mut t = text.to_vec();
    for pat in [&b"f32::from_bits"[..], &b"f64::from_bits"[..]] {
        let mut from = 0usize;
        while let Some(p) = find_from(&t, from, pat) {
            blank_range(&mut t, p, p + pat.len());
            from = p + pat.len();
        }
    }
    if !find_word_all(&t, b"f32").is_empty() || !find_word_all(&t, b"f64").is_empty() {
        return true;
    }
    t.windows(3)
        .any(|w| w[0].is_ascii_digit() && w[1] == b'.' && w[2].is_ascii_digit())
}

const INT_TYPES: [&str; 12] = [
    "i8", "i16", "i32", "i64", "i128", "isize", "u8", "u16", "u32", "u64", "u128",
    "usize",
];

// --------------------------------------------------------------------------
// The scanner.
// --------------------------------------------------------------------------

struct Candidate {
    pos: usize,
    rule: &'static str,
    message: String,
}

/// Scan one file's source. `path` is used for scoping and reporting;
/// scope matching is segment-based, so both repo-relative and absolute
/// paths work.
pub fn scan_source(path: &str, src: &str) -> Report {
    let Blanked { code, comments } = blank(src);
    let mask = test_mask(&code);

    // Line bookkeeping.
    let mut line_starts: Vec<usize> = vec![0];
    for (i, &b) in code.iter().enumerate() {
        if b == b'\n' {
            line_starts.push(i + 1);
        }
    }
    let line_of = |pos: usize| -> usize {
        match line_starts.binary_search(&pos) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    };
    let line_is_blank = |line: usize| -> bool {
        let a = line_starts[line - 1];
        let b = line_starts.get(line).copied().unwrap_or(code.len());
        code[a..b].iter().all(|&c| c == b' ' || c == b'\n')
    };

    // Markers.
    let mut report = Report { files_scanned: 1, ..Report::default() };
    let mut markers: Vec<Marker> = Vec::new();
    for (line, text) in &comments {
        match parse_marker(text) {
            None => {}
            Some(Err(msg)) => report.marker_problems.push(MarkerProblem {
                path: path.to_string(),
                line: *line,
                message: msg,
            }),
            Some(Ok((rules, reason))) => {
                // A comment-only line covers the next line; a trailing
                // comment covers its own.
                let target = if line_is_blank(*line) {
                    *line + 1
                } else {
                    *line
                };
                markers.push(Marker { line: *line, target, rules, reason });
            }
        }
    }
    let mut allow: BTreeMap<usize, BTreeMap<String, String>> = BTreeMap::new();
    for m in &markers {
        let entry = allow.entry(m.target).or_default();
        for r in &m.rules {
            entry.insert(r.clone(), m.reason.clone());
        }
    }

    // Collect candidates per rule.
    let mut cands: Vec<Candidate> = Vec::new();
    if in_scope(path, D1_SCOPE) {
        for word in [&b"HashMap"[..], &b"HashSet"[..]] {
            for p in find_word_all(&code, word) {
                cands.push(Candidate {
                    pos: p,
                    rule: "D1",
                    message: format!(
                        "hash-ordered container `{}` in a trajectory/artifact module \
                         (iteration order leaks; use BTreeMap/BTreeSet or annotate a \
                         lookup-only use)",
                        String::from_utf8_lossy(word)
                    ),
                });
            }
        }
    }
    if in_scope(path, D2_SCOPE) {
        for pat in [&b"Instant::now"[..], &b"SystemTime"[..], &b"std::time"[..]] {
            for p in find_word_all(&code, pat) {
                cands.push(Candidate {
                    pos: p,
                    rule: "D2",
                    message: format!(
                        "wall-clock `{}` in a step-math module (breaks bit-identical \
                         replay; move timing out of the step path or annotate \
                         telemetry-only use)",
                        String::from_utf8_lossy(pat)
                    ),
                });
            }
        }
    }
    if !in_scope(path, D3_SPAWN_EXEMPT) {
        for p in find_word_all(&code, b"thread::spawn") {
            cands.push(Candidate {
                pos: p,
                rule: "D3",
                message: "raw `thread::spawn` outside parallel/ (use \
                          parallel::par_map / par_chunks_mut, which keep results \
                          thread-count invariant)"
                    .into(),
            });
        }
    }
    if in_scope(path, D3_REDUCE_SCOPE) {
        for pat in [&b".sum::<f32>"[..], &b".sum::<f64>"[..]] {
            for p in find_all(&code, pat) {
                cands.push(Candidate {
                    pos: p,
                    rule: "D3",
                    message: "float `.sum()` reduction in the numeric spine (must be \
                              sequential in a fixed order — annotate why this one is, \
                              or route through the k-ordered kernels)"
                        .into(),
                });
            }
        }
        for p in find_all(&code, b".sum()") {
            if float_evidence(&code[stmt_start(&code, p)..p]) {
                cands.push(Candidate {
                    pos: p,
                    rule: "D3",
                    message: "float `.sum()` reduction in the numeric spine (must be \
                              sequential in a fixed order — annotate why this one is, \
                              or route through the k-ordered kernels)"
                        .into(),
                });
            }
        }
        for p in find_all(&code, b".fold(") {
            let end = (p + 6 + 64).min(code.len());
            if float_evidence(&code[p + 6..end]) {
                cands.push(Candidate {
                    pos: p,
                    rule: "D3",
                    message: "float-accumulator `.fold(..)` reduction in the numeric \
                              spine (order-sensitive; annotate or restructure)"
                        .into(),
                });
            }
        }
    }
    if in_scope(path, P1_SCOPE) {
        for pat in [&b".unwrap()"[..], &b".expect("[..]] {
            for p in find_all(&code, pat) {
                cands.push(Candidate {
                    pos: p,
                    rule: "P1",
                    message: format!(
                        "`{}` in the resilience spine (typed errors are the contract \
                         here: a panic turns a recoverable fault into an abort)",
                        String::from_utf8_lossy(&pat[1..])
                    ),
                });
            }
        }
        let macros = [&b"panic!"[..], &b"unreachable!"[..], &b"todo!"[..], &b"unimplemented!"[..]];
        for mac in macros {
            let word = &mac[..mac.len() - 1];
            let mut from = 0usize;
            while let Some(p) = find_from(&code, from, mac) {
                if bounded(&code, p, p + word.len()) {
                    cands.push(Candidate {
                        pos: p,
                        rule: "P1",
                        message: format!(
                            "`{}` in the resilience spine (raise a typed error instead)",
                            String::from_utf8_lossy(mac)
                        ),
                    });
                }
                from = p + 1;
            }
        }
    }
    if in_scope(path, S1_SCOPE) {
        for p in find_word_all(&code, b"as") {
            let mut k = p + 2;
            while k < code.len() && (code[k] == b' ' || code[k] == b'\t' || code[k] == b'\n') {
                k += 1;
            }
            let ty_start = k;
            while k < code.len() && is_ident(code[k]) {
                k += 1;
            }
            let ty = String::from_utf8_lossy(&code[ty_start..k]).into_owned();
            if INT_TYPES.contains(&ty.as_str())
                && float_evidence(&code[stmt_start(&code, p)..p])
            {
                cands.push(Candidate {
                    pos: p,
                    rule: "S1",
                    message: format!(
                        "float->int `as {ty}` cast in a mult/ decomposition path \
                         (silently saturates/truncates; use the checked helpers in \
                         mult::cast)"
                    ),
                });
            }
        }
    }

    // Resolve candidates against the test mask and allow markers.
    cands.sort_by_key(|c| (c.pos, c.rule));
    let mut used: BTreeSet<(usize, String)> = BTreeSet::new();
    for c in cands {
        if mask[c.pos] {
            continue;
        }
        let line = line_of(c.pos);
        if let Some(rules) = allow.get(&line) {
            if let Some(reason) = rules.get(c.rule) {
                used.insert((line, c.rule.to_string()));
                report.suppressions.push(Suppression {
                    rule: c.rule.to_string(),
                    path: path.to_string(),
                    line,
                    reason: reason.clone(),
                });
                continue;
            }
        }
        report.violations.push(Violation {
            rule: c.rule,
            path: path.to_string(),
            line,
            message: c.message,
        });
    }
    for m in &markers {
        for r in &m.rules {
            if !used.contains(&(m.target, r.clone())) {
                report.stale_markers.push(MarkerProblem {
                    path: path.to_string(),
                    line: m.line,
                    message: format!("stale marker: allow({r}) suppressed nothing"),
                });
            }
        }
    }
    report
}

/// Scan a file or directory tree (only `.rs` files), in sorted path
/// order so output is deterministic.
pub fn scan_path(path: &std::path::Path) -> std::io::Result<Report> {
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    collect_rs_files(path, &mut files)?;
    files.sort();
    let mut report = Report::default();
    for f in files {
        let src = std::fs::read_to_string(&f)?;
        let rel = f.to_string_lossy().replace('\\', "/");
        report.merge(scan_source(&rel, &src));
    }
    Ok(report)
}

fn collect_rs_files(
    path: &std::path::Path,
    out: &mut Vec<std::path::PathBuf>,
) -> std::io::Result<()> {
    let meta = std::fs::metadata(path)?;
    if meta.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<std::path::PathBuf> = Vec::new();
    for entry in std::fs::read_dir(path)? {
        entries.push(entry?.path());
    }
    entries.sort();
    for e in entries {
        let m = std::fs::metadata(&e)?;
        if m.is_dir() {
            collect_rs_files(&e, out)?;
        } else if e.extension().is_some_and(|x| x == "rs") {
            out.push(e);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(path: &str, src: &str) -> Vec<(String, usize)> {
        scan_source(path, src)
            .violations
            .into_iter()
            .map(|x| (x.rule.to_string(), x.line))
            .collect()
    }

    #[test]
    fn comments_and_strings_are_blanked() {
        let src = "// HashMap in a comment\nlet s = \"HashMap\"; /* HashMap */\n";
        assert!(v("src/mult/x.rs", src).is_empty());
    }

    #[test]
    fn raw_strings_and_chars_are_blanked() {
        let src = "let s = r#\"HashMap \"quoted\" \"#;\nlet c = '\"';\nlet b = b\"HashMap\";\n";
        assert!(v("src/mult/x.rs", src).is_empty());
        // A char-literal brace must not desync statement tracking.
        let src2 = "fn f() { let open = '{'; let m: HashMap<u32, u32> = x; }\n";
        assert_eq!(v("src/mult/x.rs", src2), vec![("D1".to_string(), 1)]);
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\nlet m: HashMap<u8, u8> = y;\n";
        assert_eq!(v("src/tensor/mod.rs", src), vec![("D1".to_string(), 2)]);
    }

    #[test]
    fn d1_out_of_scope_is_ignored() {
        let src = "use std::collections::HashMap;\n";
        assert!(v("src/cli/mod.rs", src).is_empty());
        assert_eq!(v("src/config/mod.rs", src).len(), 1);
    }

    #[test]
    fn d2_scope_exempts_benchkit() {
        let src = "use std::time::Instant;\n";
        assert!(v("src/benchkit/mod.rs", src).is_empty());
        assert_eq!(v("src/runtime/native/mod.rs", src).len(), 1);
        // runtime/ outside native/ is not step math.
        assert!(v("src/runtime/engine.rs", src).is_empty());
    }

    #[test]
    fn d3_spawn_everywhere_but_parallel() {
        let src = "std::thread::spawn(|| {});\n";
        assert_eq!(v("src/report/mod.rs", src).len(), 1);
        assert!(v("src/parallel/mod.rs", src).is_empty());
    }

    #[test]
    fn d3_float_sum_needs_float_evidence() {
        let int_sum = "fn f(x: &[u64]) -> u64 { x.iter().sum() }\n";
        assert!(v("src/data/mod.rs", int_sum).is_empty());
        let float_sum = "fn f(x: &[f32]) -> f32 { let s: f32 = x.iter().sum(); s }\n";
        assert_eq!(v("src/data/mod.rs", float_sum).len(), 1);
        let turbofish = "let s = xs.iter().sum::<f64>();\n";
        assert_eq!(v("src/tensor/mod.rs", turbofish).len(), 1);
        let float_fold = "let m = xs.iter().fold(f64::MIN, f64::max);\n";
        assert_eq!(v("src/tensor/mod.rs", float_fold).len(), 1);
        let welford_fold = "accs.into_iter().fold(Welford::new(), Welford::merge);\n";
        assert!(v("src/mult/stats.rs", welford_fold).is_empty());
    }

    #[test]
    fn p1_fires_in_spine_only_outside_tests() {
        let src = "fn f() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn g() { y.unwrap(); }\n}\n";
        let got = v("src/checkpoint/mod.rs", src);
        assert_eq!(got, vec![("P1".to_string(), 1)]);
        // unwrap_or is fine.
        assert!(v("src/checkpoint/mod.rs", "x.unwrap_or(0);\n").is_empty());
        // Not spine: no P1.
        assert!(v("src/coordinator/sweep.rs", "x.unwrap();\n").is_empty());
        assert_eq!(v("src/coordinator/trainer.rs", "panic!(\"boom\");\n").len(), 1);
    }

    #[test]
    fn test_attr_on_fn_is_masked() {
        let src = "#[test]\nfn t() { x.unwrap(); }\nfn live() { y.unwrap(); }\n";
        assert_eq!(v("src/checkpoint/mod.rs", src), vec![("P1".to_string(), 3)]);
    }

    #[test]
    fn s1_flags_float_casts_not_bit_casts() {
        let float_cast = "let q = (x * 0.5) as u32;\n";
        assert_eq!(v("src/mult/gaussian.rs", float_cast), vec![("S1".to_string(), 1)]);
        let bit_repack = "let w = f32::from_bits((sign << 31) | ((er as u32) << 23));\n";
        assert!(v("src/mult/matmul.rs", bit_repack).is_empty());
        let int_cast = "let k = (bits >> 23) as i32;\n";
        assert!(v("src/mult/prepared.rs", int_cast).is_empty());
        // Out of mult/: not S1's business.
        assert!(v("src/tensor/mod.rs", float_cast).is_empty());
    }

    #[test]
    fn allow_marker_suppresses_and_records() {
        let src = "// detlint: allow(D1) -- lookup-only cache, never iterated\n\
                   let m: HashMap<u32, u32> = x;\n";
        let r = scan_source("src/mult/x.rs", src);
        assert!(r.violations.is_empty());
        assert_eq!(r.suppressions.len(), 1);
        assert_eq!(r.suppressions[0].rule, "D1");
        assert!(r.suppressions[0].reason.contains("lookup-only"));
        assert!(r.stale_markers.is_empty());
    }

    #[test]
    fn same_line_marker_works() {
        let src = "let m: HashMap<u32, u32> = x; // detlint: allow(D1) -- fixture\n";
        let r = scan_source("src/mult/x.rs", src);
        assert!(r.violations.is_empty());
        assert_eq!(r.suppressions.len(), 1);
    }

    #[test]
    fn malformed_markers_are_problems() {
        let no_reason = "// detlint: allow(D1)\nlet m: HashMap<u8, u8> = x;\n";
        let r = scan_source("src/mult/x.rs", no_reason);
        assert_eq!(r.marker_problems.len(), 1);
        assert_eq!(r.violations.len(), 1); // marker invalid -> no suppression
        let unknown = "// detlint: allow(D9) -- whatever\n";
        let r = scan_source("src/mult/x.rs", unknown);
        assert_eq!(r.marker_problems.len(), 1);
    }

    #[test]
    fn stale_marker_warns() {
        let src = "// detlint: allow(P1) -- nothing here\nlet x = 1;\n";
        let r = scan_source("src/checkpoint/mod.rs", src);
        assert!(r.violations.is_empty());
        assert_eq!(r.stale_markers.len(), 1);
        assert!(!r.failed()); // stale markers warn, not fail
    }

    #[test]
    fn string_continuation_escape_keeps_line_numbers() {
        // `\` + newline inside a string consumes the newline; losing it
        // desyncs every later line number and detaches same-line
        // markers from their code (found on the real tree).
        let src = "let s = \"a \\\n b\";\nx.unwrap(); // detlint: allow(P1) -- continuation test\n";
        let r = scan_source("src/checkpoint/mod.rs", src);
        assert!(r.violations.is_empty(), "violations: {:?}", r.violations);
        assert_eq!(r.suppressions.len(), 1);
        assert_eq!(r.suppressions[0].line, 3);
        assert!(r.stale_markers.is_empty());
    }

    #[test]
    fn scope_matching_is_segment_based() {
        assert!(in_scope("rust/src/runtime/native/mod.rs", &["runtime/native"]));
        assert!(!in_scope("rust/src/runtime/engine.rs", &["runtime/native"]));
        assert!(in_scope("/abs/path/rust/src/mult/lut.rs", &["mult"]));
        assert!(!in_scope("rust/src/multiplier/x.rs", &["mult"]));
        assert!(in_scope("fixtures/bad/checkpoint/p1.rs", &["checkpoint"]));
    }

    #[test]
    fn rules_table_is_consistent() {
        assert_eq!(RULES.len(), RULE_IDS.len());
        for (r, id) in RULES.iter().zip(RULE_IDS.iter()) {
            assert_eq!(r.id, *id);
            assert!(!r.summary.is_empty() && !r.rationale.is_empty());
            assert!(r.severity == "deny" || r.severity == "warn");
        }
    }
}
