//! detlint CLI.
//!
//! ```text
//! detlint [--json] [--strict-stale] [--baseline <report.json>] <path>...
//! detlint --list-rules [--json]
//! ```
//!
//! Exit codes: 0 = clean, 1 = violations or malformed markers (or stale
//! markers under `--strict-stale`), 2 = usage or I/O error. Stale
//! (unused) allow markers are reported but only fail the run under
//! `--strict-stale`. `--baseline` reads a previous `--json` report and
//! grandfathers its violations by (rule, path, message): the ratchet —
//! old findings burn down without blocking CI, new ones fail.
//!
//! All named paths are scanned as ONE project, so cross-file rules (C1
//! SIMD-parity coverage) see `mult/` registrations, the parity suite,
//! and the bench rows together.

use std::process::ExitCode;

use detlint::{Report, RULES};

const USAGE: &str =
    "usage: detlint [--json] [--strict-stale] [--baseline <report.json>] <path>... \
     | detlint --list-rules [--json]";

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn print_rules(json: bool) {
    if json {
        let rows: Vec<String> = RULES
            .iter()
            .map(|r| {
                format!(
                    "{{\"id\":\"{}\",\"severity\":\"{}\",\"scope\":[{}],\"summary\":\"{}\",\"rationale\":\"{}\"}}",
                    r.id,
                    r.severity,
                    r.scope
                        .iter()
                        .map(|s| format!("\"{}\"", json_escape(s)))
                        .collect::<Vec<_>>()
                        .join(","),
                    json_escape(r.summary),
                    json_escape(r.rationale),
                )
            })
            .collect();
        println!("[{}]", rows.join(","));
        return;
    }
    for r in &RULES {
        println!("{} [{}] — {}", r.id, r.severity, r.summary);
        println!("    scope: {}", r.scope.join(", "));
        println!("    {}", r.rationale);
    }
}

fn print_report(report: &Report, json: bool, failed: bool) {
    if json {
        let vio = |v: &detlint::Violation| {
            format!(
                "{{\"rule\":\"{}\",\"path\":\"{}\",\"line\":{},\"message\":\"{}\"}}",
                v.rule,
                json_escape(&v.path),
                v.line,
                json_escape(&v.message)
            )
        };
        let vs: Vec<String> = report.violations.iter().map(vio).collect();
        let gs: Vec<String> = report.grandfathered.iter().map(vio).collect();
        let ss: Vec<String> = report
            .suppressions
            .iter()
            .map(|s| {
                format!(
                    "{{\"rule\":\"{}\",\"path\":\"{}\",\"line\":{},\"reason\":\"{}\"}}",
                    json_escape(&s.rule),
                    json_escape(&s.path),
                    s.line,
                    json_escape(&s.reason)
                )
            })
            .collect();
        let mp = |p: &detlint::MarkerProblem| {
            format!(
                "{{\"path\":\"{}\",\"line\":{},\"message\":\"{}\"}}",
                json_escape(&p.path),
                p.line,
                json_escape(&p.message)
            )
        };
        let probs: Vec<String> = report.marker_problems.iter().map(mp).collect();
        let stale: Vec<String> = report.stale_markers.iter().map(mp).collect();
        println!(
            "{{\"files_scanned\":{},\"violations\":[{}],\"grandfathered\":[{}],\"suppressions\":[{}],\"marker_problems\":[{}],\"stale_markers\":[{}],\"ok\":{}}}",
            report.files_scanned,
            vs.join(","),
            gs.join(","),
            ss.join(","),
            probs.join(","),
            stale.join(","),
            !failed
        );
        return;
    }
    for v in &report.violations {
        println!("{}:{}: [{}] {}", v.path, v.line, v.rule, v.message);
    }
    for v in &report.grandfathered {
        println!("{}:{}: [grandfathered {}] {}", v.path, v.line, v.rule, v.message);
    }
    for p in &report.marker_problems {
        println!("{}:{}: [marker] {}", p.path, p.line, p.message);
    }
    for s in &report.stale_markers {
        println!("{}:{}: [stale] {}", s.path, s.line, s.message);
    }
    println!(
        "detlint: {} file(s), {} violation(s), {} grandfathered, {} suppression(s), {} marker problem(s), {} stale marker(s)",
        report.files_scanned,
        report.violations.len(),
        report.grandfathered.len(),
        report.suppressions.len(),
        report.marker_problems.len(),
        report.stale_markers.len()
    );
}

fn main() -> ExitCode {
    let mut json = false;
    let mut list_rules = false;
    let mut strict_stale = false;
    let mut baseline_path: Option<String> = None;
    let mut expect_baseline = false;
    let mut paths: Vec<String> = Vec::new();
    for arg in std::env::args().skip(1) {
        if expect_baseline {
            baseline_path = Some(arg);
            expect_baseline = false;
            continue;
        }
        match arg.as_str() {
            "--json" => json = true,
            "--list-rules" => list_rules = true,
            "--strict-stale" => strict_stale = true,
            "--baseline" => expect_baseline = true,
            "--help" | "-h" => {
                eprintln!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            a if a.starts_with('-') => {
                eprintln!("detlint: unknown flag `{a}`");
                return ExitCode::from(2);
            }
            a => paths.push(a.to_string()),
        }
    }
    if expect_baseline {
        eprintln!("detlint: --baseline needs a report path");
        return ExitCode::from(2);
    }
    if list_rules {
        print_rules(json);
        return ExitCode::SUCCESS;
    }
    if paths.is_empty() {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    }
    let baseline = match &baseline_path {
        None => Vec::new(),
        Some(p) => {
            let text = match std::fs::read_to_string(p) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("detlint: --baseline {p}: {e}");
                    return ExitCode::from(2);
                }
            };
            match detlint::parse_baseline(&text) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("detlint: --baseline {p}: {e}");
                    return ExitCode::from(2);
                }
            }
        }
    };
    let path_bufs: Vec<std::path::PathBuf> =
        paths.iter().map(std::path::PathBuf::from).collect();
    let mut report = match detlint::scan_paths(&path_bufs) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("detlint: {e}");
            return ExitCode::from(2);
        }
    };
    if !baseline.is_empty() {
        report.apply_baseline(&baseline);
    }
    let failed = report.failed() || (strict_stale && !report.stale_markers.is_empty());
    print_report(&report, json, failed);
    if failed {
        ExitCode::from(1)
    } else {
        ExitCode::SUCCESS
    }
}
