//! detlint CLI.
//!
//! ```text
//! detlint [--json] <path>...     scan files/trees (exit 0 clean, 1 findings)
//! detlint --list-rules [--json]  print the rule table
//! ```
//!
//! Exit codes: 0 = clean, 1 = violations or malformed markers, 2 = usage
//! or I/O error. Stale (unused) allow markers are reported but do not
//! fail the run.

use std::process::ExitCode;

use detlint::{Report, RULES};

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn print_rules(json: bool) {
    if json {
        let rows: Vec<String> = RULES
            .iter()
            .map(|r| {
                format!(
                    "{{\"id\":\"{}\",\"severity\":\"{}\",\"scope\":[{}],\"summary\":\"{}\",\"rationale\":\"{}\"}}",
                    r.id,
                    r.severity,
                    r.scope
                        .iter()
                        .map(|s| format!("\"{}\"", json_escape(s)))
                        .collect::<Vec<_>>()
                        .join(","),
                    json_escape(r.summary),
                    json_escape(r.rationale),
                )
            })
            .collect();
        println!("[{}]", rows.join(","));
        return;
    }
    for r in &RULES {
        println!("{} [{}] — {}", r.id, r.severity, r.summary);
        println!("    scope: {}", r.scope.join(", "));
        println!("    {}", r.rationale);
    }
}

fn print_report(report: &Report, json: bool) {
    if json {
        let vs: Vec<String> = report
            .violations
            .iter()
            .map(|v| {
                format!(
                    "{{\"rule\":\"{}\",\"path\":\"{}\",\"line\":{},\"message\":\"{}\"}}",
                    v.rule,
                    json_escape(&v.path),
                    v.line,
                    json_escape(&v.message)
                )
            })
            .collect();
        let ss: Vec<String> = report
            .suppressions
            .iter()
            .map(|s| {
                format!(
                    "{{\"rule\":\"{}\",\"path\":\"{}\",\"line\":{},\"reason\":\"{}\"}}",
                    json_escape(&s.rule),
                    json_escape(&s.path),
                    s.line,
                    json_escape(&s.reason)
                )
            })
            .collect();
        let mp = |p: &detlint::MarkerProblem| {
            format!(
                "{{\"path\":\"{}\",\"line\":{},\"message\":\"{}\"}}",
                json_escape(&p.path),
                p.line,
                json_escape(&p.message)
            )
        };
        let probs: Vec<String> = report.marker_problems.iter().map(mp).collect();
        let stale: Vec<String> = report.stale_markers.iter().map(mp).collect();
        println!(
            "{{\"files_scanned\":{},\"violations\":[{}],\"suppressions\":[{}],\"marker_problems\":[{}],\"stale_markers\":[{}],\"ok\":{}}}",
            report.files_scanned,
            vs.join(","),
            ss.join(","),
            probs.join(","),
            stale.join(","),
            !report.failed()
        );
        return;
    }
    for v in &report.violations {
        println!("{}:{}: [{}] {}", v.path, v.line, v.rule, v.message);
    }
    for p in &report.marker_problems {
        println!("{}:{}: [marker] {}", p.path, p.line, p.message);
    }
    for s in &report.stale_markers {
        println!("{}:{}: [stale] {}", s.path, s.line, s.message);
    }
    println!(
        "detlint: {} file(s), {} violation(s), {} suppression(s), {} marker problem(s), {} stale marker(s)",
        report.files_scanned,
        report.violations.len(),
        report.suppressions.len(),
        report.marker_problems.len(),
        report.stale_markers.len()
    );
}

fn main() -> ExitCode {
    let mut json = false;
    let mut list_rules = false;
    let mut paths: Vec<String> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--list-rules" => list_rules = true,
            "--help" | "-h" => {
                eprintln!("usage: detlint [--json] <path>... | detlint --list-rules [--json]");
                return ExitCode::SUCCESS;
            }
            a if a.starts_with('-') => {
                eprintln!("detlint: unknown flag `{a}`");
                return ExitCode::from(2);
            }
            a => paths.push(a.to_string()),
        }
    }
    if list_rules {
        print_rules(json);
        return ExitCode::SUCCESS;
    }
    if paths.is_empty() {
        eprintln!("usage: detlint [--json] <path>... | detlint --list-rules [--json]");
        return ExitCode::from(2);
    }
    let mut report = Report::default();
    for p in &paths {
        match detlint::scan_path(std::path::Path::new(p)) {
            Ok(r) => report.merge(r),
            Err(e) => {
                eprintln!("detlint: {p}: {e}");
                return ExitCode::from(2);
            }
        }
    }
    print_report(&report, json);
    if report.failed() {
        ExitCode::from(1)
    } else {
        ExitCode::SUCCESS
    }
}
