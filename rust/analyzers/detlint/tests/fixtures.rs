//! Fixture-corpus self-tests: each known-bad file trips its rule exactly
//! once, the allow-marker file suppresses with a recorded reason, the
//! clean file scans clean, and the CLI's exit codes match the contract.

use std::path::{Path, PathBuf};
use std::process::Command;

use detlint::{scan_path, scan_source};

fn fixture(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(rel)
}

fn scan_fixture(rel: &str) -> detlint::Report {
    let path = fixture(rel);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    // Scope matching is segment-based, so the path under fixtures/
    // (bad/mult/..., bad/runtime/native/...) lands in the right rule
    // scopes exactly like the mirrored src/ tree would.
    scan_source(&path.to_string_lossy().replace('\\', "/"), &src)
}

#[test]
fn each_bad_fixture_fires_its_rule_exactly_once() {
    let cases = [
        ("bad/mult/d1_hash_iteration.rs", "D1"),
        ("bad/runtime/native/d2_wall_clock.rs", "D2"),
        ("bad/runtime/native/d3_unordered_reduction.rs", "D3"),
        ("bad/checkpoint/p1_panic_in_recovery.rs", "P1"),
        ("bad/mult/s1_unchecked_cast.rs", "S1"),
    ];
    for (rel, rule) in cases {
        let r = scan_fixture(rel);
        assert_eq!(
            r.violations.len(),
            1,
            "{rel}: expected exactly one violation, got {:?}",
            r.violations
        );
        assert_eq!(r.violations[0].rule, rule, "{rel}: wrong rule");
        assert!(r.suppressions.is_empty(), "{rel}: unexpected suppressions");
        assert!(r.marker_problems.is_empty(), "{rel}: marker problems");
        assert!(r.failed(), "{rel}: report must fail");
    }
}

#[test]
fn allow_marker_fixture_suppresses_with_recorded_reasons() {
    let r = scan_fixture("allowed/mult/allow_marker.rs");
    assert!(r.violations.is_empty(), "violations: {:?}", r.violations);
    assert_eq!(r.suppressions.len(), 2, "suppressions: {:?}", r.suppressions);
    let mut rules: Vec<&str> = r.suppressions.iter().map(|s| s.rule.as_str()).collect();
    rules.sort_unstable();
    assert_eq!(rules, ["D1", "S1"]);
    for s in &r.suppressions {
        assert!(!s.reason.is_empty(), "suppression without reason: {s:?}");
    }
    let d1 = r.suppressions.iter().find(|s| s.rule == "D1").unwrap();
    assert!(d1.reason.contains("never iterated"), "reason not recorded: {d1:?}");
    assert!(r.marker_problems.is_empty());
    assert!(r.stale_markers.is_empty(), "stale: {:?}", r.stale_markers);
    assert!(!r.failed());
}

#[test]
fn clean_fixture_scans_clean() {
    let r = scan_fixture("clean/mult/ordered_clean.rs");
    assert!(r.violations.is_empty(), "violations: {:?}", r.violations);
    assert!(r.suppressions.is_empty());
    assert!(r.marker_problems.is_empty());
    assert!(r.stale_markers.is_empty());
    assert!(!r.failed());
}

#[test]
fn whole_corpus_counts_add_up() {
    let r = scan_path(&fixture("")).expect("scan fixtures/");
    assert_eq!(r.files_scanned, 7);
    assert_eq!(r.violations.len(), 5, "violations: {:?}", r.violations);
    assert_eq!(r.suppressions.len(), 2);
    assert!(r.marker_problems.is_empty());
    assert!(r.stale_markers.is_empty());
    assert!(r.failed());
}

#[test]
fn cli_exit_codes_match_contract() {
    let bin = env!("CARGO_BIN_EXE_detlint");

    // Bad corpus -> exit 1, findings on stdout.
    let out = Command::new(bin)
        .arg(fixture("bad"))
        .output()
        .expect("run detlint on bad corpus");
    assert_eq!(out.status.code(), Some(1), "bad corpus must exit 1");
    let stdout = String::from_utf8_lossy(&out.stdout);
    for rule in ["D1", "D2", "D3", "P1", "S1"] {
        assert!(stdout.contains(&format!("[{rule}]")), "missing {rule} in:\n{stdout}");
    }

    // Clean corpus -> exit 0.
    let out = Command::new(bin)
        .arg(fixture("clean"))
        .output()
        .expect("run detlint on clean corpus");
    assert_eq!(out.status.code(), Some(0), "clean corpus must exit 0");

    // Allowed corpus -> exit 0, suppressions surfaced in --json.
    let out = Command::new(bin)
        .arg("--json")
        .arg(fixture("allowed"))
        .output()
        .expect("run detlint --json on allowed corpus");
    assert_eq!(out.status.code(), Some(0), "allowed corpus must exit 0");
    let js = String::from_utf8_lossy(&out.stdout);
    assert!(js.contains("\"ok\":true"), "json: {js}");
    assert!(js.contains("\"rule\":\"D1\"") && js.contains("\"rule\":\"S1\""), "json: {js}");
    assert!(js.contains("never iterated"), "reason missing from json: {js}");

    // --list-rules -> exit 0, all five ids present.
    let out = Command::new(bin)
        .arg("--list-rules")
        .output()
        .expect("run detlint --list-rules");
    assert_eq!(out.status.code(), Some(0));
    let rules = String::from_utf8_lossy(&out.stdout);
    for id in ["D1", "D2", "D3", "P1", "S1"] {
        assert!(rules.contains(id), "--list-rules missing {id}: {rules}");
    }

    // Unknown flag / missing path -> exit 2.
    let out = Command::new(bin).arg("--bogus").output().expect("run detlint --bogus");
    assert_eq!(out.status.code(), Some(2));
    let out = Command::new(bin).output().expect("run detlint with no args");
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn json_output_is_deterministic_across_runs() {
    let bin = env!("CARGO_BIN_EXE_detlint");
    let run = || {
        Command::new(bin)
            .arg("--json")
            .arg(fixture(""))
            .output()
            .expect("run detlint --json on fixtures")
            .stdout
    };
    assert_eq!(run(), run(), "detlint --json must be byte-stable");
}
