//! Fixture-corpus integration tests.
//!
//! The corpus under `fixtures/` is scanned as text (never compiled):
//! `bad/` must fire each rule exactly once per fixture, `allowed/` must
//! produce suppressions only, `clean/` must be silent, and `c1/` holds
//! three 3-file mini-projects (mult registration + parity suite + bench
//! rows) because C1 is a cross-file rule. CLI tests pin exit codes, the
//! `--baseline` ratchet round-trip, `--strict-stale`, and byte-identical
//! `--json` output.

use std::path::{Path, PathBuf};
use std::process::Command;

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures")
}

fn scan_fixture(rel: &str) -> detlint::Report {
    let path = fixture_root().join(rel);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    // Scope matching is segment-based, so the path under fixtures/
    // (bad/mult/..., bad/runtime/native/...) lands in the right rule
    // scopes exactly like the mirrored src/ tree would.
    detlint::scan_source(&path.to_string_lossy().replace('\\', "/"), &src)
}

fn scan_dir(rel: &str) -> detlint::Report {
    detlint::scan_path(&fixture_root().join(rel)).expect("scan fixture dir")
}

fn run_detlint(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_detlint"))
        .args(args)
        .output()
        .expect("run detlint")
}

#[test]
fn each_bad_fixture_fires_its_rule_exactly_once() {
    let cases = [
        ("bad/mult/d1_hash_iteration.rs", "D1"),
        ("bad/mult/d1v2_iteration_site.rs", "D1v2"),
        ("bad/mult/s1_unchecked_cast.rs", "S1"),
        ("bad/runtime/native/d2_wall_clock.rs", "D2"),
        ("bad/runtime/native/d3_unordered_reduction.rs", "D3"),
        ("bad/checkpoint/p2_slice_index.rs", "P2"),
        ("bad/runtime/u1_unsafe_no_safety.rs", "U1"),
    ];
    for (rel, rule) in cases {
        let r = scan_fixture(rel);
        let hits = r.violations.iter().filter(|v| v.rule == rule).count();
        assert_eq!(hits, 1, "{rel}: expected {rule} x1, got {:?}", r.violations);
        assert!(
            r.violations.iter().all(|v| v.rule == rule),
            "{rel}: unexpected extra rules: {:?}",
            r.violations
        );
        assert!(r.marker_problems.is_empty(), "{rel}: marker problems");
        assert!(r.failed(), "{rel}: report must fail");
    }
}

#[test]
fn p1_fixture_crossfires_p2_on_the_slice_expression() {
    // `bytes[..4].try_into().unwrap()` is both a panicking index (P2)
    // and a panicking unwrap (P1) — the v2 engine sees both on the same
    // line. This pins the documented crossfire.
    let r = scan_fixture("bad/checkpoint/p1_panic_in_recovery.rs");
    assert_eq!(r.violations.len(), 2, "{:?}", r.violations);
    assert_eq!(r.violations.iter().filter(|v| v.rule == "P1").count(), 1);
    assert_eq!(r.violations.iter().filter(|v| v.rule == "P2").count(), 1);
    assert_eq!(r.violations[0].line, r.violations[1].line);
}

#[test]
fn allowed_fixtures_suppress_without_violations_or_stale() {
    let cases = [
        ("allowed/mult/allow_marker.rs", 2),
        ("allowed/mult/d1v2_allowed.rs", 2),
        ("allowed/checkpoint/p2_allowed.rs", 1),
        ("allowed/runtime/u1_allowed.rs", 1),
    ];
    for (rel, n) in cases {
        let r = scan_fixture(rel);
        assert!(r.violations.is_empty(), "{rel}: {:?}", r.violations);
        assert_eq!(r.suppressions.len(), n, "{rel}: {:?}", r.suppressions);
        for s in &r.suppressions {
            assert!(!s.reason.is_empty(), "{rel}: suppression without reason: {s:?}");
        }
        assert!(r.marker_problems.is_empty(), "{rel}: {:?}", r.marker_problems);
        assert!(r.stale_markers.is_empty(), "{rel}: {:?}", r.stale_markers);
        assert!(!r.failed());
    }
    let marker = scan_fixture("allowed/mult/allow_marker.rs");
    assert!(marker
        .suppressions
        .iter()
        .any(|s| s.rule == "D1" && s.reason.contains("never iterated")));
    assert!(marker.suppressions.iter().any(|s| s.rule == "S1"));
    let d1v2 = scan_fixture("allowed/mult/d1v2_allowed.rs");
    let mut rules: Vec<&str> = d1v2.suppressions.iter().map(|s| s.rule.as_str()).collect();
    rules.sort_unstable();
    assert_eq!(rules, ["D1", "D1v2"]);
}

#[test]
fn clean_fixtures_are_silent() {
    for rel in [
        "clean/mult/ordered_clean.rs",
        "clean/mult/d1v2_btree_iter.rs",
        "clean/checkpoint/p2_get_checked.rs",
        "clean/runtime/u1_safety_comment.rs",
    ] {
        let r = scan_fixture(rel);
        assert!(r.violations.is_empty(), "{rel}: {:?}", r.violations);
        assert!(r.suppressions.is_empty(), "{rel}: {:?}", r.suppressions);
        assert!(r.marker_problems.is_empty());
        assert!(r.stale_markers.is_empty(), "{rel}: {:?}", r.stale_markers);
        assert!(!r.failed());
    }
}

#[test]
fn c1_mini_projects_resolve_cross_file() {
    // C1 needs the parity suite and bench rows in the same scan set, so
    // each case is a directory scan, not a single-file one.
    let bad = scan_dir("c1/bad");
    assert_eq!(bad.files_scanned, 3);
    let c1: Vec<_> = bad.violations.iter().filter(|v| v.rule == "C1").collect();
    assert_eq!(c1.len(), 1, "{:?}", bad.violations);
    assert!(c1[0].message.contains("mitchell"));
    assert!(c1[0].message.contains("simd_parity.rs design lists"));
    assert!(c1[0].message.contains("named bench row"));

    let allowed = scan_dir("c1/allowed");
    assert!(allowed.violations.is_empty(), "{:?}", allowed.violations);
    assert_eq!(allowed.suppressions.len(), 1, "{:?}", allowed.suppressions);
    assert_eq!(allowed.suppressions[0].rule, "C1");
    assert!(allowed.stale_markers.is_empty(), "{:?}", allowed.stale_markers);

    let clean = scan_dir("c1/clean");
    assert!(clean.violations.is_empty(), "{:?}", clean.violations);
    assert!(clean.suppressions.is_empty());
}

#[test]
fn whole_corpus_counts_add_up() {
    let r = scan_dir("");
    assert_eq!(r.files_scanned, 25, "fixture corpus drifted");
    assert_eq!(r.violations.len(), 10, "violations: {:#?}", r.violations);
    assert_eq!(r.suppressions.len(), 8, "suppressions: {:#?}", r.suppressions);
    assert!(r.marker_problems.is_empty(), "{:?}", r.marker_problems);
    assert!(r.stale_markers.is_empty(), "{:?}", r.stale_markers);
    assert!(r.failed());
}

#[test]
fn cli_exit_codes_match_contract() {
    let root = fixture_root();

    // Bad corpus -> exit 1, findings on stdout.
    let out = run_detlint(&[root.join("bad").to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(1), "bad corpus must exit 1");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    for rule in ["D1", "D1v2", "D2", "D3", "P1", "P2", "S1", "U1"] {
        assert!(stdout.contains(&format!("[{rule}]")), "missing {rule} in:\n{stdout}");
    }

    // C1 mini-project -> exit 1 with the cross-file finding.
    let out = run_detlint(&[root.join("c1").join("bad").to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stdout).contains("[C1]"));

    // Clean corpus -> exit 0.
    let out = run_detlint(&[root.join("clean").to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(0), "clean corpus must exit 0");

    // Allowed corpus -> exit 0, suppressions surfaced in --json.
    let out = run_detlint(&["--json", root.join("allowed").to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(0), "allowed corpus must exit 0");
    let js = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(js.contains("\"ok\":true"), "json: {js}");
    assert!(js.contains("\"rule\":\"D1v2\"") && js.contains("\"rule\":\"U1\""), "json: {js}");
    assert!(js.contains("never iterated"), "reason missing from json: {js}");

    // --list-rules -> exit 0, all nine ids present.
    let out = run_detlint(&["--list-rules"]);
    assert_eq!(out.status.code(), Some(0));
    let rules = String::from_utf8_lossy(&out.stdout).into_owned();
    for id in detlint::RULE_IDS {
        assert!(rules.contains(id), "--list-rules missing {id}: {rules}");
    }
    assert_eq!(detlint::RULE_IDS.len(), 9);

    // Unknown flag / missing path / dangling --baseline -> exit 2.
    assert_eq!(run_detlint(&["--bogus"]).status.code(), Some(2));
    assert_eq!(run_detlint(&[]).status.code(), Some(2));
    assert_eq!(run_detlint(&["--baseline"]).status.code(), Some(2));
}

#[test]
fn json_output_is_deterministic_across_runs() {
    let root = fixture_root();
    let a = run_detlint(&["--json", root.to_str().unwrap()]);
    let b = run_detlint(&["--json", root.to_str().unwrap()]);
    assert_eq!(a.stdout, b.stdout, "detlint --json must be byte-stable");
    assert!(!a.stdout.is_empty());
}

#[test]
fn baseline_ratchet_round_trip() {
    let root = fixture_root();
    let bad = root.join("bad");
    let report = run_detlint(&["--json", bad.to_str().unwrap()]);
    assert_eq!(report.status.code(), Some(1));
    let tmp = std::env::temp_dir()
        .join(format!("detlint_baseline_{}.json", std::process::id()));
    std::fs::write(&tmp, &report.stdout).expect("write baseline");

    // Same tree against its own report: everything grandfathers, exit 0.
    let ratcheted = run_detlint(&[
        "--json",
        "--baseline",
        tmp.to_str().unwrap(),
        bad.to_str().unwrap(),
    ]);
    assert_eq!(ratcheted.status.code(), Some(0));
    let stdout = String::from_utf8_lossy(&ratcheted.stdout).into_owned();
    assert!(stdout.contains("\"violations\":[]"), "{stdout}");
    assert!(stdout.contains("\"grandfathered\":[{"), "{stdout}");
    assert!(stdout.contains("\"ok\":true"), "{stdout}");

    // A different tree with new findings still fails under the baseline.
    let c1bad = root.join("c1").join("bad");
    let fresh = run_detlint(&["--baseline", tmp.to_str().unwrap(), c1bad.to_str().unwrap()]);
    assert_eq!(fresh.status.code(), Some(1));

    // A garbage baseline is a usage error, not a silent pass.
    std::fs::write(&tmp, b"not json").expect("write garbage baseline");
    let broken = run_detlint(&["--baseline", tmp.to_str().unwrap(), bad.to_str().unwrap()]);
    assert_eq!(broken.status.code(), Some(2));
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn strict_stale_promotes_stale_markers_to_failures() {
    let dir = std::env::temp_dir().join(format!("detlint_stale_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("make temp dir");
    let file = dir.join("mult_stale.rs");
    std::fs::write(
        &file,
        "// detlint: allow(D1) -- suppresses nothing anymore\npub fn f() {}\n",
    )
    .expect("write stale fixture");
    let lenient = run_detlint(&[file.to_str().unwrap()]);
    assert_eq!(lenient.status.code(), Some(0));
    assert!(String::from_utf8_lossy(&lenient.stdout).contains("[stale]"));
    let strict = run_detlint(&["--strict-stale", file.to_str().unwrap()]);
    assert_eq!(strict.status.code(), Some(1));
    let json = run_detlint(&["--strict-stale", "--json", file.to_str().unwrap()]);
    assert!(String::from_utf8_lossy(&json.stdout).contains("\"ok\":false"));
    std::fs::remove_file(&file).ok();
    std::fs::remove_dir(&dir).ok();
}
