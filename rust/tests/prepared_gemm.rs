//! Bit-identity property suite for the decompose-once prepared GEMM.
//!
//! The blocked kernel behind `approx_matmul` / `_tn` / `_nt` must be
//! **bit-identical** to the scalar reference walk
//! (`approx_matmul_reference`: one `approx_mul_f32` per product, f32
//! accumulation in strict k-order) for every design × operand layout ×
//! thread count — including chains with non-finite and flushed
//! operands planted mid-chain. This pins the whole contract the native
//! backend trains under: same mantissa products through the same
//! `Multiplier`, same k-order accumulation, thread-count invariance.

use approxmul::mult::{
    approx_matmul, approx_matmul_nt, approx_matmul_reference, approx_matmul_tn,
    by_name, GEMM_ROW_BLOCK,
};
use approxmul::parallel;
use approxmul::rng::Xoshiro256;

const DESIGNS: &[&str] =
    &["exact", "drum6", "mitchell", "roba", "bam8", "trunc8", "lut12:drum6"];

fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0f32; src.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = src[r * cols + c];
        }
    }
    out
}

/// Random operands with occasional special values (inf, NaN, signed
/// zero, subnormal) planted through the chains.
fn operands(rows: usize, inner: usize, cols: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let mut gen = |n: usize| -> Vec<f32> {
        (0..n)
            .map(|_| match rng.next_u32() % 64 {
                0 => f32::INFINITY,
                1 => f32::NEG_INFINITY,
                2 => f32::NAN,
                3 => 0.0,
                4 => -0.0,
                5 => 1.0e-41, // subnormal -> flushed
                _ => 2.0 * rng.next_f32() - 1.0,
            })
            .collect()
    };
    (gen(rows * inner), gen(inner * cols))
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: element {i} differs ({g} vs {w})"
        );
    }
}

#[test]
fn prepared_kernel_is_bit_identical_to_reference_across_threads() {
    // Shape crosses both the row-block and col-panel boundaries so the
    // blocked paths (multi-block partials, panel edges) are exercised.
    let (rows, inner, cols) = (GEMM_ROW_BLOCK + 11, 21, 53);
    for (di, design) in DESIGNS.iter().enumerate() {
        let m = by_name(design).unwrap();
        let (a, b) = operands(rows, inner, cols, 1000 + di as u64);
        let want = approx_matmul_reference(m.as_ref(), &a, &b, rows, inner, cols)
            .unwrap();

        // TN stores A untransposed [inner x rows]; NT stores B
        // untransposed [cols x inner]. Derive both from (a, b) so all
        // three layouts compute the *same* logical product.
        let a_t = transpose(&a, rows, inner); // [inner x rows]
        let b_t = transpose(&b, inner, cols); // [cols x inner]

        for threads in [1usize, 2, 5] {
            parallel::set_max_threads(threads);
            let nn = approx_matmul(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
            let tn =
                approx_matmul_tn(m.as_ref(), &a_t, &b, rows, inner, cols).unwrap();
            let nt =
                approx_matmul_nt(m.as_ref(), &a, &b_t, rows, inner, cols).unwrap();
            parallel::set_max_threads(0);
            assert_bits_eq(&nn, &want, &format!("{design} NN t={threads}"));
            assert_bits_eq(&tn, &want, &format!("{design} TN t={threads}"));
            assert_bits_eq(&nt, &want, &format!("{design} NT t={threads}"));
        }
    }
}

#[test]
fn all_finite_chains_match_reference_on_small_shapes() {
    // Purely finite data (the training regime) on shapes below one row
    // block: the sequential path of the kernel.
    for (di, design) in DESIGNS.iter().enumerate() {
        let m = by_name(design).unwrap();
        let (rows, inner, cols) = (9usize, 16usize, 7usize);
        let mut rng = Xoshiro256::new(7 + di as u64);
        let a: Vec<f32> =
            (0..rows * inner).map(|_| 4.0 * rng.next_f32() - 2.0).collect();
        let b: Vec<f32> =
            (0..inner * cols).map(|_| 4.0 * rng.next_f32() - 2.0).collect();
        let fast = approx_matmul(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
        let slow =
            approx_matmul_reference(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
        assert_bits_eq(&fast, &slow, design);
    }
}

#[test]
fn nonfinite_and_flushed_chains_match_reference() {
    // Dense special-value chains: every k position cycles through the
    // special classes, so non-finite fallbacks and flushed skips
    // interleave with batched products inside single chains.
    let specials = [
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::NAN,
        0.0,
        -0.0,
        1.0e-41,
        1.5,
        -2.25,
    ];
    let (rows, inner, cols) = (4usize, specials.len() * 2, 3usize);
    let mut rng = Xoshiro256::new(99);
    let a: Vec<f32> = (0..rows * inner)
        .map(|i| {
            if i % 3 == 0 {
                specials[(i / 3) % specials.len()]
            } else {
                rng.next_f32() - 0.5
            }
        })
        .collect();
    let b: Vec<f32> = (0..inner * cols)
        .map(|i| {
            if i % 4 == 1 {
                specials[(i / 4) % specials.len()]
            } else {
                rng.next_f32() - 0.5
            }
        })
        .collect();
    for design in DESIGNS {
        let m = by_name(design).unwrap();
        let fast = approx_matmul(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
        let slow =
            approx_matmul_reference(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
        assert_bits_eq(&fast, &slow, design);
    }
}
