//! Serve-mode integration suite: virtual-clock batching semantics,
//! typed rejection paths, bit-identical replay at any thread count,
//! and the once-per-(checkpoint, spec) decomposition invariant.

use approxmul::config::ServeConfig;
use approxmul::mult::MultSpec;
use approxmul::runtime::NativeBackend;
use approxmul::serve::{
    replay, synth_trace, InferenceSession, InferRequest, RejectReason, ReplaySummary,
    Server, TraceSpec,
};

fn cfg() -> ServeConfig {
    ServeConfig {
        batch_window_us: 1_000,
        max_batch: 4,
        queue_capacity: 16,
        max_specs: 4,
        service_estimate_us: 500,
        max_request_bytes: 1 << 16,
    }
}

fn server(cfg: &ServeConfig, specs: &[&str]) -> Server {
    let parsed: Vec<MultSpec> =
        specs.iter().map(|s| MultSpec::parse(s).unwrap()).collect();
    let session =
        InferenceSession::from_fresh("micro", 7, &parsed, cfg.max_specs, 11).unwrap();
    Server::new(session, cfg).unwrap()
}

fn request(id: u64, elems: usize, deadline_us: u64, mult: Option<&str>) -> InferRequest {
    InferRequest {
        id,
        tenant: format!("tenant-{}", id % 3),
        mult: mult.map(str::to_string),
        deadline_us,
        input: vec![0.5; elems],
    }
}

#[test]
fn deadline_imminent_flushes_before_batch_full() {
    let c = cfg();
    let mut s = server(&c, &["exact"]);
    let elems = s.session().input_elems();
    // Two requests, far from max_batch=4, but with deadlines inside
    // the imminence horizon (start + 2*svc = 1000).
    s.submit(request(1, elems, 900, None), 0).unwrap();
    s.submit(request(2, elems, 900, None), 0).unwrap();
    let out = s.poll(0).unwrap();
    assert_eq!(out.responses.len(), 2, "imminent deadline must flush a partial batch");
    assert!(out.rejects.is_empty());
    let log = s.batch_log();
    assert_eq!(log.len(), 1);
    assert_eq!(log[0].trigger, "deadline-imminent");
    // Control: same arrivals with lazy deadlines wait for the window.
    let mut s2 = server(&c, &["exact"]);
    s2.submit(request(1, elems, 500_000, None), 0).unwrap();
    s2.submit(request(2, elems, 500_000, None), 0).unwrap();
    assert!(s2.poll(0).unwrap().responses.is_empty(), "no trigger at t=0");
    let out = s2.poll(1_000).unwrap(); // window elapses
    assert_eq!(out.responses.len(), 2);
    assert_eq!(s2.batch_log()[0].trigger, "window-elapsed");
}

#[test]
fn batch_full_flushes_immediately() {
    let c = cfg();
    let mut s = server(&c, &["exact"]);
    let elems = s.session().input_elems();
    for i in 0..4 {
        s.submit(request(i, elems, 500_000, None), 0).unwrap();
    }
    let out = s.poll(0).unwrap();
    assert_eq!(out.responses.len(), 4);
    assert_eq!(s.batch_log()[0].trigger, "batch-full");
    for r in &out.responses {
        assert_eq!(r.batch, 4);
    }
}

#[test]
fn queue_overflow_rejects_typed_and_preserves_accepted_work() {
    let c = ServeConfig { queue_capacity: 6, ..cfg() };
    let mut s = server(&c, &["exact"]);
    let elems = s.session().input_elems();
    let mut accepted = 0u64;
    let mut queue_full = 0u64;
    // Flood without polling: admission is bounded, never panics.
    for i in 0..20 {
        match s.submit(request(i, elems, 500_000, None), 0) {
            Ok(_) => accepted += 1,
            Err(r) => {
                assert_eq!(r.reason, RejectReason::QueueFull);
                assert!(r.detail.contains("6"), "detail names the bound: {}", r.detail);
                queue_full += 1;
            }
        }
    }
    assert_eq!(accepted, 6);
    assert_eq!(queue_full, 14);
    // Everything accepted is still served.
    let out = s.poll(0).unwrap();
    let drained = s.drain(0).unwrap();
    assert_eq!(out.responses.len() + drained.responses.len(), 6);
    assert_eq!(s.stats().rejected_queue, 14);
}

#[test]
fn specs_are_never_mixed_within_a_batch() {
    let c = cfg();
    let mut s = server(&c, &["exact", "drum6"]);
    let elems = s.session().input_elems();
    for i in 0..12 {
        let mult = if i % 2 == 0 { Some("exact") } else { Some("drum6") };
        s.submit(request(i, elems, 50_000, mult), 0).unwrap();
    }
    let _ = s.poll(0).unwrap();
    let _ = s.drain(0).unwrap();
    assert!(s.batch_log().len() >= 2);
    for rec in s.batch_log() {
        let parity = if rec.spec == "exact" { 0 } else { 1 };
        for id in &rec.ids {
            assert_eq!(
                id % 2,
                parity,
                "request {id} (spec parity) landed in a {} batch",
                rec.spec
            );
        }
    }
    assert_eq!(s.stats().completed, 12);
}

fn run_trace(threads: usize) -> (ReplaySummary, Vec<approxmul::serve::BatchRecord>, u64) {
    approxmul::parallel::set_max_threads(threads);
    let c = cfg();
    let mut s = server(&c, &["exact", "drum6", "sdrum6"]);
    let trace = synth_trace(
        &TraceSpec {
            seed: 99,
            requests: 48,
            mean_gap_us: 600,
            deadline_us: 4_000,
            specs: vec!["exact".into(), "drum6".into(), "sdrum6".into()],
        },
        s.session().input_elems(),
    );
    let summary = replay(&mut s, &trace).unwrap();
    let prepare_calls = s.session().prepare_calls();
    (summary, s.batch_log().to_vec(), prepare_calls)
}

#[test]
fn replay_is_bit_identical_across_runs_and_thread_counts() {
    let (a, log_a, prep_a) = run_trace(1);
    let (b, log_b, prep_b) = run_trace(4);
    let (c, log_c, _) = run_trace(1);
    // Logits are f32-exact, not approximately equal: same batches, same
    // GEMMs, same multiplier tables, regardless of worker count.
    assert_eq!(a.responses, b.responses);
    assert_eq!(a.rejects, b.rejects);
    assert_eq!(log_a, log_b, "batch compositions must not depend on threads");
    assert_eq!(a.responses, c.responses);
    assert_eq!(log_a, log_c, "same trace, same run, every time");
    assert_eq!(prep_a, prep_b);
    assert!(!a.responses.is_empty());
}

#[test]
fn decomposition_happens_once_per_checkpoint_spec_pair() {
    let c = cfg();
    let specs = [
        MultSpec::parse("exact").unwrap(),
        MultSpec::parse("drum6").unwrap(),
    ];
    let session = InferenceSession::from_fresh("micro", 7, &specs, 4, 11).unwrap();
    let per_spec = NativeBackend::new("micro", MultSpec::Exact)
        .unwrap()
        .n_gemm_layers() as u64;
    assert_eq!(session.prepare_calls(), per_spec * 2, "one decomposition per spec");
    let mut s = Server::new(session, &c).unwrap();
    let elems = s.session().input_elems();
    // Many batches across both specs: prepare count must not move.
    let mut t = 0u64;
    for i in 0..40 {
        let mult = if i % 2 == 0 { Some("exact") } else { Some("drum6") };
        s.submit(request(i, elems, 20_000, mult), t).unwrap();
        let _ = s.poll(t).unwrap();
        t += 700;
    }
    let _ = s.drain(t).unwrap();
    assert!(s.stats().batches >= 4);
    assert_eq!(s.stats().completed, 40);
    assert_eq!(
        s.session().prepare_calls(),
        per_spec * 2,
        "serving must reuse resident planes, never re-decompose"
    );
}

#[test]
fn duplicate_canonical_specs_share_one_resident_session() {
    // gaussian:0.05 spelled twice plus exact: registry holds 2 entries.
    let specs = [
        MultSpec::parse("exact").unwrap(),
        MultSpec::parse("gaussian:0.05").unwrap(),
        MultSpec::parse("gaussian:0.05").unwrap(),
    ];
    let session = InferenceSession::from_fresh("micro", 7, &specs, 4, 11).unwrap();
    assert_eq!(session.specs().len(), 2);
    let per_spec = NativeBackend::new("micro", MultSpec::Exact)
        .unwrap()
        .n_gemm_layers() as u64;
    assert_eq!(session.prepare_calls(), per_spec * 2);
    // The registry bound is enforced with a typed error, not a panic.
    let many: Vec<MultSpec> = ["exact", "drum6", "sdrum6"]
        .iter()
        .map(|s| MultSpec::parse(s).unwrap())
        .collect();
    let err = InferenceSession::from_fresh("micro", 7, &many, 2, 11).unwrap_err();
    assert!(err.to_string().contains("bounded"), "got: {err:#}");
}

#[test]
fn wire_roundtrip_and_hostile_bodies_through_submit() {
    let c = cfg();
    let mut s = server(&c, &["exact"]);
    let elems = s.session().input_elems();
    // Round-trip a request through the codec, then serve it.
    let req = request(31, elems, 500_000, Some("exact"));
    let line = req.to_value().to_string();
    let decoded = InferRequest::decode(line.as_bytes(), c.max_request_bytes).unwrap();
    assert_eq!(decoded, req);
    s.submit(decoded, 0).unwrap();
    let out = s.poll(1_000).unwrap();
    assert_eq!(out.responses.len(), 1);
    let resp = &out.responses[0];
    assert_eq!(resp.id, 31);
    assert_eq!(resp.logits.len(), s.session().num_classes());
    // Response survives its own codec round-trip.
    let back =
        approxmul::serve::InferResponse::from_value(&resp.to_value()).unwrap();
    assert_eq!(&back, resp);

    // Hostile bodies are typed decode errors, never panics, and a
    // wrong-shaped but well-formed request is rejected at submit.
    assert!(InferRequest::decode(&[0xFF, 0xFE], c.max_request_bytes).is_err());
    assert!(InferRequest::decode(b"{\"id\":1,\"id\":2}", c.max_request_bytes).is_err());
    let oversized = vec![b'x'; 1 << 20];
    assert!(InferRequest::decode(&oversized, 64).is_err());
    let bad = request(7, elems + 3, 500_000, None);
    let rej = s.submit(bad, 0).unwrap_err();
    assert_eq!(rej.reason, RejectReason::BadInput);
    let rej_back =
        approxmul::serve::InferReject::from_value(&rej.to_value()).unwrap();
    assert_eq!(rej_back.reason, RejectReason::BadInput);
}

#[test]
fn overload_burst_sheds_with_deadline_misses_and_conserves_requests() {
    let c = cfg();
    let mut s = server(&c, &["exact"]);
    let trace = synth_trace(
        &TraceSpec {
            seed: 3,
            requests: 48,
            mean_gap_us: 0,
            deadline_us: 1_500,
            specs: vec![],
        },
        s.session().input_elems(),
    );
    let summary = replay(&mut s, &trace).unwrap();
    let st = s.stats();
    assert_eq!(st.completed + st.rejected_queue + st.rejected_deadline, 48);
    assert!(st.rejected_deadline >= 1, "overload must shed by deadline");
    assert!(st.completed >= 1, "head of burst must still be served");
    for rej in &summary.rejects {
        assert!(
            rej.reason == RejectReason::DeadlineMissed
                || rej.reason == RejectReason::QueueFull
        );
    }
    // Every served request met its (absolute) deadline by construction.
    for resp in &summary.responses {
        assert!(resp.latency_us <= 1_500);
    }
}
