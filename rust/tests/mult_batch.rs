//! Batch-engine equivalence and determinism properties (PR 1's
//! acceptance contract):
//!
//! * `mul_batch` is bit-identical to scalar `mul` for every design
//!   across every operand distribution;
//! * the LUT backend is bit-identical wherever its contract guarantees
//!   it (in-table operands for all designs; full-range for DRUM-k with
//!   k <= table width);
//! * parallel `characterize` is deterministic in seed, independent of
//!   worker count, and reproduces the designs' published error bands.

use approxmul::mult::{
    by_name, characterize, characterize_threads, standard_designs, GaussianModel,
    LutMultiplier, Multiplier, OperandDist,
};
use approxmul::rng::Xoshiro256;
use approxmul::testkit::{forall, Gen};

fn sample_pairs(dist: OperandDist, n: usize, seed: u64) -> (Vec<u32>, Vec<u32>) {
    let mut rng = Xoshiro256::new(seed);
    let mut a = Vec::with_capacity(n);
    let mut b = Vec::with_capacity(n);
    for _ in 0..n {
        a.push(dist.sample(&mut rng));
        b.push(dist.sample(&mut rng));
    }
    (a, b)
}

#[test]
fn batch_is_bit_identical_to_scalar_for_every_design_and_dist() {
    for d in standard_designs() {
        for dist in OperandDist::all() {
            let (a, b) = sample_pairs(dist, 4096, 0x5eed);
            let mut out = vec![0u64; a.len()];
            d.mul_batch(&a, &b, &mut out);
            for i in 0..a.len() {
                assert_eq!(
                    out[i],
                    d.mul(a[i], b[i]),
                    "{} on {} at index {i}: {} * {}",
                    d.name(),
                    dist.name(),
                    a[i],
                    b[i]
                );
            }
        }
    }
}

#[test]
fn batch_matches_scalar_for_gaussian_model() {
    // Fresh instances with the same seed: the batched path reserves the
    // same noise-counter range the scalar sequence would consume.
    let scalar = GaussianModel::new(0.05, 9);
    let batched = GaussianModel::new(0.05, 9);
    let (a, b) = sample_pairs(OperandDist::Mantissa, 2000, 3);
    let want: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| scalar.mul(x, y)).collect();
    let mut got = vec![0u64; a.len()];
    batched.mul_batch(&a, &b, &mut got);
    assert_eq!(want, got);
}

#[test]
fn lut_is_bit_identical_inside_its_table_for_every_design() {
    // Operands < 2^8 index an 8-bit table directly: the LUT *is* the
    // design there, for every design.
    for d in standard_designs() {
        let lut = LutMultiplier::new(d.as_ref(), 8).unwrap();
        let (a, b) = sample_pairs(OperandDist::Small, 4096, 0xA11CE);
        for (&x, &y) in a.iter().zip(&b) {
            assert_eq!(lut.mul(x, y), d.mul(x, y), "{} {x}*{y}", lut.name());
        }
    }
}

#[test]
fn lut_is_bit_identical_to_drum_on_every_dist() {
    // DRUM only inspects the top k bits from the leading one, which
    // the LUT reduction preserves for k < bits (strictly — at
    // k == bits DRUM's forced steering bit is skipped inside the
    // table): identity over the full range.
    for (k, bits) in [(4u32, 8u32), (6, 8), (8, 10)] {
        let d = by_name(&format!("drum{k}")).unwrap();
        let lut = LutMultiplier::new(d.as_ref(), bits).unwrap();
        for dist in OperandDist::all() {
            let (a, b) = sample_pairs(dist, 4096, 7 + k as u64);
            let mut got = vec![0u64; a.len()];
            lut.mul_batch(&a, &b, &mut got);
            for i in 0..a.len() {
                assert_eq!(
                    got[i],
                    d.mul(a[i], b[i]),
                    "lut{bits}:drum{k} on {} at {i}",
                    dist.name()
                );
            }
        }
    }
}

#[test]
fn lut_at_equal_width_differs_from_drum_as_documented() {
    // The contract's boundary, pinned so nobody "fixes" it backwards:
    // lut8:drum8 loses drum8's forced steering bit on wide operands.
    let d = by_name("drum8").unwrap();
    let lut = LutMultiplier::new(d.as_ref(), 8).unwrap();
    assert_eq!(d.mul(512, 1), 516); // (128|1) << 2
    assert_eq!(lut.mul(512, 1), 512); // table entry 128 has msb < k
}

#[test]
fn prop_batch_equivalence_on_arbitrary_slices() {
    let specs = ["exact", "drum5", "mitchell", "roba", "bam9", "trunc6", "lut8:drum6"];
    forall(60, 0xBA7C4, |g: &mut Gen| {
        let spec = *g.choose(&specs);
        let d = by_name(spec).unwrap();
        let n = g.usize_in(0, 300);
        let mut a = Vec::with_capacity(n);
        let mut b = Vec::with_capacity(n);
        for _ in 0..n {
            a.push(g.u32());
            b.push(g.u32());
        }
        let mut out = vec![0u64; n];
        d.mul_batch(&a, &b, &mut out);
        for i in 0..n {
            assert_eq!(out[i], d.mul(a[i], b[i]), "{spec} at {i}");
        }
    });
}

#[test]
fn characterize_is_deterministic_in_seed_for_stateless_designs() {
    // Multi-chunk runs (n > 2^16) through the full parallel path.
    for d in standard_designs() {
        let x = characterize(d.as_ref(), OperandDist::Uniform16, 150_000, 11);
        let y = characterize(d.as_ref(), OperandDist::Uniform16, 150_000, 11);
        assert_eq!(x.mre, y.mre, "{}", d.name());
        assert_eq!(x.sd, y.sd, "{}", d.name());
        assert_eq!(x.mean_re, y.mean_re, "{}", d.name());
        assert_eq!(x.min_re, y.min_re, "{}", d.name());
        assert_eq!(x.max_re, y.max_re, "{}", d.name());
        assert_eq!(x.samples, y.samples, "{}", d.name());
    }
}

#[test]
fn characterize_is_independent_of_worker_count() {
    for threads in [1usize, 2, 3, 8] {
        let d = by_name("drum6").unwrap();
        let s = characterize_threads(d.as_ref(), OperandDist::Mantissa, 200_000, 5, threads);
        let base = characterize_threads(d.as_ref(), OperandDist::Mantissa, 200_000, 5, 1);
        assert_eq!(s.mre, base.mre, "threads={threads}");
        assert_eq!(s.sd, base.sd, "threads={threads}");
        assert_eq!(s.min_re, base.min_re, "threads={threads}");
        assert_eq!(s.max_re, base.max_re, "threads={threads}");
    }
}

#[test]
fn parallel_characterize_reproduces_published_error_bands() {
    // The same pinned bands the per-design unit tests assert, now
    // through the chunked parallel reduction: the rewrite must not
    // move the statistics.
    let drum6 = by_name("drum6").unwrap();
    let s = characterize(drum6.as_ref(), OperandDist::Uniform16, 200_000, 7);
    assert!((0.010..0.020).contains(&s.mre), "drum6 MRE {:.4}", s.mre);
    assert!(s.mean_re.abs() < 0.004, "drum6 bias {:.4}", s.mean_re);

    let mitchell = by_name("mitchell").unwrap();
    let s = characterize(mitchell.as_ref(), OperandDist::Uniform16, 200_000, 7);
    assert!(s.max_re <= 1e-12, "mitchell positive error {:.5}", s.max_re);
    assert!(s.min_re > -0.12, "mitchell min {:.5}", s.min_re);
    assert!((0.02..0.06).contains(&s.mre), "mitchell MRE {:.4}", s.mre);

    let roba = by_name("roba").unwrap();
    let s = characterize(roba.as_ref(), OperandDist::Uniform16, 200_000, 7);
    assert!(s.mean_re.abs() < 0.02, "roba bias {:.4}", s.mean_re);
    assert!((0.01..0.06).contains(&s.mre), "roba MRE {:.4}", s.mre);

    // The Gaussian model keeps satisfying the MRE = sigma*sqrt(2/pi)
    // identity under the parallel harness (fresh instance per run).
    let g = GaussianModel::new(0.045, 13);
    let s = characterize(&g, OperandDist::Mantissa, 200_000, 11);
    let expect = 0.045 * approxmul::HALF_NORMAL_MEAN;
    assert!((s.mre - expect).abs() < 0.002, "gauss MRE {:.5} vs {expect:.5}", s.mre);
}

#[test]
fn gaussian_model_stats_are_reproducible_for_fresh_instances() {
    // Not bit-deterministic per call (thread-order-dependent pairing),
    // but the aggregate stats of a fresh instance are stable because
    // the counter range 0..n is consumed exactly once either way.
    let a = characterize(&GaussianModel::new(0.03, 21), OperandDist::Mantissa, 150_000, 2);
    let b = characterize(&GaussianModel::new(0.03, 21), OperandDist::Mantissa, 150_000, 2);
    assert!((a.mre - b.mre).abs() < 1e-6, "{} vs {}", a.mre, b.mre);
    assert!((a.sd - b.sd).abs() < 1e-6, "{} vs {}", a.sd, b.sd);
}
