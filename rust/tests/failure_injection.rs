//! Failure injection: every load-time contract violation must fail
//! loudly with a useful error, never as silent numerical garbage.

use std::fs;

use approxmul::runtime::{Engine, Manifest};

fn artifacts_exist() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

/// Copy the artifacts dir, apply `mutate` to the manifest JSON text,
/// and return the scratch dir.
fn mutated_artifacts(
    name: &str,
    mutate: impl FnOnce(String) -> String,
) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("axm-fi-{name}-{}", std::process::id()));
    fs::create_dir_all(&dir).unwrap();
    for entry in fs::read_dir("artifacts").unwrap() {
        let entry = entry.unwrap();
        if entry.file_name() != ".stamp" {
            fs::copy(entry.path(), dir.join(entry.file_name())).unwrap();
        }
    }
    let manifest_path = dir.join("manifest.json");
    let text = fs::read_to_string(&manifest_path).unwrap();
    fs::write(&manifest_path, mutate(text)).unwrap();
    dir
}

#[test]
fn corrupt_manifest_json_rejected() {
    if !artifacts_exist() {
        return;
    }
    let dir = mutated_artifacts("garbage", |mut t| {
        t.truncate(t.len() / 2);
        t
    });
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("manifest.json"), "{err}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn missing_artifact_file_rejected() {
    if !artifacts_exist() {
        return;
    }
    let dir = mutated_artifacts("missing", |t| t);
    fs::remove_file(dir.join("train_tiny.hlo.txt")).unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("missing artifact"), "{err}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn tampered_param_count_rejected() {
    if !artifacts_exist() {
        return;
    }
    let dir = mutated_artifacts("params", |t| {
        // Inflate tiny's declared total_params so it no longer matches
        // the per-tensor shapes.
        t.replacen("\"total_params\": 3914", "\"total_params\": 4000", 1)
    });
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("total_params"), "{err}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn unknown_preset_and_entry_error() {
    if !artifacts_exist() {
        return;
    }
    let engine = Engine::from_artifacts("artifacts").unwrap();
    assert!(engine.load("nope", "train").is_err());
    assert!(engine.load("vgg16", "train").is_err()); // not lowered
}

#[test]
fn malformed_hlo_text_rejected_at_compile() {
    if !artifacts_exist() {
        return;
    }
    let dir = mutated_artifacts("hlo", |t| t);
    fs::write(dir.join("train_tiny.hlo.txt"), "HloModule broken\nENTRY {").unwrap();
    let engine = Engine::from_artifacts(&dir).unwrap();
    assert!(engine.load("tiny", "train").is_err());
    fs::remove_dir_all(dir).ok();
}
