//! Failure injection: every load-time contract violation must fail
//! loudly with a useful error, never as silent numerical garbage.
//!
//! Two sections: the PJRT artifact contract (skipped when no compiled
//! artifacts are checked out) and the checkpoint-store contract (always
//! runs — the store is backend-independent).

use std::fs;

use approxmul::checkpoint::{self, FailureClass, Store};
use approxmul::runtime::{Engine, Manifest};
use approxmul::tensor::Tensor;

fn artifacts_exist() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

/// Copy the artifacts dir, apply `mutate` to the manifest JSON text,
/// and return the scratch dir.
fn mutated_artifacts(
    name: &str,
    mutate: impl FnOnce(String) -> String,
) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("axm-fi-{name}-{}", std::process::id()));
    fs::create_dir_all(&dir).unwrap();
    for entry in fs::read_dir("artifacts").unwrap() {
        let entry = entry.unwrap();
        if entry.file_name() != ".stamp" {
            fs::copy(entry.path(), dir.join(entry.file_name())).unwrap();
        }
    }
    let manifest_path = dir.join("manifest.json");
    let text = fs::read_to_string(&manifest_path).unwrap();
    fs::write(&manifest_path, mutate(text)).unwrap();
    dir
}

#[test]
fn corrupt_manifest_json_rejected() {
    if !artifacts_exist() {
        return;
    }
    let dir = mutated_artifacts("garbage", |mut t| {
        t.truncate(t.len() / 2);
        t
    });
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("manifest.json"), "{err}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn missing_artifact_file_rejected() {
    if !artifacts_exist() {
        return;
    }
    let dir = mutated_artifacts("missing", |t| t);
    fs::remove_file(dir.join("train_tiny.hlo.txt")).unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("missing artifact"), "{err}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn tampered_param_count_rejected() {
    if !artifacts_exist() {
        return;
    }
    let dir = mutated_artifacts("params", |t| {
        // Inflate tiny's declared total_params so it no longer matches
        // the per-tensor shapes.
        t.replacen("\"total_params\": 3914", "\"total_params\": 4000", 1)
    });
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("total_params"), "{err}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn unknown_preset_and_entry_error() {
    if !artifacts_exist() {
        return;
    }
    let engine = Engine::from_artifacts("artifacts").unwrap();
    assert!(engine.load("nope", "train").is_err());
    assert!(engine.load("vgg16", "train").is_err()); // not lowered
}

#[test]
fn malformed_hlo_text_rejected_at_compile() {
    if !artifacts_exist() {
        return;
    }
    let dir = mutated_artifacts("hlo", |t| t);
    fs::write(dir.join("train_tiny.hlo.txt"), "HloModule broken\nENTRY {").unwrap();
    let engine = Engine::from_artifacts(&dir).unwrap();
    assert!(engine.load("tiny", "train").is_err());
    fs::remove_dir_all(dir).ok();
}

// ---------------------------------------------------------------------------
// checkpoint store (no artifacts needed)

/// Fresh store in a scratch dir with `n` one-tensor checkpoints
/// (epochs 1..=n) under tag "fi".
fn seeded_store(name: &str, n: u64) -> (std::path::PathBuf, Store) {
    let dir = std::env::temp_dir().join(format!("axm-fi-ckpt-{name}-{}", std::process::id()));
    fs::remove_dir_all(&dir).ok();
    let store = Store::new(&dir).unwrap();
    for epoch in 1..=n {
        let t = Tensor::from_f32(&[2], vec![epoch as f32, -1.0]).unwrap();
        let meta = checkpoint::Meta {
            preset: "micro".into(),
            epoch,
            step: epoch * 4,
            sigma: 0.0,
            mult: "drum6".into(),
            tag: "fi".into(),
            escalated_from: None,
        };
        store.save(&meta, &[("w".into(), &t)]).unwrap();
    }
    (dir, store)
}

fn class_of(err: &anyhow::Error) -> FailureClass {
    checkpoint::classify(err).unwrap_or_else(|| panic!("unclassified: {err:#}"))
}

#[test]
fn truncated_checkpoint_rejected_loudly() {
    let (dir, store) = seeded_store("trunc", 1);
    let path = store.path_for("fi", 1);
    let bytes = fs::read(&path).unwrap();
    // Sub-header stub: too short to even hold the trailing CRC.
    fs::write(&path, &bytes[..10]).unwrap();
    let err = store.load("fi", 1).unwrap_err();
    assert_eq!(class_of(&err), FailureClass::Truncated, "{err:#}");
    // Torn mid-payload: the tail bytes parse as a (wrong) CRC, so the
    // realistic torn-write classification is CrcMismatch.
    fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    let err = store.load("fi", 1).unwrap_err();
    assert_eq!(class_of(&err), FailureClass::CrcMismatch, "{err:#}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn flipped_payload_bit_rejected() {
    let (dir, store) = seeded_store("bitflip", 1);
    let path = store.path_for("fi", 1);
    let mut bytes = fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    fs::write(&path, &bytes).unwrap();
    let err = store.load("fi", 1).unwrap_err();
    assert_eq!(class_of(&err), FailureClass::CrcMismatch, "{err:#}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn flipped_crc_trailer_rejected() {
    let (dir, store) = seeded_store("crcflip", 1);
    let path = store.path_for("fi", 1);
    let mut bytes = fs::read(&path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01;
    fs::write(&path, &bytes).unwrap();
    let err = store.load("fi", 1).unwrap_err();
    assert_eq!(class_of(&err), FailureClass::CrcMismatch, "{err:#}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn missing_checkpoint_classified() {
    let (dir, store) = seeded_store("missing", 1);
    let err = store.load("fi", 7).unwrap_err();
    assert_eq!(class_of(&err), FailureClass::Missing, "{err:#}");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn latest_valid_skips_corruption_and_ignores_stale_tmps() {
    let (dir, store) = seeded_store("latest", 3);
    // A dead run's torn tmp must be invisible to recovery...
    let stale = dir.join("fi-epoch0009.ckpt.99999999.tmp");
    fs::write(&stale, b"partial").unwrap();
    // ...and the corrupt newest checkpoint must be scanned past.
    let newest = store.path_for("fi", 3);
    let bytes = fs::read(&newest).unwrap();
    fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();
    let (epoch, meta, tensors) = store.latest_valid("fi").unwrap().unwrap();
    assert_eq!(epoch, 2);
    assert_eq!(meta.step, 8);
    assert_eq!(tensors[0].1.as_f32().unwrap()[0], 2.0);
    // Retention sweeps the stale tmp file too.
    store.gc_keep_last("fi", 2).unwrap();
    assert!(!stale.exists(), "stale tmp survived gc");
    // With every file corrupted, recovery reports "nothing valid"
    // rather than erroring or returning garbage.
    for epoch in store.list_epochs("fi").unwrap() {
        let p = store.path_for("fi", epoch);
        let b = fs::read(&p).unwrap();
        fs::write(&p, &b[..b.len() / 2]).unwrap();
    }
    assert!(store.latest_valid("fi").unwrap().is_none());
    fs::remove_dir_all(dir).ok();
}
