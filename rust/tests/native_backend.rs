//! Integration: end-to-end CNN training on the native backend — no
//! PJRT, no artifacts, every GEMM through `mult::approx_matmul`.
//!
//! Covers the backend-split acceptance contract:
//! * a 2-epoch run on the tiny preset completes and the loss decreases;
//! * a `HybridSearch` over a native run produces a Table-III-shaped row;
//! * gradients check against finite differences on the `micro` preset;
//! * training is bit-identical at any thread count;
//! * `lut12:drum6` trains bit-identically to `drum6` (the PR-1 LUT
//!   fidelity contract, now at training scale);
//! * signed designs (`sdrum6`, `booth8`) train end to end; `sdrum6`
//!   trains bit-identically to `drum6` (sign-routing pin) and
//!   `slut12:sdrum6` to `sdrum6` (signed-LUT fidelity at training
//!   scale);
//! * checkpoints round-trip the full multiplier spec (signed included).

use approxmul::checkpoint::Store;
use approxmul::config::{ExperimentConfig, MultiplierPolicy};
use approxmul::coordinator::{HybridSearch, Sweep, Trainer};
use approxmul::data::SyntheticCifar;
use approxmul::mult::{approx_matmul, by_name, MultSpec};
use approxmul::parallel;
use approxmul::rng::Xoshiro256;
use approxmul::runtime::session::StepInputs;
use approxmul::runtime::{Backend, NativeBackend};
use approxmul::tensor::Tensor;

fn native_cfg(tag: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset_tiny();
    cfg.epochs = 2;
    cfg.train_examples = 256;
    cfg.test_examples = 128;
    cfg.tag = tag.into();
    cfg
}

fn policy(spec: &str) -> MultiplierPolicy {
    MultiplierPolicy::Approximate { mult: MultSpec::parse(spec).unwrap() }
}

#[test]
fn two_epoch_tiny_run_completes_and_learns() {
    let mut trainer = Trainer::native(native_cfg("nat-learn")).unwrap();
    assert_eq!(trainer.session().backend_kind(), "native");
    let outcome = trainer.run().unwrap();
    assert_eq!(outcome.epochs_run, 2);
    let first = outcome.history.records.first().unwrap().train_loss;
    let last = outcome.history.records.last().unwrap().train_loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(
        outcome.final_accuracy > 0.2,
        "accuracy {:.3} barely above chance",
        outcome.final_accuracy
    );
}

#[test]
fn bit_accurate_designs_train_and_differ_from_exact() {
    let mut cfg = native_cfg("nat-exact");
    cfg.epochs = 1;
    let exact = Trainer::native(cfg).unwrap().run().unwrap();

    // Unsigned and signed designs alike: the acceptance path for the
    // signed subsystem is literally `train --backend native --mult
    // sdrum6` (and booth8) training the tiny preset end to end.
    for spec in ["drum6", "mitchell", "sdrum6", "booth8"] {
        let mut cfg = native_cfg(&format!("nat-{spec}"));
        cfg.epochs = 1;
        cfg.policy = policy(spec);
        let outcome = Trainer::native(cfg).unwrap().run().unwrap();
        let loss = outcome.history.records[0].train_loss;
        assert!(loss.is_finite(), "{spec}: loss {loss}");
        assert_ne!(
            loss, exact.history.records[0].train_loss,
            "{spec}: approximate GEMMs had no effect on training"
        );
    }
}

#[test]
fn signed_designs_train_two_epochs_and_learn() {
    for spec in ["sdrum6", "booth8"] {
        let mut cfg = native_cfg(&format!("nat-e2e-{spec}"));
        cfg.policy = policy(spec);
        let outcome = Trainer::native(cfg).unwrap().run().unwrap();
        assert_eq!(outcome.epochs_run, 2, "{spec}");
        let first = outcome.history.records.first().unwrap().train_loss;
        let last = outcome.history.records.last().unwrap().train_loss;
        assert!(last < first, "{spec}: loss did not decrease: {first} -> {last}");
        assert!(
            outcome.final_accuracy > 0.2,
            "{spec}: accuracy {:.3} barely above chance",
            outcome.final_accuracy
        );
    }
}

#[test]
fn sdrum6_training_is_bit_identical_to_drum6() {
    // The sign-routing pin at training scale: sdrum6 carries the sign
    // through the design, drum6 routes it around the core — for a
    // sign-magnitude design the whole trajectory must agree bit for
    // bit (same products, same k-order, same epilogues).
    let run = |spec: &str| {
        let mut cfg = native_cfg(&format!("nat-sroute-{spec}"));
        cfg.epochs = 1;
        cfg.policy = policy(spec);
        Trainer::native(cfg).unwrap().run().unwrap()
    };
    let s = run("sdrum6");
    let u = run("drum6");
    for (a, b) in s.history.records.iter().zip(&u.history.records) {
        assert_eq!(a.train_loss, b.train_loss, "signed routing changed training");
        assert_eq!(a.test_acc, b.test_acc);
    }
}

#[test]
fn gaussian_weight_injection_matches_policy_semantics() {
    // Same seed, gaussian surrogate vs exact: must differ while the
    // error is active; sampling mode must matter.
    let mut cfg = native_cfg("nat-g");
    cfg.epochs = 1;
    cfg.policy = policy("gaussian:0.2");
    let g = Trainer::native(cfg).unwrap().run().unwrap();
    let mut cfg = native_cfg("nat-g0");
    cfg.epochs = 1;
    let e = Trainer::native(cfg).unwrap().run().unwrap();
    assert_ne!(g.history.records[0].train_loss, e.history.records[0].train_loss);

    let mut cfg_step = native_cfg("nat-gs");
    cfg_step.epochs = 1;
    cfg_step.policy = policy("gaussian:0.2");
    cfg_step.sampling = approxmul::config::ErrorSampling::PerStep;
    let s = Trainer::native(cfg_step).unwrap().run().unwrap();
    assert_ne!(
        s.history.records[0].train_loss,
        g.history.records[0].train_loss,
        "per-step resampling had no effect"
    );
}

#[test]
fn training_is_bit_identical_across_thread_counts() {
    // approx_matmul splits work by the problem, never the worker count;
    // everything else is sequential — so whole *training runs* must be
    // bit-reproducible under any parallelism, for the exact design
    // (native exact GEMM == `mult::approx_matmul` with `Exact`) and a
    // bit-accurate design alike.
    let run = |threads: usize, spec: &str, tag: &str| {
        parallel::set_max_threads(threads);
        let mut cfg = native_cfg(tag);
        cfg.epochs = 1;
        cfg.policy = policy(spec);
        let trainer_out = Trainer::native(cfg).unwrap().run().unwrap();
        parallel::set_max_threads(0);
        trainer_out
    };
    for spec in ["exact", "drum6", "booth8"] {
        let one = run(1, spec, "nat-t1");
        let many = run(4, spec, "nat-t4");
        for (a, b) in one.history.records.iter().zip(&many.history.records) {
            assert_eq!(
                a.train_loss, b.train_loss,
                "{spec}: thread count changed training"
            );
            assert_eq!(a.test_acc, b.test_acc, "{spec}");
        }
    }
}

#[test]
fn identical_native_configs_reproduce_exactly() {
    let a = Trainer::native(native_cfg("nat-rep")).unwrap().run().unwrap();
    let b = Trainer::native(native_cfg("nat-rep")).unwrap().run().unwrap();
    for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
        assert_eq!(ra.train_loss, rb.train_loss);
        assert_eq!(ra.test_acc, rb.test_acc);
    }
}

#[test]
fn lut12_drum6_training_is_bit_identical_to_drum6() {
    // DRUM-6 through a 12-bit LUT is bit-identical for every operand
    // the mantissa pipeline produces (k=6 < 12, the PR-1 fidelity
    // contract) — so whole training runs must match bit for bit.
    let run = |spec: &str| {
        let mut cfg = ExperimentConfig::preset_tiny();
        cfg.preset = "micro".into();
        cfg.epochs = 1;
        cfg.train_examples = 64;
        cfg.test_examples = 16;
        cfg.tag = format!("nat-lut-{}", spec.replace(':', "_"));
        cfg.policy = policy(spec);
        let mut trainer = Trainer::native(cfg).unwrap();
        let outcome = trainer.run().unwrap();
        let params: Vec<Vec<f32>> = trainer
            .session()
            .params()
            .iter()
            .map(|t| t.as_f32().unwrap())
            .collect();
        (outcome, params)
    };
    let (out_d, params_d) = run("drum6");
    let (out_l, params_l) = run("lut12:drum6");
    for (a, b) in out_d.history.records.iter().zip(&out_l.history.records) {
        assert_eq!(a.train_loss, b.train_loss, "LUT diverged from wrapped design");
        assert_eq!(a.test_acc, b.test_acc);
    }
    assert_eq!(params_d, params_l, "final parameters diverged");
}

#[test]
fn slut12_sdrum6_training_is_bit_identical_to_sdrum6() {
    // The signed-LUT fidelity contract at training scale, mirroring the
    // unsigned lut12:drum6 test: DRUM-6 magnitudes fit the 11-bit
    // magnitude field's reduction (k = 6 < 11), so the tabulated signed
    // design trains bit-identically to the simulated one.
    let run = |spec: &str| {
        let mut cfg = ExperimentConfig::preset_tiny();
        cfg.preset = "micro".into();
        cfg.epochs = 1;
        cfg.train_examples = 64;
        cfg.test_examples = 16;
        cfg.tag = format!("nat-slut-{}", spec.replace(':', "_"));
        cfg.policy = policy(spec);
        let mut trainer = Trainer::native(cfg).unwrap();
        let outcome = trainer.run().unwrap();
        let params: Vec<Vec<f32>> = trainer
            .session()
            .params()
            .iter()
            .map(|t| t.as_f32().unwrap())
            .collect();
        (outcome, params)
    };
    let (out_d, params_d) = run("sdrum6");
    let (out_l, params_l) = run("slut12:sdrum6");
    for (a, b) in out_d.history.records.iter().zip(&out_l.history.records) {
        assert_eq!(a.train_loss, b.train_loss, "signed LUT diverged from design");
        assert_eq!(a.test_acc, b.test_acc);
    }
    assert_eq!(params_d, params_l, "final parameters diverged");
}

#[test]
fn lut12_drum6_gemm_is_bit_identical_to_drum6() {
    // The same identity at the GEMM level (the PR-1 harness shape,
    // on mantissa operands produced from random f32 matrices).
    let drum = by_name("drum6").unwrap();
    let lut = by_name("lut12:drum6").unwrap();
    let mut rng = Xoshiro256::new(77);
    let a: Vec<f32> = (0..24 * 32).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
    let b: Vec<f32> = (0..32 * 12).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
    let c_d = approx_matmul(drum.as_ref(), &a, &b, 24, 32, 12).unwrap();
    let c_l = approx_matmul(lut.as_ref(), &a, &b, 24, 32, 12).unwrap();
    assert_eq!(c_d, c_l);
}

#[test]
fn native_gradients_match_finite_differences() {
    // Exact mode on the micro preset: analytic gradients (recovered
    // from one SGD step at lr=1 with zero momentum state) vs central
    // finite differences of the total loss (CE + weight decay).
    let backend = NativeBackend::new("micro", MultSpec::Exact).unwrap();
    let tensors = backend.init(5).unwrap();
    let model = backend.model().clone();
    let ds = SyntheticCifar::for_input(4, 3, 4, 11).generate(8);
    let (x, y) = ds.gather_batch(&[0, 1, 2, 3]).unwrap();
    let k = StepInputs { seed_err: 3, seed_drop: 9, sigma: 0.0, lr: 1.0, approx: false, step: 0 };

    let (stepped, _) = backend.train_step(&tensors, &x, &y, k).unwrap();
    let n_params = model.params.len();

    // Compare at every sampled element; tolerate a tiny fraction of
    // mismatches (a ±h perturbation can flip a ReLU/pool decision,
    // which legitimately breaks the FD approximation at that point) —
    // a wrong backward would fail broadly, not at isolated kinks.
    let mut checked = 0usize;
    let mut failures = Vec::new();
    let mut abs_err_sum = 0f64;
    let mut mag_sum = 0f64;
    for ti in 0..n_params {
        let p0 = tensors[ti].as_f32().unwrap();
        let p1 = stepped[ti].as_f32().unwrap();
        // g = (p - p') / lr with lr = 1 and fresh (zero) momentum.
        let grad: Vec<f64> =
            p0.iter().zip(&p1).map(|(&a, &b)| a as f64 - b as f64).collect();
        // A few spread-out elements per tensor.
        let len = p0.len();
        for &i in &[0usize, len / 3, (2 * len) / 3, len - 1] {
            let h = 1e-2f32;
            let perturb = |delta: f32| -> f64 {
                let mut t = tensors.clone();
                let mut data = t[ti].as_f32().unwrap();
                data[i] += delta;
                t[ti] = approxmul::tensor::Tensor::from_f32(
                    tensors[ti].shape(),
                    data,
                )
                .unwrap();
                backend.total_loss(&t, &x, &y, k).unwrap()
            };
            let fd = (perturb(h) - perturb(-h)) / (2.0 * h as f64);
            let g = grad[i];
            let tol = 0.05 * fd.abs().max(g.abs()) + 2e-3;
            if (fd - g).abs() > tol {
                failures.push(format!(
                    "tensor {} elem {i}: fd {fd:.6} vs analytic {g:.6}",
                    model.params[ti].name
                ));
            }
            abs_err_sum += (fd - g).abs();
            mag_sum += fd.abs() + g.abs();
            checked += 1;
        }
    }
    assert!(checked >= 4 * n_params, "only {checked} gradient entries checked");
    assert!(
        failures.len() * 20 <= checked,
        "{} / {checked} gradient entries off:\n{}",
        failures.len(),
        failures.join("\n")
    );
    let rel = abs_err_sum / mag_sum.max(1e-9);
    assert!(rel < 0.05, "aggregate gradient mismatch {rel:.4}");
}

#[test]
fn hybrid_search_native_produces_table3_row() {
    let dir = std::env::temp_dir().join(format!("axm-nat-hs-{}", std::process::id()));
    let mut cfg = native_cfg("nat-hs");
    cfg.epochs = 3;
    cfg.out_dir = dir.to_str().unwrap().to_string();
    let mut search = HybridSearch::native(cfg);
    search.tolerance = 0.02;

    let baseline = search.baseline().unwrap();
    assert!(baseline.final_accuracy > 0.2);

    // A destructive error level: the search must find that some exact
    // tail is needed (utilization < 100%) or prove the full run passes.
    let config = MultSpec::gaussian(0.48);
    let (approx, tag) = search.approx_run(&config).unwrap();
    let outcome = search
        .search(&config, baseline.final_accuracy, &tag, approx.final_accuracy)
        .unwrap();
    // The Table-III row shape: approx + exact epochs partition the
    // schedule; utilization is their ratio.
    assert_eq!(outcome.approx_epochs + outcome.exact_epochs, 3);
    assert!((0.0..=1.0).contains(&outcome.utilization));
    assert_eq!(
        outcome.utilization,
        outcome.approx_epochs as f64 / 3.0
    );
    assert_eq!(outcome.config.canonical(), "gaussian:0.48");
    if approx.final_accuracy < outcome.target {
        assert!(outcome.exact_epochs >= 1, "destructive error needs a tail");
        assert!(outcome.evaluations >= 1);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hybrid_search_native_over_bit_accurate_design() {
    // The headline capability: a Table-III row for an actual hardware
    // design, end to end, with checkpoints carrying the spec.
    let dir = std::env::temp_dir().join(format!("axm-nat-hsd-{}", std::process::id()));
    let mut cfg = native_cfg("nat-hsd");
    cfg.epochs = 2;
    cfg.train_examples = 128;
    cfg.test_examples = 64;
    cfg.out_dir = dir.to_str().unwrap().to_string();
    let mut search = HybridSearch::native(cfg);
    search.tolerance = 0.05; // generous: tiny-scale noise

    let baseline = search.baseline().unwrap();
    let config = MultSpec::parse("drum6").unwrap();
    let (approx, tag) = search.approx_run(&config).unwrap();
    // The checkpointed approx run recorded the design's identity.
    let store = Store::new(&dir).unwrap();
    let (meta, _) = store.load(&tag, 1).unwrap();
    assert_eq!(meta.mult, "drum6");
    assert_eq!(meta.sigma, 0.0); // operand-dependent error, no sigma

    let outcome = search
        .search(&config, baseline.final_accuracy, &tag, approx.final_accuracy)
        .unwrap();
    assert_eq!(outcome.approx_epochs + outcome.exact_epochs, 2);
    assert_eq!(outcome.config.canonical(), "drum6");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn native_sweep_orders_rows_and_baselines() {
    let mut cfg = native_cfg("nat-sw");
    cfg.epochs = 1;
    cfg.train_examples = 128;
    cfg.test_examples = 64;
    let cases = vec![
        (0, MultSpec::exact(), 93.60),
        (8, MultSpec::gaussian_mre(0.382), 65.65),
    ];
    let sweep = Sweep::native(cfg);
    let mut seen = Vec::new();
    let rows = sweep.run(&cases, |id, _| seen.push(id)).unwrap();
    assert_eq!(seen, vec![0, 8]);
    assert_eq!(rows[0].diff_from_exact, 0.0);
    for r in &rows {
        assert!((0.0..=1.0).contains(&r.accuracy));
    }
}

#[test]
fn native_checkpoint_resume_replays_run() {
    // The property the hybrid search depends on, now on the native
    // backend: resuming epoch k replays the full run bit-exactly — for
    // an unsigned design and a signed one (whose checkpoint meta must
    // round-trip the signed spec and replay its signed GEMMs exactly).
    for spec in ["drum6", "booth8"] {
        let dir = std::env::temp_dir()
            .join(format!("axm-nat-res-{spec}-{}", std::process::id()));
        let mut cfg = native_cfg("nat-res");
        cfg.epochs = 3;
        cfg.train_examples = 128;
        cfg.test_examples = 64;
        cfg.out_dir = dir.to_str().unwrap().to_string();
        cfg.checkpoint_every = 1;
        cfg.policy = policy(spec);
        let full = Trainer::native(cfg.clone()).unwrap().run().unwrap();

        let store = Store::new(&dir).unwrap();
        let (meta, tensors) = store.load("nat-res", 2).unwrap();
        assert_eq!(meta.epoch, 2, "{spec}");
        assert_eq!(meta.mult, spec);
        let mut resumed = Trainer::native(cfg).unwrap();
        resumed
            .restore_state(tensors.into_iter().map(|(_, t)| t).collect())
            .unwrap();
        let tail = resumed.run_from(2, None).unwrap();
        assert_eq!(tail.history.records.len(), 1, "{spec}");
        let r_full = &full.history.records[2];
        let r_tail = &tail.history.records[0];
        assert_eq!(r_full.train_loss, r_tail.train_loss, "{spec}");
        assert_eq!(r_full.test_acc, r_tail.test_acc, "{spec}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// FNV-1a over the raw words of a tensor list — the training-state
/// fingerprint the golden test pins.
fn state_hash(tensors: &[Tensor]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for t in tensors {
        for &w in t.raw() {
            for b in w.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
    }
    h
}

/// One-step golden-pin protocol, shared by the unsigned and signed
/// pins: run one `spec` training step on the tiny preset twice
/// (determinism), then enforce the hash against the sealed file. When
/// the sealed file is absent, that is a hard failure in CI (or under
/// `APPROXMUL_REQUIRE_GOLDEN`) — an uncommitted pin enforces nothing —
/// while a local run seals it loudly so the value can be committed
/// (the authoring containers have no Rust toolchain, so the seal can
/// only come from a toolchain'd checkout).
fn check_or_seal_golden(spec: &str, golden_file: &str) {
    let backend = NativeBackend::new("tiny", MultSpec::parse(spec).unwrap()).unwrap();
    let tensors = backend.init(42).unwrap();
    let mut ds = SyntheticCifar::for_input(8, 3, 10, 5).generate(16);
    ds.normalize();
    let (x, y) = ds.gather_batch(&(0..16).collect::<Vec<_>>()).unwrap();
    let k = StepInputs { seed_err: 3, seed_drop: 1, sigma: 0.0, lr: 0.05, approx: true, step: 0 };

    let (out1, s1) = backend.train_step(&tensors, &x, &y, k).unwrap();
    let (out2, s2) = backend.train_step(&tensors, &x, &y, k).unwrap();
    let (h1, h2) = (state_hash(&out1), state_hash(&out2));
    assert_eq!(h1, h2, "{spec}: one step is not deterministic");
    assert_eq!(s1.loss.to_bits(), s2.loss.to_bits());

    let got = format!("{h1:016x}");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(golden_file);
    match std::fs::read_to_string(&path) {
        Ok(want) => assert_eq!(
            got,
            want.trim(),
            "{spec}: one-step training trajectory changed; if intentional, \
             delete {} and re-run to re-seal",
            path.display()
        ),
        Err(_) if std::env::var_os("CI").is_some()
            || std::env::var_os("APPROXMUL_REQUIRE_GOLDEN").is_some() =>
        {
            panic!(
                "golden trajectory pin {} is not committed; run `cargo test \
                 golden_` on a toolchain'd checkout and commit the sealed \
                 file (this run computed {got})",
                path.display()
            );
        }
        Err(_) => {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, format!("{got}\n")).unwrap();
            eprintln!(
                "WARNING: sealed golden {spec} one-step hash {got} -> {} — \
                 COMMIT this file; until it lands, CI fails and the \
                 trajectory pin only checks determinism, not history",
                path.display()
            );
        }
    }
}

#[test]
fn golden_one_step_training_hash() {
    // One drum6 step on the tiny preset, fully pinned: if the fused
    // bias/BN epilogues, the prepared kernel, or the accumulation
    // order ever silently change the training trajectory, this hash
    // moves.
    check_or_seal_golden("drum6", "native_step_tiny.hash");
}

#[test]
fn golden_signed_one_step_training_hash() {
    // The signed twin: one booth8 step through the signed prepared
    // kernel, hashed under the same seal/enforce rules.
    check_or_seal_golden("booth8", "native_step_tiny_booth8.hash");
}

#[test]
fn short_final_batch_trains_on_native() {
    // The native backend has no static batch shape: a session step on
    // fewer examples than the configured batch must work (the
    // Batcher's drop_last=false path feeds exactly this).
    let backend = NativeBackend::new("tiny", MultSpec::Exact).unwrap();
    let model = backend.model().clone();
    let mut session =
        approxmul::runtime::TrainSession::with_backend(Box::new(backend), 11).unwrap();
    let mut ds = SyntheticCifar::for_input(8, 3, 10, 13).generate(16);
    ds.normalize();
    let (x, y) = ds.gather_batch(&[0, 1, 2]).unwrap(); // 3 < batch=16
    assert_eq!(model.batch, 16);
    let k = StepInputs { seed_err: 1, seed_drop: 2, sigma: 0.0, lr: 0.01, approx: false, step: 0 };
    let stats = session.step(x, y, k).unwrap();
    assert!(stats.loss.is_finite());
    assert!((0.0..=1.0).contains(&stats.accuracy));
    // Oversized or ragged inputs are still rejected.
    let (x17, y17) = {
        let big = SyntheticCifar::for_input(8, 3, 10, 13).generate(17);
        big.gather_batch(&(0..17).collect::<Vec<_>>()).unwrap()
    };
    assert!(session.step(x17, y17, k).is_err());
}

#[test]
fn eval_pass_matches_per_batch_eval_and_handles_short_tail() {
    let backend = NativeBackend::new("tiny", MultSpec::Exact).unwrap();
    let session =
        approxmul::runtime::TrainSession::with_backend(Box::new(backend), 21).unwrap();
    let mut ds = SyntheticCifar::for_input(8, 3, 10, 17).generate(80);
    ds.normalize();

    // Full batch: the amortized pass must agree with the per-batch path.
    let (x, y) = ds.gather_batch(&(0..64).collect::<Vec<_>>()).unwrap();
    let pass = session.eval_pass().unwrap();
    let a = pass.eval_batch(x.clone(), y.clone()).unwrap();
    let b = session.eval_batch(x, y).unwrap();
    assert_eq!(a.loss_sum.to_bits(), b.loss_sum.to_bits());
    assert_eq!(a.correct, b.correct);
    assert_eq!(a.total, b.total);

    // Short tail (80 - 64 = 16 examples) evaluates unpadded.
    let (xt, yt) = ds.gather_batch(&(64..80).collect::<Vec<_>>()).unwrap();
    let t = pass.eval_batch(xt, yt).unwrap();
    assert_eq!(t.total, 16);
    assert!(t.loss_sum.is_finite());
}

#[test]
fn trainer_evaluates_non_multiple_test_set_on_native() {
    // 50 test examples against eval_batch=64: rejected by static-shape
    // backends, evaluated unpadded (all 50 counted once) on native.
    let mut gen = SyntheticCifar::for_input(8, 3, 10, 23);
    gen.noise = 0.4;
    let mut train_ds = gen.generate(114);
    train_ds.normalize();
    let (train_ds, test_ds) = train_ds.split_tail(50).unwrap();
    let mut cfg = ExperimentConfig::preset_tiny();
    cfg.epochs = 1;
    cfg.tag = "nat-oddtest".into();
    let mut trainer =
        Trainer::native_with_data(cfg, train_ds, test_ds).unwrap();
    let outcome = trainer.run().unwrap();
    assert_eq!(outcome.epochs_run, 1);
    assert!((0.0..=1.0).contains(&outcome.final_accuracy));
    let (acc, loss) = trainer.evaluate().unwrap();
    assert!((0.0..=1.0).contains(&acc));
    assert!(loss.is_finite());
}
