//! Signed-multiplier semantics suite: two's-complement edge cases,
//! documented sign-symmetry per design, batch ≡ scalar bit-identity,
//! and the signed-LUT fidelity contract — the signed twin of
//! `tests/mult_batch.rs`.

use approxmul::mult::signed::{
    by_name, characterize_signed, characterize_signed_threads, SignedMultiplier,
};
use approxmul::mult::OperandDist;
use approxmul::rng::Xoshiro256;

const SIGNED_DESIGNS: &[&str] =
    &["sexact", "sdrum4", "sdrum6", "sdrum8", "booth8", "booth16", "sroba", "slut8:sdrum6"];

/// Two's-complement operand values every design must survive (and get
/// directionally right): extremes, sign boundaries, zero crossings.
const EDGE_OPERANDS: &[i32] = &[
    i32::MIN,
    i32::MIN + 1,
    -1,
    0,
    1,
    i32::MAX,
    -2,
    2,
    -65_536,
    65_535,
    -(1 << 23), // negative f32-mantissa magnitude
    (1 << 24) - 1,
];

#[test]
fn edge_operands_never_panic_and_keep_sign_and_magnitude_sane() {
    for spec in SIGNED_DESIGNS {
        let m = by_name(spec).unwrap();
        for &a in EDGE_OPERANDS {
            for &b in EDGE_OPERANDS {
                let p = m.mul(a, b);
                let exact = a as i64 * b as i64;
                if exact == 0 {
                    // Designs may approximate near-zero products, but a
                    // zero operand must yield zero (no partial products).
                    if a == 0 || b == 0 {
                        assert_eq!(p, 0, "{spec}: {a}*{b}");
                    }
                    continue;
                }
                // Error stays within a loose band at the extremes (the
                // exact designs are exact; DRUM/RoBA are within their
                // published bounds; Booth's worst truncation gap is
                // 16 * 2^k, tiny next to these magnitudes).
                assert!(
                    (p as f64 - exact as f64).abs()
                        <= 0.6 * exact.unsigned_abs() as f64 + (16i64 << 16) as f64,
                    "{spec}: {a}*{b} = {p} vs {exact}"
                );
            }
        }
    }
}

#[test]
fn minus_one_squared_is_plus_one_for_all_designs() {
    // -1 * -1: the smallest-magnitude sign-crossing product; every
    // design with a non-truncating column path must return exactly +1,
    // and Booth's truncated tree must flush it to 0 (never a wrong
    // sign or magnitude blow-up).
    for spec in &["sexact", "sdrum4", "sdrum6", "sdrum8", "sroba", "slut8:sdrum6"] {
        let m = by_name(spec).unwrap();
        assert_eq!(m.mul(-1, -1), 1, "{spec}");
        assert_eq!(m.mul(-1, 1), -1, "{spec}");
        assert_eq!(m.mul(1, -1), -1, "{spec}");
    }
    let booth = by_name("booth8").unwrap();
    assert_eq!(booth.mul(-1, -1), 0, "booth truncates the only column");
    assert_eq!(by_name("booth0").unwrap().mul(-1, -1), 1, "booth0 is exact");
}

#[test]
fn i32_min_edge_cases_are_exact_where_the_design_is_exact() {
    // |i32::MIN| = 2^31 is a power of two: DRUM and RoBA cores are
    // exact on it, so the signed wrappers must be too.
    for spec in &["sexact", "sdrum6", "sroba", "slut8:sdrum6"] {
        let m = by_name(spec).unwrap();
        assert_eq!(
            m.mul(i32::MIN, i32::MIN),
            (i32::MIN as i64) * (i32::MIN as i64),
            "{spec}"
        );
        assert_eq!(m.mul(i32::MIN, 1), i32::MIN as i64, "{spec}");
        assert_eq!(m.mul(i32::MIN, -1), -(i32::MIN as i64), "{spec}");
        assert_eq!(m.mul(i32::MIN, 0), 0, "{spec}");
    }
}

#[test]
fn sign_magnitude_designs_are_sign_symmetric() {
    // sdrum / sroba / slut-of-sdrum route the sign around a magnitude
    // core: (-a)*b == -(a*b) == a*(-b), bit for bit, everywhere.
    let mut rng = Xoshiro256::new(51);
    for spec in &["sexact", "sdrum4", "sdrum6", "sroba", "slut8:sdrum6"] {
        let m = by_name(spec).unwrap();
        for _ in 0..20_000 {
            // i32::MIN has no negation; it gets its own edge-case test.
            let a = (rng.next_u32() as i32).max(i32::MIN + 1);
            let b = (rng.next_u32() as i32).max(i32::MIN + 1);
            let p = m.mul(a, b);
            assert_eq!(m.mul(-a, b), -p, "{spec}: -a*b");
            assert_eq!(m.mul(a, -b), -p, "{spec}: a*-b");
            assert_eq!(m.mul(-a, -b), p, "{spec}: -a*-b");
        }
    }
}

#[test]
fn booth_deliberately_breaks_sign_symmetry() {
    // The truncated partial-product tree floors toward -inf: negating
    // the multiplicand changes which low bits are lost, so
    // booth(-a, b) != -booth(a, b) whenever truncation is active —
    // and the product always under-runs the exact signed value.
    let m = by_name("booth8").unwrap();
    let mut rng = Xoshiro256::new(53);
    let mut asymmetric = 0usize;
    for _ in 0..20_000 {
        let a = (rng.next_u32() >> 8) as i32 + 1;
        let b = (rng.next_u32() >> 8) as i32 + 1;
        let exact = a as i64 * b as i64;
        assert!(m.mul(a, b) <= exact, "{a}*{b}");
        assert!(m.mul(-a, b) <= -exact, "-{a}*{b}");
        if m.mul(-a, b) != -m.mul(a, b) {
            asymmetric += 1;
        }
    }
    assert!(
        asymmetric > 15_000,
        "booth8 looked sign-symmetric on {asymmetric}/20000 pairs"
    );
}

#[test]
fn batch_is_bit_identical_to_scalar_for_every_design() {
    let mut rng = Xoshiro256::new(55);
    let mut a: Vec<i32> = (0..4096).map(|_| rng.next_u32() as i32).collect();
    let mut b: Vec<i32> = (0..4096).map(|_| rng.next_u32() as i32).collect();
    // Make sure the edge values ride along.
    for (i, &v) in EDGE_OPERANDS.iter().enumerate() {
        a[i] = v;
        b[EDGE_OPERANDS.len() - 1 - i] = v;
    }
    for spec in SIGNED_DESIGNS {
        let m: Box<dyn SignedMultiplier> = by_name(spec).unwrap();
        let mut out = vec![0i64; a.len()];
        m.mul_batch(&a, &b, &mut out);
        for i in 0..a.len() {
            assert_eq!(out[i], m.mul(a[i], b[i]), "{spec} idx {i}");
        }
    }
}

#[test]
fn slut_identity_and_truncation_contract() {
    // In-contract: sdrum6 through slut8 (magnitude field 7 > 6) is the
    // design, everywhere. Out-of-contract: sdrum8 through slut8 must
    // differ somewhere (k == magnitude width loses the steering bit).
    let mut rng = Xoshiro256::new(57);
    let sd6 = by_name("sdrum6").unwrap();
    let via8 = by_name("slut8:sdrum6").unwrap();
    let sd8 = by_name("sdrum8").unwrap();
    let via8_of8 = by_name("slut8:sdrum8").unwrap();
    let mut diverged = false;
    for _ in 0..50_000 {
        let (a, b) = (rng.next_u32() as i32, rng.next_u32() as i32);
        assert_eq!(via8.mul(a, b), sd6.mul(a, b), "{a}*{b}");
        diverged |= via8_of8.mul(a, b) != sd8.mul(a, b);
    }
    assert!(diverged, "slut8:sdrum8 unexpectedly matched sdrum8 everywhere");
}

#[test]
fn characterization_is_deterministic_and_thread_invariant() {
    for spec in &["sdrum6", "booth8", "sroba"] {
        let m = by_name(spec).unwrap();
        for dist in OperandDist::all() {
            let seq = characterize_signed_threads(m.as_ref(), dist, 150_000, 11, 1);
            let par = characterize_signed_threads(m.as_ref(), dist, 150_000, 11, 8);
            assert_eq!(seq.mre, par.mre, "{spec} {}", dist.name());
            assert_eq!(seq.sd, par.sd, "{spec} {}", dist.name());
            assert_eq!(seq.min_re, par.min_re, "{spec} {}", dist.name());
        }
    }
}

#[test]
fn signed_mre_bands_match_published_unsigned_figures_for_symmetric_designs() {
    // Sign-magnitude designs inherit the unsigned error statistics
    // under symmetric operands: sdrum6 lands in DRUM-6's published
    // band, sroba within RoBA's bound.
    let s = characterize_signed(
        by_name("sdrum6").unwrap().as_ref(),
        OperandDist::Uniform16,
        200_000,
        7,
    );
    assert!((0.010..0.020).contains(&s.mre), "sdrum6 MRE {:.4}", s.mre);
    assert!(s.mean_re.abs() < 0.004, "sdrum6 bias {:.4}", s.mean_re);
    let r = characterize_signed(
        by_name("sroba").unwrap().as_ref(),
        OperandDist::Uniform16,
        200_000,
        7,
    );
    assert!(r.max_re < 0.12 && r.min_re > -0.12, "sroba band {:?}", (r.min_re, r.max_re));
}
