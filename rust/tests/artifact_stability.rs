//! Byte-stability of emitted artifacts (detlint rule D1's runtime twin).
//!
//! Everything the tree writes to disk — checkpoints, JSON configs,
//! metrics, bench reports — must serialize to the *same bytes* for the
//! same logical content, independent of construction order or process.
//! `json::Value::Object` is a `BTreeMap` precisely for this; these tests
//! pin the property end to end so a future change that reintroduces
//! hash-ordered serialization fails loudly rather than producing
//! un-diffable artifacts and un-hashable checkpoint metadata.

use std::collections::BTreeMap;

use approxmul::checkpoint::{self, Meta};
use approxmul::json::{self, Value};
use approxmul::tensor::Tensor;

fn meta() -> Meta {
    Meta {
        preset: "tiny".to_string(),
        epoch: 3,
        step: 1234,
        sigma: 0.0,
        mult: "drum6".to_string(),
        tag: "stability".to_string(),
        escalated_from: None,
    }
}

#[test]
fn json_object_serialization_is_key_order_independent() {
    // Same members, inserted in opposite orders, must print identically.
    let fwd = json::object(vec![
        ("alpha", Value::from(1usize)),
        ("beta", Value::from("two")),
        ("gamma", Value::from(3.5)),
    ]);
    let rev = json::object(vec![
        ("gamma", Value::from(3.5)),
        ("beta", Value::from("two")),
        ("alpha", Value::from(1usize)),
    ]);
    assert_eq!(fwd.to_string(), rev.to_string());

    // And the underlying representation is an ordered map, not a
    // hash-ordered one: keys come back sorted.
    let keys: Vec<&String> = fwd.as_object().unwrap().keys().collect();
    assert_eq!(keys, ["alpha", "beta", "gamma"]);
}

#[test]
fn json_roundtrip_is_byte_stable() {
    let src = r#"{"z":1,"a":{"nested":[1,2,3],"b":true},"m":"text"}"#;
    let once = Value::parse(src).unwrap().to_string();
    let twice = Value::parse(&once).unwrap().to_string();
    assert_eq!(once, twice, "parse/print must reach a fixed point");
}

#[test]
fn checkpoint_bytes_are_identical_across_builds() {
    let t1 = Tensor::from_f32(&[2, 3], vec![1.0, -2.5, 0.0, 3.25, -0.125, 9.0]).unwrap();
    let t2 = Tensor::from_f32(&[4], vec![0.5, 0.25, -1.0, 2.0]).unwrap();

    // Two independently built snapshots of the same logical state.
    let a = checkpoint::to_bytes(
        &meta(),
        &[("w".to_string(), &t1), ("b".to_string(), &t2)],
    );
    let b = checkpoint::to_bytes(
        &meta(),
        &[("w".to_string(), &t1), ("b".to_string(), &t2)],
    );
    assert_eq!(a, b, "same state must serialize to the same bytes");

    // And the round trip preserves them exactly.
    let (m, tensors) = checkpoint::from_bytes(&a).unwrap();
    let named: Vec<(String, &Tensor)> =
        tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
    let c = checkpoint::to_bytes(&m, &named);
    assert_eq!(a, c, "decode/encode must be a byte-level fixed point");
}

#[test]
fn checkpoint_meta_json_is_deterministic() {
    let bytes = checkpoint::to_bytes(&meta(), &[]);
    let (m, _) = checkpoint::from_bytes(&bytes).unwrap();
    let bytes2 = checkpoint::to_bytes(&m, &[]);
    assert_eq!(bytes, bytes2);
}

#[test]
fn malformed_length_fields_surface_typed_faults_not_panics() {
    // The decoder must never panic on hostile length fields. Flip the
    // first tensor's name-length field to u32::MAX and re-seal the CRC
    // so the corruption reaches the structural decoder: the reader must
    // answer with a classified Truncated fault, not an abort.
    let t = Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap();
    let good = checkpoint::to_bytes(&meta(), &[("w".to_string(), &t)]);
    let meta_len = u32::from_le_bytes(good[8..12].try_into().unwrap()) as usize;
    let name_len_off = 8 + 4 + meta_len + 4; // magic | meta_len | meta | count
    let mut evil = good.clone();
    evil[name_len_off..name_len_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    let body_len = evil.len() - 4;
    let crc = checkpoint::crc32(&evil[..body_len]);
    evil[body_len..].copy_from_slice(&crc.to_le_bytes());

    let err = checkpoint::from_bytes(&evil).expect_err("hostile length must fail");
    assert_eq!(
        checkpoint::classify(&err),
        Some(checkpoint::FailureClass::Truncated),
        "hostile length field must classify as Truncated, got: {err:#}"
    );
}

#[test]
fn hostile_json_inputs_surface_typed_faults_not_panics() {
    use approxmul::json::JsonFaultClass;

    // Duplicate object keys: must be a typed fault, never a silent
    // last-write-wins merge. Checked at every nesting depth.
    let err = Value::parse(r#"{"k": 1, "k": 2}"#).expect_err("dup key");
    assert_eq!(json::classify(&err), Some(JsonFaultClass::DuplicateKey));
    let err = Value::parse(r#"{"outer": {"k": 1, "k": 1}}"#).expect_err("nested dup key");
    assert_eq!(json::classify(&err), Some(JsonFaultClass::DuplicateKey));

    // Oversized payloads: rejected before the parser runs, so a hostile
    // multi-GB body can't cost parse time or memory.
    let body = br#"{"k": "v"}"#;
    let err = Value::parse_bytes(body, 4).expect_err("over cap");
    assert_eq!(json::classify(&err), Some(JsonFaultClass::Oversized));

    // Non-UTF-8 byte streams: typed, not a str-conversion panic.
    let err = Value::parse_bytes(&[b'"', 0xC3, 0x28, b'"'], 1024).expect_err("bad utf8");
    assert_eq!(json::classify(&err), Some(JsonFaultClass::NonUtf8));

    // Plain grammar garbage classifies as Syntax.
    let err = Value::parse_bytes(b"{\"k\": nope}", 1024).expect_err("garbage");
    assert_eq!(json::classify(&err), Some(JsonFaultClass::Syntax));

    // A well-formed body under the cap still parses.
    let ok = Value::parse_bytes(body, 1024).expect("clean parse");
    assert_eq!(ok.get("k").unwrap().as_str().unwrap(), "v");
}

#[test]
fn json_rejection_is_bytewise_deterministic() {
    // The same hostile input must produce the same classified fault on
    // every parse — rejection is part of the deterministic surface.
    let evil = br#"{"a": 1, "a": 2}"#;
    let c1 = json::classify(&Value::parse_bytes(evil, 1024).unwrap_err());
    let c2 = json::classify(&Value::parse_bytes(evil, 1024).unwrap_err());
    assert_eq!(c1, c2);
    assert_eq!(c1, Some(json::JsonFaultClass::DuplicateKey));
}

#[test]
fn btreemap_is_the_artifact_map_type() {
    // Compile-time pin: Value::Object exposes a BTreeMap. If someone
    // swaps the representation for a hash map this stops compiling.
    let v = json::object(vec![("k", Value::from(1usize))]);
    let m: &BTreeMap<String, Value> = v.as_object().unwrap();
    assert_eq!(m.len(), 1);
}
