//! Feature-matrix bit-identity suite for the `simd` microkernels.
//!
//! Every test here pins a public-API result against a **scalar
//! oracle** that never touches `mul_batch` or the GEMM chain engine:
//! per-element `Multiplier::mul` / `SignedMultiplier::mul`, and the
//! reference GEMM walks (`approx_matmul_reference`,
//! `approx_matmul_reference_signed` — one `approx_mul_f32*` per
//! product, strict k-order f32 accumulation). The suite compiles and
//! must pass **identically with and without `--features simd`**; CI
//! runs both builds, which is what proves simd-on ≡ simd-off
//! bit-identity for `mul_batch`, `characterize*`, and the prepared
//! unsigned/signed GEMMs across designs × operand layouts × thread
//! counts — including chains carrying inf/NaN/subnormal operands.
//!
//! Shapes are chosen to cross the vector-width boundaries: inner
//! dimensions below, at, and away from multiples of the 8-wide lane
//! count, so both the main vector loop and the padded-tail path of
//! every kernel are exercised.

use approxmul::mult::signed::{
    approx_matmul_prepared_signed, approx_matmul_reference_signed,
    approx_matmul_signed, approx_matmul_signed_nt, approx_matmul_signed_tn,
    by_name as signed_by_name, characterize_signed_threads, SignedMultiplier,
};
use approxmul::mult::{
    approx_matmul, approx_matmul_nt, approx_matmul_prepared, approx_matmul_reference,
    approx_matmul_tn, by_name, characterize_threads, gemm_row_block, Multiplier,
    OperandDist, PreparedMatrix, GEMM_ROW_BLOCK,
};
use approxmul::parallel;
use approxmul::rng::Xoshiro256;

/// Unsigned designs under test: every design with an explicit vector
/// kernel (drum/trunc/mitchell/exact, plus the flat-table LUT via the
/// GEMM path) and two that stay on the scalar engine (roba, bam8) as
/// dispatch-fallback coverage. k values sit at both domain edges.
const DESIGNS: &[&str] = &[
    "exact", "drum3", "drum6", "drum8", "drum32", "trunc1", "trunc8", "trunc31",
    "mitchell", "roba", "bam8", "lut8:drum6",
];

/// Signed designs under test, same policy (sroba is the scalar-engine
/// fallback; booth0/booth32 are the truncation-domain edges).
const SIGNED_DESIGNS: &[&str] = &[
    "sexact", "sdrum3", "sdrum6", "sdrum32", "booth0", "booth8", "booth24",
    "booth32", "sroba", "slut8:sdrum6",
];

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: element {i} differs ({g} vs {w})"
        );
    }
}

fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0f32; src.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = src[r * cols + c];
        }
    }
    out
}

/// Random operands with special values (inf, NaN, signed zero,
/// subnormal) planted through the chains — same recipe as
/// `tests/prepared_gemm.rs`.
fn operands(rows: usize, inner: usize, cols: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let mut gen = |n: usize| -> Vec<f32> {
        (0..n)
            .map(|_| match rng.next_u32() % 64 {
                0 => f32::INFINITY,
                1 => f32::NEG_INFINITY,
                2 => f32::NAN,
                3 => 0.0,
                4 => -0.0,
                5 => 1.0e-41, // subnormal -> flushed
                _ => 2.0 * rng.next_f32() - 1.0,
            })
            .collect()
    };
    (gen(rows * inner), gen(inner * cols))
}

#[test]
fn unsigned_mul_batch_matches_scalar_mul() {
    // Edge operands (zero, one-bit values, mantissa-domain bounds, all
    // ones) as a full cross product, then a random pool sliced at every
    // length in [0, 17] so the 8-wide kernels see pure-tail, exactly-
    // one-vector, and vector-plus-tail batches.
    let edges: [u32; 16] = [
        0,
        1,
        2,
        3,
        5,
        0x80,
        0xFFFF,
        0x0001_0000,
        0x007F_FFFF,
        0x0080_0000,
        0x00FF_FFFF,
        0x0100_0000,
        0x7FFF_FFFF,
        0x8000_0000,
        0xAAAA_5555,
        0xFFFF_FFFF,
    ];
    let mut ea = Vec::new();
    let mut eb = Vec::new();
    for &x in &edges {
        for &y in &edges {
            ea.push(x);
            eb.push(y);
        }
    }
    let mut rng = Xoshiro256::new(2024);
    let pool_a: Vec<u32> = (0..64).map(|_| rng.next_u32()).collect();
    let pool_b: Vec<u32> = (0..64).map(|_| rng.next_u32()).collect();
    for spec in DESIGNS {
        let m = by_name(spec).unwrap();
        let mut out = vec![0u64; ea.len()];
        m.mul_batch(&ea, &eb, &mut out);
        for i in 0..ea.len() {
            assert_eq!(
                out[i],
                m.mul(ea[i], eb[i]),
                "{spec}: edge {:#x} * {:#x}",
                ea[i],
                eb[i]
            );
        }
        for len in 0..=17usize {
            let (a, b) = (&pool_a[..len], &pool_b[..len]);
            let mut out = vec![0u64; len];
            m.mul_batch(a, b, &mut out);
            for i in 0..len {
                assert_eq!(out[i], m.mul(a[i], b[i]), "{spec}: len {len}, i {i}");
            }
        }
    }
}

#[test]
fn signed_mul_batch_matches_scalar_mul() {
    let edges: [i32; 16] = [
        0,
        1,
        -1,
        2,
        -2,
        127,
        -128,
        0xFFFF,
        1 << 23,
        -(1 << 23),
        0x00FF_FFFF,
        -0x00FF_FFFF,
        0x5555_AAAA,
        -0x1234_5678,
        i32::MAX,
        i32::MIN,
    ];
    let mut ea = Vec::new();
    let mut eb = Vec::new();
    for &x in &edges {
        for &y in &edges {
            ea.push(x);
            eb.push(y);
        }
    }
    let mut rng = Xoshiro256::new(2025);
    let pool_a: Vec<i32> = (0..64).map(|_| rng.next_u32() as i32).collect();
    let pool_b: Vec<i32> = (0..64).map(|_| rng.next_u32() as i32).collect();
    for spec in SIGNED_DESIGNS {
        let m = signed_by_name(spec).unwrap();
        let mut out = vec![0i64; ea.len()];
        m.mul_batch(&ea, &eb, &mut out);
        for i in 0..ea.len() {
            assert_eq!(
                out[i],
                m.mul(ea[i], eb[i]),
                "{spec}: edge {} * {}",
                ea[i],
                eb[i]
            );
        }
        for len in 0..=17usize {
            let (a, b) = (&pool_a[..len], &pool_b[..len]);
            let mut out = vec![0i64; len];
            m.mul_batch(a, b, &mut out);
            for i in 0..len {
                assert_eq!(out[i], m.mul(a[i], b[i]), "{spec}: len {len}, i {i}");
            }
        }
    }
}

#[test]
fn characterize_is_thread_invariant() {
    // The characterization harness runs on `mul_batch` chunks; any two
    // worker counts (and therefore the simd and scalar batch paths,
    // across CI's two builds) must agree to the bit on every statistic.
    let check = |stats: &[approxmul::mult::ErrorStats], what: &str| {
        let s0 = &stats[0];
        for s in &stats[1..] {
            assert_eq!(s.mre.to_bits(), s0.mre.to_bits(), "{what}: mre");
            assert_eq!(s.sd.to_bits(), s0.sd.to_bits(), "{what}: sd");
            assert_eq!(s.mean_re.to_bits(), s0.mean_re.to_bits(), "{what}: mean_re");
            assert_eq!(s.min_re.to_bits(), s0.min_re.to_bits(), "{what}: min_re");
            assert_eq!(s.max_re.to_bits(), s0.max_re.to_bits(), "{what}: max_re");
            assert_eq!(s.samples, s0.samples, "{what}: samples");
        }
    };
    for dist in [OperandDist::Mantissa, OperandDist::Uniform32] {
        for spec in ["drum6", "mitchell", "trunc8"] {
            let m = by_name(spec).unwrap();
            let stats: Vec<_> = [1usize, 3, 8]
                .iter()
                .map(|&t| characterize_threads(m.as_ref(), dist, 40_000, 42, t))
                .collect();
            check(&stats, spec);
        }
        for spec in ["sdrum6", "booth8"] {
            let m = signed_by_name(spec).unwrap();
            let stats: Vec<_> = [1usize, 3, 8]
                .iter()
                .map(|&t| characterize_signed_threads(m.as_ref(), dist, 40_000, 42, t))
                .collect();
            check(&stats, spec);
        }
    }
}

#[test]
fn unsigned_gemm_matches_reference_across_layouts_and_threads() {
    // inner = 19: two full 8-lane vectors plus a 3-element tail in
    // every k-chain (before specials knock terms out of the batch).
    let (rows, inner, cols) = (GEMM_ROW_BLOCK + 5, 19usize, 50usize);
    for (di, spec) in DESIGNS.iter().enumerate() {
        let m = by_name(spec).unwrap();
        let (a, b) = operands(rows, inner, cols, 3000 + di as u64);
        let want =
            approx_matmul_reference(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
        let a_t = transpose(&a, rows, inner); // [inner x rows]
        let b_t = transpose(&b, inner, cols); // [cols x inner]
        for threads in [1usize, 2, 5] {
            parallel::set_max_threads(threads);
            let nn = approx_matmul(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
            let tn =
                approx_matmul_tn(m.as_ref(), &a_t, &b, rows, inner, cols).unwrap();
            let nt =
                approx_matmul_nt(m.as_ref(), &a, &b_t, rows, inner, cols).unwrap();
            parallel::set_max_threads(0);
            assert_bits_eq(&nn, &want, &format!("{spec} NN t={threads}"));
            assert_bits_eq(&tn, &want, &format!("{spec} TN t={threads}"));
            assert_bits_eq(&nt, &want, &format!("{spec} NT t={threads}"));
        }
    }
}

#[test]
fn signed_gemm_matches_reference_across_layouts_and_threads() {
    let (rows, inner, cols) = (GEMM_ROW_BLOCK + 3, 19usize, 37usize);
    for (di, spec) in ["sexact", "sdrum6", "booth8", "slut8:sdrum6"]
        .iter()
        .enumerate()
    {
        let m = signed_by_name(spec).unwrap();
        let (a, b) = operands(rows, inner, cols, 4000 + di as u64);
        let want =
            approx_matmul_reference_signed(m.as_ref(), &a, &b, rows, inner, cols)
                .unwrap();
        let a_t = transpose(&a, rows, inner);
        let b_t = transpose(&b, inner, cols);
        for threads in [1usize, 2, 5] {
            parallel::set_max_threads(threads);
            let nn =
                approx_matmul_signed(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
            let tn = approx_matmul_signed_tn(m.as_ref(), &a_t, &b, rows, inner, cols)
                .unwrap();
            let nt = approx_matmul_signed_nt(m.as_ref(), &a, &b_t, rows, inner, cols)
                .unwrap();
            parallel::set_max_threads(0);
            assert_bits_eq(&nn, &want, &format!("{spec} NN t={threads}"));
            assert_bits_eq(&tn, &want, &format!("{spec} TN t={threads}"));
            assert_bits_eq(&nt, &want, &format!("{spec} NT t={threads}"));
        }
    }
}

#[test]
fn short_inner_dimensions_hit_the_tail_only_paths() {
    // inner in [1, 9]: chains shorter than one vector (pure padded
    // tail) through exactly-one-vector-plus-one.
    for inner in 1usize..=9 {
        let (rows, cols) = (5usize, 7usize);
        for spec in ["drum6", "mitchell", "lut8:drum6"] {
            let m = by_name(spec).unwrap();
            let (a, b) = operands(rows, inner, cols, 70 + inner as u64);
            let fast = approx_matmul(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
            let slow = approx_matmul_reference(m.as_ref(), &a, &b, rows, inner, cols)
                .unwrap();
            assert_bits_eq(&fast, &slow, &format!("{spec} inner={inner}"));
        }
        for spec in ["sdrum6", "booth8", "slut8:sdrum6"] {
            let m = signed_by_name(spec).unwrap();
            let (a, b) = operands(rows, inner, cols, 700 + inner as u64);
            let fast =
                approx_matmul_signed(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
            let slow =
                approx_matmul_reference_signed(m.as_ref(), &a, &b, rows, inner, cols)
                    .unwrap();
            assert_bits_eq(&fast, &slow, &format!("{spec} inner={inner}"));
        }
    }
}

#[test]
fn dense_special_value_chains_match_reference() {
    // Every k position cycles through the special classes, so
    // non-finite fallbacks (scalar-patched lanes in the simd build)
    // and flushed skips interleave densely with batched products.
    let specials = [
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::NAN,
        0.0,
        -0.0,
        1.0e-41,
        1.5,
        -2.25,
    ];
    let (rows, inner, cols) = (4usize, specials.len() * 2, 3usize);
    let mut rng = Xoshiro256::new(99);
    let a: Vec<f32> = (0..rows * inner)
        .map(|i| {
            if i % 3 == 0 {
                specials[(i / 3) % specials.len()]
            } else {
                rng.next_f32() - 0.5
            }
        })
        .collect();
    let b: Vec<f32> = (0..inner * cols)
        .map(|i| {
            if i % 4 == 1 {
                specials[(i / 4) % specials.len()]
            } else {
                rng.next_f32() - 0.5
            }
        })
        .collect();
    for spec in DESIGNS {
        let m = by_name(spec).unwrap();
        let fast = approx_matmul(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
        let slow =
            approx_matmul_reference(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
        assert_bits_eq(&fast, &slow, spec);
    }
    for spec in SIGNED_DESIGNS {
        let m = signed_by_name(spec).unwrap();
        let fast = approx_matmul_signed(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
        let slow =
            approx_matmul_reference_signed(m.as_ref(), &a, &b, rows, inner, cols)
                .unwrap();
        assert_bits_eq(&fast, &slow, spec);
    }
}

#[test]
fn fused_epilogues_match_unfused() {
    // Bias and column-sum epilogues sit downstream of the chain engine;
    // they must see identical element values from either engine.
    let (rows, inner, cols) = (73usize, 13usize, 6usize);
    let mut rng = Xoshiro256::new(137);
    let a: Vec<f32> = (0..rows * inner).map(|_| rng.next_f32() - 0.5).collect();
    let b: Vec<f32> = (0..inner * cols).map(|_| rng.next_f32() - 0.5).collect();
    let bias: Vec<f32> = (0..cols).map(|_| rng.next_f32() - 0.5).collect();
    let col_sums_by_block = |plain: &[f32]| -> Vec<f32> {
        let mut want = vec![0f32; cols];
        for blk in plain.chunks(gemm_row_block(rows) * cols) {
            let mut part = vec![0f32; cols];
            for row in blk.chunks(cols) {
                for (p, &v) in part.iter_mut().zip(row) {
                    *p += v;
                }
            }
            for (w, p) in want.iter_mut().zip(&part) {
                *w += p;
            }
        }
        want
    };

    let m: Box<dyn Multiplier> = by_name("drum6").unwrap();
    let ap = PreparedMatrix::prepare(&a, rows, inner).unwrap();
    let bp = PreparedMatrix::prepare_strided(&b, cols, inner, 1, cols).unwrap();
    let fused = approx_matmul_prepared(m.as_ref(), &ap, &bp, Some(&bias), true).unwrap();
    let mut plain = approx_matmul(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
    for r in 0..rows {
        for c in 0..cols {
            plain[r * cols + c] += bias[c];
        }
    }
    assert_bits_eq(&fused.out, &plain, "drum6 fused bias");
    assert_bits_eq(
        &fused.col_sums.unwrap(),
        &col_sums_by_block(&plain),
        "drum6 col_sums",
    );

    let sm: Box<dyn SignedMultiplier> = signed_by_name("booth8").unwrap();
    let sap = PreparedMatrix::prepare(&a, rows, inner)
        .unwrap()
        .with_signed_mantissas();
    let sbp = PreparedMatrix::prepare_strided(&b, cols, inner, 1, cols)
        .unwrap()
        .with_signed_mantissas();
    let sfused =
        approx_matmul_prepared_signed(sm.as_ref(), &sap, &sbp, Some(&bias), true)
            .unwrap();
    let mut splain =
        approx_matmul_signed(sm.as_ref(), &a, &b, rows, inner, cols).unwrap();
    for r in 0..rows {
        for c in 0..cols {
            splain[r * cols + c] += bias[c];
        }
    }
    assert_bits_eq(&sfused.out, &splain, "booth8 fused bias");
    assert_bits_eq(
        &sfused.col_sums.unwrap(),
        &col_sums_by_block(&splain),
        "booth8 col_sums",
    );
}
