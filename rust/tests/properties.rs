//! Property-based tests (via the in-tree `testkit`) over coordinator
//! and substrate invariants: multiplier error bounds, checkpoint/JSON
//! round trips, batcher coverage, cost-model sanity, policy algebra.

use approxmul::checkpoint;
use approxmul::config::{LrSchedule, MultiplierPolicy};
use approxmul::costmodel::{CostModel, HwDesign};
use approxmul::data::SyntheticCifar;
use approxmul::error_model::{mre_to_sigma, sigma_to_mre, ErrorMatrix};
use approxmul::json::Value;
use approxmul::mult::{Drum, Exact, Mitchell, MultSpec, Multiplier, Truncation};
use approxmul::tensor::Tensor;
use approxmul::testkit::{forall, Gen};

#[test]
fn prop_drum_error_bounded_by_truncation_level() {
    // DRUM-k keeps k significant bits per operand; its relative error
    // per operand is < 2^(1-k), so the product error is < ~2^(2-k).
    forall(300, 11, |g: &mut Gen| {
        let k = g.usize_in(4, 10) as u32;
        let d = Drum::new(k).unwrap();
        let a = g.u32().max(1);
        let b = g.u32().max(1);
        let re = d.relative_error(a, b).abs();
        let bound = f64::powi(2.0, 2 - k as i32);
        assert!(re <= bound, "drum{k}: |re|={re} > {bound} for {a}*{b}");
    });
}

#[test]
fn prop_mitchell_always_underestimates() {
    forall(500, 12, |g: &mut Gen| {
        let a = g.u32().max(1);
        let b = g.u32().max(1);
        let m = Mitchell;
        assert!(m.mul(a, b) <= m.exact(a, b) + 1); // +1: fixed-point floor
    });
}

#[test]
fn prop_truncation_never_exceeds_exact() {
    forall(500, 13, |g: &mut Gen| {
        let k = g.usize_in(1, 20) as u32;
        let t = Truncation::new(k).unwrap();
        let a = g.u32();
        let b = g.u32();
        assert!(t.mul(a, b) <= t.exact(a, b));
    });
}

#[test]
fn prop_exact_commutes_and_identities() {
    forall(300, 14, |g: &mut Gen| {
        let m = Exact;
        let a = g.u32();
        let b = g.u32();
        assert_eq!(m.mul(a, b), m.mul(b, a));
        assert_eq!(m.mul(a, 1), a as u64);
        assert_eq!(m.mul(a, 0), 0);
    });
}

#[test]
fn prop_mre_sigma_roundtrip() {
    forall(200, 15, |g: &mut Gen| {
        let mre = g.f64_in(1e-6, 0.5);
        let back = sigma_to_mre(mre_to_sigma(mre));
        assert!((back - mre).abs() < 1e-12);
        assert!(mre_to_sigma(mre) > mre); // sigma > MRE always
    });
}

#[test]
fn prop_error_matrix_stats_track_sigma() {
    forall(20, 16, |g: &mut Gen| {
        let sigma = g.f64_in(0.005, 0.3);
        let seed = g.u32();
        let m = ErrorMatrix::generate(seed, 1, sigma, 50_000);
        assert!((m.measured_sd() - sigma).abs() < 0.15 * sigma + 1e-4);
        assert!((m.measured_mre() - sigma_to_mre(sigma)).abs() < 0.15 * sigma + 1e-4);
    });
}

#[test]
fn prop_checkpoint_roundtrip_random_tensors() {
    forall(50, 17, |g: &mut Gen| {
        let n_tensors = g.usize_in(1, 5);
        let mut named = Vec::new();
        let mut tensors = Vec::new();
        for i in 0..n_tensors {
            let rows = g.usize_in(1, 8);
            let cols = g.usize_in(1, 8);
            let data = g.vec_f32(rows * cols, -10.0, 10.0);
            tensors.push(Tensor::from_f32(&[rows, cols], data).unwrap());
            named.push(format!("t{i}"));
        }
        let pairs: Vec<(String, &Tensor)> =
            named.iter().cloned().zip(tensors.iter()).collect();
        let meta = checkpoint::Meta {
            preset: "p".into(),
            epoch: g.usize_in(0, 1000) as u64,
            step: 5,
            sigma: g.f64_in(0.0, 0.5),
            mult: "drum6".into(),
            tag: "prop".into(),
            escalated_from: None,
        };
        let bytes = checkpoint::to_bytes(&meta, &pairs);
        let (m2, t2) = checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(m2.epoch, meta.epoch);
        assert_eq!(t2.len(), n_tensors);
        for ((_, orig), (name, restored)) in pairs.iter().zip(&t2) {
            assert_eq!(*orig, restored, "{name}");
        }
    });
}

#[test]
fn prop_checkpoint_bitflip_always_detected() {
    forall(60, 18, |g: &mut Gen| {
        let t = Tensor::from_f32(&[4], g.vec_f32(4, -1.0, 1.0)).unwrap();
        let meta = checkpoint::Meta {
            preset: "p".into(),
            epoch: 1,
            step: 1,
            sigma: 0.0,
            mult: "exact".into(),
            tag: "flip".into(),
            escalated_from: None,
        };
        let mut bytes = checkpoint::to_bytes(&meta, &[("t".into(), &t)]);
        let pos = g.usize_in(0, bytes.len() - 1);
        let bit = g.usize_in(0, 7);
        bytes[pos] ^= 1 << bit;
        assert!(
            checkpoint::from_bytes(&bytes).is_err(),
            "flip at byte {pos} bit {bit} undetected"
        );
    });
}

#[test]
fn prop_json_number_string_roundtrip() {
    forall(200, 19, |g: &mut Gen| {
        let n = g.f64_in(-1e9, 1e9);
        let v = Value::parse(&format!("{n}")).unwrap();
        assert!((v.as_f64().unwrap() - n).abs() <= n.abs() * 1e-12);
        // String with escapes round-trips through serialization.
        let s = format!("a\"b\\c\n{}", g.usize_in(0, 9));
        let ser = Value::String(s.clone()).to_string();
        assert_eq!(Value::parse(&ser).unwrap().as_str().unwrap(), s);
    });
}

#[test]
fn prop_policy_utilization_bounds() {
    forall(200, 20, |g: &mut Gen| {
        let total = g.usize_in(1, 500) as u64;
        let switch = g.usize_in(0, 500) as u64;
        let p = MultiplierPolicy::Hybrid {
            mult: MultSpec::gaussian(0.05),
            switch_epoch: switch,
        };
        let u = p.utilization(total);
        assert!((0.0..=1.0).contains(&u));
        // Epoch sigma is consistent with utilization extremes.
        if u == 0.0 {
            assert_eq!(p.sigma_at(0), if switch == 0 { 0.0 } else { 0.05 });
        }
    });
}

#[test]
fn prop_lr_schedule_monotone_nonincreasing() {
    forall(100, 21, |g: &mut Gen| {
        let s = LrSchedule::StepDecay {
            lr: g.f64_in(0.001, 1.0),
            factor: g.f64_in(0.1, 1.0),
            every: g.usize_in(1, 50) as u64,
        };
        let mut prev = f64::INFINITY;
        for e in 0..100 {
            let lr = s.at_epoch(e);
            assert!(lr <= prev + 1e-15);
            assert!(lr > 0.0);
            prev = lr;
        }
    });
}

#[test]
fn prop_costmodel_amdahl_invariants() {
    forall(200, 22, |g: &mut Gen| {
        let share = g.f64_in(0.1, 0.99);
        let speed = g.f64_in(0.01, 0.9);
        let cm = CostModel::new(share, 1_000);
        let d = HwDesign {
            speed_gain: speed,
            area_saving: 0.5,
            power_saving: 0.5,
            mre: 0.01,
            sd: 0.0125,
        };
        let gain = cm.system_gains(&d);
        assert!(gain.step_speedup >= 1.0);
        assert!(gain.step_speedup <= 1.0 / (1.0 - share) + 1e-9);
        assert!(gain.step_speedup <= 1.0 / (1.0 - speed) + 1e-9);
        // Hybrid gain interpolates monotonically in utilization.
        let total = 100;
        let mut prev = 0.0;
        for a in [0u32, 25, 50, 75, 100] {
            let h = cm.hybrid_gains(&d, a, total);
            assert!(h.time_saving >= prev - 1e-12);
            prev = h.time_saving;
        }
    });
}

#[test]
fn prop_synthetic_dataset_valid_for_any_size() {
    forall(20, 23, |g: &mut Gen| {
        let hw = [4usize, 8, 16][g.usize_in(0, 2)];
        let n = g.usize_in(10, 200);
        let classes = g.usize_in(2, 10);
        let gen = SyntheticCifar {
            hw,
            channels: 3,
            num_classes: classes,
            modes: g.usize_in(1, 6),
            noise: g.f64_in(0.0, 3.0) as f32,
            seed: g.u32() as u64,
        };
        let ds = gen.generate(n);
        ds.check().unwrap();
        assert_eq!(ds.len(), n);
        assert!(ds.images.iter().all(|v| v.is_finite()));
    });
}
