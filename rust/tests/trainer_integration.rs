//! Integration: the coordinator end to end — training improves
//! accuracy, the hybrid policy switches multipliers mid-run, and
//! checkpoint/resume replays bit-exactly (the property the Figure-4
//! search depends on).

use approxmul::checkpoint::Store;
use approxmul::config::{ExperimentConfig, MultiplierPolicy};
use approxmul::coordinator::Trainer;
use approxmul::mult::MultSpec;
use approxmul::runtime::Engine;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::from_artifacts("artifacts").expect("engine"))
}

fn quick_cfg(tag: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset_tiny();
    cfg.epochs = 4;
    cfg.train_examples = 512;
    cfg.test_examples = 256;
    cfg.tag = tag.into();
    cfg
}

#[test]
fn training_learns_synthetic_task() {
    let Some(engine) = engine() else { return };
    let mut trainer = Trainer::new(&engine, quick_cfg("learn")).unwrap();
    let outcome = trainer.run().unwrap();
    assert_eq!(outcome.epochs_run, 4);
    assert!(
        outcome.final_accuracy > 0.5,
        "only {:.3} accuracy",
        outcome.final_accuracy
    );
    // Loss decreased across epochs.
    let first = outcome.history.records.first().unwrap().train_loss;
    let last = outcome.history.records.last().unwrap().train_loss;
    assert!(last < first, "loss {first} -> {last}");
}

#[test]
fn hybrid_policy_switches_sigma() {
    let Some(engine) = engine() else { return };
    let mut cfg = quick_cfg("hybrid");
    cfg.policy = MultiplierPolicy::Hybrid {
        mult: MultSpec::gaussian(0.1),
        switch_epoch: 2,
    };
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    let outcome = trainer.run().unwrap();
    let sigmas: Vec<f64> = outcome.history.records.iter().map(|r| r.sigma).collect();
    assert_eq!(sigmas.len(), 4);
    assert!(sigmas[0] > 0.0 && sigmas[1] > 0.0, "{sigmas:?}");
    assert_eq!(sigmas[2], 0.0);
    assert_eq!(sigmas[3], 0.0);
}

#[test]
fn identical_configs_reproduce_exactly() {
    let Some(engine) = engine() else { return };
    let a = Trainer::new(&engine, quick_cfg("rep")).unwrap().run().unwrap();
    let b = Trainer::new(&engine, quick_cfg("rep")).unwrap().run().unwrap();
    for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
        assert_eq!(ra.train_loss, rb.train_loss);
        assert_eq!(ra.test_acc, rb.test_acc);
    }
}

#[test]
fn checkpoint_resume_replays_run() {
    let Some(engine) = engine() else { return };
    let dir = std::env::temp_dir().join(format!("axm-resume-{}", std::process::id()));

    // Full 4-epoch run, checkpointing every epoch.
    let mut cfg = quick_cfg("resume");
    cfg.out_dir = dir.to_str().unwrap().to_string();
    cfg.checkpoint_every = 1;
    let full = Trainer::new(&engine, cfg.clone()).unwrap().run().unwrap();

    // Resume from the epoch-2 checkpoint and run epochs 2..4.
    let store = Store::new(&dir).unwrap();
    let (meta, tensors) = store.load("resume", 2).unwrap();
    assert_eq!(meta.epoch, 2);
    let mut resumed = Trainer::new(&engine, cfg).unwrap();
    resumed
        .restore_state(tensors.into_iter().map(|(_, t)| t).collect())
        .unwrap();
    let tail = resumed.run_from(2, None).unwrap();

    // The resumed tail must match the full run's epochs 2..4 exactly
    // (same data order, same seeds, same state).
    assert_eq!(tail.history.records.len(), 2);
    for (r_full, r_tail) in full.history.records[2..].iter().zip(&tail.history.records) {
        assert_eq!(r_full.epoch, r_tail.epoch);
        assert_eq!(r_full.train_loss, r_tail.train_loss, "epoch {}", r_full.epoch);
        assert_eq!(r_full.test_acc, r_tail.test_acc);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn per_step_sampling_differs_from_fixed() {
    let Some(engine) = engine() else { return };
    let mut cfg_fixed = quick_cfg("samp-f");
    cfg_fixed.policy =
        MultiplierPolicy::Approximate { mult: MultSpec::gaussian(0.2) };
    let mut cfg_step = cfg_fixed.clone();
    cfg_step.tag = "samp-s".into();
    cfg_step.sampling = approxmul::config::ErrorSampling::PerStep;

    let a = Trainer::new(&engine, cfg_fixed).unwrap().run().unwrap();
    let b = Trainer::new(&engine, cfg_step).unwrap().run().unwrap();
    let la: Vec<f64> = a.history.records.iter().map(|r| r.train_loss).collect();
    let lb: Vec<f64> = b.history.records.iter().map(|r| r.train_loss).collect();
    assert_ne!(la, lb, "sampling mode had no effect");
}
