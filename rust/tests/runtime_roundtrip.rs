//! Integration: the full artifact -> PJRT -> step -> eval round trip.
//! Requires `make artifacts` (skips cleanly when absent, e.g. pure
//! unit-test environments).

use approxmul::runtime::session::StepInputs;
use approxmul::runtime::{Engine, TrainSession};
use approxmul::tensor::Tensor;

/// StepInputs shorthand (`approx` tracks sigma, as the trainer does).
fn knobs(seed_err: u32, seed_drop: u32, sigma: f32, lr: f32) -> StepInputs {
    StepInputs { seed_err, seed_drop, sigma, lr, approx: sigma > 0.0, step: 0 }
}

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::from_artifacts("artifacts").expect("engine"))
}

fn batch(engine: &Engine, preset: &str, seed: u64) -> (Tensor, Tensor) {
    let m = engine.manifest().model(preset).unwrap();
    let mut rng = approxmul::rng::Xoshiro256::new(seed);
    let n = m.batch * m.input_hw * m.input_hw * m.in_ch;
    let x = Tensor::from_f32(
        &[m.batch, m.input_hw, m.input_hw, m.in_ch],
        (0..n).map(|_| rng.next_f32() - 0.5).collect(),
    )
    .unwrap();
    let y = Tensor::from_i32(
        &[m.batch],
        (0..m.batch).map(|_| rng.next_below(10) as i32).collect(),
    )
    .unwrap();
    (x, y)
}

#[test]
fn init_is_deterministic_in_seed() {
    let Some(engine) = engine() else { return };
    let a = TrainSession::new(&engine, "tiny", 7).unwrap();
    let b = TrainSession::new(&engine, "tiny", 7).unwrap();
    let c = TrainSession::new(&engine, "tiny", 8).unwrap();
    for (x, y) in a.state_tensors().iter().zip(b.state_tensors()) {
        assert_eq!(x, y);
    }
    assert!(a
        .state_tensors()
        .iter()
        .zip(c.state_tensors())
        .any(|(x, y)| x != y));
}

#[test]
fn step_is_deterministic_and_updates_params() {
    let Some(engine) = engine() else { return };
    let (x, y) = batch(&engine, "tiny", 1);
    let k = knobs(5, 6, 0.1, 0.05);

    let mut s1 = TrainSession::new(&engine, "tiny", 3).unwrap();
    let before = s1.params().to_vec();
    let r1 = s1.step(x.clone(), y.clone(), k).unwrap();
    let mut s2 = TrainSession::new(&engine, "tiny", 3).unwrap();
    let r2 = s2.step(x.clone(), y.clone(), k).unwrap();

    assert_eq!(r1.loss, r2.loss);
    for (a, b) in s1.params().iter().zip(s2.params()) {
        assert_eq!(a, b, "replayed step diverged");
    }
    assert!(
        before.iter().zip(s1.params()).any(|(a, b)| a != b),
        "params did not move"
    );
    assert!(r1.loss > 0.0 && r1.loss.is_finite());
    assert!((0.0..=1.0).contains(&r1.accuracy));
}

#[test]
fn sigma_zero_matches_between_error_seeds() {
    // With sigma = 0 the error seed must be irrelevant.
    let Some(engine) = engine() else { return };
    let (x, y) = batch(&engine, "tiny", 2);
    let mut a = TrainSession::new(&engine, "tiny", 4).unwrap();
    let mut b = TrainSession::new(&engine, "tiny", 4).unwrap();
    let ra = a
        .step(x.clone(), y.clone(), knobs(1, 9, 0.0, 0.05))
        .unwrap();
    let rb = b
        .step(x, y, knobs(999, 9, 0.0, 0.05))
        .unwrap();
    assert_eq!(ra.loss, rb.loss);
    for (ta, tb) in a.params().iter().zip(b.params()) {
        assert_eq!(ta, tb);
    }
}

#[test]
fn sigma_changes_trajectory() {
    let Some(engine) = engine() else { return };
    let (x, y) = batch(&engine, "tiny", 3);
    let mut a = TrainSession::new(&engine, "tiny", 5).unwrap();
    let mut b = TrainSession::new(&engine, "tiny", 5).unwrap();
    a.step(x.clone(), y.clone(), knobs(1, 2, 0.0, 0.05))
        .unwrap();
    b.step(x, y, knobs(1, 2, 0.3, 0.05))
        .unwrap();
    assert!(a.params().iter().zip(b.params()).any(|(ta, tb)| ta != tb));
}

#[test]
fn eval_runs_and_counts() {
    let Some(engine) = engine() else { return };
    let m = engine.manifest().model("tiny").unwrap();
    let s = TrainSession::new(&engine, "tiny", 6).unwrap();
    let mut rng = approxmul::rng::Xoshiro256::new(8);
    let n = m.eval_batch * m.input_hw * m.input_hw * m.in_ch;
    let x = Tensor::from_f32(
        &[m.eval_batch, m.input_hw, m.input_hw, m.in_ch],
        (0..n).map(|_| rng.next_f32()).collect(),
    )
    .unwrap();
    let y = Tensor::from_i32(&[m.eval_batch], vec![0; m.eval_batch]).unwrap();
    let r = s.eval_batch(x, y).unwrap();
    assert!(r.correct >= 0 && r.correct <= m.eval_batch as i64);
    assert!(r.loss_sum.is_finite() && r.loss_sum > 0.0);
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(engine) = engine() else { return };
    let mut s = TrainSession::new(&engine, "tiny", 1).unwrap();
    let bad_x = Tensor::from_f32(&[1, 2, 2, 3], vec![0.0; 12]).unwrap();
    let y = Tensor::from_i32(&[16], vec![0; 16]).unwrap();
    assert!(s
        .step(bad_x, y, knobs(0, 0, 0.0, 0.1))
        .is_err());
}

#[test]
fn product_preset_runs() {
    let Some(engine) = engine() else { return };
    let (x, y) = batch(&engine, "tiny_product", 4);
    let mut s = TrainSession::new(&engine, "tiny_product", 2).unwrap();
    let r = s
        .step(x, y, knobs(3, 4, 0.1, 0.05))
        .unwrap();
    assert!(r.loss.is_finite());
}

#[test]
fn restore_roundtrip() {
    let Some(engine) = engine() else { return };
    let (x, y) = batch(&engine, "tiny", 5);
    let mut s = TrainSession::new(&engine, "tiny", 9).unwrap();
    let snapshot = s.state_tensors().to_vec();
    s.step(x.clone(), y.clone(), knobs(1, 1, 0.0, 0.1))
        .unwrap();
    let after_one = s.state_tensors().to_vec();
    // Rewind and replay: identical result.
    s.restore(snapshot).unwrap();
    s.step(x, y, knobs(1, 1, 0.0, 0.1))
        .unwrap();
    for (a, b) in s.state_tensors().iter().zip(&after_one) {
        assert_eq!(a, b);
    }
}
