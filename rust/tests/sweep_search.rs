//! Integration: the Table-II sweep runner and the Figure-4 hybrid
//! search at miniature scale (fast enough for CI, exercising the same
//! code paths the bench harnesses use).

use approxmul::config::ExperimentConfig;
use approxmul::coordinator::{HybridSearch, Sweep};
use approxmul::mult::MultSpec;
use approxmul::runtime::Engine;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::from_artifacts("artifacts").expect("engine"))
}

fn mini_cfg(tag: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset_tiny();
    cfg.epochs = 3;
    cfg.train_examples = 384;
    cfg.test_examples = 128;
    cfg.tag = tag.into();
    cfg
}

#[test]
fn sweep_produces_comparable_rows() {
    let Some(engine) = engine() else { return };
    let cases = vec![
        (0, MultSpec::exact(), 93.60),
        (4, MultSpec::gaussian_mre(0.036), 93.23),
        (8, MultSpec::gaussian_mre(0.382), 65.65),
    ];
    let sweep = Sweep::new(&engine, mini_cfg("sw"));
    let mut seen = Vec::new();
    let rows = sweep.run(&cases, |id, _| seen.push(id)).unwrap();
    assert_eq!(seen, vec![0, 4, 8]);
    assert_eq!(rows.len(), 3);
    // Baseline row defines diff = 0.
    assert_eq!(rows[0].diff_from_exact, 0.0);
    assert!(rows[0].paper_accuracy.unwrap() > 0.93);
    // Collapse case must be visibly below the benign case even at 3
    // epochs (sigma 0.48 destroys training signal immediately).
    assert!(
        rows[2].accuracy < rows[1].accuracy,
        "collapse {} !< benign {}",
        rows[2].accuracy,
        rows[1].accuracy
    );
    // All results are probabilities.
    for r in &rows {
        assert!((0.0..=1.0).contains(&r.accuracy));
    }
}

#[test]
fn hybrid_search_full_procedure() {
    let Some(engine) = engine() else { return };
    let dir = std::env::temp_dir().join(format!("axm-search-{}", std::process::id()));
    let mut cfg = mini_cfg("hs");
    cfg.out_dir = dir.to_str().unwrap().to_string();
    let mut search = HybridSearch::new(&engine, cfg);
    search.tolerance = 0.02;

    let baseline = search.baseline().unwrap();
    assert!(baseline.final_accuracy > 0.3);

    // A destructive error level: the search must find that some exact
    // tail is needed (utilization < 100%) or prove the full run passes.
    let config = MultSpec::gaussian(0.48);
    let (approx, tag) = search.approx_run(&config).unwrap();
    let outcome = search
        .search(&config, baseline.final_accuracy, &tag, approx.final_accuracy)
        .unwrap();
    assert_eq!(outcome.approx_epochs + outcome.exact_epochs, 3);
    assert!((0.0..=1.0).contains(&outcome.utilization));
    if approx.final_accuracy < outcome.target {
        assert!(outcome.exact_epochs >= 1, "destructive error needs a tail");
        assert!(outcome.evaluations >= 1);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn benign_error_needs_no_tail() {
    let Some(engine) = engine() else { return };
    let dir = std::env::temp_dir().join(format!("axm-search2-{}", std::process::id()));
    let mut cfg = mini_cfg("hs2");
    cfg.out_dir = dir.to_str().unwrap().to_string();
    let mut search = HybridSearch::new(&engine, cfg);
    search.tolerance = 0.05; // generous: tiny-scale noise

    let baseline = search.baseline().unwrap();
    let config = MultSpec::gaussian(0.018); // DRUM-6 level
    let (approx, tag) = search.approx_run(&config).unwrap();
    let outcome = search
        .search(&config, baseline.final_accuracy, &tag, approx.final_accuracy)
        .unwrap();
    // Paper row 1: benign error -> full utilization.
    if approx.final_accuracy >= outcome.target {
        assert_eq!(outcome.utilization, 1.0);
        assert_eq!(outcome.evaluations, 0);
    }
    std::fs::remove_dir_all(&dir).ok();
}
