//! Cross-language golden tests: the Rust Threefry/Box-Muller pipeline
//! must be bit-compatible with `python/compile/kernels/prng.py` (which
//! itself is validated against JAX's native threefry2x32 in
//! `python/tests/test_prng.py`). The constants below were exported from
//! the Python implementation; if either side drifts, the coordinator
//! can no longer predict the error matrices the compiled graphs inject.

use approxmul::rng::threefry::{counter_normal, threefry2x32};

/// (key0, key1, ctr0, ctr1, out0, out1) — from compile/kernels/prng.py.
const THREEFRY_GOLDEN: [(u32, u32, u32, u32, u32, u32); 4] = [
    (0, 0, 0, 0, 1_797_259_609, 2_579_123_966),
    (42, 7, 123, 456, 4_160_435_612, 3_144_904_172),
    (0xFFFF_FFFF, 1, 0xDEAD_BEEF, 0xCAFE_BABE, 4_034_250_102, 3_996_092_623),
    (1, 2, 3, 4, 1_576_285_164, 2_249_660_814),
];

/// counter_normal(seed=42, stream=3, base=0, n=8) from python.
const NORMAL_GOLDEN: [f32; 8] = [
    -0.000_839_522_05,
    -0.132_705_077_5,
    -0.956_750_214,
    0.042_182_546,
    0.262_230_426,
    -0.230_525_18,
    0.720_327_735,
    -1.202_048_42,
];

#[test]
fn threefry_matches_python_bit_exact() {
    for &(k0, k1, c0, c1, e0, e1) in &THREEFRY_GOLDEN {
        let (x0, x1) = threefry2x32(k0, k1, c0, c1);
        assert_eq!((x0, x1), (e0, e1), "key=({k0},{k1}) ctr=({c0},{c1})");
    }
}

#[test]
fn counter_normal_matches_python() {
    let z = counter_normal(42, 3, 0, 8);
    for (i, (&got, &expect)) in z.iter().zip(&NORMAL_GOLDEN).enumerate() {
        // Transcendental libm differences can cost a few ulps; the
        // fields must still agree to float32 display precision.
        assert!(
            (got - expect).abs() <= 2e-6 * expect.abs().max(1.0),
            "index {i}: rust {got} vs python {expect}"
        );
    }
}

#[test]
fn error_matrix_prediction_matches_python_field() {
    // The factors (1 + sigma*eps) the graph injects for layer stream 3
    // under seed 42 — predicted host-side.
    let sigma = 0.045f32;
    let z = counter_normal(42, 3, 0, 8);
    for (i, &eps) in z.iter().enumerate() {
        let factor = 1.0 + sigma * eps;
        let expect = 1.0 + sigma * NORMAL_GOLDEN[i];
        assert!((factor - expect).abs() < 1e-6);
    }
}
