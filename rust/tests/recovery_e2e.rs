//! Recovery end-to-end: the resilient training runtime on the native
//! backend — no artifacts needed, fully deterministic.
//!
//! The acceptance contract for the watchdog subsystem:
//! * watchdog ON but idle == watchdog OFF, bit for bit (supervision is
//!   purely observational);
//! * an injected NaN trips the watchdog, rolls back to the newest
//!   verified checkpoint, and the replay finishes the run with the
//!   *exact* trajectory of an un-faulted run (per-step seeds are pure
//!   functions of the global step, so rollback needs no seed surgery);
//! * a fault that recurs at the same global step escalates the
//!   multiplier along the configured ladder, recorded in the health
//!   log and in checkpoint metadata (`escalated_from`);
//! * a torn checkpoint write is caught by the save-time verify read and
//!   re-written, without perturbing the trajectory;
//! * exhausted budgets fail loudly instead of looping.

use approxmul::checkpoint::StoreFault;
use approxmul::config::{ExperimentConfig, MultiplierPolicy, WatchdogConfig};
use approxmul::coordinator::Trainer;
use approxmul::metrics::{FailureKind, History};
use approxmul::mult::MultSpec;
use approxmul::testkit::faults::FaultPlan;

/// Micro-preset config: batch 4, 64 train examples -> 16 steps/epoch.
fn micro_cfg(tag: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset_tiny();
    cfg.preset = "micro".into();
    cfg.epochs = 3;
    cfg.train_examples = 64;
    cfg.test_examples = 16;
    cfg.tag = tag.into();
    cfg
}

fn scratch_dir(name: &str) -> String {
    let dir = std::env::temp_dir().join(format!("axm-rec-{name}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir.to_str().unwrap().to_string()
}

/// Watchdog with the loss-spike heuristic effectively disabled, so
/// bit-identity tests exercise exactly the injected failure and not
/// the (also deterministic, but config-dependent) divergence verdict.
fn quiet_watchdog() -> WatchdogConfig {
    WatchdogConfig { spike_factor: 1e6, ..WatchdogConfig::default() }
}

fn assert_same_history(a: &History, b: &History) {
    assert_eq!(a.records.len(), b.records.len(), "epoch counts differ");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.epoch, rb.epoch);
        assert_eq!(ra.train_loss, rb.train_loss, "epoch {}", ra.epoch);
        assert_eq!(ra.train_acc, rb.train_acc, "epoch {}", ra.epoch);
        assert_eq!(ra.test_acc, rb.test_acc, "epoch {}", ra.epoch);
        assert_eq!(ra.test_loss, rb.test_loss, "epoch {}", ra.epoch);
    }
}

fn final_params(trainer: &Trainer) -> Vec<Vec<f32>> {
    trainer.session().params().iter().map(|t| t.as_f32().unwrap()).collect()
}

#[test]
fn idle_watchdog_changes_nothing() {
    // OFF: the plain trajectory (no store, no supervision).
    let mut off = Trainer::native(micro_cfg("rec-idle")).unwrap();
    let out_off = off.run().unwrap();

    // ON: same seed/tag, checkpointing + per-step health checks.
    let mut cfg = micro_cfg("rec-idle");
    cfg.out_dir = scratch_dir("idle");
    cfg.checkpoint_every = 1;
    cfg.watchdog = Some(quiet_watchdog());
    let mut on = Trainer::native(cfg.clone()).unwrap();
    let out_on = on.run().unwrap();

    assert_same_history(&out_off.history, &out_on.history);
    assert_eq!(final_params(&off), final_params(&on));
    assert!(out_on.health.trips.is_empty());
    assert_eq!(out_on.health.rollbacks, 0);
    assert!(out_on.health.steps_checked > 0);
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn nan_activation_rolls_back_and_replays_bit_identically() {
    // Clean baseline (watchdog on but nothing armed — proven identical
    // to watchdog-off by `idle_watchdog_changes_nothing`).
    let mut cfg = micro_cfg("rec-nan");
    cfg.out_dir = scratch_dir("nan-base");
    cfg.checkpoint_every = 1;
    cfg.watchdog = Some(quiet_watchdog());
    let mut base = Trainer::native(cfg.clone()).unwrap();
    let out_base = base.run().unwrap();
    assert!(out_base.health.trips.is_empty());
    std::fs::remove_dir_all(&cfg.out_dir).ok();

    // Faulted run: one whole-layer NaN fill at global step 20 (epoch 1,
    // step 4 of 16). The fault budget is 1, so the post-rollback replay
    // of step 20 runs clean.
    cfg.out_dir = scratch_dir("nan-fault");
    let mut faulted = Trainer::native(cfg.clone()).unwrap();
    faulted.set_fault_plan(FaultPlan::nan_activation(20, 0)).unwrap();
    let out = faulted.run().unwrap();

    assert_eq!(out.health.trips.len(), 1, "{:?}", out.health.trips);
    let trip = &out.health.trips[0];
    assert_eq!(trip.kind, FailureKind::NonFinite);
    assert_eq!(trip.step, 20);
    assert_eq!(trip.epoch, 1);
    assert_eq!(out.health.rollbacks, 1);
    assert!(out.health.escalations.is_empty());

    // The recovered trajectory IS the un-faulted trajectory.
    assert_same_history(&out_base.history, &out.history);
    assert_eq!(final_params(&base), final_params(&faulted));
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn gradient_fault_behind_finite_loss_is_caught_by_the_param_scan() {
    let mut cfg = micro_cfg("rec-grad");
    cfg.out_dir = scratch_dir("grad-base");
    cfg.checkpoint_every = 1;
    cfg.watchdog = Some(quiet_watchdog());
    let mut base = Trainer::native(cfg.clone()).unwrap();
    let out_base = base.run().unwrap();
    std::fs::remove_dir_all(&cfg.out_dir).ok();

    // A poisoned gradient commits NaN params while the step's loss
    // stays finite — only the post-step state scan can see it.
    cfg.out_dir = scratch_dir("grad-fault");
    let mut faulted = Trainer::native(cfg.clone()).unwrap();
    faulted.set_fault_plan(FaultPlan::nan_gradient(20, 0)).unwrap();
    let out = faulted.run().unwrap();

    assert_eq!(out.health.trips.len(), 1, "{:?}", out.health.trips);
    assert_eq!(out.health.trips[0].kind, FailureKind::NonFinite);
    assert!(
        out.health.trips[0].detail.contains("state tensor"),
        "trip came from the loss guard, not the param scan: {:?}",
        out.health.trips[0]
    );
    assert_eq!(out.health.rollbacks, 1);
    assert_same_history(&out_base.history, &out.history);
    assert_eq!(final_params(&base), final_params(&faulted));
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn repeated_trip_escalates_along_the_ladder_and_is_recorded() {
    let mut cfg = micro_cfg("rec-esc");
    cfg.out_dir = scratch_dir("esc");
    cfg.checkpoint_every = 1;
    cfg.policy =
        MultiplierPolicy::Approximate { mult: MultSpec::parse("drum6").unwrap() };
    cfg.watchdog = Some(WatchdogConfig {
        ladder: vec![MultSpec::Exact],
        spike_factor: 1e6,
        ..WatchdogConfig::default()
    });
    let mut trainer = Trainer::native(cfg.clone()).unwrap();
    // Budget 2: the fault fires on the first pass AND on the
    // post-rollback replay of the same global step — a deterministic,
    // systematic failure, which is exactly what escalation is for.
    trainer
        .set_fault_plan(FaultPlan::nan_activation(20, 0).with_fires(2))
        .unwrap();
    let out = trainer.run().unwrap();

    assert_eq!(out.health.trips.len(), 2, "{:?}", out.health.trips);
    assert!(out.health.trips.iter().all(|t| t.step == 20));
    assert_eq!(out.health.rollbacks, 2);
    assert_eq!(out.health.escalations, vec![(20, "exact".to_string())]);
    assert_eq!(out.epochs_run, 3);

    // The escalation is durable: the final checkpoint records both the
    // active multiplier (exact) and where the run started (drum6).
    let (_, meta, _) = trainer
        .store()
        .unwrap()
        .latest_valid("rec-esc")
        .unwrap()
        .expect("no valid checkpoint after recovery");
    assert_eq!(meta.mult, "exact");
    assert_eq!(meta.escalated_from.as_deref(), Some("drum6"));
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn torn_checkpoint_write_is_caught_by_the_verify_read_and_rewritten() {
    let mut cfg = micro_cfg("rec-tear");
    cfg.out_dir = scratch_dir("tear-base");
    cfg.checkpoint_every = 1;
    cfg.watchdog = Some(quiet_watchdog());
    let mut base = Trainer::native(cfg.clone()).unwrap();
    let out_base = base.run().unwrap();
    std::fs::remove_dir_all(&cfg.out_dir).ok();

    cfg.out_dir = scratch_dir("tear-fault");
    let mut trainer = Trainer::native(cfg.clone()).unwrap();
    // Tear the first save mid-write: the final path gets a truncated
    // file. The watched save reads every checkpoint straight back, so
    // the corruption is caught immediately and the save retried.
    trainer
        .store()
        .unwrap()
        .inject_fault(Some(StoreFault::TearNextSave { keep: 64 }));
    let out = trainer.run().unwrap();

    assert!(out.health.save_retries >= 1, "torn write went unnoticed");
    assert!(out.health.trips.is_empty());
    assert_eq!(out.health.rollbacks, 0);
    // Checkpointing trouble never perturbs the trajectory.
    assert_same_history(&out_base.history, &out.history);

    // Every retained checkpoint on disk is valid.
    let store = trainer.store().unwrap();
    for epoch in store.list_epochs("rec-tear").unwrap() {
        store
            .load("rec-tear", epoch)
            .unwrap_or_else(|e| panic!("epoch {epoch} unreadable after recovery: {e:#}"));
    }
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn checkpoint_retention_keeps_last_k() {
    let mut cfg = micro_cfg("rec-gc");
    cfg.out_dir = scratch_dir("gc");
    cfg.checkpoint_every = 1;
    cfg.epochs = 5;
    cfg.watchdog = Some(WatchdogConfig { keep: 2, spike_factor: 1e6, ..WatchdogConfig::default() });
    let mut trainer = Trainer::native(cfg.clone()).unwrap();
    trainer.run().unwrap();
    let epochs = trainer.store().unwrap().list_epochs("rec-gc").unwrap();
    assert_eq!(epochs, vec![4, 5], "retention failed: {epochs:?}");
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn exhausted_ladder_fails_loudly_instead_of_looping() {
    let mut cfg = micro_cfg("rec-exhaust");
    cfg.out_dir = scratch_dir("exhaust");
    cfg.checkpoint_every = 1;
    // Empty ladder + a fault with a huge budget: every replay re-trips
    // at step 20 and there is nothing to escalate to.
    cfg.watchdog = Some(WatchdogConfig {
        ladder: vec![],
        max_retries: 2,
        spike_factor: 1e6,
        ..WatchdogConfig::default()
    });
    let mut trainer = Trainer::native(cfg.clone()).unwrap();
    trainer
        .set_fault_plan(FaultPlan::nan_activation(20, 0).with_fires(1000))
        .unwrap();
    let err = trainer.run().unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("ladder exhausted") || msg.contains("retry budget exhausted"),
        "unbounded or unlabelled failure: {msg}"
    );
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}
