//! Bit-identity property suite for the **signed** prepared GEMM — the
//! signed twin of `tests/prepared_gemm.rs`.
//!
//! The blocked signed kernel behind `approx_matmul_signed` / `_tn` /
//! `_nt` must be bit-identical to the signed scalar-walk oracle
//! (`approx_matmul_reference_signed`: one `approx_mul_f32_signed` per
//! product, f32 accumulation in strict k-order) for every signed
//! design × operand layout × thread count — including chains with
//! non-finite and flushed operands planted mid-chain. On top of that,
//! two routing pins:
//!
//! * `sdrum6` (sign-magnitude) through the signed path is bit-identical
//!   to `drum6` through the unsigned path — moving the sign *into* the
//!   design must not change one bit for a design that routes it around
//!   a magnitude core anyway;
//! * `booth8` is **not** sign-symmetric at GEMM level — negating A does
//!   not negate C — which is the behavior the signed path exists to
//!   express and the unsigned path provably cannot.

use approxmul::mult::signed::{
    approx_matmul_reference_signed, approx_matmul_signed, approx_matmul_signed_nt,
    approx_matmul_signed_tn, by_name,
};
use approxmul::mult::{approx_matmul, by_name as unsigned_by_name, GEMM_ROW_BLOCK};
use approxmul::parallel;
use approxmul::rng::Xoshiro256;

const SIGNED_DESIGNS: &[&str] =
    &["sexact", "sdrum6", "booth8", "booth24", "sroba", "slut12:sdrum6"];

fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0f32; src.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = src[r * cols + c];
        }
    }
    out
}

/// Random operands with occasional special values (inf, NaN, signed
/// zero, subnormal) planted through the chains.
fn operands(rows: usize, inner: usize, cols: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let mut gen = |n: usize| -> Vec<f32> {
        (0..n)
            .map(|_| match rng.next_u32() % 64 {
                0 => f32::INFINITY,
                1 => f32::NEG_INFINITY,
                2 => f32::NAN,
                3 => 0.0,
                4 => -0.0,
                5 => 1.0e-41, // subnormal -> flushed
                _ => 2.0 * rng.next_f32() - 1.0,
            })
            .collect()
    };
    (gen(rows * inner), gen(inner * cols))
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: element {i} differs ({g} vs {w})"
        );
    }
}

#[test]
fn signed_kernel_is_bit_identical_to_reference_across_threads() {
    // Shape crosses both the row-block and col-panel boundaries so the
    // blocked paths (multi-block partials, panel edges) are exercised.
    let (rows, inner, cols) = (GEMM_ROW_BLOCK + 11, 21, 53);
    for (di, design) in SIGNED_DESIGNS.iter().enumerate() {
        let m = by_name(design).unwrap();
        let (a, b) = operands(rows, inner, cols, 2000 + di as u64);
        let want =
            approx_matmul_reference_signed(m.as_ref(), &a, &b, rows, inner, cols)
                .unwrap();

        let a_t = transpose(&a, rows, inner); // [inner x rows]
        let b_t = transpose(&b, inner, cols); // [cols x inner]

        for threads in [1usize, 2, 5] {
            parallel::set_max_threads(threads);
            let nn =
                approx_matmul_signed(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
            let tn = approx_matmul_signed_tn(m.as_ref(), &a_t, &b, rows, inner, cols)
                .unwrap();
            let nt = approx_matmul_signed_nt(m.as_ref(), &a, &b_t, rows, inner, cols)
                .unwrap();
            parallel::set_max_threads(0);
            assert_bits_eq(&nn, &want, &format!("{design} NN t={threads}"));
            assert_bits_eq(&tn, &want, &format!("{design} TN t={threads}"));
            assert_bits_eq(&nt, &want, &format!("{design} NT t={threads}"));
        }
    }
}

#[test]
fn all_finite_chains_match_reference_on_small_shapes() {
    // Purely finite data (the training regime) on shapes below one row
    // block: the sequential path of the kernel.
    for (di, design) in SIGNED_DESIGNS.iter().enumerate() {
        let m = by_name(design).unwrap();
        let (rows, inner, cols) = (9usize, 16usize, 7usize);
        let mut rng = Xoshiro256::new(71 + di as u64);
        let a: Vec<f32> =
            (0..rows * inner).map(|_| 4.0 * rng.next_f32() - 2.0).collect();
        let b: Vec<f32> =
            (0..inner * cols).map(|_| 4.0 * rng.next_f32() - 2.0).collect();
        let fast = approx_matmul_signed(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
        let slow =
            approx_matmul_reference_signed(m.as_ref(), &a, &b, rows, inner, cols)
                .unwrap();
        assert_bits_eq(&fast, &slow, design);
    }
}

#[test]
fn nonfinite_and_flushed_chains_match_reference() {
    // Dense special-value chains: non-finite fallbacks and flushed
    // skips interleave with batched signed products inside single
    // chains.
    let specials = [
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::NAN,
        0.0,
        -0.0,
        1.0e-41,
        1.5,
        -2.25,
    ];
    let (rows, inner, cols) = (4usize, specials.len() * 2, 3usize);
    let mut rng = Xoshiro256::new(199);
    let a: Vec<f32> = (0..rows * inner)
        .map(|i| {
            if i % 3 == 0 {
                specials[(i / 3) % specials.len()]
            } else {
                rng.next_f32() - 0.5
            }
        })
        .collect();
    let b: Vec<f32> = (0..inner * cols)
        .map(|i| {
            if i % 4 == 1 {
                specials[(i / 4) % specials.len()]
            } else {
                rng.next_f32() - 0.5
            }
        })
        .collect();
    for design in SIGNED_DESIGNS {
        let m = by_name(design).unwrap();
        let fast = approx_matmul_signed(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
        let slow =
            approx_matmul_reference_signed(m.as_ref(), &a, &b, rows, inner, cols)
                .unwrap();
        assert_bits_eq(&fast, &slow, design);
    }
}

#[test]
fn sdrum6_gemm_is_bit_identical_to_drum6_gemm() {
    // The sign-routing pin: a sign-magnitude design behaves identically
    // whether the sign is routed around the core (unsigned pipeline) or
    // through it (signed pipeline) — down to the last bit, including
    // special values.
    let sd = by_name("sdrum6").unwrap();
    let ud = unsigned_by_name("drum6").unwrap();
    let (rows, inner, cols) = (33usize, 24usize, 17usize);
    let (a, b) = operands(rows, inner, cols, 311);
    let signed_c = approx_matmul_signed(sd.as_ref(), &a, &b, rows, inner, cols).unwrap();
    let unsigned_c = approx_matmul(ud.as_ref(), &a, &b, rows, inner, cols).unwrap();
    assert_bits_eq(&signed_c, &unsigned_c, "sdrum6 vs drum6");
}

#[test]
fn booth_gemm_is_not_sign_symmetric() {
    // Negating A flips every product's sign exactly under any unsigned
    // design; under Booth truncation the two GEMMs must disagree
    // somewhere beyond pure negation.
    let m = by_name("booth24").unwrap();
    let (rows, inner, cols) = (8usize, 16usize, 8usize);
    let mut rng = Xoshiro256::new(313);
    let a: Vec<f32> = (0..rows * inner).map(|_| rng.next_f32() + 0.5).collect();
    let b: Vec<f32> = (0..inner * cols).map(|_| rng.next_f32() + 0.5).collect();
    let neg_a: Vec<f32> = a.iter().map(|&v| -v).collect();
    let c = approx_matmul_signed(m.as_ref(), &a, &b, rows, inner, cols).unwrap();
    let c_neg = approx_matmul_signed(m.as_ref(), &neg_a, &b, rows, inner, cols).unwrap();
    let asym = c
        .iter()
        .zip(&c_neg)
        .filter(|&(&x, &y)| (-x).to_bits() != y.to_bits())
        .count();
    assert!(
        asym > c.len() / 2,
        "booth24 came out sign-symmetric on {asym}/{} outputs",
        c.len()
    );
}

#[test]
fn signed_gemm_is_deterministic_across_calls() {
    let m = by_name("booth8").unwrap();
    let mut rng = Xoshiro256::new(317);
    let a: Vec<f32> = (0..32 * 24).map(|_| rng.next_f32() - 0.5).collect();
    let b: Vec<f32> = (0..24 * 16).map(|_| rng.next_f32() - 0.5).collect();
    let c1 = approx_matmul_signed(m.as_ref(), &a, &b, 32, 24, 16).unwrap();
    let c2 = approx_matmul_signed(m.as_ref(), &a, &b, 32, 24, 16).unwrap();
    assert_eq!(c1, c2);
}
