//! Deterministic fault-injection plans for the training path.
//!
//! A [`FaultPlan`] describes *one* failure to manufacture at a specific
//! global step: a poisoned layer activation, a poisoned weight
//! gradient, or (via [`StoreFault`], re-exported from `checkpoint`) a
//! torn/failed checkpoint write. Backends that support injection
//! accept a plan through `Backend::set_fault_plan`; everything is keyed
//! on the trainer's global step so faults land at the same place on
//! every run — recovery tests must be reproducible, not probabilistic.
//!
//! Fault plans never touch the RNG streams or the math of un-faulted
//! steps: with `max_fires` exhausted (or no plan armed) the trajectory
//! is bit-identical to a clean run.

pub use crate::checkpoint::StoreFault;

/// Where in the training step to inject.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultSite {
    /// Overwrite the post-activation output of GEMM layer `layer`
    /// (conv layers first, then dense, then classifier — the backend's
    /// `gemm_layers` order) with `value` during the forward pass. The
    /// whole layer output is filled: a single poisoned element can be
    /// silently dropped by max-pooling (NaN loses every `>`
    /// comparison), and the harness wants a guaranteed trip.
    Activation { layer: u32, value: f32 },
    /// Overwrite the weight gradient of GEMM layer `layer` with
    /// `value` after the backward pass, so the optimizer commits
    /// poisoned parameters while the step's loss is still finite.
    Gradient { layer: u32, value: f32 },
}

/// A deterministic one-site fault: fire at global step `step`, at most
/// `max_fires` times (re-visits of the same step after a rollback
/// re-fire until the budget runs out).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Global step (epoch * steps_per_epoch + step_in_epoch) to hit.
    pub step: u64,
    pub site: FaultSite,
    /// Total number of times the fault may fire across the run.
    pub max_fires: u32,
}

impl FaultPlan {
    /// NaN the whole output of `layer` at `step`, once.
    pub fn nan_activation(step: u64, layer: u32) -> Self {
        FaultPlan {
            step,
            site: FaultSite::Activation { layer, value: f32::NAN },
            max_fires: 1,
        }
    }

    /// NaN the weight gradient of `layer` at `step`, once.
    pub fn nan_gradient(step: u64, layer: u32) -> Self {
        FaultPlan {
            step,
            site: FaultSite::Gradient { layer, value: f32::NAN },
            max_fires: 1,
        }
    }

    pub fn with_fires(mut self, n: u32) -> Self {
        self.max_fires = n;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_set_site_and_budget() {
        let p = FaultPlan::nan_activation(7, 1);
        assert_eq!(p.step, 7);
        assert_eq!(p.max_fires, 1);
        match p.site {
            FaultSite::Activation { layer, value } => {
                assert_eq!(layer, 1);
                assert!(value.is_nan());
            }
            _ => panic!("wrong site"),
        }
        let p = FaultPlan::nan_gradient(3, 0).with_fires(2);
        assert_eq!(p.max_fires, 2);
        assert!(matches!(p.site, FaultSite::Gradient { layer: 0, .. }));
    }
}
