//! Property-testing mini-framework (no `proptest` in this offline
//! environment). Seeded generators + a forall runner with failure-case
//! reporting and a simple halving shrinker for integer tuples.
//!
//! Usage:
//! ```no_run
//! use approxmul::testkit::{forall, Gen};
//! forall(100, 42, |g: &mut Gen| {
//!     let a = g.u32_below(1000);
//!     let b = g.u32_below(1000);
//!     assert_eq!(a as u64 + b as u64, b as u64 + a as u64);
//! });
//! ```

use crate::rng::Xoshiro256;

pub mod faults;

/// Random case generator handed to each property iteration.
pub struct Gen {
    rng: Xoshiro256,
    /// Trace of drawn values for failure reporting.
    trace: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Xoshiro256::new(seed), trace: Vec::new() }
    }

    pub fn u32(&mut self) -> u32 {
        let v = self.rng.next_u32();
        self.trace.push(format!("u32={v}"));
        v
    }

    pub fn u32_below(&mut self, n: u32) -> u32 {
        let v = self.rng.next_below(n.max(1) as usize) as u32;
        self.trace.push(format!("u32<{n}={v}"));
        v
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let v = lo + self.rng.next_below(hi - lo + 1);
        self.trace.push(format!("usize[{lo},{hi}]={v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + (hi - lo) * self.rng.next_f64();
        self.trace.push(format!("f64[{lo},{hi}]={v:.6}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_f64() < 0.5;
        self.trace.push(format!("bool={v}"));
        v
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let v: Vec<f32> = (0..len)
            .map(|_| lo + (hi - lo) * self.rng.next_f32())
            .collect();
        self.trace.push(format!("vec_f32[{len}]"));
        v
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.next_below(xs.len());
        self.trace.push(format!("choose#{i}"));
        &xs[i]
    }
}

/// Run `prop` on `cases` generated cases; panics with the seed and the
/// drawn-value trace of the first failing case.
pub fn forall(cases: u64, seed: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(case);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(case_seed);
            prop(&mut g);
            g
        });
        if let Err(panic) = result {
            // Re-generate the trace for the failing case.
            let mut g = Gen::new(case_seed);
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prop(&mut g);
            }));
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case} (case_seed {case_seed:#x}):\n  \
                 {msg}\n  drawn: [{}]",
                g.trace.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(50, 1, |g| {
            let a = g.u32_below(100) as u64;
            let b = g.u32_below(100) as u64;
            assert!(a + b <= 198);
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        forall(100, 2, |g| {
            let v = g.u32_below(10);
            assert!(v < 9, "hit the 1-in-10 case");
        });
    }

    #[test]
    fn generators_in_bounds() {
        forall(100, 3, |g| {
            let x = g.usize_in(5, 10);
            assert!((5..=10).contains(&x));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
            let v = g.vec_f32(4, 0.0, 1.0);
            assert_eq!(v.len(), 4);
            let c = *g.choose(&[1, 2, 3]);
            assert!((1..=3).contains(&c));
        });
    }
}
