//! Experiment configuration: typed config with JSON file loading,
//! validation, and the presets the CLI/examples use.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::Value;
use crate::mult::MultSpec;

/// Which execution backend runs the training graphs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecBackend {
    /// Compiled PJRT executables (needs `make artifacts` + real XLA).
    Pjrt,
    /// Pure-Rust bit-accurate path ([`crate::runtime::NativeBackend`]).
    Native,
}

impl ExecBackend {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "pjrt" | "xla" => ExecBackend::Pjrt,
            "native" => ExecBackend::Native,
            other => bail!("unknown backend {other:?} (pjrt | native)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecBackend::Pjrt => "pjrt",
            ExecBackend::Native => "native",
        }
    }
}

/// When the error matrices are (re)generated — the paper's Figure-3
/// procedure fixes them once per run; resampling is our ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorSampling {
    /// One fixed error matrix per layer for the whole run (paper).
    FixedPerRun,
    /// Fresh error matrices every step (models data-dependent error).
    PerStep,
}

impl ErrorSampling {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fixed" => ErrorSampling::FixedPerRun,
            "per-step" | "per_step" => ErrorSampling::PerStep,
            other => bail!("unknown error sampling {other:?} (fixed | per-step)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ErrorSampling::FixedPerRun => "fixed",
            ErrorSampling::PerStep => "per-step",
        }
    }
}

/// Learning-rate schedule (paper: "SGD with learning rate decay"; the
/// reference implementation uses step decay).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    Constant { lr: f64 },
    /// `lr * factor^(epoch / every)` (integer division).
    StepDecay { lr: f64, factor: f64, every: u64 },
}

impl LrSchedule {
    pub fn at_epoch(&self, epoch: u64) -> f64 {
        match *self {
            LrSchedule::Constant { lr } => lr,
            LrSchedule::StepDecay { lr, factor, every } => {
                lr * factor.powi((epoch / every.max(1)) as i32)
            }
        }
    }
}

/// The multiplier policy over epochs: exact, approximate, or the
/// paper's hybrid (approximate then exact). The approximate multiplier
/// is a full [`MultSpec`] — the paper's Gaussian surrogate
/// (`gaussian:<sigma>`) or a bit-accurate design (`drum6`,
/// `lut12:drum6`, ...; native backend only).
#[derive(Debug, Clone, PartialEq)]
pub enum MultiplierPolicy {
    Exact,
    Approximate { mult: MultSpec },
    /// Approximate for epochs `< switch_epoch`, exact after (§IV).
    Hybrid { mult: MultSpec, switch_epoch: u64 },
}

impl MultiplierPolicy {
    /// The configured approximate multiplier, if any.
    pub fn mult(&self) -> Option<&MultSpec> {
        match self {
            MultiplierPolicy::Exact => None,
            MultiplierPolicy::Approximate { mult }
            | MultiplierPolicy::Hybrid { mult, .. } => Some(mult),
        }
    }

    /// Whether the approximate multiplier is in force at `epoch`.
    pub fn active_at(&self, epoch: u64) -> bool {
        match self {
            MultiplierPolicy::Exact => false,
            MultiplierPolicy::Approximate { mult } => !mult.is_exact(),
            MultiplierPolicy::Hybrid { mult, switch_epoch } => {
                epoch < *switch_epoch && !mult.is_exact()
            }
        }
    }

    /// Gaussian sigma in force at `epoch` (0 for exact phases and for
    /// bit-accurate designs, whose error is operand-dependent).
    pub fn sigma_at(&self, epoch: u64) -> f64 {
        if self.active_at(epoch) {
            self.mult().map(|m| m.sigma()).unwrap_or(0.0)
        } else {
            0.0
        }
    }

    /// The multiplier spec in force at `epoch`.
    pub fn spec_at(&self, epoch: u64) -> MultSpec {
        if self.active_at(epoch) {
            self.mult().cloned().unwrap_or(MultSpec::Exact)
        } else {
            MultSpec::Exact
        }
    }

    /// Fraction of epochs run approximately (Table III's utilization).
    pub fn utilization(&self, total_epochs: u64) -> f64 {
        match self {
            MultiplierPolicy::Exact => 0.0,
            MultiplierPolicy::Approximate { .. } => 1.0,
            MultiplierPolicy::Hybrid { switch_epoch, .. } => {
                (*switch_epoch).min(total_epochs) as f64 / total_epochs.max(1) as f64
            }
        }
    }
}

/// Watchdog + recovery policy for the resilient training runtime
/// ([`crate::coordinator::health`] / [`crate::coordinator::recovery`]).
#[derive(Debug, Clone, PartialEq)]
pub struct WatchdogConfig {
    /// Escalation ladder: on a repeat trip at the same step, the run's
    /// approximate multiplier is replaced by the next rung (the
    /// Figure-4 hybrid switch as a *reactive* policy). Usually ends in
    /// `exact`.
    pub ladder: Vec<MultSpec>,
    /// Rollback/retry budget before the run is declared unrecoverable.
    pub max_retries: u32,
    /// Base backoff between checkpoint-IO retries (doubles per
    /// attempt).
    pub backoff_ms: u64,
    /// Verified-good checkpoints to retain (`Store::gc_keep_last`);
    /// 0 keeps everything.
    pub keep: usize,
    /// Loss-spike window length (steps) for the divergence heuristic.
    pub window: usize,
    /// A loss > `spike_factor` × windowed mean counts as divergence.
    pub spike_factor: f64,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        WatchdogConfig {
            ladder: vec![MultSpec::Exact],
            max_retries: 3,
            backoff_ms: 50,
            keep: 3,
            window: 8,
            spike_factor: 4.0,
        }
    }
}

/// Serving-mode policy ([`crate::serve`]): dynamic-batching knobs and
/// admission bounds for the resident multi-tenant inference server.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Max coalescing wait for a lane's oldest request (µs) before a
    /// partial batch flushes anyway.
    pub batch_window_us: u64,
    /// Max requests per GEMM batch.
    pub max_batch: usize,
    /// Bounded request-queue capacity across all lanes; admission past
    /// it is a typed `queue-full` rejection.
    pub queue_capacity: usize,
    /// Bound on distinct resident multiplier specs.
    pub max_specs: usize,
    /// Deterministic per-batch service-time model (µs) used for
    /// deadline feasibility and modeled completion times.
    pub service_estimate_us: u64,
    /// Byte cap enforced on request bodies *before* JSON parsing.
    pub max_request_bytes: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batch_window_us: 2_000,
            max_batch: 8,
            queue_capacity: 256,
            max_specs: 8,
            service_estimate_us: 2_000,
            max_request_bytes: 1 << 20,
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            bail!("serve.max_batch must be >= 1");
        }
        if self.queue_capacity < self.max_batch {
            bail!(
                "serve.queue_capacity {} must be >= max_batch {}",
                self.queue_capacity,
                self.max_batch
            );
        }
        if self.max_specs == 0 {
            bail!("serve.max_specs must be >= 1");
        }
        if self.service_estimate_us == 0 {
            bail!("serve.service_estimate_us must be >= 1");
        }
        if self.max_request_bytes == 0 {
            bail!("serve.max_request_bytes must be >= 1");
        }
        Ok(())
    }
}

/// A full training-run configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Model preset name (must exist in the manifest / native table).
    pub preset: String,
    /// Execution backend for the training session.
    pub backend: ExecBackend,
    pub epochs: u64,
    pub train_examples: usize,
    pub test_examples: usize,
    pub seed: u64,
    pub policy: MultiplierPolicy,
    pub sampling: ErrorSampling,
    pub lr: LrSchedule,
    pub augment: bool,
    /// Save a checkpoint every `n` epochs (0 = never).
    pub checkpoint_every: u64,
    /// Directory for checkpoints/logs (empty = no persistence).
    pub out_dir: String,
    /// Run tag for checkpoints and reports.
    pub tag: String,
    /// Stop early if test accuracy hasn't improved for `n` epochs
    /// (0 = never).
    pub patience: u64,
    /// Synthetic-data difficulty (noise/signal ratio of the surrogate;
    /// ignored when real data is supplied). Tuned so the presets
    /// saturate below 100% — Table II needs headroom to damage.
    pub data_noise: f64,
    /// Resilient-runtime policy; `None` = watchdog off (the default:
    /// trajectories bit-identical to pre-watchdog builds).
    pub watchdog: Option<WatchdogConfig>,
}

impl ExperimentConfig {
    /// Defaults for the e2e `small` training run.
    pub fn preset_small() -> Self {
        ExperimentConfig {
            preset: "small".into(),
            backend: ExecBackend::Pjrt,
            epochs: 12,
            train_examples: 4096,
            test_examples: 1024,
            seed: 42,
            policy: MultiplierPolicy::Exact,
            sampling: ErrorSampling::FixedPerRun,
            lr: LrSchedule::StepDecay { lr: 0.05, factor: 0.5, every: 5 },
            augment: true,
            checkpoint_every: 0,
            out_dir: String::new(),
            tag: "run".into(),
            patience: 0,
            data_noise: 2.5,
            watchdog: None,
        }
    }

    /// Defaults for fast harness runs on the `tiny` preset.
    pub fn preset_tiny() -> Self {
        ExperimentConfig {
            preset: "tiny".into(),
            backend: ExecBackend::Pjrt,
            epochs: 10,
            train_examples: 1024,
            test_examples: 512,
            seed: 42,
            policy: MultiplierPolicy::Exact,
            sampling: ErrorSampling::FixedPerRun,
            lr: LrSchedule::StepDecay { lr: 0.05, factor: 0.5, every: 6 },
            augment: false,
            checkpoint_every: 0,
            out_dir: String::new(),
            tag: "tiny".into(),
            patience: 0,
            data_noise: 2.5,
            watchdog: None,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.epochs == 0 {
            bail!("epochs must be > 0");
        }
        if self.train_examples == 0 || self.test_examples == 0 {
            bail!("train/test example counts must be > 0");
        }
        if let MultiplierPolicy::Hybrid { switch_epoch, .. } = &self.policy {
            if *switch_epoch > self.epochs {
                bail!(
                    "switch_epoch {} exceeds total epochs {}",
                    switch_epoch,
                    self.epochs
                );
            }
        }
        let sigma = self.policy.sigma_at(0).max(self.policy.sigma_at(self.epochs));
        if !(0.0..1.0).contains(&sigma) {
            bail!("sigma {sigma} out of sane range [0, 1)");
        }
        if self.backend == ExecBackend::Pjrt {
            if let Some(mult) = self.policy.mult() {
                if mult.surrogate_sigma().is_none() {
                    bail!(
                        "multiplier {:?} is bit-accurate; the PJRT backend can only \
                         express gaussian:<sigma> — use the native backend",
                        mult.canonical()
                    );
                }
            }
        }
        if let Some(w) = &self.watchdog {
            if self.out_dir.is_empty() {
                bail!("watchdog needs an out_dir: rollback restores from checkpoints");
            }
            if self.checkpoint_every == 0 {
                bail!("watchdog needs checkpoint_every >= 1 (rollback targets)");
            }
            if w.max_retries == 0 {
                bail!("watchdog max_retries must be >= 1");
            }
            if w.window < 2 {
                bail!("watchdog window must be >= 2 steps");
            }
            if w.spike_factor <= 1.0 {
                bail!("watchdog spike_factor must be > 1");
            }
            if self.backend == ExecBackend::Pjrt {
                for rung in &w.ladder {
                    if rung.surrogate_sigma().is_none() {
                        bail!(
                            "escalation rung {:?} is bit-accurate; the PJRT backend \
                             can only express gaussian:<sigma> — use the native backend",
                            rung.canonical()
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Load from a JSON config file; missing keys take the `small`
    /// preset's defaults.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let v = Value::parse_file(&path)?;
        Self::from_json(&v)
            .with_context(|| format!("config {}", path.as_ref().display()))
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let mut cfg = Self::preset_small();
        if let Some(p) = v.opt("preset") {
            cfg.preset = p.as_str()?.to_string();
        }
        if let Some(b) = v.opt("backend") {
            cfg.backend = ExecBackend::parse(b.as_str()?)?;
        }
        if let Some(e) = v.opt("epochs") {
            cfg.epochs = e.as_i64()? as u64;
        }
        if let Some(n) = v.opt("train_examples") {
            cfg.train_examples = n.as_usize()?;
        }
        if let Some(n) = v.opt("test_examples") {
            cfg.test_examples = n.as_usize()?;
        }
        if let Some(s) = v.opt("seed") {
            cfg.seed = s.as_i64()? as u64;
        }
        if let Some(s) = v.opt("sampling") {
            cfg.sampling = ErrorSampling::parse(s.as_str()?)?;
        }
        if let Some(a) = v.opt("augment") {
            cfg.augment = a.as_bool()?;
        }
        if let Some(c) = v.opt("checkpoint_every") {
            cfg.checkpoint_every = c.as_i64()? as u64;
        }
        if let Some(d) = v.opt("out_dir") {
            cfg.out_dir = d.as_str()?.to_string();
        }
        if let Some(t) = v.opt("tag") {
            cfg.tag = t.as_str()?.to_string();
        }
        if let Some(p) = v.opt("patience") {
            cfg.patience = p.as_i64()? as u64;
        }
        if let Some(d) = v.opt("data_noise") {
            cfg.data_noise = d.as_f64()?;
        }
        if let Some(lr) = v.opt("lr") {
            let base = lr.get("base")?.as_f64()?;
            cfg.lr = match lr.opt("decay_every") {
                Some(every) => LrSchedule::StepDecay {
                    lr: base,
                    factor: lr.get("factor")?.as_f64()?,
                    every: every.as_i64()? as u64,
                },
                None => LrSchedule::Constant { lr: base },
            };
        }
        if let Some(w) = v.opt("watchdog") {
            // `true` takes the default policy; an object tunes it.
            cfg.watchdog = match w.as_bool() {
                Ok(true) => Some(WatchdogConfig::default()),
                Ok(false) => None,
                Err(_) => {
                    let mut wd = WatchdogConfig::default();
                    if let Some(l) = w.opt("ladder") {
                        wd.ladder = l
                            .as_array()?
                            .iter()
                            .map(|s| MultSpec::parse(s.as_str()?))
                            .collect::<Result<_>>()?;
                    }
                    if let Some(n) = w.opt("max_retries") {
                        wd.max_retries = n.as_i64()? as u32;
                    }
                    if let Some(n) = w.opt("backoff_ms") {
                        wd.backoff_ms = n.as_i64()? as u64;
                    }
                    if let Some(n) = w.opt("keep") {
                        wd.keep = n.as_usize()?;
                    }
                    if let Some(n) = w.opt("window") {
                        wd.window = n.as_usize()?;
                    }
                    if let Some(n) = w.opt("spike_factor") {
                        wd.spike_factor = n.as_f64()?;
                    }
                    Some(wd)
                }
            };
        }
        if let Some(p) = v.opt("policy") {
            let kind = p.get("kind")?.as_str()?;
            // `mult` names a full spec; a bare `sigma` number keeps the
            // pre-backend-split configs loading (gaussian surrogate).
            let mult = |p: &Value| -> Result<MultSpec> {
                match p.opt("mult") {
                    Some(m) => MultSpec::parse(m.as_str()?),
                    None => Ok(MultSpec::gaussian(p.get("sigma")?.as_f64()?)),
                }
            };
            cfg.policy = match kind {
                "exact" => MultiplierPolicy::Exact,
                "approx" => MultiplierPolicy::Approximate { mult: mult(p)? },
                "hybrid" => MultiplierPolicy::Hybrid {
                    mult: mult(p)?,
                    switch_epoch: p.get("switch_epoch")?.as_i64()? as u64,
                },
                other => bail!("unknown policy kind {other:?}"),
            };
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedules() {
        let c = LrSchedule::Constant { lr: 0.1 };
        assert_eq!(c.at_epoch(0), 0.1);
        assert_eq!(c.at_epoch(100), 0.1);
        let s = LrSchedule::StepDecay { lr: 0.1, factor: 0.5, every: 10 };
        assert_eq!(s.at_epoch(0), 0.1);
        assert_eq!(s.at_epoch(9), 0.1);
        assert!((s.at_epoch(10) - 0.05).abs() < 1e-12);
        assert!((s.at_epoch(25) - 0.025).abs() < 1e-12);
    }

    #[test]
    fn policy_sigma_switching() {
        let h = MultiplierPolicy::Hybrid {
            mult: MultSpec::gaussian(0.045),
            switch_epoch: 5,
        };
        assert_eq!(h.sigma_at(0), 0.045);
        assert_eq!(h.sigma_at(4), 0.045);
        assert_eq!(h.sigma_at(5), 0.0);
        assert!(h.active_at(4) && !h.active_at(5));
        assert_eq!(h.utilization(10), 0.5);
        assert_eq!(MultiplierPolicy::Exact.utilization(10), 0.0);
        assert_eq!(h.spec_at(0), MultSpec::gaussian(0.045));
        assert_eq!(h.spec_at(5), MultSpec::Exact);
    }

    #[test]
    fn policy_with_design_spec() {
        let p = MultiplierPolicy::Approximate {
            mult: MultSpec::parse("drum6").unwrap(),
        };
        assert!(p.active_at(0));
        assert_eq!(p.sigma_at(0), 0.0); // operand-dependent, not a sigma
        assert_eq!(p.spec_at(0).canonical(), "drum6");
    }

    #[test]
    fn json_config_parsing() {
        let v = Value::parse(
            r#"{
                "preset": "tiny", "epochs": 3, "seed": 7,
                "policy": {"kind": "hybrid", "sigma": 0.12, "switch_epoch": 2},
                "lr": {"base": 0.1, "factor": 0.5, "decay_every": 2},
                "sampling": "per-step", "augment": false
            }"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.preset, "tiny");
        assert_eq!(cfg.epochs, 3);
        assert_eq!(cfg.backend, ExecBackend::Pjrt);
        assert_eq!(cfg.sampling, ErrorSampling::PerStep);
        match cfg.policy {
            MultiplierPolicy::Hybrid { mult, switch_epoch } => {
                assert!((mult.sigma() - 0.12).abs() < 1e-12);
                assert_eq!(switch_epoch, 2);
            }
            _ => panic!("wrong policy"),
        }
    }

    #[test]
    fn json_config_with_mult_spec_and_backend() {
        let v = Value::parse(
            r#"{
                "preset": "tiny", "backend": "native", "epochs": 2,
                "policy": {"kind": "approx", "mult": "lut8:drum6"}
            }"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.backend, ExecBackend::Native);
        assert_eq!(cfg.policy.mult().unwrap().canonical(), "lut8:drum6");
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut cfg = ExperimentConfig::preset_tiny();
        cfg.epochs = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::preset_tiny();
        cfg.policy = MultiplierPolicy::Hybrid {
            mult: MultSpec::gaussian(0.1),
            switch_epoch: 99,
        };
        assert!(cfg.validate().is_err());
        // Bit-accurate design on the PJRT backend: rejected with a hint.
        let mut cfg = ExperimentConfig::preset_tiny();
        cfg.policy = MultiplierPolicy::Approximate {
            mult: MultSpec::parse("drum6").unwrap(),
        };
        assert!(cfg.validate().is_err());
        cfg.backend = ExecBackend::Native;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn signed_design_policy_needs_native_backend() {
        // Signed designs have no surrogate sigma, so the PJRT backend
        // rejects them with the same hint as unsigned designs.
        let mut cfg = ExperimentConfig::preset_tiny();
        cfg.policy = MultiplierPolicy::Approximate {
            mult: MultSpec::parse("booth8").unwrap(),
        };
        assert!(cfg.validate().is_err());
        cfg.backend = ExecBackend::Native;
        assert!(cfg.validate().is_ok());
        assert_eq!(cfg.policy.sigma_at(0), 0.0);
        assert_eq!(cfg.policy.spec_at(0).canonical(), "booth8");
    }

    #[test]
    fn watchdog_validation() {
        let mut cfg = ExperimentConfig::preset_tiny();
        cfg.watchdog = Some(WatchdogConfig::default());
        // Needs a checkpoint target to roll back to.
        assert!(cfg.validate().is_err());
        cfg.out_dir = "/tmp/wd".into();
        assert!(cfg.validate().is_err());
        cfg.checkpoint_every = 1;
        assert!(cfg.validate().is_ok());
        // Degenerate heuristics rejected.
        cfg.watchdog.as_mut().unwrap().window = 1;
        assert!(cfg.validate().is_err());
        cfg.watchdog.as_mut().unwrap().window = 8;
        cfg.watchdog.as_mut().unwrap().spike_factor = 1.0;
        assert!(cfg.validate().is_err());
        cfg.watchdog.as_mut().unwrap().spike_factor = 4.0;
        cfg.watchdog.as_mut().unwrap().max_retries = 0;
        assert!(cfg.validate().is_err());
        // Bit-accurate ladder rung on PJRT: rejected (exact is fine —
        // its surrogate sigma is 0.0, not None).
        cfg.watchdog = Some(WatchdogConfig {
            ladder: vec![MultSpec::parse("drum6").unwrap(), MultSpec::Exact],
            ..WatchdogConfig::default()
        });
        assert!(cfg.validate().is_err());
        cfg.backend = ExecBackend::Native;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn json_watchdog_parsing() {
        let v = Value::parse(
            r#"{
                "preset": "tiny", "backend": "native", "out_dir": "/tmp/wd",
                "checkpoint_every": 1, "watchdog": true
            }"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.watchdog, Some(WatchdogConfig::default()));
        let v = Value::parse(
            r#"{
                "preset": "tiny", "backend": "native", "out_dir": "/tmp/wd",
                "checkpoint_every": 2,
                "watchdog": {
                    "ladder": ["sdrum6", "exact"], "max_retries": 5,
                    "backoff_ms": 10, "keep": 2, "window": 4, "spike_factor": 3.0
                }
            }"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        let w = cfg.watchdog.unwrap();
        assert_eq!(w.ladder.len(), 2);
        assert_eq!(w.ladder[0].canonical(), "sdrum6");
        assert_eq!(w.max_retries, 5);
        assert_eq!(w.keep, 2);
        assert_eq!(w.window, 4);
        assert_eq!(w.spike_factor, 3.0);
        // `false` explicitly turns it off.
        let v = Value::parse(r#"{"preset": "tiny", "watchdog": false}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&v).unwrap().watchdog, None);
    }

    #[test]
    fn backend_parses() {
        assert_eq!(ExecBackend::parse("native").unwrap(), ExecBackend::Native);
        assert_eq!(ExecBackend::parse("pjrt").unwrap(), ExecBackend::Pjrt);
        assert!(ExecBackend::parse("gpu").is_err());
        assert_eq!(ExecBackend::Native.name(), "native");
    }
}
