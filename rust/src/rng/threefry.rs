//! Threefry-2x32 (20 rounds) + Box-Muller — bit-compatible with
//! `python/compile/kernels/prng.py`.
//!
//! The compiled XLA graphs generate their approximate-multiplier error
//! matrices from this exact cipher, keyed `(seed, stream)` and counted
//! by flat element index. Reimplementing it here lets the coordinator
//! *predict* (not just observe) every error field: the `fig2` histogram
//! harness, the error-model statistics and the cross-language golden
//! tests all rely on that.

/// Rotation schedule (Salmon et al., SC'11).
const ROTATIONS: [u32; 8] = [13, 15, 26, 6, 17, 29, 16, 24];
const PARITY: u32 = 0x1BD1_1BDA;

/// One Threefry-2x32 block: encrypt counter `(ctr0, ctr1)` under key
/// `(key0, key1)`. Returns the two output words.
#[inline]
pub fn threefry2x32(key0: u32, key1: u32, ctr0: u32, ctr1: u32) -> (u32, u32) {
    let k0 = key0;
    let k1 = key1;
    let k2 = k0 ^ k1 ^ PARITY;
    let ks = [k0, k1, k2];
    let mut x0 = ctr0.wrapping_add(k0);
    let mut x1 = ctr1.wrapping_add(k1);

    for block in 0..5u32 {
        for i in 0..4 {
            x0 = x0.wrapping_add(x1);
            x1 = x1.rotate_left(ROTATIONS[((block % 2) * 4 + i) as usize]);
            x1 ^= x0;
        }
        let inj = block + 1;
        x0 = x0.wrapping_add(ks[(inj % 3) as usize]);
        x1 = x1.wrapping_add(ks[((inj + 1) % 3) as usize]).wrapping_add(inj);
    }
    (x0, x1)
}

/// `u32` bits -> f32 uniform in the open interval `(0, 1)` — identical
/// constants to `prng.uniform_from_bits`.
#[inline]
pub fn uniform_from_bits(bits: u32) -> f32 {
    const INV: f32 = 2.328_306_4e-10; // 1 / 2^32, f32-rounded like numpy
    bits as f32 * INV + INV / 2.0
}

/// Standard-normal pair via Box-Muller from one Threefry block —
/// bit-identical math to `prng.normal_pair` (f32 throughout).
#[inline]
pub fn normal_pair(key0: u32, key1: u32, ctr0: u32, ctr1: u32) -> (f32, f32) {
    let (b0, b1) = threefry2x32(key0, key1, ctr0, ctr1);
    let u1 = uniform_from_bits(b0);
    let u2 = uniform_from_bits(b1);
    let r = (-2.0f32 * u1.ln()).sqrt();
    let theta = 6.283_185_3_f32 * u2;
    (r * theta.cos(), r * theta.sin())
}

/// The `counter_normal` field: standard-normal values at flat indices
/// `base..base+n` of stream `(seed, stream)` — element `i` here equals
/// element `i` of the tensor the compiled graph perturbs.
pub fn counter_normal(seed: u32, stream: u32, base: u32, n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| normal_pair(seed, stream, base.wrapping_add(i as u32), 0).0)
        .collect()
}

// ---------------------------------------------------------------------------
// Trainer seed streams
//
// The coordinator derives all of a run's per-step sub-seeds from one
// 64-bit run seed by *counter splitting*: the run seed is the Threefry
// key, the (domain, step) pair is the counter. Unlike the previous
// ad-hoc `base.wrapping_add(step)` scheme — where the error and
// dropout streams were arithmetic shifts of each other and collided
// *structurally* (stream A at step s equals stream B at step s+Δ for a
// fixed Δ) — the cipher makes the streams statistically independent:
// any residual 32-bit collision is birthday-bounded (~n²/2³² over n
// steps) instead of guaranteed.

/// Domain tag for model/optimizer initialization ("INIT").
pub const STREAM_INIT: u32 = 0x494E_4954;
/// Domain tag for the error-matrix seed stream ("ERRM").
pub const STREAM_ERR: u32 = 0x4552_524D;
/// Domain tag for the dropout seed stream ("DROP").
pub const STREAM_DROP: u32 = 0x4452_4F50;

/// Value `step` of stream `domain` under run seed `seed`: one Threefry
/// block keyed by the run seed, counted by `(domain, step)`, truncated
/// to the u32 the step ABI carries. Steps wrap at 2^32 (a run would
/// need billions of steps to notice).
pub fn counter_split(seed: u64, domain: u32, step: u64) -> u32 {
    threefry2x32(seed as u32, (seed >> 32) as u32, domain, step as u32).0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_zero() {
        // Golden vector exported from the python implementation:
        //   prng.threefry2x32(0, 0, [0], [0])
        // (validated there against jax's native threefry2x32).
        let (x0, x1) = threefry2x32(0, 0, 0, 0);
        // These values are pinned by tests/cross_lang.rs against a JSON
        // fixture generated at artifact-build time; here we only check
        // determinism and avalanche.
        assert_eq!((x0, x1), threefry2x32(0, 0, 0, 0));
        let (y0, _) = threefry2x32(0, 0, 1, 0);
        assert_ne!(x0, y0);
        // Avalanche: flipping one counter bit flips ~half the output bits.
        let flipped = (x0 ^ y0).count_ones();
        assert!((8..=24).contains(&flipped), "weak diffusion: {flipped}");
    }

    #[test]
    fn uniform_open_interval() {
        assert!(uniform_from_bits(0) > 0.0);
        assert!(uniform_from_bits(u32::MAX) <= 1.0 + 1e-6);
    }

    #[test]
    fn normal_field_stats() {
        let z = counter_normal(7, 1, 0, 100_000);
        let mean: f64 = z.iter().map(|&x| x as f64).sum::<f64>() / z.len() as f64;
        let var: f64 =
            z.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / z.len() as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var.sqrt() - 1.0).abs() < 0.01, "std {}", var.sqrt());
        // MRE/SD must be sqrt(2/pi) — the paper's Table II identity.
        let mre: f64 = z.iter().map(|&x| (x as f64).abs()).sum::<f64>() / z.len() as f64;
        assert!((mre / var.sqrt() - crate::HALF_NORMAL_MEAN).abs() < 0.01);
    }

    #[test]
    fn base_offset_slices_global_field() {
        let full = counter_normal(5, 2, 0, 128);
        let part = counter_normal(5, 2, 32, 96);
        assert_eq!(&full[32..], &part[..]);
    }

    #[test]
    fn counter_split_streams_are_disjoint() {
        // The old wrapping_add scheme collided *structurally* (the two
        // streams were shifts of each other); the cipher reduces any
        // residual overlap to 32-bit birthday odds (~n²/2³²). This pins
        // that for this fixed seed over a realistic step horizon there
        // is zero cross-stream overlap and zero within-stream repeat —
        // a deterministic regression pin, not an all-seeds guarantee.
        use std::collections::HashSet;
        let seed = 0xDEAD_BEEF_0042_u64;
        let n = 8192u64;
        let err: Vec<u32> = (0..n).map(|s| counter_split(seed, STREAM_ERR, s)).collect();
        let drop: Vec<u32> =
            (0..n).map(|s| counter_split(seed, STREAM_DROP, s)).collect();
        let err_set: HashSet<u32> = err.iter().copied().collect();
        let drop_set: HashSet<u32> = drop.iter().copied().collect();
        assert_eq!(err_set.len(), n as usize, "collision inside ERR stream");
        assert_eq!(drop_set.len(), n as usize, "collision inside DROP stream");
        assert!(
            err_set.is_disjoint(&drop_set),
            "ERR and DROP streams overlap"
        );
        // Init stream stays clear of both at step 0.
        let init = counter_split(seed, STREAM_INIT, 0);
        assert!(!err_set.contains(&init) && !drop_set.contains(&init));
    }

    #[test]
    fn counter_split_is_deterministic_and_seed_sensitive() {
        assert_eq!(counter_split(7, STREAM_ERR, 3), counter_split(7, STREAM_ERR, 3));
        assert_ne!(counter_split(7, STREAM_ERR, 3), counter_split(8, STREAM_ERR, 3));
        assert_ne!(counter_split(7, STREAM_ERR, 3), counter_split(7, STREAM_ERR, 4));
        // High seed bits matter (the old xor-fold scheme lost them).
        assert_ne!(
            counter_split(1 << 40, STREAM_ERR, 0),
            counter_split(0, STREAM_ERR, 0)
        );
    }
}
