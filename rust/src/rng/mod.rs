//! Deterministic RNG substrate.
//!
//! Two generators:
//!
//! * [`SplitMix64`] / [`Xoshiro256`] — fast sequential PRNG for data
//!   shuffling, synthetic dataset generation and property tests.
//! * [`threefry2x32`] — the *same* counter-based block cipher the Pallas
//!   kernels and the lowered graphs use (20-round Threefry-2x32). The
//!   Rust implementation is bit-compatible with the Python one, which a
//!   cross-language golden test enforces (`tests/cross_lang.rs` vs
//!   `python/tests/test_prng.py`): the coordinator can therefore
//!   reproduce any error matrix the compiled graph will generate, purely
//!   host-side (used by `fig2` and the error-model reports).

pub mod threefry;

pub use threefry::{
    counter_normal, counter_split, threefry2x32, uniform_from_bits, STREAM_DROP,
    STREAM_ERR, STREAM_INIT,
};

/// SplitMix64 — seeds Xoshiro and serves as a tiny standalone PRNG.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna) — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Xoshiro256 {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our
    /// small n; modulo bias is < 2^-32 for n < 2^32, irrelevant here —
    /// but we use the widening trick anyway).
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (one value; the pair's second half
    /// is discarded — simplicity over speed, this is not a hot path).
    pub fn next_normal(&mut self) -> f64 {
        // Avoid u1 == 0.
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xoshiro_reference_vector() {
        // First outputs for splitmix-seeded state from seed 0 — pinned so
        // any change to the generator breaks loudly (downstream
        // experiments depend on stable shuffles).
        let mut r = Xoshiro256::new(0);
        let first: Vec<u64> = (0..3).map(|_| r.next_u64()).collect();
        let mut r2 = Xoshiro256::new(0);
        let again: Vec<u64> = (0..3).map(|_| r2.next_u64()).collect();
        assert_eq!(first, again);
        assert_ne!(first[0], first[1]);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Xoshiro256::new(1);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Xoshiro256::new(2);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.next_below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(4);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
