//! Small host-side tensor type used for data batches, checkpoints and
//! marshalling to/from PJRT literals.
//!
//! This is deliberately not an ndarray clone: the coordinator only needs
//! shape-carrying contiguous buffers with a few statistics and
//! conversions. The heavy math lives in the AOT-compiled XLA graphs.

use anyhow::{bail, Result};
use std::fmt;

/// Element type of a [`Tensor`]. Mirrors the dtypes the manifest can
/// declare (the lowered graphs use nothing else).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "float32" => DType::F32,
            "int32" => DType::I32,
            "uint32" => DType::U32,
            other => bail!("unsupported dtype {other:?}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
            DType::U32 => "uint32",
        })
    }
}

/// Contiguous row-major tensor. Storage is always `f32`-width words; the
/// logical dtype tags how the bits are interpreted when marshalled.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    dtype: DType,
    /// Raw little-endian words; reinterpreted per `dtype`.
    data: Vec<u32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor<{}>{:?}", self.dtype, self.shape)
    }
}

impl Tensor {
    // -- constructors -------------------------------------------------------

    pub fn zeros(shape: &[usize], dtype: DType) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), dtype, data: vec![0u32; n] }
    }

    pub fn from_f32(shape: &[usize], values: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if values.len() != n {
            bail!("shape {:?} needs {} values, got {}", shape, n, values.len());
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            dtype: DType::F32,
            data: values.into_iter().map(f32::to_bits).collect(),
        })
    }

    pub fn from_i32(shape: &[usize], values: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if values.len() != n {
            bail!("shape {:?} needs {} values, got {}", shape, n, values.len());
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            dtype: DType::I32,
            data: values.into_iter().map(|v| v as u32).collect(),
        })
    }

    pub fn from_u32(shape: &[usize], values: Vec<u32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if values.len() != n {
            bail!("shape {:?} needs {} values, got {}", shape, n, values.len());
        }
        Ok(Tensor { shape: shape.to_vec(), dtype: DType::U32, data: values })
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor { shape: vec![], dtype: DType::F32, data: vec![v.to_bits()] }
    }

    pub fn scalar_u32(v: u32) -> Self {
        Tensor { shape: vec![], dtype: DType::U32, data: vec![v] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        Tensor { shape: vec![], dtype: DType::I32, data: vec![v as u32] }
    }

    // -- accessors ----------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Raw words (bit patterns) — used by checkpointing.
    pub fn raw(&self) -> &[u32] {
        &self.data
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        Ok(self.f32s()?.collect())
    }

    /// Zero-allocation view of the f32 elements: an exact-size iterator
    /// over the word storage, reinterpreted per element. The statistics
    /// below use this instead of [`Tensor::as_f32`], which clones the
    /// whole buffer.
    pub fn f32s(&self) -> Result<impl ExactSizeIterator<Item = f32> + Clone + '_> {
        if self.dtype != DType::F32 {
            bail!("tensor is {}, not float32", self.dtype);
        }
        Ok(self.data.iter().map(|&b| f32::from_bits(b)))
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor is {}, not int32", self.dtype);
        }
        Ok(self.data.iter().map(|&b| b as i32).collect())
    }

    pub fn as_u32(&self) -> Result<Vec<u32>> {
        if self.dtype != DType::U32 {
            bail!("tensor is {}, not uint32", self.dtype);
        }
        Ok(self.data.clone())
    }

    pub fn scalar_as_f32(&self) -> Result<f32> {
        if self.len() != 1 {
            bail!("not a scalar: shape {:?}", self.shape);
        }
        Ok(f32::from_bits(self.data[0]))
    }

    pub fn scalar_as_i32(&self) -> Result<i32> {
        if self.len() != 1 {
            bail!("not a scalar: shape {:?}", self.shape);
        }
        Ok(self.data[0] as i32)
    }

    /// Whether every f32 element is finite, checked at the bit level on
    /// the raw words (exponent all-ones ⇔ NaN/Inf) — no f32 copy, so
    /// the watchdog can scan every parameter each step. Integer tensors
    /// are trivially finite.
    pub fn all_finite(&self) -> bool {
        match self.dtype {
            DType::F32 => self.data.iter().all(|&w| (w >> 23) & 0xFF != 0xFF),
            DType::I32 | DType::U32 => true,
        }
    }

    // -- mutation -----------------------------------------------------------

    pub fn f32_mut(&mut self) -> Result<F32View<'_>> {
        if self.dtype != DType::F32 {
            bail!("tensor is {}, not float32", self.dtype);
        }
        Ok(F32View { words: &mut self.data })
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    // -- statistics (f32 only, allocation-free via `f32s`) -------------------

    pub fn mean(&self) -> Result<f64> {
        if self.is_empty() {
            bail!("mean of empty tensor");
        }
        let n = self.len() as f64;
        // detlint: allow(D3) -- sequential iterator over the flat view, fixed element order
        Ok(self.f32s()?.map(|x| x as f64).sum::<f64>() / n)
    }

    pub fn std(&self) -> Result<f64> {
        if self.is_empty() {
            bail!("std of empty tensor");
        }
        // Two passes over the view (numerically stable, still no clone).
        let n = self.len() as f64;
        let m = self.mean()?;
        // detlint: allow(D3) -- sequential iterator over the flat view, fixed element order
        let var = self.f32s()?.map(|x| (x as f64 - m).powi(2)).sum::<f64>() / n;
        Ok(var.sqrt())
    }

    pub fn abs_mean(&self) -> Result<f64> {
        if self.is_empty() {
            bail!("abs_mean of empty tensor");
        }
        let n = self.len() as f64;
        // detlint: allow(D3) -- sequential iterator over the flat view, fixed element order
        Ok(self.f32s()?.map(|x| (x as f64).abs()).sum::<f64>() / n)
    }
}

/// Mutable f32 view over a tensor's words.
pub struct F32View<'a> {
    words: &'a mut Vec<u32>,
}

impl F32View<'_> {
    pub fn set(&mut self, i: usize, v: f32) {
        self.words[i] = v.to_bits();
    }

    pub fn get(&self, i: usize) -> f32 {
        f32::from_bits(self.words[i])
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn fill_with(&mut self, mut f: impl FnMut(usize) -> f32) {
        for i in 0..self.words.len() {
            self.words[i] = f(i).to_bits();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_read() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        assert!(Tensor::from_f32(&[2, 2], vec![1.0]).is_err());
    }

    #[test]
    fn dtype_guards() {
        let t = Tensor::from_i32(&[2], vec![1, -1]).unwrap();
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), vec![1, -1]);
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(&[4], DType::F32);
        assert!(t.clone().reshape(&[2, 2]).is_ok());
        assert!(t.reshape(&[3]).is_err());
    }

    #[test]
    fn stats() {
        let t = Tensor::from_f32(&[4], vec![-1., 1., -1., 1.]).unwrap();
        assert_eq!(t.mean().unwrap(), 0.0);
        assert_eq!(t.std().unwrap(), 1.0);
        assert_eq!(t.abs_mean().unwrap(), 1.0);
    }

    #[test]
    fn f32s_view_matches_clone_path() {
        let values = vec![0.5f32, -2.0, 3.75, 0.0, -0.125];
        let t = Tensor::from_f32(&[5], values.clone()).unwrap();
        let viewed: Vec<f32> = t.f32s().unwrap().collect();
        assert_eq!(viewed, values);
        assert_eq!(t.f32s().unwrap().len(), 5);
        // Wrong dtype is rejected like `as_f32`.
        let i = Tensor::from_i32(&[1], vec![3]).unwrap();
        assert!(i.f32s().is_err());
        // Empty-tensor statistics still error cleanly.
        let e = Tensor::zeros(&[0], DType::F32);
        assert!(e.mean().is_err() && e.std().is_err() && e.abs_mean().is_err());
    }

    #[test]
    fn all_finite_bit_scan() {
        let good = Tensor::from_f32(&[3], vec![0.0, -1.5e30, f32::MIN_POSITIVE]).unwrap();
        assert!(good.all_finite());
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let t = Tensor::from_f32(&[2], vec![1.0, bad]).unwrap();
            assert!(!t.all_finite(), "{bad} not caught");
        }
        // Integer tensors are finite whatever their bits say: -1i32 has
        // the all-ones exponent pattern as a word.
        assert!(Tensor::from_i32(&[1], vec![-1]).unwrap().all_finite());
        assert!(Tensor::from_u32(&[1], vec![u32::MAX]).unwrap().all_finite());
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(Tensor::scalar_f32(0.25).scalar_as_f32().unwrap(), 0.25);
        assert_eq!(Tensor::scalar_i32(-3).scalar_as_i32().unwrap(), -3);
    }
}
