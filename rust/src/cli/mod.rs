//! Minimal CLI argument parser (the environment has no `clap`).
//!
//! Grammar: `approxmul <command> [--flag[=value] | --flag value]...
//! [positional]...`. Flags are declared up front so typos fail with a
//! helpful message instead of being silently ignored.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A declared flag.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Boolean flags take no value.
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one subcommand.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn parse_f64(&self, name: &str) -> Result<Option<f64>> {
        self.get(name)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{name}={v}")))
            .transpose()
    }

    pub fn parse_u64(&self, name: &str) -> Result<Option<u64>> {
        self.get(name)
            .map(|v| v.parse::<u64>().with_context(|| format!("--{name}={v}")))
            .transpose()
    }

    pub fn parse_usize(&self, name: &str) -> Result<Option<usize>> {
        self.get(name)
            .map(|v| v.parse::<usize>().with_context(|| format!("--{name}={v}")))
            .transpose()
    }
}

/// Parse `argv` (excluding the program/subcommand names) against specs.
pub fn parse(argv: &[String], specs: &[FlagSpec]) -> Result<Args> {
    let mut args = Args::default();
    // Seed defaults.
    for s in specs {
        if let Some(d) = s.default {
            args.values.insert(s.name.to_string(), d.to_string());
        }
    }
    let find = |name: &str| -> Result<&FlagSpec> {
        specs
            .iter()
            .find(|s| s.name == name)
            .with_context(|| format!("unknown flag --{name}"))
    };
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(raw) = a.strip_prefix("--") {
            let (name, inline) = match raw.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (raw, None),
            };
            let spec = find(name)?;
            if spec.takes_value {
                let value = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .with_context(|| format!("--{name} needs a value"))?
                            .clone()
                    }
                };
                args.values.insert(name.to_string(), value);
            } else {
                if inline.is_some() {
                    bail!("--{name} takes no value");
                }
                args.bools.insert(name.to_string(), true);
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Render a help block for a subcommand.
pub fn help(command: &str, summary: &str, specs: &[FlagSpec]) -> String {
    let mut out = format!("approxmul {command} — {summary}\n\nflags:\n");
    for s in specs {
        let arg = if s.takes_value { format!("--{} <v>", s.name) } else { format!("--{}", s.name) };
        let default = s.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        out.push_str(&format!("  {arg:<28} {}{default}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<FlagSpec> {
        vec![
            FlagSpec { name: "sigma", help: "", takes_value: true, default: Some("0.0") },
            FlagSpec { name: "fast", help: "", takes_value: false, default: None },
        ]
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positional() {
        let a = parse(&argv(&["--sigma=0.5", "pos1", "--fast", "pos2"]), &specs()).unwrap();
        assert_eq!(a.get("sigma"), Some("0.5"));
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn space_separated_value() {
        let a = parse(&argv(&["--sigma", "0.25"]), &specs()).unwrap();
        assert_eq!(a.parse_f64("sigma").unwrap(), Some(0.25));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&argv(&[]), &specs()).unwrap();
        assert_eq!(a.get("sigma"), Some("0.0"));
        assert!(!a.flag("fast"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse(&argv(&["--bogus"]), &specs()).is_err());
        assert!(parse(&argv(&["--fast=1"]), &specs()).is_err());
        assert!(parse(&argv(&["--sigma"]), &specs()).is_err());
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&argv(&["--sigma", "abc"]), &specs()).unwrap();
        assert!(a.parse_f64("sigma").is_err());
    }
}
