//! `artifacts/manifest.json` — the ABI contract with the AOT build.
//!
//! The manifest pins, for every lowered entry point, the positional
//! input/output tensor list (name, shape, dtype), the model's parameter
//! and BN-state layout, and the per-layer MAC table the cost model uses.
//! Everything the coordinator knows about the compiled graphs comes from
//! here; shape or order drift between Python and Rust fails loudly at
//! load time rather than as silent numerical garbage.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::Value;
use crate::tensor::DType;

/// One positional input/output of a compiled entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Value) -> Result<Self> {
        Ok(IoSpec {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v
                .get("shape")?
                .as_array()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: DType::parse(v.get("dtype")?.as_str()?)?,
        })
    }
}

/// One lowered entry point (train / eval / init).
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl EntrySpec {
    fn parse(v: &Value) -> Result<Self> {
        Ok(EntrySpec {
            file: v.get("file")?.as_str()?.to_string(),
            sha256: v.get("sha256")?.as_str()?.to_string(),
            inputs: v
                .get("inputs")?
                .as_array()?
                .iter()
                .map(IoSpec::parse)
                .collect::<Result<_>>()?,
            outputs: v
                .get("outputs")?
                .as_array()?
                .iter()
                .map(IoSpec::parse)
                .collect::<Result<_>>()?,
        })
    }
}

/// A model parameter or BN-state tensor in manifest order.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// `conv_w` / `dense_w` / `bias` / `bn_gamma` / `bn_beta` ("state"
    /// for BN running stats).
    pub kind: String,
    /// Error-stream id for weight tensors, -1 otherwise.
    pub layer: i64,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Figure-1 layer-table row (drives the cost model + `arch` report).
#[derive(Debug, Clone)]
pub struct LayerRow {
    pub name: String,
    pub ty: String,
    pub out: Vec<usize>,
    pub params: u64,
    pub macs: u64,
}

/// One preset's manifest entry.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub preset: String,
    pub inject: String,
    pub batch: usize,
    pub eval_batch: usize,
    pub input_hw: usize,
    pub in_ch: usize,
    pub num_classes: usize,
    pub total_params: u64,
    pub params: Vec<TensorSpec>,
    pub state: Vec<TensorSpec>,
    pub layers: Vec<LayerRow>,
    pub entries: BTreeMap<String, EntrySpec>,
}

impl ModelManifest {
    pub fn entry(&self, kind: &str) -> Result<&EntrySpec> {
        self.entries.get(kind).with_context(|| {
            format!("preset {:?} has no lowered {kind:?} entry", self.preset)
        })
    }

    /// Total MACs of one forward pass for one sample.
    pub fn forward_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// MACs in conv layers only (the 90.7% share of [12]).
    pub fn conv_macs(&self) -> u64 {
        self.layers
            .iter()
            .filter(|l| l.ty.starts_with("conv"))
            .map(|l| l.macs)
            .sum()
    }
}

/// Paper reference data embedded in the manifest (single source of truth
/// shared with Python).
#[derive(Debug, Clone)]
pub struct PaperData {
    /// (test_id, mre, sd, accuracy_pct)
    pub table2: Vec<(u32, f64, f64, f64)>,
    /// (test_id, mre, approx_epochs, exact_epochs)
    pub table3: Vec<(u32, f64, u32, u32)>,
    /// name -> (speed_gain, area_saving, power_saving, mre, sd)
    pub hw_designs: BTreeMap<String, (f64, f64, f64, f64, f64)>,
    pub conv_time_share: f64,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub paper: PaperData,
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let root = Value::parse_file(dir.join("manifest.json"))?;
        if root.get("format")?.as_i64()? != 1 {
            bail!("unknown manifest format");
        }

        let paper = root.get("paper")?;
        let table2 = paper
            .get("table2")?
            .as_array()?
            .iter()
            .map(|r| {
                let r = r.as_array()?;
                Ok((
                    r[0].as_usize()? as u32,
                    r[1].as_f64()?,
                    r[2].as_f64()?,
                    r[3].as_f64()?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let table3 = paper
            .get("table3")?
            .as_array()?
            .iter()
            .map(|r| {
                let r = r.as_array()?;
                Ok((
                    r[0].as_usize()? as u32,
                    r[1].as_f64()?,
                    r[2].as_usize()? as u32,
                    r[3].as_usize()? as u32,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let mut hw_designs = BTreeMap::new();
        for (name, v) in paper.get("hw_designs")?.as_object()? {
            let a = v.as_array()?;
            hw_designs.insert(
                name.clone(),
                (
                    a[0].as_f64()?,
                    a[1].as_f64()?,
                    a[2].as_f64()?,
                    a[3].as_f64()?,
                    a[4].as_f64()?,
                ),
            );
        }
        let paper = PaperData {
            table2,
            table3,
            hw_designs,
            conv_time_share: paper.get("conv_time_share")?.as_f64()?,
        };

        let mut models = BTreeMap::new();
        for (name, m) in root.get("models")?.as_object()? {
            models.insert(name.clone(), Self::parse_model(m)?);
        }
        let manifest = Manifest { dir, paper, models };
        manifest.validate()?;
        Ok(manifest)
    }

    fn parse_model(m: &Value) -> Result<ModelManifest> {
        let tensor_specs = |key: &str, default_kind: &str| -> Result<Vec<TensorSpec>> {
            m.get(key)?
                .as_array()?
                .iter()
                .map(|p| {
                    Ok(TensorSpec {
                        name: p.get("name")?.as_str()?.to_string(),
                        shape: p
                            .get("shape")?
                            .as_array()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<_>>()?,
                        kind: p
                            .opt("kind")
                            .map(|k| k.as_str().map(str::to_string))
                            .transpose()?
                            .unwrap_or_else(|| default_kind.to_string()),
                        layer: p.opt("layer").map(|l| l.as_i64()).transpose()?.unwrap_or(-1),
                    })
                })
                .collect()
        };
        let layers = m
            .get("layers")?
            .as_array()?
            .iter()
            .map(|l| {
                Ok(LayerRow {
                    name: l.get("name")?.as_str()?.to_string(),
                    ty: l.get("type")?.as_str()?.to_string(),
                    out: l
                        .get("out")?
                        .as_array()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<_>>()?,
                    params: l.get("params")?.as_i64()? as u64,
                    macs: l.get("macs")?.as_i64()? as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut entries = BTreeMap::new();
        for (kind, e) in m.get("entries")?.as_object()? {
            entries.insert(kind.clone(), EntrySpec::parse(e)?);
        }
        Ok(ModelManifest {
            preset: m.get("preset")?.as_str()?.to_string(),
            inject: m.get("inject")?.as_str()?.to_string(),
            batch: m.get("batch")?.as_usize()?,
            eval_batch: m.get("eval_batch")?.as_usize()?,
            input_hw: m.get("input_hw")?.as_usize()?,
            in_ch: m.get("in_ch")?.as_usize()?,
            num_classes: m.get("num_classes")?.as_usize()?,
            total_params: m.get("total_params")?.as_i64()? as u64,
            params: tensor_specs("params", "param")?,
            state: tensor_specs("state", "state")?,
            layers,
            entries,
        })
    }

    /// Structural invariants every loaded manifest must satisfy.
    fn validate(&self) -> Result<()> {
        for (name, m) in &self.models {
            let declared: u64 = m.params.iter().map(|p| p.element_count() as u64).sum();
            if declared != m.total_params {
                bail!("{name}: total_params {} != declared {declared}", m.total_params);
            }
            for (kind, e) in &m.entries {
                let path = self.dir.join(&e.file);
                if !path.exists() {
                    bail!("{name}/{kind}: missing artifact {}", path.display());
                }
                if kind == "train" {
                    let expect = 2 * m.params.len() + m.state.len() + 6;
                    if e.inputs.len() != expect {
                        bail!(
                            "{name}/train: {} inputs, expected {expect}",
                            e.inputs.len()
                        );
                    }
                    // Threading symmetry: output i mirrors input i for the
                    // params/state/opt prefix.
                    let n = 2 * m.params.len() + m.state.len();
                    for i in 0..n {
                        if e.inputs[i].shape != e.outputs[i].shape {
                            bail!("{name}/train: io shape mismatch at {i}");
                        }
                    }
                }
            }
        }
        Ok(())
    }

    pub fn model(&self, preset: &str) -> Result<&ModelManifest> {
        self.models
            .get(preset)
            .with_context(|| format!("unknown preset {preset:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(dir).unwrap();
        assert!(m.models.contains_key("tiny"));
        assert_eq!(m.paper.table2.len(), 9);
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.num_classes, 10);
        assert!(tiny.entry("train").is_ok());
        assert!(tiny.entry("nope").is_err());
        assert!(tiny.forward_macs() > 0);
    }

    #[test]
    fn vgg16_conv_dominates() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(dir).unwrap();
        let vgg = m.model("vgg16").unwrap();
        let share = vgg.conv_macs() as f64 / vgg.forward_macs() as f64;
        assert!(share > 0.9, "conv share {share}");
    }
}
