//! Execution runtime: pluggable backends behind one training session.
//!
//! [`session::TrainSession`] owns the model/optimizer/BN state and the
//! per-step knob ABI; *how* a step executes is a [`backend::Backend`]:
//!
//! * [`PjrtBackend`] — the compiled-artifact path. The contract with
//!   the Python build step is `artifacts/manifest.json` ([`manifest`])
//!   plus one HLO **text** file per entry point (text, not serialized
//!   proto — see `python/compile/aot.py` for why). [`Engine`] owns the
//!   PJRT CPU client and a compile cache.
//! * [`NativeBackend`] — pure-Rust forward/backward over the
//!   bit-accurate multiplier engine ([`crate::mult`]); needs no
//!   artifacts and trains real designs (`drum6`, `lut12:drum6`, ...)
//!   end to end on stock hardware.

pub mod backend;
pub mod engine;
pub mod integrity;
pub mod manifest;
pub mod native;
pub mod pjrt_backend;
pub mod session;

pub use backend::{Backend, BackendModel, EvalPass};
pub use engine::{Engine, Executable};
pub use manifest::{EntrySpec, IoSpec, LayerRow, Manifest, ModelManifest, TensorSpec};
pub use native::{NativeBackend, NativeConfig};
pub use pjrt_backend::PjrtBackend;
pub use session::{EvalOnlySession, NonFiniteLoss, TrainSession};

use crate::tensor::{DType, Tensor};
use anyhow::{bail, Context, Result};

/// Host tensor -> PJRT literal.
///
/// Perf note (EXPERIMENTS.md §Perf): built with
/// `create_from_shape_and_untyped_data` — a single memcpy of the
/// tensor's raw words — instead of the naive
/// `as_f32() -> vec1 -> reshape` chain, which costs three full copies
/// per tensor per step. All three supported dtypes are 4-byte words,
/// so the raw `u32` storage is the wire format for each of them.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let ty = match t.dtype() {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
        DType::U32 => xla::ElementType::U32,
    };
    let words = t.raw();
    // SAFETY: `words` is a live `&[u32]`, so the pointer is valid for
    // `words.len() * 4` bytes, `u8` has no alignment requirement, and the
    // byte view cannot outlive the borrow it was derived from.
    let bytes = unsafe {
        std::slice::from_raw_parts(words.as_ptr() as *const u8, words.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, t.shape(), bytes)
        .context("creating literal from raw tensor data")
}

/// PJRT literal -> host tensor (dtype from the literal's element type).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().context("literal shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            Tensor::from_f32(&dims, lit.to_vec::<f32>().context("f32 read")?)
        }
        xla::ElementType::S32 => {
            Tensor::from_i32(&dims, lit.to_vec::<i32>().context("i32 read")?)
        }
        xla::ElementType::U32 => {
            Tensor::from_u32(&dims, lit.to_vec::<u32>().context("u32 read")?)
        }
        xla::ElementType::Pred => {
            // Predicates surface from eval comparisons; widen to i32.
            let v = lit.to_vec::<u8>().context("pred read")?;
            Tensor::from_i32(&dims, v.into_iter().map(|b| b as i32).collect())
        }
        other => bail!("unsupported literal element type {other:?}"),
    }
}
