//! Artifact integrity: verify every HLO file on disk against the
//! sha256 the AOT build recorded in the manifest. Catches stale or
//! hand-edited artifacts before they produce silently-wrong numerics
//! (`approxmul validate`).

use anyhow::{Context, Result};
use sha2::{Digest, Sha256};

use super::manifest::Manifest;

/// Outcome for one artifact file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FileStatus {
    Ok,
    Mismatch { expected: String, actual: String },
    Missing,
}

/// One row of a validation report.
#[derive(Debug, Clone)]
pub struct FileReport {
    pub preset: String,
    pub kind: String,
    pub file: String,
    pub status: FileStatus,
}

/// Hash every artifact referenced by the manifest.
pub fn validate(manifest: &Manifest) -> Result<Vec<FileReport>> {
    let mut out = Vec::new();
    for (preset, model) in &manifest.models {
        for (kind, entry) in &model.entries {
            let path = manifest.dir.join(&entry.file);
            let status = if !path.exists() {
                FileStatus::Missing
            } else {
                let bytes = std::fs::read(&path)
                    .with_context(|| format!("reading {}", path.display()))?;
                let actual = hex(&Sha256::digest(&bytes));
                if actual == entry.sha256 {
                    FileStatus::Ok
                } else {
                    FileStatus::Mismatch { expected: entry.sha256.clone(), actual }
                }
            };
            out.push(FileReport {
                preset: preset.clone(),
                kind: kind.clone(),
                file: entry.file.clone(),
                status,
            });
        }
    }
    Ok(out)
}

/// True iff every artifact verified.
pub fn all_ok(reports: &[FileReport]) -> bool {
    reports.iter().all(|r| r.status == FileStatus::Ok)
}

fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_encodes() {
        assert_eq!(hex(&[0x00, 0xff, 0x0a]), "00ff0a");
    }

    #[test]
    fn sha256_known_answer() {
        // sha256("abc")
        assert_eq!(
            hex(&Sha256::digest(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn validates_real_artifacts() {
        let dir = std::path::Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let manifest = Manifest::load(dir).unwrap();
        let reports = validate(&manifest).unwrap();
        assert!(!reports.is_empty());
        assert!(all_ok(&reports), "{reports:?}");
    }

    #[test]
    fn detects_tampering() {
        let dir = std::path::Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let tmp = std::env::temp_dir().join(format!("axm-int-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        for e in std::fs::read_dir(dir).unwrap() {
            let e = e.unwrap();
            if e.file_name() != ".stamp" {
                std::fs::copy(e.path(), tmp.join(e.file_name())).unwrap();
            }
        }
        // Append a byte to one artifact.
        let victim = tmp.join("train_tiny.hlo.txt");
        let mut bytes = std::fs::read(&victim).unwrap();
        bytes.push(b'\n');
        std::fs::write(&victim, bytes).unwrap();
        let manifest = Manifest::load(&tmp).unwrap();
        let reports = validate(&manifest).unwrap();
        assert!(!all_ok(&reports));
        assert!(reports.iter().any(|r| matches!(
            r.status,
            FileStatus::Mismatch { .. }
        ) && r.file == "train_tiny.hlo.txt"));
        std::fs::remove_dir_all(&tmp).ok();
    }
}
