//! [`PjrtBackend`]: the compiled-artifact execution path, extracted
//! as-is from the pre-refactor `TrainSession` behind the [`Backend`]
//! trait.
//!
//! Executables are `Arc`-held, so the backend is self-contained after
//! construction; keep the [`Engine`] alive for the life of the backend
//! all the same — the executables reference its PJRT client.

use anyhow::{Context, Result};

use crate::tensor::Tensor;

use super::backend::{Backend, BackendModel};
use super::engine::{Engine, Executable};
use super::session::{EvalStats, StepInputs, StepStats};

/// Compiled train/eval/init entry points for one preset.
pub struct PjrtBackend {
    model: BackendModel,
    train: Executable,
    eval: Executable,
    init: Executable,
}

impl PjrtBackend {
    /// Load (compiling on first use) the preset's three entry points.
    pub fn new(engine: &Engine, preset: &str) -> Result<Self> {
        let m = engine.manifest().model(preset)?;
        Ok(PjrtBackend {
            model: BackendModel::from_manifest(m),
            train: engine.load(preset, "train")?,
            eval: engine.load(preset, "eval")?,
            init: engine.load(preset, "init")?,
        })
    }
}

impl Backend for PjrtBackend {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn model(&self) -> &BackendModel {
        &self.model
    }

    fn init(&self, seed: u32) -> Result<Vec<Tensor>> {
        self.init.run(&[Tensor::scalar_u32(seed)])
    }

    fn train_step(
        &self,
        tensors: &[Tensor],
        x: &Tensor,
        y: &Tensor,
        k: StepInputs,
    ) -> Result<(Vec<Tensor>, StepStats)> {
        // Scalars live on the stack; state tensors are passed by
        // reference — no per-step copy of the model state on the host
        // side (EXPERIMENTS.md §Perf). The graphs encode the hybrid
        // approximate/exact switch purely through sigma, so `k.approx`
        // carries no extra information here.
        let scalars = [
            Tensor::scalar_u32(k.seed_err),
            Tensor::scalar_u32(k.seed_drop),
            Tensor::scalar_f32(if k.approx { k.sigma } else { 0.0 }),
            Tensor::scalar_f32(k.lr),
        ];
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(tensors.len() + 6);
        inputs.extend(tensors.iter());
        inputs.push(x);
        inputs.push(y);
        inputs.extend(scalars.iter());

        let mut outputs = self.train.run_refs(&inputs).context("train step")?;
        let acc = outputs.pop().expect("acc output").scalar_as_f32()?;
        let loss = outputs.pop().expect("loss output").scalar_as_f32()?;
        Ok((outputs, StepStats { loss, accuracy: acc }))
    }

    fn eval_batch(
        &self,
        params_state: &[Tensor],
        x: &Tensor,
        y: &Tensor,
    ) -> Result<EvalStats> {
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(params_state.len() + 2);
        inputs.extend(params_state.iter());
        inputs.push(x);
        inputs.push(y);
        let outputs = self.eval.run_refs(&inputs).context("eval step")?;
        Ok(EvalStats {
            loss_sum: outputs[0].scalar_as_f32()?,
            correct: outputs[1].scalar_as_i32()? as i64,
            total: self.model.eval_batch,
        })
    }
}
