//! The execution-backend abstraction behind [`super::TrainSession`].
//!
//! A [`Backend`] owns *how* a train/eval/init step is computed; the
//! session owns the state tensors and the step loop. Two
//! implementations ship:
//!
//! * [`super::PjrtBackend`] — the original path: AOT-lowered XLA graphs
//!   executed through PJRT (needs `make artifacts` + a real `xla`
//!   crate).
//! * [`super::NativeBackend`] — pure-Rust forward/backward for the CNN
//!   presets in which every GEMM routes through
//!   [`crate::mult::approx_matmul`], so bit-accurate multiplier designs
//!   (DRUM, Mitchell, LUT backends, ...) train real networks on stock
//!   CPU hardware with no PJRT at all.
//!
//! [`BackendModel`] is the backend-agnostic model description the
//! session and coordinator need (batch sizes, input geometry, the
//! params/state tensor layout): the PJRT backend reads it from the
//! artifact manifest, the native backend derives it from its built-in
//! preset table — same names, shapes and order, so checkpoints are
//! interchangeable.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::manifest::{ModelManifest, TensorSpec};
use super::session::{EvalStats, StepInputs, StepStats};

/// Backend-agnostic model description (the manifest contract, minus
/// PJRT entry points).
#[derive(Debug, Clone)]
pub struct BackendModel {
    pub preset: String,
    pub batch: usize,
    pub eval_batch: usize,
    pub input_hw: usize,
    pub in_ch: usize,
    pub num_classes: usize,
    /// Parameter tensors in threading order.
    pub params: Vec<TensorSpec>,
    /// BN running-stat tensors in threading order.
    pub state: Vec<TensorSpec>,
}

impl BackendModel {
    pub fn from_manifest(m: &ModelManifest) -> Self {
        BackendModel {
            preset: m.preset.clone(),
            batch: m.batch,
            eval_batch: m.eval_batch,
            input_hw: m.input_hw,
            in_ch: m.in_ch,
            num_classes: m.num_classes,
            params: m.params.clone(),
            state: m.state.clone(),
        }
    }

    /// Total state-vector length: params ++ state ++ opt.
    pub fn n_tensors(&self) -> usize {
        2 * self.params.len() + self.state.len()
    }

    /// Elements of one training input batch (`[batch, hw, hw, c]`).
    pub fn input_elems(&self) -> usize {
        self.batch * self.input_hw * self.input_hw * self.in_ch
    }

    /// Elements of one eval input batch.
    pub fn eval_input_elems(&self) -> usize {
        self.eval_batch * self.input_hw * self.input_hw * self.in_ch
    }

    /// Checkpoint tensor names in threading order
    /// (`param:` / `state:` / `opt:` prefixed).
    pub fn tensor_names(&self) -> Vec<String> {
        self.params
            .iter()
            .map(|p| format!("param:{}", p.name))
            .chain(self.state.iter().map(|s| format!("state:{}", s.name)))
            .chain(self.params.iter().map(|p| format!("opt:{}", p.name)))
            .collect()
    }

    /// Validate a params++state++opt vector against the declared layout.
    pub fn validate_tensors(&self, tensors: &[Tensor]) -> Result<()> {
        if tensors.len() != self.n_tensors() {
            bail!(
                "{}: state vector has {} tensors, expected {}",
                self.preset,
                tensors.len(),
                self.n_tensors()
            );
        }
        for (t, spec) in tensors.iter().zip(
            self.params.iter().chain(self.state.iter()).chain(self.params.iter()),
        ) {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "{}: tensor {} shape {:?} != manifest {:?}",
                    self.preset,
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        Ok(())
    }
}

/// One execution backend bound to one model preset.
pub trait Backend: Send + Sync {
    /// Short backend id: `"pjrt"` or `"native"`.
    fn kind(&self) -> &'static str;

    /// The model this backend executes.
    fn model(&self) -> &BackendModel;

    /// Freshly initialized state tensors (params ++ state ++ opt) for
    /// `seed` — deterministic in the seed.
    fn init(&self, seed: u32) -> Result<Vec<Tensor>>;

    /// One SGD step: consumes the current state vector, returns the
    /// next one plus step statistics. `x` is `[batch, hw, hw, c]` f32,
    /// `y` `[batch]` i32.
    fn train_step(
        &self,
        tensors: &[Tensor],
        x: &Tensor,
        y: &Tensor,
        k: StepInputs,
    ) -> Result<(Vec<Tensor>, StepStats)>;

    /// Evaluate one batch with exact multipliers (the paper's test
    /// protocol). `params_state` is the params ++ state prefix of the
    /// state vector.
    fn eval_batch(
        &self,
        params_state: &[Tensor],
        x: &Tensor,
        y: &Tensor,
    ) -> Result<EvalStats>;
}
