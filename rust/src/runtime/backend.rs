//! The execution-backend abstraction behind [`super::TrainSession`].
//!
//! A [`Backend`] owns *how* a train/eval/init step is computed; the
//! session owns the state tensors and the step loop. Two
//! implementations ship:
//!
//! * [`super::PjrtBackend`] — the original path: AOT-lowered XLA graphs
//!   executed through PJRT (needs `make artifacts` + a real `xla`
//!   crate).
//! * [`super::NativeBackend`] — pure-Rust forward/backward for the CNN
//!   presets in which every GEMM routes through
//!   [`crate::mult::approx_matmul`], so bit-accurate multiplier designs
//!   (DRUM, Mitchell, LUT backends, ...) train real networks on stock
//!   CPU hardware with no PJRT at all.
//!
//! [`BackendModel`] is the backend-agnostic model description the
//! session and coordinator need (batch sizes, input geometry, the
//! params/state tensor layout): the PJRT backend reads it from the
//! artifact manifest, the native backend derives it from its built-in
//! preset table — same names, shapes and order, so checkpoints are
//! interchangeable.

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::testkit::faults::FaultPlan;

use super::manifest::{ModelManifest, TensorSpec};
use super::session::{EvalStats, StepInputs, StepStats};

/// Backend-agnostic model description (the manifest contract, minus
/// PJRT entry points).
#[derive(Debug, Clone)]
pub struct BackendModel {
    pub preset: String,
    pub batch: usize,
    pub eval_batch: usize,
    pub input_hw: usize,
    pub in_ch: usize,
    pub num_classes: usize,
    /// Parameter tensors in threading order.
    pub params: Vec<TensorSpec>,
    /// BN running-stat tensors in threading order.
    pub state: Vec<TensorSpec>,
}

impl BackendModel {
    pub fn from_manifest(m: &ModelManifest) -> Self {
        BackendModel {
            preset: m.preset.clone(),
            batch: m.batch,
            eval_batch: m.eval_batch,
            input_hw: m.input_hw,
            in_ch: m.in_ch,
            num_classes: m.num_classes,
            params: m.params.clone(),
            state: m.state.clone(),
        }
    }

    /// Total state-vector length: params ++ state ++ opt.
    pub fn n_tensors(&self) -> usize {
        2 * self.params.len() + self.state.len()
    }

    /// Elements of one training input batch (`[batch, hw, hw, c]`).
    pub fn input_elems(&self) -> usize {
        self.batch * self.input_hw * self.input_hw * self.in_ch
    }

    /// Elements of one eval input batch.
    pub fn eval_input_elems(&self) -> usize {
        self.eval_batch * self.input_hw * self.input_hw * self.in_ch
    }

    /// Number of whole examples in a dynamic-batch input of `len`
    /// elements; errors on empty or ragged inputs. The one definition
    /// of "a valid dynamic batch" shared by the session's train/eval
    /// validation and the native backend's batch derivation.
    pub fn examples_of(&self, len: usize) -> Result<usize> {
        let per = self.input_hw * self.input_hw * self.in_ch;
        if len == 0 || len % per != 0 {
            bail!(
                "{}: input has {len} elements, not a whole (non-zero) number \
                 of {per}-element examples",
                self.preset
            );
        }
        Ok(len / per)
    }

    /// [`BackendModel::examples_of`] plus an upper bound: dynamic-batch
    /// backends accept short batches but never more than the declared
    /// batch capacity (`max_elems` = train or eval input elements).
    pub fn check_dynamic_len(&self, len: usize, max_elems: usize) -> Result<()> {
        self.examples_of(len)?;
        if len > max_elems {
            bail!(
                "{}: input has {len} elements, more than the declared \
                 maximum {max_elems}",
                self.preset
            );
        }
        Ok(())
    }

    /// Checkpoint tensor names in threading order
    /// (`param:` / `state:` / `opt:` prefixed).
    pub fn tensor_names(&self) -> Vec<String> {
        self.params
            .iter()
            .map(|p| format!("param:{}", p.name))
            .chain(self.state.iter().map(|s| format!("state:{}", s.name)))
            .chain(self.params.iter().map(|p| format!("opt:{}", p.name)))
            .collect()
    }

    /// Validate a params++state++opt vector against the declared layout.
    pub fn validate_tensors(&self, tensors: &[Tensor]) -> Result<()> {
        if tensors.len() != self.n_tensors() {
            bail!(
                "{}: state vector has {} tensors, expected {}",
                self.preset,
                tensors.len(),
                self.n_tensors()
            );
        }
        for (t, spec) in tensors.iter().zip(
            self.params.iter().chain(self.state.iter()).chain(self.params.iter()),
        ) {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "{}: tensor {} shape {:?} != manifest {:?}",
                    self.preset,
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        Ok(())
    }

    /// Validate an *evaluation* state vector — params++state with the
    /// optimizer tail either absent (an eval-only restore) or present
    /// (a full training checkpoint, whose tail the caller may drop).
    /// Returns the params++state prefix length on success.
    pub fn validate_eval_tensors(&self, tensors: &[Tensor]) -> Result<usize> {
        let eval_len = self.params.len() + self.state.len();
        if tensors.len() != eval_len && tensors.len() != self.n_tensors() {
            bail!(
                "{}: state vector has {} tensors, expected {eval_len} \
                 (params++state) or {} (params++state++opt)",
                self.preset,
                tensors.len(),
                self.n_tensors()
            );
        }
        for (t, spec) in tensors
            .iter()
            .take(eval_len)
            .zip(self.params.iter().chain(self.state.iter()))
        {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "{}: tensor {} shape {:?} != manifest {:?}",
                    self.preset,
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        Ok(eval_len)
    }
}

/// An evaluation pass at fixed parameters: per-pass setup (e.g. the
/// native backend's one-time weight-plane decomposition) is amortized
/// across all batches evaluated through it.
pub trait EvalPass {
    /// Evaluate one batch with exact multipliers. Backends without a
    /// static batch shape accept a short final batch.
    fn eval_batch(&self, x: &Tensor, y: &Tensor) -> Result<EvalStats>;
}

/// One execution backend bound to one model preset.
pub trait Backend: Send + Sync {
    /// Short backend id: `"pjrt"` or `"native"`.
    fn kind(&self) -> &'static str;

    /// Whether [`Backend::train_step`]/[`Backend::eval_batch`] accept
    /// batches smaller than the model's declared batch sizes. Compiled
    /// static-shape graphs cannot; the native backend can.
    fn supports_dynamic_batch(&self) -> bool {
        false
    }

    /// Start an amortized evaluation pass over `params_state` (the
    /// params ++ state prefix of the state vector). `None` means the
    /// backend has no per-pass setup worth amortizing — the caller
    /// falls back to [`Backend::eval_batch`] per batch.
    fn eval_pass<'a>(
        &'a self,
        _params_state: &'a [Tensor],
    ) -> Result<Option<Box<dyn EvalPass + 'a>>> {
        Ok(None)
    }

    /// The model this backend executes.
    fn model(&self) -> &BackendModel;

    /// Arm a deterministic training-path fault
    /// ([`crate::testkit::faults`]). Backends without injection hooks
    /// refuse loudly — a fault plan that silently does nothing would
    /// turn a recovery test into a false pass.
    fn set_fault_plan(&mut self, _plan: FaultPlan) -> Result<()> {
        bail!("{} backend has no fault-injection hooks", self.kind())
    }

    /// Freshly initialized state tensors (params ++ state ++ opt) for
    /// `seed` — deterministic in the seed.
    fn init(&self, seed: u32) -> Result<Vec<Tensor>>;

    /// One SGD step: consumes the current state vector, returns the
    /// next one plus step statistics. `x` is `[batch, hw, hw, c]` f32,
    /// `y` `[batch]` i32.
    fn train_step(
        &self,
        tensors: &[Tensor],
        x: &Tensor,
        y: &Tensor,
        k: StepInputs,
    ) -> Result<(Vec<Tensor>, StepStats)>;

    /// Evaluate one batch with exact multipliers (the paper's test
    /// protocol). `params_state` is the params ++ state prefix of the
    /// state vector.
    fn eval_batch(
        &self,
        params_state: &[Tensor],
        x: &Tensor,
        y: &Tensor,
    ) -> Result<EvalStats>;
}
