//! Layer kernels for the native CNN backend: im2col/col2im (3x3 SAME
//! convolution as a GEMM), batch normalization, 2x2 max pooling,
//! Threefry-counter dropout and softmax cross-entropy — forward *and*
//! backward, all in plain f32 on NHWC data.
//!
//! These mirror `python/compile/model.py` layer for layer (same patch
//! ordering, same BN axes, same dropout stream construction) so the
//! native backend trains the same network the lowered graphs do. None
//! of these kernels multiplies matrices: every GEMM in the backend goes
//! through `mult::approx_matmul` / `_tn` / `_nt`, keeping the
//! approximate-multiplier contract in exactly one place.

use crate::rng::threefry::{threefry2x32, uniform_from_bits};

/// NHWC `[n, hw, hw, c]` -> SAME-padded 3x3 patch matrix
/// `[n*hw*hw, 9c]`, patch features ordered `(dy, dx, channel)` to match
/// the `[3, 3, cin, cout]` weight layout flattened to `[9*cin, cout]`.
pub(crate) fn im2col(x: &[f32], n: usize, hw: usize, c: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * hw * hw * c);
    let row_len = 9 * c;
    let mut out = vec![0f32; n * hw * hw * row_len];
    for img in 0..n {
        for y in 0..hw {
            for xx in 0..hw {
                let base = ((img * hw + y) * hw + xx) * row_len;
                let mut f = 0usize;
                for dy in 0..3usize {
                    let sy = y as isize + dy as isize - 1;
                    for dx in 0..3usize {
                        let sx = xx as isize + dx as isize - 1;
                        if sy >= 0
                            && (sy as usize) < hw
                            && sx >= 0
                            && (sx as usize) < hw
                        {
                            let src =
                                ((img * hw + sy as usize) * hw + sx as usize) * c;
                            out[base + f..base + f + c]
                                .copy_from_slice(&x[src..src + c]);
                        }
                        f += c;
                    }
                }
            }
        }
    }
    out
}

/// Adjoint of [`im2col`]: scatter-add patch gradients `[n*hw*hw, 9c]`
/// back onto the input image gradient `[n, hw, hw, c]`. Accumulation
/// order is input-derived and sequential — deterministic.
pub(crate) fn col2im(dp: &[f32], n: usize, hw: usize, c: usize) -> Vec<f32> {
    let row_len = 9 * c;
    debug_assert_eq!(dp.len(), n * hw * hw * row_len);
    let mut dx = vec![0f32; n * hw * hw * c];
    for img in 0..n {
        for y in 0..hw {
            for xx in 0..hw {
                let base = ((img * hw + y) * hw + xx) * row_len;
                let mut f = 0usize;
                for dy in 0..3usize {
                    let sy = y as isize + dy as isize - 1;
                    for dx2 in 0..3usize {
                        let sx = xx as isize + dx2 as isize - 1;
                        if sy >= 0
                            && (sy as usize) < hw
                            && sx >= 0
                            && (sx as usize) < hw
                        {
                            let dst =
                                ((img * hw + sy as usize) * hw + sx as usize) * c;
                            for ch in 0..c {
                                dx[dst + ch] += dp[base + f + ch];
                            }
                        }
                        f += c;
                    }
                }
            }
        }
    }
    dx
}

/// Saved forward quantities the BN backward needs.
pub(crate) struct BnCache {
    /// Normalized activations (pre gamma/beta).
    pub xn: Vec<f32>,
    /// Per-channel `1/sqrt(var + eps)`.
    pub inv_std: Vec<f32>,
    /// Per-channel batch mean.
    pub mean: Vec<f32>,
    /// Per-channel biased batch variance.
    pub var: Vec<f32>,
}

/// Train-mode batch norm over `[rows, ch]` (channels innermost: conv
/// activations flattened over N*H*W rows, dense over N rows).
pub(crate) fn bn_train(
    x: &[f32],
    rows: usize,
    ch: usize,
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
) -> (Vec<f32>, BnCache) {
    debug_assert_eq!(x.len(), rows * ch);
    let m = rows as f32;
    let mut mean = vec![0f32; ch];
    for r in 0..rows {
        for c in 0..ch {
            mean[c] += x[r * ch + c];
        }
    }
    for v in mean.iter_mut() {
        *v /= m;
    }
    bn_train_with_mean(x, rows, ch, mean, gamma, beta, eps)
}

/// [`bn_train`] with the per-channel batch mean supplied by the caller
/// — the fused-GEMM path computes the mean as a per-row-block epilogue
/// of the convolution/dense GEMM (merged in input-derived block order), so the
/// mean pass over the full activation tensor is skipped here.
pub(crate) fn bn_train_with_mean(
    x: &[f32],
    rows: usize,
    ch: usize,
    mean: Vec<f32>,
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
) -> (Vec<f32>, BnCache) {
    debug_assert_eq!(x.len(), rows * ch);
    debug_assert_eq!(mean.len(), ch);
    let m = rows as f32;
    let mut var = vec![0f32; ch];
    for r in 0..rows {
        for c in 0..ch {
            let d = x[r * ch + c] - mean[c];
            var[c] += d * d;
        }
    }
    for v in var.iter_mut() {
        *v /= m;
    }
    let inv_std: Vec<f32> = var.iter().map(|&v| 1.0 / (v + eps).sqrt()).collect();
    let mut xn = vec![0f32; x.len()];
    let mut out = vec![0f32; x.len()];
    for r in 0..rows {
        for c in 0..ch {
            let i = r * ch + c;
            let z = (x[i] - mean[c]) * inv_std[c];
            xn[i] = z;
            out[i] = gamma[c] * z + beta[c];
        }
    }
    (out, BnCache { xn, inv_std, mean, var })
}

/// BN backward: returns `(dx, dgamma, dbeta)`.
pub(crate) fn bn_train_back(
    dy: &[f32],
    cache: &BnCache,
    gamma: &[f32],
    rows: usize,
    ch: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let m = rows as f32;
    let mut dgamma = vec![0f32; ch];
    let mut dbeta = vec![0f32; ch];
    for r in 0..rows {
        for c in 0..ch {
            let i = r * ch + c;
            dgamma[c] += dy[i] * cache.xn[i];
            dbeta[c] += dy[i];
        }
    }
    let mut dx = vec![0f32; dy.len()];
    for r in 0..rows {
        for c in 0..ch {
            let i = r * ch + c;
            dx[i] = gamma[c]
                * cache.inv_std[c]
                * (dy[i] - dbeta[c] / m - cache.xn[i] * dgamma[c] / m);
        }
    }
    (dx, dgamma, dbeta)
}

/// Eval-mode batch norm with running statistics.
pub(crate) fn bn_eval(
    x: &[f32],
    rows: usize,
    ch: usize,
    gamma: &[f32],
    beta: &[f32],
    mean: &[f32],
    var: &[f32],
    eps: f32,
) -> Vec<f32> {
    let mut out = vec![0f32; x.len()];
    let inv_std: Vec<f32> = var.iter().map(|&v| 1.0 / (v + eps).sqrt()).collect();
    for r in 0..rows {
        for c in 0..ch {
            let i = r * ch + c;
            out[i] = gamma[c] * (x[i] - mean[c]) * inv_std[c] + beta[c];
        }
    }
    out
}

/// 2x2/stride-2 max pool on NHWC; also returns the flat source index of
/// each maximum for the backward scatter.
pub(crate) fn maxpool2(x: &[f32], n: usize, hw: usize, c: usize) -> (Vec<f32>, Vec<u32>) {
    debug_assert_eq!(x.len(), n * hw * hw * c);
    let oh = hw / 2;
    let mut out = vec![0f32; n * oh * oh * c];
    let mut idx = vec![0u32; n * oh * oh * c];
    for img in 0..n {
        for y in 0..oh {
            for xx in 0..oh {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bi = 0u32;
                    for dy in 0..2usize {
                        for dx in 0..2usize {
                            let src = ((img * hw + 2 * y + dy) * hw + 2 * xx + dx)
                                * c
                                + ch;
                            if x[src] > best {
                                best = x[src];
                                bi = src as u32;
                            }
                        }
                    }
                    let o = ((img * oh + y) * oh + xx) * c + ch;
                    out[o] = best;
                    idx[o] = bi;
                }
            }
        }
    }
    (out, idx)
}

/// Max-pool backward: route each output gradient to its argmax source.
pub(crate) fn maxpool2_back(dy: &[f32], idx: &[u32], in_len: usize) -> Vec<f32> {
    let mut dx = vec![0f32; in_len];
    for (g, &i) in dy.iter().zip(idx) {
        dx[i as usize] += g;
    }
    dx
}

/// Inverted-dropout factors (`0` or `1/keep`) from the same Threefry
/// stream construction the lowered graphs use: element `i` keeps iff
/// `uniform(threefry(seed_drop, stream, i, 0).0) < keep`.
pub(crate) fn dropout_mask(len: usize, keep: f32, seed: u32, stream: u32) -> Vec<f32> {
    let inv = 1.0 / keep;
    (0..len)
        .map(|i| {
            let (bits, _) = threefry2x32(seed, stream, i as u32, 0);
            if uniform_from_bits(bits) < keep {
                inv
            } else {
                0.0
            }
        })
        .collect()
}

/// Softmax cross-entropy over `[n, classes]` logits: returns
/// `(mean CE loss, minibatch accuracy, dlogits)` with
/// `dlogits = (softmax - onehot) / n`.
pub(crate) fn softmax_ce_grad(
    logits: &[f32],
    y: &[i32],
    n: usize,
    classes: usize,
) -> (f32, f32, Vec<f32>) {
    let mut dl = vec![0f32; logits.len()];
    let mut loss = 0f64;
    let mut correct = 0usize;
    let scale = 1.0 / n as f32;
    for r in 0..n {
        let row = &logits[r * classes..(r + 1) * classes];
        let (lse, argmax) = log_sum_exp(row);
        let label = y[r] as usize;
        loss += (lse - row[label]) as f64;
        if argmax == label {
            correct += 1;
        }
        for c in 0..classes {
            let p = (row[c] - lse).exp();
            let onehot = if c == label { 1.0 } else { 0.0 };
            dl[r * classes + c] = (p - onehot) * scale;
        }
    }
    (
        (loss / n as f64) as f32,
        correct as f32 / n as f32,
        dl,
    )
}

/// Eval-side statistics: `(summed CE loss, correct count)`.
pub(crate) fn softmax_ce_stats(
    logits: &[f32],
    y: &[i32],
    n: usize,
    classes: usize,
) -> (f32, i64) {
    let mut loss = 0f64;
    let mut correct = 0i64;
    for r in 0..n {
        let row = &logits[r * classes..(r + 1) * classes];
        let (lse, argmax) = log_sum_exp(row);
        let label = y[r] as usize;
        loss += (lse - row[label]) as f64;
        if argmax == label {
            correct += 1;
        }
    }
    (loss as f32, correct)
}

/// Stable `log(sum(exp(row)))` plus the row argmax.
fn log_sum_exp(row: &[f32]) -> (f32, usize) {
    let mut mx = f32::NEG_INFINITY;
    let mut argmax = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > mx {
            mx = v;
            argmax = i;
        }
    }
    let mut sum = 0f32;
    for &v in row {
        sum += (v - mx).exp();
    }
    (mx + sum.ln(), argmax)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn im2col_center_patch_identity() {
        // A 1x3x3x1 image: the center row of the patch matrix holds the
        // whole image, edges are zero-padded.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let p = im2col(&x, 1, 3, 1);
        assert_eq!(p.len(), 9 * 9);
        // Patch at (1,1) sees the full image in (dy, dx) order.
        let center = &p[4 * 9..5 * 9];
        assert_eq!(center, &x[..]);
        // Patch at (0,0): top-left 2x2 visible, rest padding.
        let corner = &p[0..9];
        assert_eq!(corner, &[0., 0., 0., 0., 1., 2., 0., 4., 5.]);
    }

    #[test]
    fn col2im_is_im2col_adjoint() {
        // <im2col(x), p> == <x, col2im(p)> for random x, p — the
        // defining adjoint identity, checked in f64.
        let mut rng = crate::rng::Xoshiro256::new(9);
        let (n, hw, c) = (2usize, 4usize, 3usize);
        let x: Vec<f32> = (0..n * hw * hw * c).map(|_| rng.next_f32() - 0.5).collect();
        let p: Vec<f32> =
            (0..n * hw * hw * 9 * c).map(|_| rng.next_f32() - 0.5).collect();
        let fx = im2col(&x, n, hw, c);
        let bp = col2im(&p, n, hw, c);
        let lhs: f64 =
            fx.iter().zip(&p).map(|(&a, &b)| a as f64 * b as f64).sum();
        let rhs: f64 =
            x.iter().zip(&bp).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn bn_train_normalizes_and_updates() {
        let x = vec![1.0f32, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let (out, cache) = bn_train(&x, 4, 2, &[1.0, 1.0], &[0.0, 0.0], 1e-5);
        // Per-channel mean ~0, var ~1 after normalization.
        for c in 0..2 {
            let vals: Vec<f32> = (0..4).map(|r| out[r * 2 + c]).collect();
            let mean: f32 = vals.iter().sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "mean {mean}");
        }
        assert!((cache.mean[0] - 2.5).abs() < 1e-6);
        assert!((cache.mean[1] - 25.0).abs() < 1e-6);
    }

    #[test]
    fn bn_backward_matches_finite_difference() {
        let mut rng = crate::rng::Xoshiro256::new(3);
        let (rows, ch) = (6usize, 3usize);
        let x: Vec<f32> = (0..rows * ch).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let gamma: Vec<f32> = (0..ch).map(|_| 0.5 + rng.next_f32()).collect();
        let beta: Vec<f32> = (0..ch).map(|_| rng.next_f32() - 0.5).collect();
        let dy: Vec<f32> = (0..rows * ch).map(|_| rng.next_f32() - 0.5).collect();
        let eps = 1e-5f32;
        let loss = |x: &[f32]| -> f64 {
            let (out, _) = bn_train(x, rows, ch, &gamma, &beta, eps);
            out.iter().zip(&dy).map(|(&o, &g)| o as f64 * g as f64).sum()
        };
        let (_, cache) = bn_train(&x, rows, ch, &gamma, &beta, eps);
        let (dx, _, _) = bn_train_back(&dy, &cache, &gamma, rows, ch);
        let h = 1e-3f32;
        for i in [0usize, 5, 11, 17] {
            let mut xp = x.clone();
            xp[i] += h;
            let mut xm = x.clone();
            xm[i] -= h;
            let fd = (loss(&xp) - loss(&xm)) / (2.0 * h as f64);
            let got = dx[i] as f64;
            assert!(
                (fd - got).abs() < 2e-2 * (1.0 + fd.abs()),
                "dx[{i}]: fd {fd} vs {got}"
            );
        }
    }

    #[test]
    fn bn_with_supplied_mean_matches_bn_train() {
        let mut rng = crate::rng::Xoshiro256::new(21);
        let (rows, ch) = (5usize, 4usize);
        let x: Vec<f32> = (0..rows * ch).map(|_| rng.next_f32() * 3.0 - 1.0).collect();
        let gamma = vec![1.25f32; ch];
        let beta = vec![-0.5f32; ch];
        let mut mean = vec![0f32; ch];
        for r in 0..rows {
            for c in 0..ch {
                mean[c] += x[r * ch + c];
            }
        }
        for v in mean.iter_mut() {
            *v /= rows as f32;
        }
        let (a, ca) = bn_train(&x, rows, ch, &gamma, &beta, 1e-5);
        let (b, cb) = bn_train_with_mean(&x, rows, ch, mean, &gamma, &beta, 1e-5);
        assert_eq!(a, b);
        assert_eq!(ca.mean, cb.mean);
        assert_eq!(ca.var, cb.var);
        assert_eq!(ca.xn, cb.xn);
    }

    #[test]
    fn maxpool_selects_and_routes() {
        // 1x2x2x1 -> single output.
        let x = vec![1.0f32, 5.0, 3.0, 2.0];
        let (out, idx) = maxpool2(&x, 1, 2, 1);
        assert_eq!(out, vec![5.0]);
        assert_eq!(idx, vec![1]);
        let dx = maxpool2_back(&[2.5], &idx, 4);
        assert_eq!(dx, vec![0.0, 2.5, 0.0, 0.0]);
    }

    #[test]
    fn dropout_mask_rate_and_determinism() {
        let m1 = dropout_mask(10_000, 0.7, 42, 1000);
        let m2 = dropout_mask(10_000, 0.7, 42, 1000);
        assert_eq!(m1, m2);
        let kept = m1.iter().filter(|&&v| v > 0.0).count();
        assert!((kept as f64 / 10_000.0 - 0.7).abs() < 0.03, "kept {kept}");
        // Inverted scaling keeps the expectation.
        assert!(m1.iter().all(|&v| v == 0.0 || (v - 1.0 / 0.7).abs() < 1e-6));
        assert_ne!(m1, dropout_mask(10_000, 0.7, 43, 1000));
    }

    #[test]
    fn softmax_ce_uniform_logits() {
        let logits = vec![0.0f32; 2 * 4];
        let (loss, _acc, dl) = softmax_ce_grad(&logits, &[1, 2], 2, 4);
        assert!((loss - (4f32).ln()).abs() < 1e-6);
        // Gradient rows sum to zero.
        for r in 0..2 {
            let s: f32 = dl[r * 4..(r + 1) * 4].iter().sum();
            assert!(s.abs() < 1e-6);
        }
        let (sum, correct) = softmax_ce_stats(&logits, &[1, 2], 2, 4);
        assert!((sum - 2.0 * (4f32).ln()).abs() < 1e-5);
        assert!(correct <= 2);
    }
}
