//! [`NativeBackend`]: pure-Rust CNN training on the bit-accurate
//! multiplier engine — no PJRT, no artifacts, runs anywhere.
//!
//! The backend implements the manifest's VGG-style presets
//! (`python/compile/model.py` layer for layer: 3x3 SAME conv-BN-ReLU
//! blocks, 2x2 max pool, Threefry dropout, dense-BN-ReLU, softmax
//! cross-entropy with L2 weight decay, SGD with momentum) with one
//! crucial property: **every forward and backward GEMM goes through
//! the bit-accurate prepared kernel**
//! ([`crate::mult::approx_matmul_prepared`]), so the multiplier a run
//! trains with is the *simulated hardware design itself* — DRUM,
//! Mitchell, truncation, a LUT backend — not a statistical surrogate.
//! This is the ApproxTrain (arXiv:2209.04161) architecture: the
//! simulated-multiplier GEMM is a swappable kernel under an otherwise
//! ordinary training loop. Each GEMM operand is decomposed **once**
//! into [`PreparedMatrix`] planes (the weight matrix once per step,
//! shared between the forward GEMM and the backward `dY·Wᵀ`; eval
//! passes share one decomposition across all batches), and the
//! bias-add / BN-mean epilogues run fused inside the GEMM's output
//! block loop instead of as separate full-tensor passes.
//!
//! Error-injection modes, selected by the run's [`MultSpec`]:
//!
//! * `exact` — every GEMM through the exact mantissa pipeline;
//! * `gaussian:<sigma>` — the paper's weight-level model: each weight
//!   matrix is perturbed `W*(1 + sigma*eps)` with the *same* Threefry
//!   field (`(seed_err, layer)` streams) the compiled graphs inject,
//!   in both forward and backward (custom-VJP semantics: the weight
//!   gradient is scaled by the same factors). GEMMs run exact.
//! * a design spec — product-level injection: forward and backward
//!   GEMMs run the bit-accurate design. Signed designs (`sdrum6`,
//!   `booth8`, `sroba`, `slut12:sdrum6`, ...) run the **signed**
//!   prepared kernel ([`crate::mult::signed`]): operands carry their
//!   sign into the multiplier as two's-complement mantissas, so
//!   sign-asymmetric error (Booth truncation) reaches training — the
//!   sign-externalized unsigned pipeline cannot express it.
//!
//! Determinism: `approx_matmul` is deterministic at any worker count,
//! dropout/error fields are counter-based, and every other kernel is
//! sequential — so a training run is bit-reproducible regardless of
//! thread count (pinned by `tests/native_backend.rs`).

mod layers;

use std::sync::atomic::{AtomicU32, Ordering};

use anyhow::{bail, Context, Result};

use crate::mult::PreparedMatrix;
use crate::mult::{Exact, GemmDesign, GemmMode, MultSpec};
use crate::rng::threefry::counter_normal;
use crate::tensor::Tensor;
use crate::testkit::faults::{FaultPlan, FaultSite};

use super::backend::{Backend, BackendModel, EvalPass};
use super::manifest::TensorSpec;
use super::session::{EvalStats, StepInputs, StepStats};

use layers::BnCache;

/// Dropout stream offsets (shared with `python/compile/model.py`).
const DROP_STREAM_OFFSET: u32 = 1000;
/// Init stream offset (He-normal fields per parameter index).
const INIT_STREAM_OFFSET: u32 = 2000;

static EXACT_MULT: Exact = Exact;

/// Static architecture + training hyperparameters for one native
/// preset (mirrors `ModelConfig` on the Python side).
#[derive(Debug, Clone)]
pub struct NativeConfig {
    pub name: String,
    pub input_hw: usize,
    pub in_ch: usize,
    /// Conv widths per block; each block ends in a 2x2 max pool.
    pub blocks: Vec<Vec<usize>>,
    /// Hidden dense widths.
    pub dense: Vec<usize>,
    pub num_classes: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub dropout_conv: f32,
    pub dropout_dense: f32,
    pub bn_momentum: f32,
    pub bn_eps: f32,
    pub weight_decay: f32,
    pub sgd_momentum: f32,
}

impl NativeConfig {
    /// Built-in presets. `tiny`/`small`/`vgg16` match the manifest's
    /// architectures; `micro` is a native-only gradient-check scale.
    pub fn preset(name: &str) -> Result<NativeConfig> {
        let base = NativeConfig {
            name: name.to_string(),
            input_hw: 32,
            in_ch: 3,
            blocks: vec![],
            dense: vec![],
            num_classes: 10,
            batch: 64,
            eval_batch: 256,
            dropout_conv: 0.3,
            dropout_dense: 0.5,
            bn_momentum: 0.9,
            bn_eps: 1e-5,
            weight_decay: 5e-4,
            sgd_momentum: 0.9,
        };
        Ok(match name {
            "micro" => NativeConfig {
                input_hw: 4,
                blocks: vec![vec![4]],
                dense: vec![8],
                num_classes: 4,
                batch: 4,
                eval_batch: 8,
                dropout_conv: 0.0,
                dropout_dense: 0.0,
                ..base
            },
            "tiny" => NativeConfig {
                input_hw: 8,
                blocks: vec![vec![8], vec![16]],
                dense: vec![32],
                batch: 16,
                eval_batch: 64,
                dropout_conv: 0.0,
                dropout_dense: 0.0,
                ..base
            },
            "small" => NativeConfig {
                blocks: vec![vec![32, 32], vec![64, 64], vec![128, 128]],
                dense: vec![128],
                ..base
            },
            "vgg16" => NativeConfig {
                blocks: vec![
                    vec![64, 64],
                    vec![128, 128],
                    vec![256, 256, 256],
                    vec![512, 512, 512],
                    vec![512, 512, 512],
                ],
                dense: vec![512],
                batch: 128,
                ..base
            },
            other => bail!(
                "unknown native preset {other:?} (micro | tiny | small | vgg16)"
            ),
        })
    }

    /// Forward-order flat parameter layout (the manifest contract).
    fn param_specs(&self) -> Vec<TensorSpec> {
        let mut specs = Vec::new();
        let mut ch = self.in_ch;
        let mut layer: i64 = 0;
        for (bi, widths) in self.blocks.iter().enumerate() {
            for (ci, &w) in widths.iter().enumerate() {
                let p = format!("conv{bi}_{ci}");
                specs.push(TensorSpec {
                    name: format!("{p}.w"),
                    shape: vec![3, 3, ch, w],
                    kind: "conv_w".into(),
                    layer,
                });
                specs.push(TensorSpec {
                    name: format!("{p}.b"),
                    shape: vec![w],
                    kind: "bias".into(),
                    layer: -1,
                });
                specs.push(TensorSpec {
                    name: format!("{p}.bn_gamma"),
                    shape: vec![w],
                    kind: "bn_gamma".into(),
                    layer: -1,
                });
                specs.push(TensorSpec {
                    name: format!("{p}.bn_beta"),
                    shape: vec![w],
                    kind: "bn_beta".into(),
                    layer: -1,
                });
                ch = w;
                layer += 1;
            }
        }
        let hw = self.input_hw >> self.blocks.len();
        let mut feat = ch * hw * hw;
        for (di, &w) in self.dense.iter().enumerate() {
            let p = format!("dense{di}");
            specs.push(TensorSpec {
                name: format!("{p}.w"),
                shape: vec![feat, w],
                kind: "dense_w".into(),
                layer,
            });
            specs.push(TensorSpec {
                name: format!("{p}.b"),
                shape: vec![w],
                kind: "bias".into(),
                layer: -1,
            });
            specs.push(TensorSpec {
                name: format!("{p}.bn_gamma"),
                shape: vec![w],
                kind: "bn_gamma".into(),
                layer: -1,
            });
            specs.push(TensorSpec {
                name: format!("{p}.bn_beta"),
                shape: vec![w],
                kind: "bn_beta".into(),
                layer: -1,
            });
            feat = w;
            layer += 1;
        }
        specs.push(TensorSpec {
            name: "classifier.w".into(),
            shape: vec![feat, self.num_classes],
            kind: "dense_w".into(),
            layer,
        });
        specs.push(TensorSpec {
            name: "classifier.b".into(),
            shape: vec![self.num_classes],
            kind: "bias".into(),
            layer: -1,
        });
        specs
    }

    /// `(kin, kout, param-quad index)` of every GEMM layer in forward
    /// order — conv blocks, dense layers, classifier. The single
    /// source of truth for weight-matrix shapes wherever they are
    /// (re)packed; `param_specs` stays consistent with it by test.
    fn gemm_layers(&self) -> Vec<(usize, usize, usize)> {
        let mut v = Vec::new();
        let mut ch = self.in_ch;
        let mut pi = 0usize;
        for widths in &self.blocks {
            for &w in widths {
                v.push((9 * ch, w, pi));
                pi += 4;
                ch = w;
            }
        }
        let hw = self.input_hw >> self.blocks.len();
        let mut feat = ch * hw * hw;
        for &w in &self.dense {
            v.push((feat, w, pi));
            pi += 4;
            feat = w;
        }
        v.push((feat, self.num_classes, pi));
        v
    }

    /// BN running statistics, forward order.
    fn state_specs(&self) -> Vec<TensorSpec> {
        let mut specs = Vec::new();
        for (bi, widths) in self.blocks.iter().enumerate() {
            for (ci, &w) in widths.iter().enumerate() {
                for stat in ["bn_mean", "bn_var"] {
                    specs.push(TensorSpec {
                        name: format!("conv{bi}_{ci}.{stat}"),
                        shape: vec![w],
                        kind: "state".into(),
                        layer: -1,
                    });
                }
            }
        }
        for (di, &w) in self.dense.iter().enumerate() {
            for stat in ["bn_mean", "bn_var"] {
                specs.push(TensorSpec {
                    name: format!("dense{di}.{stat}"),
                    shape: vec![w],
                    kind: "state".into(),
                    layer: -1,
                });
            }
        }
        specs
    }

    /// The backend-agnostic model description for this preset.
    pub fn backend_model(&self) -> BackendModel {
        BackendModel {
            preset: self.name.clone(),
            batch: self.batch,
            eval_batch: self.eval_batch,
            input_hw: self.input_hw,
            in_ch: self.in_ch,
            num_classes: self.num_classes,
            params: self.param_specs(),
            state: self.state_specs(),
        }
    }
}

/// Saved forward context of one GEMM layer (conv or dense).
struct GemmTape {
    /// Left GEMM operand (im2col patches / dense input), `[rows, kin]`.
    input: Vec<f32>,
    /// The (possibly error-injected) weight matrix, decomposed **once
    /// per step** into forward-packed `[kout × kin]` planes. The
    /// backward `dX = dY·Wᵀ` re-packs these planes (a copy, not a
    /// re-decomposition); with no injection active, no f32 copy of the
    /// weights is made at all.
    w_packed: PreparedMatrix,
    /// Gaussian weight-injection factors `1 + sigma*eps` (scale the
    /// weight gradient too — the custom-VJP semantics).
    factors: Option<Vec<f32>>,
    bn: Option<BnCache>,
    /// Post-ReLU output (mask source); `None` for the classifier.
    relu_out: Option<Vec<f32>>,
    rows: usize,
    kin: usize,
    kout: usize,
    /// Param index of the weight tensor (`+1` bias, `+2/+3` BN scale).
    pw: usize,
    /// `(hw, cin)` for conv layers (col2im geometry), `None` for dense.
    conv_geom: Option<(usize, usize)>,
}

/// Full forward tape of one training step.
struct Forward {
    logits: Vec<f32>,
    conv_tapes: Vec<GemmTape>,
    dense_tapes: Vec<GemmTape>,
    cls_tape: GemmTape,
    /// Per block: (argmax indices, pre-pool length).
    pools: Vec<(Vec<u32>, usize)>,
    /// Per block: post-pool dropout factors, if dropout is on.
    conv_drops: Vec<Option<Vec<f32>>>,
    dense_drop: Option<Vec<f32>>,
    /// Updated BN running stats, state order.
    new_state: Vec<Vec<f32>>,
}

/// A fault plan armed on the backend, plus its consumed-fire count.
/// `AtomicU32` because [`Backend::train_step`] takes `&self`.
struct ArmedFault {
    plan: FaultPlan,
    fires: AtomicU32,
}

/// The native execution backend bound to one preset + multiplier spec.
pub struct NativeBackend {
    cfg: NativeConfig,
    model: BackendModel,
    spec: MultSpec,
    /// Built product-level design (bit-accurate specs only) — unsigned
    /// or signed; [`GemmDesign`] carries which pipeline it runs.
    design: Option<GemmDesign>,
    /// Armed training-path fault ([`crate::testkit::faults`]); `None`
    /// in production — the un-faulted path is untouched.
    fault: Option<ArmedFault>,
}

impl NativeBackend {
    /// Build a backend for `preset` training under `spec`.
    pub fn new(preset: &str, spec: MultSpec) -> Result<Self> {
        let cfg = NativeConfig::preset(preset)?;
        let design = match &spec {
            MultSpec::Design { .. } => {
                Some(spec.build_gemm().context("building multiplier design")?)
            }
            _ => None,
        };
        let model = cfg.backend_model();
        Ok(NativeBackend { cfg, model, spec, design, fault: None })
    }

    /// The multiplier spec this backend trains with.
    pub fn spec(&self) -> &MultSpec {
        &self.spec
    }

    /// Consume one fire of the armed fault if it targets this phase of
    /// global step `step`; returns the `(layer, value)` to poison with.
    fn fault_fire(&self, step: u64, grad_phase: bool) -> Option<(u32, f32)> {
        let armed = self.fault.as_ref()?;
        if armed.plan.step != step {
            return None;
        }
        let (layer, value) = match (armed.plan.site, grad_phase) {
            (FaultSite::Activation { layer, value }, false) => (layer, value),
            (FaultSite::Gradient { layer, value }, true) => (layer, value),
            _ => return None,
        };
        let max = armed.plan.max_fires;
        armed
            .fires
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                (n < max).then_some(n + 1)
            })
            .ok()
            .map(|_| (layer, value))
    }

    /// Active GEMM mode (multiplier + operand domain) and
    /// weight-injection sigma for one step. Signed designs run the
    /// signed prepared kernel: operand signs go through the design,
    /// not the exponent bookkeeping.
    fn step_mode(&self, k: StepInputs) -> (GemmMode<'_>, f32) {
        if !k.approx {
            return (GemmMode::Unsigned(&EXACT_MULT), 0.0);
        }
        match &self.design {
            Some(d) => (d.mode(), 0.0),
            None => (GemmMode::Unsigned(&EXACT_MULT), k.sigma),
        }
    }

    /// Weight-level Gaussian injection: `wq = w * (1 + sigma*eps)` from
    /// the `(seed_err, layer)` Threefry stream — the exact field the
    /// compiled graphs inject. With `sigma == 0` no injected copy is
    /// materialized (`None`, `None`): callers read the raw weights.
    fn inject(
        w: &[f32],
        sigma: f32,
        seed_err: u32,
        stream: u32,
    ) -> (Option<Vec<f32>>, Option<Vec<f32>>) {
        if sigma == 0.0 {
            return (None, None);
        }
        let eps = counter_normal(seed_err, stream, 0, w.len());
        let factors: Vec<f32> = eps.iter().map(|&e| 1.0 + sigma * e).collect();
        let wq = w.iter().zip(&factors).map(|(&v, &f)| v * f).collect();
        (Some(wq), Some(factors))
    }

    /// Decompose the (possibly injected) `[kin × kout]` weight matrix
    /// once into forward-packed `[kout × kin]` planes, with the
    /// signed-mantissa plane derived up front when the step's GEMM
    /// mode needs it (once per step, like the decomposition itself).
    fn pack_weight(
        w: &[f32],
        wq: &Option<Vec<f32>>,
        kin: usize,
        kout: usize,
        gemm: GemmMode<'_>,
    ) -> Result<PreparedMatrix> {
        let src: &[f32] = wq.as_deref().unwrap_or(w);
        Self::prepare_operand(src, kout, kin, 1, kout, gemm)
    }

    /// Prepare a row-major activation operand for the step's GEMM mode.
    fn prepare_activation(
        data: &[f32],
        rows: usize,
        cols: usize,
        gemm: GemmMode<'_>,
    ) -> Result<PreparedMatrix> {
        Self::prepare_operand(data, rows, cols, cols, 1, gemm)
    }

    /// The one place the "signed mode carries the signed-mantissa
    /// plane" rule lives: every prepare in the training path (weights,
    /// activations, gradients, strided TN views) routes through here.
    fn prepare_operand(
        data: &[f32],
        rows: usize,
        cols: usize,
        row_stride: usize,
        col_stride: usize,
        gemm: GemmMode<'_>,
    ) -> Result<PreparedMatrix> {
        let p = PreparedMatrix::prepare_strided(data, rows, cols, row_stride, col_stride)?;
        Ok(if gemm.is_signed() { p.with_signed_mantissas() } else { p })
    }

    /// Train-mode forward pass, recording the tape the backward needs.
    /// `fault` is an armed activation poison `(gemm layer, fill value)`
    /// — the whole layer output is overwritten (a single poisoned
    /// element could be dropped by max-pooling, where NaN loses every
    /// `>` comparison); `None` on the production path.
    fn forward_train(
        &self,
        params: &[Vec<f32>],
        state: &[Vec<f32>],
        x: &[f32],
        n: usize,
        k: StepInputs,
        fault: Option<(u32, f32)>,
    ) -> Result<Forward> {
        let (gemm, sigma) = self.step_mode(k);
        let cfg = &self.cfg;
        let mom = cfg.bn_momentum;
        let mut new_state: Vec<Vec<f32>> = state.to_vec();

        let mut h = x.to_vec();
        let mut hw = cfg.input_hw;
        let mut ch = cfg.in_ch;
        let mut pi = 0usize;
        let mut si = 0usize;
        let mut layer_id = 0u32;

        let mut conv_tapes = Vec::new();
        let mut pools = Vec::new();
        let mut conv_drops = Vec::new();

        for (bi, widths) in cfg.blocks.iter().enumerate() {
            for &width in widths {
                let rows = n * hw * hw;
                let kin = 9 * ch;
                let patches = layers::im2col(&h, n, hw, ch);
                let (wq, factors) =
                    Self::inject(&params[pi], sigma, k.seed_err, layer_id);
                let w_packed =
                    Self::pack_weight(&params[pi], &wq, kin, width, gemm)?;
                let patches_prep =
                    Self::prepare_activation(&patches, rows, kin, gemm)?;
                // Bias add and the BN mean accumulation run fused in
                // the GEMM's output block loop.
                let g = gemm.matmul_prepared(
                    &patches_prep,
                    &w_packed,
                    Some(&params[pi + 1]),
                    true,
                )?;
                let z = g.out;
                let sums = g.col_sums.expect("fused col sums");
                let mean: Vec<f32> =
                    sums.iter().map(|s| s / rows as f32).collect();
                let (mut out, bn) = layers::bn_train_with_mean(
                    &z,
                    rows,
                    width,
                    mean,
                    &params[pi + 2],
                    &params[pi + 3],
                    cfg.bn_eps,
                );
                for (run, batch) in new_state[si].iter_mut().zip(&bn.mean) {
                    *run = mom * *run + (1.0 - mom) * batch;
                }
                for (run, batch) in new_state[si + 1].iter_mut().zip(&bn.var) {
                    *run = mom * *run + (1.0 - mom) * batch;
                }
                for v in out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                if let Some((fl, fv)) = fault {
                    if fl == layer_id {
                        out.fill(fv);
                    }
                }
                h = out;
                conv_tapes.push(GemmTape {
                    input: patches,
                    w_packed,
                    factors,
                    bn: Some(bn),
                    relu_out: Some(h.clone()),
                    rows,
                    kin,
                    kout: width,
                    pw: pi,
                    conv_geom: Some((hw, ch)),
                });
                pi += 4;
                si += 2;
                layer_id += 1;
                ch = width;
            }
            let in_len = h.len();
            let (pooled, idx) = layers::maxpool2(&h, n, hw, ch);
            h = pooled;
            hw /= 2;
            pools.push((idx, in_len));
            if cfg.dropout_conv > 0.0 {
                let mask = layers::dropout_mask(
                    h.len(),
                    1.0 - cfg.dropout_conv,
                    k.seed_drop,
                    DROP_STREAM_OFFSET + bi as u32,
                );
                for (v, &m) in h.iter_mut().zip(&mask) {
                    *v *= m;
                }
                conv_drops.push(Some(mask));
            } else {
                conv_drops.push(None);
            }
        }

        let mut feat = hw * hw * ch;
        let mut dense_tapes = Vec::new();
        for &width in &cfg.dense {
            let (wq, factors) = Self::inject(&params[pi], sigma, k.seed_err, layer_id);
            let w_packed = Self::pack_weight(&params[pi], &wq, feat, width, gemm)?;
            let h_prep = Self::prepare_activation(&h, n, feat, gemm)?;
            let g = gemm.matmul_prepared(
                &h_prep,
                &w_packed,
                Some(&params[pi + 1]),
                true,
            )?;
            let z = g.out;
            let sums = g.col_sums.expect("fused col sums");
            let mean: Vec<f32> = sums.iter().map(|s| s / n as f32).collect();
            let (mut out, bn) = layers::bn_train_with_mean(
                &z,
                n,
                width,
                mean,
                &params[pi + 2],
                &params[pi + 3],
                cfg.bn_eps,
            );
            for (run, batch) in new_state[si].iter_mut().zip(&bn.mean) {
                *run = mom * *run + (1.0 - mom) * batch;
            }
            for (run, batch) in new_state[si + 1].iter_mut().zip(&bn.var) {
                *run = mom * *run + (1.0 - mom) * batch;
            }
            for v in out.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            if let Some((fl, fv)) = fault {
                if fl == layer_id {
                    out.fill(fv);
                }
            }
            let input = std::mem::replace(&mut h, out);
            dense_tapes.push(GemmTape {
                input,
                w_packed,
                factors,
                bn: Some(bn),
                relu_out: Some(h.clone()),
                rows: n,
                kin: feat,
                kout: width,
                pw: pi,
                conv_geom: None,
            });
            pi += 4;
            si += 2;
            layer_id += 1;
            feat = width;
        }

        let dense_drop = if cfg.dropout_dense > 0.0 {
            let mask = layers::dropout_mask(
                h.len(),
                1.0 - cfg.dropout_dense,
                k.seed_drop,
                DROP_STREAM_OFFSET + 99,
            );
            for (v, &m) in h.iter_mut().zip(&mask) {
                *v *= m;
            }
            Some(mask)
        } else {
            None
        };

        let (wq, factors) = Self::inject(&params[pi], sigma, k.seed_err, layer_id);
        let w_packed =
            Self::pack_weight(&params[pi], &wq, feat, cfg.num_classes, gemm)?;
        let h_prep = Self::prepare_activation(&h, n, feat, gemm)?;
        let mut logits = gemm
            .matmul_prepared(&h_prep, &w_packed, Some(&params[pi + 1]), false)?
            .out;
        if let Some((fl, fv)) = fault {
            if fl == layer_id {
                logits.fill(fv);
            }
        }
        let cls_tape = GemmTape {
            input: h,
            w_packed,
            factors,
            bn: None,
            relu_out: None,
            rows: n,
            kin: feat,
            kout: cfg.num_classes,
            pw: pi,
            conv_geom: None,
        };

        Ok(Forward {
            logits,
            conv_tapes,
            dense_tapes,
            cls_tape,
            pools,
            conv_drops,
            dense_drop,
            new_state,
        })
    }

    /// Backward through one GEMM+bias layer: accumulates `dW`/`db` into
    /// `grads` and returns the gradient w.r.t. the layer input. Both
    /// backward GEMMs run on the *same* multiplier as the forward pass
    /// — an approximate MAC array is approximate in backprop too.
    fn gemm_backward(
        gemm: GemmMode<'_>,
        tape: &GemmTape,
        dz: &[f32],
        grads: &mut [Vec<f32>],
    ) -> Result<Vec<f32>> {
        {
            let gb = &mut grads[tape.pw + 1];
            for r in 0..tape.rows {
                for c in 0..tape.kout {
                    gb[c] += dz[r * tape.kout + c];
                }
            }
        }
        // dz decomposed once; both backward GEMMs read it (the TN side
        // through a plane re-pack, not a re-decomposition — the signed
        // plane, when present, re-packs along).
        let dzp = Self::prepare_activation(dz, tape.rows, tape.kout, gemm)?;
        // dW = inputᵀ · dz, through the transposed-operand GEMM.
        let a_tn =
            Self::prepare_operand(&tape.input, tape.kin, tape.rows, 1, tape.kin, gemm)?;
        let b_tn = dzp.transposed();
        let mut dw = gemm.matmul_prepared(&a_tn, &b_tn, None, false)?.out;
        if let Some(f) = &tape.factors {
            for (g, &fa) in dw.iter_mut().zip(f) {
                *g *= fa;
            }
        }
        {
            let gw = &mut grads[tape.pw];
            for (g, &d) in gw.iter_mut().zip(&dw) {
                *g += d;
            }
        }
        // dInput = dz · wqᵀ: the step's forward-packed weight planes,
        // re-packed to W's natural layout — no second decomposition.
        let b_nt = tape.w_packed.transposed();
        Ok(gemm.matmul_prepared(&dzp, &b_nt, None, false)?.out)
    }

    /// Backward through ReLU + BN of one taped layer.
    fn block_backward(
        tape: &GemmTape,
        mut dh: Vec<f32>,
        params: &[Vec<f32>],
        grads: &mut [Vec<f32>],
    ) -> Vec<f32> {
        if let Some(out) = &tape.relu_out {
            for (g, &o) in dh.iter_mut().zip(out) {
                if o <= 0.0 {
                    *g = 0.0;
                }
            }
        }
        if let Some(bn) = &tape.bn {
            let (dx, dgamma, dbeta) = layers::bn_train_back(
                &dh,
                bn,
                &params[tape.pw + 2],
                tape.rows,
                tape.kout,
            );
            for (g, d) in grads[tape.pw + 2].iter_mut().zip(&dgamma) {
                *g += d;
            }
            for (g, d) in grads[tape.pw + 3].iter_mut().zip(&dbeta) {
                *g += d;
            }
            return dx;
        }
        dh
    }

    /// Full backward pass: parameter gradients of `ce + wd*L2`.
    fn backward(
        &self,
        fwd: &Forward,
        dlogits: Vec<f32>,
        params: &[Vec<f32>],
        k: StepInputs,
        n: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let (gemm, _) = self.step_mode(k);
        let cfg = &self.cfg;
        let mut grads: Vec<Vec<f32>> =
            params.iter().map(|p| vec![0f32; p.len()]).collect();

        let mut dh = Self::gemm_backward(gemm, &fwd.cls_tape, &dlogits, &mut grads)?;
        if let Some(mask) = &fwd.dense_drop {
            for (g, &m) in dh.iter_mut().zip(mask) {
                *g *= m;
            }
        }
        for tape in fwd.dense_tapes.iter().rev() {
            let dz = Self::block_backward(tape, dh, params, &mut grads);
            dh = Self::gemm_backward(gemm, tape, &dz, &mut grads)?;
        }

        // Walk conv blocks in reverse; conv_tapes is flat in forward
        // order, so track the per-block slice boundaries.
        let mut tape_end = fwd.conv_tapes.len();
        for bi in (0..cfg.blocks.len()).rev() {
            if let Some(mask) = &fwd.conv_drops[bi] {
                for (g, &m) in dh.iter_mut().zip(mask) {
                    *g *= m;
                }
            }
            let (idx, in_len) = &fwd.pools[bi];
            dh = layers::maxpool2_back(&dh, idx, *in_len);
            let tape_start = tape_end - cfg.blocks[bi].len();
            for tape in fwd.conv_tapes[tape_start..tape_end].iter().rev() {
                let dz = Self::block_backward(tape, dh, params, &mut grads);
                let dpatches = Self::gemm_backward(gemm, tape, &dz, &mut grads)?;
                let (hw, cin) = tape.conv_geom.expect("conv tape geometry");
                dh = layers::col2im(&dpatches, n, hw, cin);
            }
            tape_end = tape_start;
        }

        // L2 weight decay on conv/dense weights (raw weights, matching
        // the Keras kernel_regularizer semantics).
        let wd = cfg.weight_decay;
        if wd > 0.0 {
            for (spec, (g, p)) in self
                .model
                .params
                .iter()
                .zip(grads.iter_mut().zip(params))
            {
                if spec.kind == "conv_w" || spec.kind == "dense_w" {
                    for (gv, &pv) in g.iter_mut().zip(p) {
                        *gv += 2.0 * wd * pv;
                    }
                }
            }
        }
        Ok(grads)
    }

    /// Decompose every weight matrix once into forward-packed planes —
    /// the per-pass setup an [`EvalPass`] shares across eval batches
    /// (weights are fixed during evaluation, so this is the one
    /// decomposition the whole pass needs).
    fn pack_eval_weights(&self, params: &[Vec<f32>]) -> Result<Vec<PreparedMatrix>> {
        self.cfg
            .gemm_layers()
            .into_iter()
            .map(|(kin, kout, pi)| {
                PreparedMatrix::prepare_strided(&params[pi], kout, kin, 1, kout)
            })
            .collect()
    }

    /// The GEMM mode an inference forward runs under: the built design
    /// for bit-accurate specs, exact otherwise (Gaussian specs model
    /// their error at the *weight* level — see [`Self::infer_params`] —
    /// so their product path is exact, matching training semantics).
    pub fn infer_mode(&self) -> GemmMode<'_> {
        match &self.design {
            Some(d) => d.mode(),
            None => GemmMode::Unsigned(&EXACT_MULT),
        }
    }

    /// Number of GEMM layers in this preset's forward — the expected
    /// prepare-call count for one full weight decomposition (pinned by
    /// the serve decompose-once test).
    pub fn n_gemm_layers(&self) -> usize {
        self.cfg.gemm_layers().len()
    }

    /// Serving-time weight materialization. For `gaussian:<sd>` specs
    /// the error is a *weight-level* field, applied once per resident
    /// session from the same per-layer Threefry streams training uses
    /// (`(seed_err, gemm layer id)`); bit-accurate and exact specs
    /// return the weights unchanged. The returned buffers are what
    /// [`Self::pack_infer_weights`] should decompose.
    pub fn infer_params(&self, params: &[Vec<f32>], seed_err: u32) -> Vec<Vec<f32>> {
        let mut out: Vec<Vec<f32>> = params.to_vec();
        if let MultSpec::Gaussian { sigma } = &self.spec {
            for (layer_id, (_kin, _kout, pi)) in
                self.cfg.gemm_layers().into_iter().enumerate()
            {
                let (wq, _) =
                    Self::inject(&params[pi], *sigma as f32, seed_err, layer_id as u32);
                if let Some(wq) = wq {
                    out[pi] = wq;
                }
            }
        }
        out
    }

    /// Decompose every weight matrix once for *mode-aware* inference:
    /// unlike [`Self::pack_eval_weights`] (exact-only eval during
    /// training), this derives the signed-mantissa plane up front when
    /// the resident spec runs the signed pipeline, so per-request
    /// batches pay zero decomposition cost.
    pub fn pack_infer_weights(&self, params: &[Vec<f32>]) -> Result<Vec<PreparedMatrix>> {
        let gemm = self.infer_mode();
        self.cfg
            .gemm_layers()
            .into_iter()
            .map(|(kin, kout, pi)| {
                Self::prepare_operand(&params[pi], kout, kin, 1, kout, gemm)
            })
            .collect()
    }

    /// Inference forward over pre-packed weight planes under the
    /// resident spec's GEMM mode: logits for `n` examples. `x` is the
    /// flat `[n, hw, hw, ch]` input; its length is validated against
    /// the preset geometry (typed error, not a shape panic).
    pub fn infer_logits(
        &self,
        params: &[Vec<f32>],
        state: &[Vec<f32>],
        packed: &[PreparedMatrix],
        x: &[f32],
        n: usize,
    ) -> Result<Vec<f32>> {
        let per = self.cfg.input_hw * self.cfg.input_hw * self.cfg.in_ch;
        if n == 0 || x.len() != n * per {
            bail!(
                "input has {} elements, expected {n} examples x {per} ({}x{}x{})",
                x.len(),
                self.cfg.input_hw,
                self.cfg.input_hw,
                self.cfg.in_ch
            );
        }
        self.forward_packed(params, state, x, n, packed, self.infer_mode())
    }

    /// Eval-mode forward (running BN stats, exact multipliers, no
    /// dropout) over pre-packed weight planes — logits only.
    fn forward_eval(
        &self,
        params: &[Vec<f32>],
        state: &[Vec<f32>],
        x: &[f32],
        n: usize,
        packed: &[PreparedMatrix],
    ) -> Result<Vec<f32>> {
        self.forward_packed(params, state, x, n, packed, GemmMode::Unsigned(&EXACT_MULT))
    }

    /// Shared packed-weight forward body (BN running stats, ReLU, no
    /// dropout) parameterized over the GEMM mode: the exact eval path
    /// and the mode-aware serving path are the same code, so the
    /// serving forward inherits every eval-path invariant (dynamic
    /// batch geometry, strict k-order accumulation).
    fn forward_packed(
        &self,
        params: &[Vec<f32>],
        state: &[Vec<f32>],
        x: &[f32],
        n: usize,
        packed: &[PreparedMatrix],
        gemm: GemmMode<'_>,
    ) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let mut h = x.to_vec();
        let mut hw = cfg.input_hw;
        let mut ch = cfg.in_ch;
        let mut pi = 0usize;
        let mut si = 0usize;
        let mut li = 0usize;

        for widths in &cfg.blocks {
            for &width in widths {
                let rows = n * hw * hw;
                let kin = 9 * ch;
                let patches = layers::im2col(&h, n, hw, ch);
                let pp = Self::prepare_activation(&patches, rows, kin, gemm)?;
                let z = gemm
                    .matmul_prepared(&pp, &packed[li], Some(&params[pi + 1]), false)?
                    .out;
                let mut out = layers::bn_eval(
                    &z,
                    rows,
                    width,
                    &params[pi + 2],
                    &params[pi + 3],
                    &state[si],
                    &state[si + 1],
                    cfg.bn_eps,
                );
                for v in out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                h = out;
                pi += 4;
                si += 2;
                li += 1;
                ch = width;
            }
            let (pooled, _) = layers::maxpool2(&h, n, hw, ch);
            h = pooled;
            hw /= 2;
        }

        let mut feat = hw * hw * ch;
        for &width in &cfg.dense {
            let hp = Self::prepare_activation(&h, n, feat, gemm)?;
            let z = gemm
                .matmul_prepared(&hp, &packed[li], Some(&params[pi + 1]), false)?
                .out;
            let mut out = layers::bn_eval(
                &z,
                n,
                width,
                &params[pi + 2],
                &params[pi + 3],
                &state[si],
                &state[si + 1],
                cfg.bn_eps,
            );
            for v in out.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            h = out;
            pi += 4;
            si += 2;
            li += 1;
            feat = width;
        }

        let hp = Self::prepare_activation(&h, n, feat, gemm)?;
        let logits = gemm
            .matmul_prepared(&hp, &packed[li], Some(&params[pi + 1]), false)?
            .out;
        Ok(logits)
    }

    /// Shared eval-batch body: derives the batch size from `x` (no
    /// static shape — short final batches are fine), runs the packed
    /// eval forward and reduces to [`EvalStats`].
    fn eval_stats(
        &self,
        params: &[Vec<f32>],
        state: &[Vec<f32>],
        packed: &[PreparedMatrix],
        x: &Tensor,
        y: &Tensor,
    ) -> Result<EvalStats> {
        let xs = x.as_f32()?;
        let ys = y.as_i32()?;
        let n = self.model.examples_of(xs.len())?;
        check_labels(&ys, n, self.cfg.num_classes)?;
        let logits = self.forward_eval(params, state, &xs, n, packed)?;
        let (loss_sum, correct) =
            layers::softmax_ce_stats(&logits, &ys, n, self.cfg.num_classes);
        Ok(EvalStats { loss_sum, correct, total: n })
    }

    /// Total train-mode loss (`CE + wd*L2`) at the given state — the
    /// finite-difference gradient-check hook (`tests/native_backend.rs`);
    /// mutates nothing.
    pub fn total_loss(
        &self,
        tensors: &[Tensor],
        x: &Tensor,
        y: &Tensor,
        k: StepInputs,
    ) -> Result<f64> {
        let n_p = self.model.params.len();
        let n_s = self.model.state.len();
        let params = to_vecs(&tensors[..n_p])?;
        let state = to_vecs(&tensors[n_p..n_p + n_s])?;
        let xs = x.as_f32()?;
        let ys = y.as_i32()?;
        let n = self.model.examples_of(xs.len())?;
        check_labels(&ys, n, self.cfg.num_classes)?;
        let fwd = self.forward_train(&params, &state, &xs, n, k, None)?;
        let (ce, _, _) =
            layers::softmax_ce_grad(&fwd.logits, &ys, n, self.cfg.num_classes);
        let mut l2 = 0f64;
        for (spec, p) in self.model.params.iter().zip(&params) {
            if spec.kind == "conv_w" || spec.kind == "dense_w" {
                // detlint: allow(D3) -- L2 term: sequential sum in parameter order, reporting-only f64
                l2 += p.iter().map(|&v| v as f64 * v as f64).sum::<f64>();
            }
        }
        Ok(ce as f64 + self.cfg.weight_decay as f64 * l2)
    }
}

/// Extract f32 buffers from a tensor slice.
fn to_vecs(tensors: &[Tensor]) -> Result<Vec<Vec<f32>>> {
    tensors.iter().map(|t| t.as_f32()).collect()
}

/// Label-batch validation: the loss kernels index `logits[.., y[r]]`
/// directly, so a short batch or out-of-range class id must surface as
/// an error here, not an index panic.
fn check_labels(ys: &[i32], n: usize, num_classes: usize) -> Result<()> {
    if ys.len() != n {
        bail!("y has {} labels, expected {n}", ys.len());
    }
    if let Some(&bad) = ys.iter().find(|&&l| l < 0 || l as usize >= num_classes) {
        bail!("label {bad} out of range 0..{num_classes}");
    }
    Ok(())
}

/// Amortized evaluation pass over fixed params/state: the weight
/// planes are decomposed once here and shared by every batch
/// evaluated through the pass.
struct NativeEvalPass<'a> {
    backend: &'a NativeBackend,
    params: Vec<Vec<f32>>,
    state: Vec<Vec<f32>>,
    packed: Vec<PreparedMatrix>,
}

impl EvalPass for NativeEvalPass<'_> {
    fn eval_batch(&self, x: &Tensor, y: &Tensor) -> Result<EvalStats> {
        self.backend
            .eval_stats(&self.params, &self.state, &self.packed, x, y)
    }
}

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn supports_dynamic_batch(&self) -> bool {
        true
    }

    fn eval_pass<'a>(
        &'a self,
        params_state: &'a [Tensor],
    ) -> Result<Option<Box<dyn EvalPass + 'a>>> {
        let n_p = self.model.params.len();
        let params = to_vecs(&params_state[..n_p])?;
        let state = to_vecs(&params_state[n_p..])?;
        let packed = self.pack_eval_weights(&params)?;
        Ok(Some(Box::new(NativeEvalPass { backend: self, params, state, packed })))
    }

    fn model(&self) -> &BackendModel {
        &self.model
    }

    fn set_fault_plan(&mut self, plan: FaultPlan) -> Result<()> {
        let n_layers = self.cfg.gemm_layers().len();
        let layer = match plan.site {
            FaultSite::Activation { layer, .. } | FaultSite::Gradient { layer, .. } => layer,
        };
        if layer as usize >= n_layers {
            bail!(
                "fault layer {layer} out of range: {} has {n_layers} GEMM layers",
                self.cfg.name
            );
        }
        self.fault = Some(ArmedFault { plan, fires: AtomicU32::new(0) });
        Ok(())
    }

    fn init(&self, seed: u32) -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(self.model.n_tensors());
        for (i, spec) in self.model.params.iter().enumerate() {
            let n = spec.element_count();
            let t = match spec.kind.as_str() {
                // He-normal from the same init streams the Python-side
                // init uses (2000+i, disjoint from error/dropout).
                "conv_w" | "dense_w" => {
                    let fan_in: usize =
                        spec.shape[..spec.shape.len() - 1].iter().product();
                    let std = (2.0 / fan_in as f64).sqrt() as f32;
                    let z = counter_normal(seed, INIT_STREAM_OFFSET + i as u32, 0, n);
                    Tensor::from_f32(&spec.shape, z.iter().map(|&v| v * std).collect())?
                }
                "bn_gamma" => Tensor::from_f32(&spec.shape, vec![1.0; n])?,
                _ => Tensor::from_f32(&spec.shape, vec![0.0; n])?,
            };
            out.push(t);
        }
        for spec in &self.model.state {
            let n = spec.element_count();
            let fill = if spec.name.ends_with("bn_var") { 1.0 } else { 0.0 };
            out.push(Tensor::from_f32(&spec.shape, vec![fill; n])?);
        }
        for spec in &self.model.params {
            out.push(Tensor::from_f32(&spec.shape, vec![0.0; spec.element_count()])?);
        }
        Ok(out)
    }

    fn train_step(
        &self,
        tensors: &[Tensor],
        x: &Tensor,
        y: &Tensor,
        k: StepInputs,
    ) -> Result<(Vec<Tensor>, StepStats)> {
        let n_p = self.model.params.len();
        let n_s = self.model.state.len();
        let params = to_vecs(&tensors[..n_p])?;
        let state = to_vecs(&tensors[n_p..n_p + n_s])?;
        let opt = to_vecs(&tensors[n_p + n_s..])?;
        let xs = x.as_f32()?;
        let ys = y.as_i32()?;
        let n = self.model.examples_of(xs.len())?;
        check_labels(&ys, n, self.cfg.num_classes)?;

        let act_fault = self.fault_fire(k.step, false);
        let fwd = self.forward_train(&params, &state, &xs, n, k, act_fault)?;
        let (ce, acc, dlogits) =
            layers::softmax_ce_grad(&fwd.logits, &ys, n, self.cfg.num_classes);
        let mut grads = self.backward(&fwd, dlogits, &params, k, n)?;
        if let Some((layer, value)) = self.fault_fire(k.step, true) {
            // Poison the layer's weight gradient: the loss stays finite
            // this step, so the optimizer commits NaN parameters — the
            // failure mode only a post-step parameter scan catches.
            let (_, _, pw) = self.cfg.gemm_layers()[layer as usize];
            for g in grads[pw].iter_mut() {
                *g = value;
            }
        }

        // SGD with momentum: v' = mom*v + g; p' = p - lr*v'.
        let mom = self.cfg.sgd_momentum;
        let mut out = Vec::with_capacity(tensors.len());
        let mut new_opt: Vec<Vec<f32>> = Vec::with_capacity(n_p);
        for (v, g) in opt.iter().zip(&grads) {
            let nv: Vec<f32> =
                v.iter().zip(g).map(|(&vv, &gv)| mom * vv + gv).collect();
            new_opt.push(nv);
        }
        for (i, p) in params.iter().enumerate() {
            let nv = &new_opt[i];
            let data: Vec<f32> =
                p.iter().zip(nv).map(|(&pv, &vv)| pv - k.lr * vv).collect();
            out.push(Tensor::from_f32(tensors[i].shape(), data)?);
        }
        for (i, s) in fwd.new_state.iter().enumerate() {
            out.push(Tensor::from_f32(tensors[n_p + i].shape(), s.clone())?);
        }
        for (i, v) in new_opt.into_iter().enumerate() {
            out.push(Tensor::from_f32(tensors[n_p + n_s + i].shape(), v)?);
        }
        Ok((out, StepStats { loss: ce, accuracy: acc }))
    }

    fn eval_batch(
        &self,
        params_state: &[Tensor],
        x: &Tensor,
        y: &Tensor,
    ) -> Result<EvalStats> {
        let n_p = self.model.params.len();
        let params = to_vecs(&params_state[..n_p])?;
        let state = to_vecs(&params_state[n_p..])?;
        let packed = self.pack_eval_weights(&params)?;
        self.eval_stats(&params, &state, &packed, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_declare_consistent_layouts() {
        for name in ["micro", "tiny", "small", "vgg16"] {
            let cfg = NativeConfig::preset(name).unwrap();
            let model = cfg.backend_model();
            // One (w, b, gamma, beta) quad per conv/dense layer plus the
            // classifier pair; two running stats per BN layer.
            let n_layers: usize =
                cfg.blocks.iter().map(|b| b.len()).sum::<usize>() + cfg.dense.len();
            assert_eq!(model.params.len(), 4 * n_layers + 2, "{name}");
            assert_eq!(model.state.len(), 2 * n_layers, "{name}");
            assert_eq!(model.tensor_names().len(), model.n_tensors(), "{name}");
        }
        assert!(NativeConfig::preset("nope").is_err());
    }

    #[test]
    fn gemm_layers_agree_with_param_specs() {
        // The shared layer-shape walk must name exactly the weight
        // tensors of the declared layout, with matching dimensions.
        for name in ["micro", "tiny", "small", "vgg16"] {
            let cfg = NativeConfig::preset(name).unwrap();
            let specs = cfg.param_specs();
            let layers = cfg.gemm_layers();
            let n_layers: usize =
                cfg.blocks.iter().map(|b| b.len()).sum::<usize>() + cfg.dense.len();
            assert_eq!(layers.len(), n_layers + 1, "{name}"); // + classifier
            for (kin, kout, pi) in layers {
                let spec = &specs[pi];
                assert!(
                    spec.kind == "conv_w" || spec.kind == "dense_w",
                    "{name}: {} is {}",
                    spec.name,
                    spec.kind
                );
                assert_eq!(spec.element_count(), kin * kout, "{name}: {}", spec.name);
                assert_eq!(*spec.shape.last().unwrap(), kout, "{name}: {}", spec.name);
            }
        }
    }

    #[test]
    fn tiny_matches_manifest_geometry() {
        // The native `tiny` must agree with the artifact manifest's
        // tiny (8x8 input, 2 blocks, 3914 params — the count the
        // failure-injection test pins against the real manifest).
        let model = NativeConfig::preset("tiny").unwrap().backend_model();
        let total: usize = model.params.iter().map(|p| p.element_count()).sum();
        assert_eq!(total, 3914);
        assert_eq!(model.batch, 16);
        assert_eq!(model.eval_batch, 64);
        assert_eq!(model.params[0].shape, vec![3, 3, 3, 8]);
        assert_eq!(model.params.last().unwrap().shape, vec![10]);
    }

    fn micro_batch() -> (Tensor, Tensor, StepInputs) {
        let x = Tensor::from_f32(&[4, 4, 4, 3], vec![0.1; 4 * 4 * 4 * 3]).unwrap();
        let y = Tensor::from_i32(&[4], vec![0, 1, 2, 3]).unwrap();
        let k = StepInputs {
            seed_err: 1,
            seed_drop: 1,
            sigma: 0.0,
            lr: 0.01,
            approx: false,
            step: 0,
        };
        (x, y, k)
    }

    #[test]
    fn armed_activation_fault_fires_at_its_step_within_budget() {
        let mut b = NativeBackend::new("micro", MultSpec::Exact).unwrap();
        b.set_fault_plan(FaultPlan::nan_activation(1, 0)).unwrap();
        let tensors = b.init(3).unwrap();
        let (x, y, k0) = micro_batch();
        // Step 0: not the target step — clean.
        let (t1, s0) = b.train_step(&tensors, &x, &y, k0).unwrap();
        assert!(s0.loss.is_finite());
        // Step 1: the fault fires and the loss blows up.
        let k1 = StepInputs { step: 1, ..k0 };
        let (_, s1) = b.train_step(&t1, &x, &y, k1).unwrap();
        assert!(!s1.loss.is_finite());
        // Budget of 1 exhausted: revisiting step 1 is clean again (the
        // rollback-then-escalate replay path relies on this).
        let (_, s2) = b.train_step(&t1, &x, &y, k1).unwrap();
        assert!(s2.loss.is_finite());
        // Out-of-range layer is refused up front.
        assert!(b.set_fault_plan(FaultPlan::nan_activation(0, 99)).is_err());
    }

    #[test]
    fn gradient_fault_poisons_params_behind_a_finite_loss() {
        let mut b = NativeBackend::new("micro", MultSpec::Exact).unwrap();
        b.set_fault_plan(FaultPlan::nan_gradient(0, 0)).unwrap();
        let tensors = b.init(3).unwrap();
        let (x, y, k) = micro_batch();
        let (out, stats) = b.train_step(&tensors, &x, &y, k).unwrap();
        // The insidious case: this step's loss is fine...
        assert!(stats.loss.is_finite());
        // ...but the committed first-layer weights are poisoned.
        assert!(!out[0].all_finite());
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let b = NativeBackend::new("micro", MultSpec::Exact).unwrap();
        let t1 = b.init(7).unwrap();
        let t2 = b.init(7).unwrap();
        let t3 = b.init(8).unwrap();
        assert_eq!(t1.len(), b.model().n_tensors());
        for (a, c) in t1.iter().zip(&t2) {
            assert_eq!(a, c);
        }
        assert!(t1.iter().zip(&t3).any(|(a, c)| a != c));
        b.model().validate_tensors(&t1).unwrap();
    }
}
