//! `TrainSession`: model + optimizer + BN state threaded through a
//! pluggable execution [`Backend`].
//!
//! The session owns the host copies of all stateful tensors and the
//! per-step knob ABI ([`StepInputs`]); *how* a step is computed is the
//! backend's business ([`super::PjrtBackend`] for compiled XLA graphs,
//! [`super::NativeBackend`] for the pure-Rust bit-accurate path). It
//! exposes exactly the knobs the paper's procedures need per step — the
//! error sigma/seed, the active-multiplier switch and the learning rate
//! — so the coordinator's policies stay pure control logic.

use anyhow::{bail, Result};

use super::backend::{Backend, BackendModel, EvalPass};
use super::engine::Engine;
use super::pjrt_backend::PjrtBackend;
use crate::tensor::Tensor;

/// Scalar knobs for one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepInputs {
    /// Error-matrix seed. Constant per run = the paper's fixed error
    /// matrices; varied per step = the resampling ablation.
    pub seed_err: u32,
    /// Dropout seed (always varied per step by the trainer).
    pub seed_drop: u32,
    /// Gaussian SD of the relative multiplier error; `0.0` = exact.
    /// Only meaningful for the `gaussian:<sigma>` surrogate — the PJRT
    /// graphs consume it as a runtime scalar.
    pub sigma: f32,
    pub lr: f32,
    /// Whether the configured approximate multiplier is in force this
    /// step (`false` = exact phase of a hybrid schedule). The native
    /// backend switches its GEMM design on this; the PJRT graphs encode
    /// the same switch through `sigma`.
    pub approx: bool,
    /// The trainer's global step (epoch * steps_per_epoch +
    /// step_in_epoch). Diagnostic and fault-keying only
    /// ([`crate::testkit::faults::FaultPlan`]): it never feeds seeds or
    /// math, so trajectories are independent of it.
    pub step: u64,
}

/// Typed marker for the session's non-finite-loss guard, carried
/// through the `anyhow` chain so the watchdog can classify the failure
/// without string matching.
#[derive(Debug, Clone, Copy)]
pub struct NonFiniteLoss {
    /// `steps_run` at the time of the trip (session-local count).
    pub step: u64,
}

impl std::fmt::Display for NonFiniteLoss {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "non-finite loss at step {}", self.step)
    }
}

impl std::error::Error for NonFiniteLoss {}

/// Outcome of one step.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    /// Cross-entropy part of the loss (excludes the L2 term).
    pub loss: f32,
    /// Minibatch training accuracy.
    pub accuracy: f32,
}

/// Outcome of one eval batch.
#[derive(Debug, Clone, Copy)]
pub struct EvalStats {
    pub loss_sum: f32,
    pub correct: i64,
    pub total: usize,
}

/// Training-state container bound to one backend instance.
pub struct TrainSession {
    backend: Box<dyn Backend>,
    /// params ++ state ++ opt, manifest order.
    tensors: Vec<Tensor>,
    steps_run: u64,
}

impl TrainSession {
    /// PJRT-backed session (compiled artifacts) with freshly
    /// initialized state — init runs *in XLA*, so a Rust-driven run
    /// reproduces the Python-side init bit-for-bit.
    pub fn new(engine: &Engine, preset: &str, seed: u32) -> Result<Self> {
        Self::with_backend(Box::new(PjrtBackend::new(engine, preset)?), seed)
    }

    /// Session over an arbitrary backend with freshly initialized state.
    pub fn with_backend(backend: Box<dyn Backend>, seed: u32) -> Result<Self> {
        let tensors = backend.init(seed)?;
        backend.model().validate_tensors(&tensors)?;
        Ok(TrainSession { backend, tensors, steps_run: 0 })
    }

    /// Restore a PJRT session from checkpointed tensors
    /// (params++state++opt).
    pub fn from_checkpoint(
        engine: &Engine,
        preset: &str,
        tensors: Vec<Tensor>,
    ) -> Result<Self> {
        Self::with_backend_tensors(Box::new(PjrtBackend::new(engine, preset)?), tensors)
    }

    /// Restore a session over an arbitrary backend from checkpointed
    /// tensors.
    pub fn with_backend_tensors(
        backend: Box<dyn Backend>,
        tensors: Vec<Tensor>,
    ) -> Result<Self> {
        backend.model().validate_tensors(&tensors)?;
        Ok(TrainSession { backend, tensors, steps_run: 0 })
    }

    pub fn preset(&self) -> &str {
        &self.backend.model().preset
    }

    /// Which backend is executing: `"pjrt"` or `"native"`.
    pub fn backend_kind(&self) -> &'static str {
        self.backend.kind()
    }

    /// The backend-agnostic model description.
    pub fn model(&self) -> &BackendModel {
        self.backend.model()
    }

    pub fn batch_size(&self) -> usize {
        self.backend.model().batch
    }

    pub fn eval_batch_size(&self) -> usize {
        self.backend.model().eval_batch
    }

    /// Whether the backend accepts batches smaller than the declared
    /// batch sizes (no static-shape graphs).
    pub fn supports_dynamic_batch(&self) -> bool {
        self.backend.supports_dynamic_batch()
    }

    pub fn steps_run(&self) -> u64 {
        self.steps_run
    }

    /// Reset the step counter — checkpoint restore rewinds it to the
    /// snapshot's recorded step so diagnostics stay truthful.
    pub fn set_steps_run(&mut self, n: u64) {
        self.steps_run = n;
    }

    /// Re-initialize the state tensors from scratch at `seed` (rollback
    /// target of last resort when no valid checkpoint exists).
    pub fn reinit(&mut self, seed: u32) -> Result<()> {
        let tensors = self.backend.init(seed)?;
        self.backend.model().validate_tensors(&tensors)?;
        self.tensors = tensors;
        self.steps_run = 0;
        Ok(())
    }

    /// Arm a deterministic training-path fault on the backend
    /// ([`crate::testkit::faults`]). Errors if the backend has no
    /// injection hooks.
    pub fn set_fault_plan(&mut self, plan: crate::testkit::faults::FaultPlan) -> Result<()> {
        self.backend.set_fault_plan(plan)
    }

    /// All stateful tensors (params ++ state ++ opt) — checkpoint payload.
    pub fn state_tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    /// Model parameters only.
    pub fn params(&self) -> &[Tensor] {
        &self.tensors[..self.backend.model().params.len()]
    }

    /// One SGD step on a minibatch.
    ///
    /// `x` must be `[batch, hw, hw, c]` f32, `y` `[batch]` i32.
    pub fn step(&mut self, x: Tensor, y: Tensor, k: StepInputs) -> Result<StepStats> {
        let model = self.backend.model();
        if self.backend.supports_dynamic_batch() {
            // No static shape: any whole number of examples up to the
            // configured batch (short final batches train fine).
            model.check_dynamic_len(x.len(), model.input_elems())?;
        } else if x.len() != model.input_elems() {
            bail!(
                "{}: x has {} elements, expected {}",
                model.preset,
                x.len(),
                model.input_elems()
            );
        }
        let (tensors, stats) = self.backend.train_step(&self.tensors, &x, &y, k)?;
        if !stats.loss.is_finite() {
            // State is NOT committed: the session stays at its pre-step
            // tensors, so a caller that survives this error still holds
            // a coherent snapshot.
            return Err(anyhow::Error::new(NonFiniteLoss { step: self.steps_run })
                .context(format!(
                    "{}: non-finite loss at step {}",
                    self.backend.model().preset,
                    self.steps_run
                )));
        }
        self.tensors = tensors;
        self.steps_run += 1;
        Ok(stats)
    }

    /// Evaluate one batch with exact multipliers (error layers removed,
    /// matching the paper's test procedure).
    pub fn eval_batch(&self, x: Tensor, y: Tensor) -> Result<EvalStats> {
        let model = self.backend.model();
        if x.len() != model.eval_input_elems() {
            bail!(
                "{}: eval x has {} elements, expected {}",
                model.preset,
                x.len(),
                model.eval_input_elems()
            );
        }
        let n = model.params.len() + model.state.len();
        self.backend.eval_batch(&self.tensors[..n], &x, &y)
    }

    /// Start an evaluation pass at the current parameters: per-pass
    /// setup (the native backend decomposes every weight matrix once)
    /// is amortized across all batches evaluated through the returned
    /// handle. Backends without such setup fall back to per-batch
    /// [`TrainSession::eval_batch`] semantics transparently.
    pub fn eval_pass(&self) -> Result<SessionEval<'_>> {
        let model = self.backend.model();
        let n = model.params.len() + model.state.len();
        let tensors = &self.tensors[..n];
        let pass = self.backend.eval_pass(tensors)?;
        Ok(SessionEval { backend: self.backend.as_ref(), tensors, pass })
    }

    /// Replace the full state vector (used by checkpoint restore-in-place).
    pub fn restore(&mut self, tensors: Vec<Tensor>) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            bail!(
                "restore: {} tensors, expected {}",
                tensors.len(),
                self.tensors.len()
            );
        }
        for (new, old) in tensors.iter().zip(&self.tensors) {
            if new.shape() != old.shape() {
                bail!("restore: shape mismatch {:?} vs {:?}", new.shape(), old.shape());
            }
        }
        self.tensors = tensors;
        Ok(())
    }
}

/// Inference-only session: params++state with the optimizer tail
/// dropped at construction. This is the long-lived owner the serving
/// path wants — a restored checkpoint's optimizer tensors are dead
/// weight at inference time (for the `vgg16` preset they double the
/// resident footprint), and a session that cannot step cannot corrupt
/// its weights. Accepts either a full training checkpoint
/// (params++state++opt, tail truncated) or an eval-only vector.
pub struct EvalOnlySession {
    backend: Box<dyn Backend>,
    /// params ++ state, manifest order — no optimizer tail.
    tensors: Vec<Tensor>,
}

impl EvalOnlySession {
    /// Session over restored tensors; shape-validated against the
    /// backend manifest, optimizer tail (if present) dropped.
    pub fn from_tensors(backend: Box<dyn Backend>, mut tensors: Vec<Tensor>) -> Result<Self> {
        let eval_len = backend.model().validate_eval_tensors(&tensors)?;
        tensors.truncate(eval_len);
        Ok(EvalOnlySession { backend, tensors })
    }

    /// Session at freshly initialized weights (no checkpoint — smoke
    /// tests and cold-start serving).
    pub fn fresh(backend: Box<dyn Backend>, seed: u32) -> Result<Self> {
        let tensors = backend.init(seed)?;
        Self::from_tensors(backend, tensors)
    }

    pub fn model(&self) -> &BackendModel {
        self.backend.model()
    }

    /// The resident params ++ state vector.
    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    /// Evaluate one batch (exact multipliers, no amortized setup).
    pub fn eval_batch(&self, x: Tensor, y: Tensor) -> Result<EvalStats> {
        let model = self.backend.model();
        if self.backend.supports_dynamic_batch() {
            model.check_dynamic_len(x.len(), model.eval_input_elems())?;
        } else if x.len() != model.eval_input_elems() {
            bail!(
                "{}: eval x has {} elements, expected {}",
                model.preset,
                x.len(),
                model.eval_input_elems()
            );
        }
        self.backend.eval_batch(&self.tensors, &x, &y)
    }

    /// Start an amortized evaluation pass (see
    /// [`TrainSession::eval_pass`]).
    pub fn eval_pass(&self) -> Result<SessionEval<'_>> {
        let pass = self.backend.eval_pass(&self.tensors)?;
        Ok(SessionEval { backend: self.backend.as_ref(), tensors: &self.tensors, pass })
    }
}

/// One evaluation pass bound to a session's current parameters (see
/// [`TrainSession::eval_pass`]). Holds the backend's amortized
/// per-pass state when it provides one; otherwise forwards each batch
/// to [`Backend::eval_batch`].
pub struct SessionEval<'a> {
    backend: &'a dyn Backend,
    /// params ++ state prefix of the session's state vector.
    tensors: &'a [Tensor],
    pass: Option<Box<dyn EvalPass + 'a>>,
}

impl SessionEval<'_> {
    /// Evaluate one batch with exact multipliers. Dynamic-batch
    /// backends accept a short final batch; static-shape backends need
    /// exactly the model's eval batch.
    pub fn eval_batch(&self, x: Tensor, y: Tensor) -> Result<EvalStats> {
        let model = self.backend.model();
        if self.backend.supports_dynamic_batch() {
            model.check_dynamic_len(x.len(), model.eval_input_elems())?;
        } else if x.len() != model.eval_input_elems() {
            bail!(
                "{}: eval x has {} elements, expected {}",
                model.preset,
                x.len(),
                model.eval_input_elems()
            );
        }
        match &self.pass {
            Some(p) => p.eval_batch(&x, &y),
            None => self.backend.eval_batch(self.tensors, &x, &y),
        }
    }
}
