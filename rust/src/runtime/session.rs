//! `TrainSession`: model + optimizer + BN state bound to compiled
//! train/eval/init executables.
//!
//! The session owns the host copies of all stateful tensors and threads
//! them through the positional train-step ABI. It exposes exactly the
//! knobs the paper's procedures need per step: the error sigma, the
//! error seed (fixed vs resampled), and the learning rate — so the
//! coordinator's policies stay pure control logic.

use anyhow::{bail, Context, Result};

use super::engine::{Engine, Executable};
use super::manifest::ModelManifest;
use crate::tensor::Tensor;

/// Scalar knobs for one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepInputs {
    /// Error-matrix seed. Constant per run = the paper's fixed error
    /// matrices; varied per step = the resampling ablation.
    pub seed_err: u32,
    /// Dropout seed (always varied per step by the trainer).
    pub seed_drop: u32,
    /// Gaussian SD of the relative multiplier error; `0.0` = exact.
    pub sigma: f32,
    pub lr: f32,
}

/// Outcome of one step.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    /// Cross-entropy part of the loss (excludes the L2 term).
    pub loss: f32,
    /// Minibatch training accuracy.
    pub accuracy: f32,
}

/// Outcome of one eval batch.
#[derive(Debug, Clone, Copy)]
pub struct EvalStats {
    pub loss_sum: f32,
    pub correct: i64,
    pub total: usize,
}

/// Training-state container bound to one preset's executables.
pub struct TrainSession {
    preset: String,
    train: Executable,
    eval: Executable,
    n_params: usize,
    n_state: usize,
    batch: usize,
    eval_batch: usize,
    input_elems: usize,
    eval_input_elems: usize,
    /// params ++ state ++ opt, manifest order.
    tensors: Vec<Tensor>,
    steps_run: u64,
}

impl TrainSession {
    /// Create a session with freshly initialized (seeded) model state by
    /// running the compiled `init` graph — init happens *in XLA*, so a
    /// Rust-driven run reproduces the Python-side init bit-for-bit.
    pub fn new(engine: &Engine, preset: &str, seed: u32) -> Result<Self> {
        let model = engine.manifest().model(preset)?;
        let init = engine.load(preset, "init")?;
        let tensors = init.run(&[Tensor::scalar_u32(seed)])?;
        Self::from_tensors(engine, preset, tensors, model)
    }

    /// Restore a session from checkpointed tensors (params++state++opt).
    pub fn from_checkpoint(
        engine: &Engine,
        preset: &str,
        tensors: Vec<Tensor>,
    ) -> Result<Self> {
        let model = engine.manifest().model(preset)?;
        Self::from_tensors(engine, preset, tensors, model)
    }

    fn from_tensors(
        engine: &Engine,
        preset: &str,
        tensors: Vec<Tensor>,
        model: &ModelManifest,
    ) -> Result<Self> {
        let n_params = model.params.len();
        let n_state = model.state.len();
        if tensors.len() != 2 * n_params + n_state {
            bail!(
                "{preset}: state vector has {} tensors, expected {}",
                tensors.len(),
                2 * n_params + n_state
            );
        }
        for (t, spec) in tensors.iter().zip(
            model.params.iter().chain(model.state.iter()).chain(model.params.iter()),
        ) {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "{preset}: tensor {} shape {:?} != manifest {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        let train = engine.load(preset, "train")?;
        let eval = engine.load(preset, "eval")?;
        let hw = model.input_hw;
        Ok(TrainSession {
            preset: preset.to_string(),
            train,
            eval,
            n_params,
            n_state,
            batch: model.batch,
            eval_batch: model.eval_batch,
            input_elems: model.batch * hw * hw * model.in_ch,
            eval_input_elems: model.eval_batch * hw * hw * model.in_ch,
            tensors,
            steps_run: 0,
        })
    }

    pub fn preset(&self) -> &str {
        &self.preset
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn eval_batch_size(&self) -> usize {
        self.eval_batch
    }

    pub fn steps_run(&self) -> u64 {
        self.steps_run
    }

    /// All stateful tensors (params ++ state ++ opt) — checkpoint payload.
    pub fn state_tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    /// Model parameters only.
    pub fn params(&self) -> &[Tensor] {
        &self.tensors[..self.n_params]
    }

    /// One SGD step on a minibatch.
    ///
    /// `x` must be `[batch, hw, hw, c]` f32, `y` `[batch]` i32.
    pub fn step(&mut self, x: Tensor, y: Tensor, k: StepInputs) -> Result<StepStats> {
        if x.len() != self.input_elems {
            bail!(
                "{}: x has {} elements, expected {}",
                self.preset,
                x.len(),
                self.input_elems
            );
        }
        // Scalars live on the stack; state tensors are passed by
        // reference — no per-step copy of the model state on the host
        // side (EXPERIMENTS.md §Perf).
        let scalars = [
            Tensor::scalar_u32(k.seed_err),
            Tensor::scalar_u32(k.seed_drop),
            Tensor::scalar_f32(k.sigma),
            Tensor::scalar_f32(k.lr),
        ];
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(self.tensors.len() + 6);
        inputs.extend(self.tensors.iter());
        inputs.push(&x);
        inputs.push(&y);
        inputs.extend(scalars.iter());

        let mut outputs = self.train.run_refs(&inputs).context("train step")?;
        let acc = outputs.pop().expect("acc output").scalar_as_f32()?;
        let loss = outputs.pop().expect("loss output").scalar_as_f32()?;
        if !loss.is_finite() {
            bail!("{}: non-finite loss at step {}", self.preset, self.steps_run);
        }
        self.tensors = outputs;
        self.steps_run += 1;
        Ok(StepStats { loss, accuracy: acc })
    }

    /// Evaluate one batch with exact multipliers (error layers removed,
    /// matching the paper's test procedure).
    pub fn eval_batch(&self, x: Tensor, y: Tensor) -> Result<EvalStats> {
        if x.len() != self.eval_input_elems {
            bail!(
                "{}: eval x has {} elements, expected {}",
                self.preset,
                x.len(),
                self.eval_input_elems
            );
        }
        let mut inputs: Vec<&Tensor> =
            Vec::with_capacity(self.n_params + self.n_state + 2);
        inputs.extend(self.tensors[..self.n_params + self.n_state].iter());
        inputs.push(&x);
        inputs.push(&y);
        let outputs = self.eval.run_refs(&inputs).context("eval step")?;
        Ok(EvalStats {
            loss_sum: outputs[0].scalar_as_f32()?,
            correct: outputs[1].scalar_as_i32()? as i64,
            total: self.eval_batch,
        })
    }

    /// Replace the full state vector (used by checkpoint restore-in-place).
    pub fn restore(&mut self, tensors: Vec<Tensor>) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            bail!(
                "restore: {} tensors, expected {}",
                tensors.len(),
                self.tensors.len()
            );
        }
        for (new, old) in tensors.iter().zip(&self.tensors) {
            if new.shape() != old.shape() {
                bail!("restore: shape mismatch {:?} vs {:?}", new.shape(), old.shape());
            }
        }
        self.tensors = tensors;
        Ok(())
    }
}
