//! PJRT engine: client + artifact registry + compile cache.

// detlint: allow(D1) -- compile cache is keyed lookup only ("preset/kind" -> Slot), never iterated
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{EntrySpec, Manifest};
use super::{literal_to_tensor, tensor_to_literal};
use crate::tensor::Tensor;

/// A compiled entry point plus its manifest spec. Cheap to clone.
#[derive(Clone)]
pub struct Executable {
    exe: Arc<xla::PjRtLoadedExecutable>,
    spec: Arc<EntrySpec>,
}

impl Executable {
    pub fn spec(&self) -> &EntrySpec {
        &self.spec
    }

    /// Run with host tensors, validating count/shape/dtype against the
    /// manifest, and untuple the result back to host tensors.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.run_refs(&inputs.iter().collect::<Vec<_>>())
    }

    /// Like [`Executable::run`] but borrowing the inputs — the step
    /// loop passes the session's resident state without cloning it
    /// (EXPERIMENTS.md §Perf).
    pub fn run_refs(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.file,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if t.shape() != s.shape.as_slice() || t.dtype() != s.dtype {
                bail!(
                    "{}: input {i} ({}) expects {}{:?}, got {}{:?}",
                    self.spec.file,
                    s.name,
                    s.dtype,
                    s.shape,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.spec.file))?;
        let mut root = result[0][0]
            .to_literal_sync()
            .context("fetching result tuple")?;
        // aot.py lowers with return_tuple=True: one top-level tuple.
        let parts = root.decompose_tuple().context("untupling result")?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: {} outputs, manifest says {}",
                self.spec.file,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts.iter().map(literal_to_tensor).collect()
    }
}

/// One compile slot per cache entry: racing loaders of the *same*
/// entry serialize on the slot's lock while different entries compile
/// concurrently. The outer map lock is never held across a compile.
type Slot = Arc<Mutex<Option<Executable>>>;

/// Owns the PJRT client, the manifest, and a per-entry compile cache.
/// One `Engine` per process; sessions and sweeps share it (`&Engine` is
/// `Sync` — PJRT CPU executables are thread-safe for execution).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Slot>>, // detlint: allow(D1) -- lookup-only compile cache, never iterated
    compiled: AtomicUsize,
}

impl Engine {
    /// Create a CPU-PJRT engine over an artifacts directory.
    pub fn from_artifacts(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()), // detlint: allow(D1) -- lookup-only compile cache, never iterated
            compiled: AtomicUsize::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compiling on first use) the `kind` entry of `preset`.
    ///
    /// Thread-safe without duplicated work: the previous
    /// check-then-insert let two threads compile the same entry
    /// concurrently (and double-count compile time); now each entry has
    /// one slot — the second loader blocks on the slot until the first
    /// finishes, then reuses its executable. A failed compile leaves
    /// the slot empty, so the next caller retries instead of caching
    /// the error.
    pub fn load(&self, preset: &str, kind: &str) -> Result<Executable> {
        let key = format!("{preset}/{kind}");
        let slot: Slot = {
            let mut cache = self.cache.lock().unwrap();
            Arc::clone(cache.entry(key.clone()).or_default())
        };
        let mut entry = slot.lock().unwrap();
        if let Some(e) = entry.as_ref() {
            return Ok(e.clone());
        }
        let model = self.manifest.model(preset)?;
        let spec = model.entry(kind)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let started = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        log::info!("compiled {key} in {:.2?}", started.elapsed());
        let executable =
            Executable { exe: Arc::new(exe), spec: Arc::new(spec) };
        *entry = Some(executable.clone());
        self.compiled.fetch_add(1, Ordering::Relaxed);
        Ok(executable)
    }

    /// Number of successfully compiled entries currently cached.
    pub fn cached_executables(&self) -> usize {
        self.compiled.load(Ordering::Relaxed)
    }
}
