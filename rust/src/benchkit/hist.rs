//! Fixed-bucket latency histogram with deterministic edges.
//!
//! `serve-bench` (and future training-step timing) need percentiles
//! that are *reproducible artifacts*: the same set of recorded
//! latencies must report the same p50/p95/p99 on every run, every
//! platform, every thread count. Sorting raw sample vectors gets that
//! too, but costs O(n log n) memory-resident samples; a histogram with
//! a fixed, deterministic bucket layout gets it in O(buckets) with
//! exact-from-counts percentiles (each percentile answers with its
//! bucket's inclusive upper edge — a deterministic, conservative
//! over-estimate bounded by the ~25% bucket width).
//!
//! Bucket edges are geometric over integer microseconds: starting at
//! 1µs each next edge is `prev + max(1, prev/4)` (~×1.25), capped at
//! one hour. The sequence is pure integer arithmetic — identical on
//! every build — so histograms from different workers merge bucket-
//! for-bucket and serialized artifacts diff cleanly across PRs.

/// Inclusive upper edge of the last regular bucket: one hour in µs.
const MAX_EDGE_US: u64 = 3_600_000_000;

/// Deterministic geometric edge sequence. Bucket `i` covers
/// `(edges[i-1], edges[i]]` in µs (bucket 0 covers `[0, edges[0]]`);
/// values above the last edge land in a single overflow bucket whose
/// percentile answer is the recorded maximum.
fn edges() -> Vec<u64> {
    let mut v = Vec::with_capacity(128);
    let mut e: u64 = 1;
    while e < MAX_EDGE_US {
        v.push(e);
        e += (e / 4).max(1);
    }
    v.push(MAX_EDGE_US);
    v
}

/// Fixed-bucket latency histogram over integer microseconds.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    edges: Vec<u64>,
    /// One count per edge, plus a final overflow bucket.
    counts: Vec<u64>,
    total: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        let edges = edges();
        let counts = vec![0u64; edges.len() + 1];
        LatencyHistogram { edges, counts, total: 0, max_us: 0 }
    }

    /// Record one latency observation in microseconds.
    pub fn record(&mut self, us: u64) {
        // First bucket whose upper edge admits the value; everything
        // past the last edge is the overflow bucket.
        let idx = self.edges.partition_point(|&e| e < us);
        if let Some(c) = self.counts.get_mut(idx) {
            *c += 1;
        }
        self.total += 1;
        self.max_us = self.max_us.max(us);
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Largest recorded observation (0 when empty).
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Exact-from-counts percentile: the inclusive upper edge of the
    /// bucket holding the `ceil(p/100 · total)`-th smallest
    /// observation. Overflow-bucket answers report the recorded max.
    /// Returns 0 for an empty histogram.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let p = p.clamp(0.0, 100.0);
        // k-th order statistic, 1-based; p=0 degenerates to k=1.
        let k = ((p / 100.0 * self.total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= k {
                // Overflow bucket (or any bucket the max falls in):
                // never answer above the recorded maximum.
                return match self.edges.get(i) {
                    Some(&edge) => edge.min(self.max_us),
                    None => self.max_us,
                };
            }
        }
        self.max_us
    }

    /// Merge another histogram's counts into this one. Layouts are
    /// identical by construction, so this is bucket-wise addition.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Standard summary row: `{count, p50_us, p95_us, p99_us, max_us}`.
    pub fn to_json(&self) -> crate::json::Value {
        crate::json::object([
            ("count", crate::json::Value::from(self.total as usize)),
            ("p50_us", crate::json::Value::from(self.percentile_us(50.0) as f64)),
            ("p95_us", crate::json::Value::from(self.percentile_us(95.0) as f64)),
            ("p99_us", crate::json::Value::from(self.percentile_us(99.0) as f64)),
            ("max_us", crate::json::Value::from(self.max_us as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edges_are_strictly_increasing_and_bounded() {
        let e = edges();
        assert!(e.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(*e.first().unwrap(), 1);
        assert_eq!(*e.last().unwrap(), MAX_EDGE_US);
        // Geometric layout stays compact: well under 200 buckets.
        assert!(e.len() < 200, "edge count {}", e.len());
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_us(50.0), 0);
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.max_us(), 0);
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let mut h = LatencyHistogram::new();
        h.record(100);
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.percentile_us(p), 100, "p{p}");
        }
    }

    #[test]
    fn percentiles_at_bucket_boundaries_are_exact() {
        // Values placed exactly on edges must be admitted by their own
        // bucket (inclusive upper edge), so percentiles on a
        // boundary-only population answer with the boundary itself.
        let e = edges();
        let mut h = LatencyHistogram::new();
        // 100 observations: edges[10] × 50, edges[20] × 45, edges[30] × 5.
        for _ in 0..50 {
            h.record(e[10]);
        }
        for _ in 0..45 {
            h.record(e[20]);
        }
        for _ in 0..5 {
            h.record(e[30]);
        }
        assert_eq!(h.count(), 100);
        // k = ceil(0.50·100) = 50 → still inside the first group.
        assert_eq!(h.percentile_us(50.0), e[10]);
        // k = 51 → second group.
        assert_eq!(h.percentile_us(51.0), e[20]);
        // k = 95 → last observation of the second group.
        assert_eq!(h.percentile_us(95.0), e[20]);
        // k = 96..=100 → third group.
        assert_eq!(h.percentile_us(96.0), e[30]);
        assert_eq!(h.percentile_us(99.0), e[30]);
        assert_eq!(h.percentile_us(100.0), e[30]);
    }

    #[test]
    fn conservative_rounding_stays_within_one_bucket() {
        // A value strictly inside a bucket reports that bucket's upper
        // edge: an over-estimate of at most ~25%.
        let mut h = LatencyHistogram::new();
        h.record(1000);
        let p = h.percentile_us(50.0);
        assert!(p >= 1000, "must not under-report: {p}");
        assert!(p <= 1000 + 1000 / 3, "bucket too wide: {p}");
    }

    #[test]
    fn overflow_bucket_reports_recorded_max() {
        let mut h = LatencyHistogram::new();
        h.record(MAX_EDGE_US * 2);
        assert_eq!(h.percentile_us(99.0), MAX_EDGE_US * 2);
        assert_eq!(h.max_us(), MAX_EDGE_US * 2);
    }

    #[test]
    fn zero_latency_lands_in_first_bucket() {
        let mut h = LatencyHistogram::new();
        h.record(0);
        // Upper edge is 1µs but the max is 0, and percentiles never
        // answer above the recorded max.
        assert_eq!(h.percentile_us(50.0), 0);
    }

    #[test]
    fn merge_equals_concatenated_recording() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut both = LatencyHistogram::new();
        for v in [10u64, 200, 3000, 40000] {
            a.record(v);
            both.record(v);
        }
        for v in [7u64, 70, 700_000, 9_999_999] {
            b.record(v);
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.max_us(), both.max_us());
        for p in [50.0, 95.0, 99.0] {
            assert_eq!(a.percentile_us(p), both.percentile_us(p), "p{p}");
        }
    }

    #[test]
    fn json_summary_roundtrips() {
        let mut h = LatencyHistogram::new();
        h.record(500);
        h.record(1500);
        let v = h.to_json();
        let re = crate::json::Value::parse(&v.to_string()).unwrap();
        assert_eq!(re.get("count").unwrap().as_usize().unwrap(), 2);
        assert!(re.get("p99_us").unwrap().as_f64().unwrap() >= 1500.0);
    }
}
