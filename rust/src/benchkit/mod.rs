//! In-tree micro/macro benchmark harness (the environment has no
//! criterion). Used by the `harness = false` bench targets.
//!
//! Methodology: warmup iterations, then timed samples; reports mean,
//! median, p95 and MAD-based outlier count. Deliberately simple, but
//! honest — each sample is a full closure invocation timed with a
//! monotonic clock, and the reporter prints enough distribution shape
//! to spot bimodality.

pub mod hist;

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl Sample {
    fn sorted_nanos(&self) -> Vec<u128> {
        let mut v: Vec<u128> = self.samples.iter().map(|d| d.as_nanos()).collect();
        v.sort_unstable();
        v
    }

    pub fn mean(&self) -> Duration {
        let total: u128 = self.samples.iter().map(|d| d.as_nanos()).sum();
        Duration::from_nanos((total / self.samples.len().max(1) as u128) as u64)
    }

    pub fn median(&self) -> Duration {
        let v = self.sorted_nanos();
        Duration::from_nanos(v[v.len() / 2] as u64)
    }

    pub fn percentile(&self, p: f64) -> Duration {
        let v = self.sorted_nanos();
        let idx = ((v.len() - 1) as f64 * p / 100.0).round() as usize;
        Duration::from_nanos(v[idx] as u64)
    }

    /// Count of samples further than 5 MADs from the median.
    pub fn outliers(&self) -> usize {
        let v = self.sorted_nanos();
        let med = v[v.len() / 2] as i128;
        let mut devs: Vec<i128> = v.iter().map(|&x| (x as i128 - med).abs()).collect();
        devs.sort_unstable();
        let mad = devs[devs.len() / 2].max(1);
        v.iter()
            .filter(|&&x| (x as i128 - med).abs() > 5 * mad)
            .count()
    }
}

/// Benchmark runner with fixed warmup/sample counts.
pub struct Bench {
    warmup: usize,
    samples: usize,
    results: Vec<Sample>,
}

impl Bench {
    pub fn new(warmup: usize, samples: usize) -> Self {
        Bench { warmup, samples, results: Vec::new() }
    }

    /// Quick profile for heavy end-to-end cases.
    pub fn heavy() -> Self {
        Bench::new(1, 5)
    }

    /// Default profile for micro benches.
    pub fn micro() -> Self {
        Bench::new(3, 20)
    }

    /// Time `f` (which should do one unit of work per call).
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> &Sample {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        self.results.push(Sample { name: name.to_string(), samples });
        self.results.last().unwrap()
    }

    /// Machine-readable results:
    /// `[{name, median_ns, mean_ns, p95_ns, samples}, ...]` — the
    /// payload of the `BENCH_*.json` perf-trajectory files.
    pub fn to_json(&self) -> crate::json::Value {
        crate::json::Value::Array(
            self.results
                .iter()
                .map(|s| {
                    crate::json::object([
                        ("name", crate::json::Value::from(s.name.clone())),
                        ("median_ns", (s.median().as_nanos() as f64).into()),
                        ("mean_ns", (s.mean().as_nanos() as f64).into()),
                        ("p95_ns", (s.percentile(95.0).as_nanos() as f64).into()),
                        ("samples", s.samples.len().into()),
                    ])
                })
                .collect(),
        )
    }

    /// Render the standard report table.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>12} {:>9}\n",
            "benchmark", "median", "mean", "p95", "outliers"
        ));
        for s in &self.results {
            out.push_str(&format!(
                "{:<44} {:>12} {:>12} {:>12} {:>9}\n",
                s.name,
                fmt_dur(s.median()),
                fmt_dur(s.mean()),
                fmt_dur(s.percentile(95.0)),
                s.outliers()
            ));
        }
        out
    }

    pub fn results(&self) -> &[Sample] {
        &self.results
    }
}

/// Human duration (ns/µs/ms/s auto-scaled).
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Throughput helper: items/sec given a per-call item count.
pub fn throughput(d: Duration, items: u64) -> f64 {
    items as f64 / d.as_secs_f64().max(1e-12)
}

/// Write a JSON value to `path` — bench harnesses emit
/// `BENCH_<name>.json` files with this so the perf trajectory is
/// machine-readable across PRs.
pub fn save_json(
    path: impl AsRef<std::path::Path>,
    value: &crate::json::Value,
) -> anyhow::Result<()> {
    use anyhow::Context as _;
    let path = path.as_ref();
    std::fs::write(path, format!("{value}\n"))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench::new(1, 5);
        let mut counter = 0u64;
        b.run("noop", || counter += 1);
        assert_eq!(counter, 6); // warmup + samples
        let r = b.report();
        assert!(r.contains("noop"));
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn stats_ordering() {
        let s = Sample {
            name: "x".into(),
            samples: (1..=100).map(Duration::from_nanos).collect(),
        };
        assert!(s.median() <= s.percentile(95.0));
        assert_eq!(s.percentile(100.0), Duration::from_nanos(100));
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_dur(Duration::from_nanos(5)), "5 ns");
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }

    #[test]
    fn throughput_sane() {
        let t = throughput(Duration::from_secs(2), 100);
        assert!((t - 50.0).abs() < 1e-9);
    }

    #[test]
    fn json_report_parses_back() {
        let mut b = Bench::new(0, 3);
        b.run("case", || {
            std::hint::black_box(1 + 1);
        });
        let v = b.to_json();
        let re = crate::json::Value::parse(&v.to_string()).unwrap();
        let rows = re.as_array().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").unwrap().as_str().unwrap(), "case");
        assert_eq!(rows[0].get("samples").unwrap().as_usize().unwrap(), 3);
        assert!(rows[0].get("median_ns").unwrap().as_f64().unwrap() >= 0.0);
    }
}
