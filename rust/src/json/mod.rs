//! Minimal JSON parser/serializer (the environment has no `serde`).
//!
//! Implements the full JSON grammar (RFC 8259) minus some escape-sequence
//! exotica we never emit (`\u` surrogate pairs are handled), with
//! line/column error reporting. The manifest and experiment configs are
//! small (< 1 MB), so the recursive-descent parser favours clarity over
//! zero-copy tricks; throughput is still ~100 MB/s, far from hot.
//!
//! ## Hostile input (the serve wire path)
//!
//! [`Value::parse_bytes`] is the entry point for bytes that arrive
//! over a wire rather than from our own artifacts: it enforces a byte
//! cap *before* parsing, rejects non-UTF-8 input, and — like every
//! parse here — rejects duplicate object keys instead of silently
//! last-write-winning. Each failure mode carries a typed
//! [`JsonFault`] in the `anyhow` chain ([`classify`]) so the wire
//! layer can answer with a machine-readable rejection, never a panic.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// Machine-readable classification of a JSON decode failure on the
/// wire path. Mirrors `checkpoint::FailureClass` in spirit: recovery
/// and rejection code dispatches on the class, the human-readable
/// message keeps the byte-level detail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JsonFaultClass {
    /// Input exceeds the caller's byte cap (checked before parsing, so
    /// an oversized body cannot cost a full parse).
    Oversized,
    /// Input is not valid UTF-8.
    NonUtf8,
    /// An object repeats a member name. RFC 8259 leaves this
    /// undefined; silently keeping the last write would let two
    /// readers disagree about the same document, so it is an error.
    DuplicateKey,
    /// Any other grammar violation.
    Syntax,
}

impl JsonFaultClass {
    pub fn name(self) -> &'static str {
        match self {
            JsonFaultClass::Oversized => "oversized",
            JsonFaultClass::NonUtf8 => "non-utf8",
            JsonFaultClass::DuplicateKey => "duplicate-key",
            JsonFaultClass::Syntax => "syntax",
        }
    }
}

/// Typed JSON decode error carried through `anyhow` chains so callers
/// can reject by class instead of string-matching messages.
#[derive(Debug)]
pub struct JsonFault {
    pub class: JsonFaultClass,
    msg: String,
}

impl fmt::Display for JsonFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for JsonFault {}

fn fault(class: JsonFaultClass, msg: String) -> anyhow::Error {
    anyhow::Error::new(JsonFault { class, msg })
}

/// Walk an error's chain for a JSON-fault classification (context
/// layers added by callers are skipped transparently).
pub fn classify(err: &anyhow::Error) -> Option<JsonFaultClass> {
    err.chain()
        .find_map(|c| c.downcast_ref::<JsonFault>())
        .map(|f| f.class)
}

/// A parsed JSON value. Numbers are kept as `f64` (the manifest has no
/// integers that exceed 2^53).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    /// BTreeMap keeps serialization deterministic (stable diffs, hashable
    /// checkpoint metadata).
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a JSON document from text.
    pub fn parse(src: &str) -> Result<Value> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at {}", p.location());
        }
        Ok(v)
    }

    /// Parse an untrusted byte buffer with a size cap — the wire-path
    /// entry point. The cap is enforced *before* any parsing work, the
    /// buffer must be UTF-8, and every failure (including grammar
    /// errors from the parse itself) carries a typed [`JsonFault`].
    pub fn parse_bytes(bytes: &[u8], max_bytes: usize) -> Result<Value> {
        if bytes.len() > max_bytes {
            return Err(fault(
                JsonFaultClass::Oversized,
                format!("input is {} bytes, cap is {max_bytes}", bytes.len()),
            ));
        }
        let text = std::str::from_utf8(bytes).map_err(|e| {
            fault(JsonFaultClass::NonUtf8, format!("input is not UTF-8: {e}"))
        })?;
        Self::parse(text).map_err(|e| {
            // Duplicate-key (and any future) classifications from the
            // parser pass through; everything else is a syntax fault.
            if classify(&e).is_some() {
                e
            } else {
                fault(JsonFaultClass::Syntax, format!("{e:#}"))
            }
        })
    }

    /// Parse the file at `path`.
    pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Value> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_object(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Ok(m),
            v => bail!("expected object, got {}", v.kind()),
        }
    }

    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(a) => Ok(a),
            v => bail!("expected array, got {}", v.kind()),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::String(s) => Ok(s),
            v => bail!("expected string, got {}", v.kind()),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Number(n) => Ok(*n),
            v => bail!("expected number, got {}", v.kind()),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            v => bail!("expected bool, got {}", v.kind()),
        }
    }

    /// Mandatory object member.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_object()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional object member (`None` when absent or null).
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => match m.get(key) {
                Some(Value::Null) | None => None,
                Some(v) => Some(v),
            },
            _ => None,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Number(_) => "number",
            Value::String(_) => "string",
            Value::Array(_) => "array",
            Value::Object(_) => "object",
        }
    }
}

// -- construction helpers (used by report/metrics emitters) ----------------

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Number(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Number(v as f64)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::String(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::String(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}

/// Build a [`Value::Object`] from `(key, value)` pairs.
pub fn object<I, K, V>(pairs: I) -> Value
where
    I: IntoIterator<Item = (K, V)>,
    K: Into<String>,
    V: Into<Value>,
{
    Value::Object(
        pairs
            .into_iter()
            .map(|(k, v)| (k.into(), v.into()))
            .collect(),
    )
}

// -- serialization ----------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => f.write_str("null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Number(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::String(s) => write_escaped(f, s),
            Value::Array(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Value::Object(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

// -- parser -------------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn location(&self) -> String {
        let (mut line, mut col) = (1usize, 1usize);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        format!("line {line}, column {col}")
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at {}", b as char, self.location());
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected input at {}", self.location()),
        }
    }

    fn literal(&mut self, text: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            bail!("invalid literal at {}", self.location());
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("bad number {text:?} at {}", self.location()))?;
        Ok(Value::Number(n))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string at {}", self.location()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| anyhow!("bad escape at {}", self.location()))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint {cp:#x}"))?,
                            );
                        }
                        c => bail!("bad escape \\{} at {}", c as char, self.location()),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            bail!("truncated \\u escape at {}", self.location());
        }
        let text = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
        self.pos += 4;
        u32::from_str_radix(text, 16)
            .map_err(|_| anyhow!("bad \\u escape {text:?} at {}", self.location()))
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                _ => bail!("expected ',' or ']' at {}", self.location()),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            if map.insert(key.clone(), val).is_some() {
                return Err(fault(
                    JsonFaultClass::DuplicateKey,
                    format!("duplicate object key {key:?} at {}", self.location()),
                ));
            }
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(map));
                }
                _ => bail!("expected ',' or '}}' at {}", self.location()),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Number(-1500.0));
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::String("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert!(v.opt("d").is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("01a").is_err());
        assert!(Value::parse("\"unterminated").is_err());
        assert!(Value::parse("{} trailing").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"n":-3,"obj":{"k":true},"s":"q\"uote"}"#;
        let v = Value::parse(src).unwrap();
        let re = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn duplicate_keys_are_typed_errors_not_last_write_wins() {
        let err = Value::parse(r#"{"a": 1, "a": 2}"#).unwrap_err();
        assert_eq!(classify(&err), Some(JsonFaultClass::DuplicateKey));
        // Nested objects are checked too.
        let err = Value::parse(r#"{"o": {"k": 1, "k": 1}}"#).unwrap_err();
        assert_eq!(classify(&err), Some(JsonFaultClass::DuplicateKey));
    }

    #[test]
    fn parse_bytes_enforces_cap_before_parse() {
        let body = br#"{"k": "v"}"#;
        assert!(Value::parse_bytes(body, 64).is_ok());
        let err = Value::parse_bytes(body, body.len() - 1).unwrap_err();
        assert_eq!(classify(&err), Some(JsonFaultClass::Oversized));
    }

    #[test]
    fn parse_bytes_rejects_non_utf8() {
        let err = Value::parse_bytes(&[b'{', 0xFF, 0xFE, b'}'], 64).unwrap_err();
        assert_eq!(classify(&err), Some(JsonFaultClass::NonUtf8));
    }

    #[test]
    fn parse_bytes_classifies_grammar_errors_as_syntax() {
        let err = Value::parse_bytes(b"{\"k\": ", 64).unwrap_err();
        assert_eq!(classify(&err), Some(JsonFaultClass::Syntax));
        // Duplicate keys keep their more specific class through
        // parse_bytes.
        let err = Value::parse_bytes(br#"{"a":1,"a":1}"#, 64).unwrap_err();
        assert_eq!(classify(&err), Some(JsonFaultClass::DuplicateKey));
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Value::parse("[1]").unwrap();
        assert!(v.as_object().is_err());
        assert!(v.as_array().unwrap()[0].as_str().is_err());
        assert!(Value::parse("1.5").unwrap().as_usize().is_err());
        assert!(Value::parse("-1").unwrap().as_usize().is_err());
    }
}
