//! Hardware cost model: translate multiplier-level gains into
//! network-level training gains, the way the paper's §III does.
//!
//! Inputs: per-design speed/area/power deltas (from the cited
//! literature), the model's per-layer MAC table (from the manifest), and
//! the conv-dominance share of Cong & Xiao [12] (90.7%). Outputs:
//! Amdahl-composed system-level speedups/energy savings for full
//! approximate training and for the paper's hybrid schedule (Table III's
//! utilization column becomes a gain multiplier here).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::runtime::manifest::ModelManifest;

/// Published hardware characteristics of one multiplier design,
/// expressed as fractional improvements over the exact design.
#[derive(Debug, Clone, Copy)]
pub struct HwDesign {
    /// Multiplier critical-path speedup (0.47 = 47% faster).
    pub speed_gain: f64,
    /// Area saving fraction.
    pub area_saving: f64,
    /// Power saving fraction.
    pub power_saving: f64,
    /// Published error stats.
    pub mre: f64,
    pub sd: f64,
}

/// The designs quoted in the paper + representative entries for the
/// other cited families ([4]-[6]; values from the respective papers'
/// headline tables, see DESIGN.md §5 for sourcing).
pub fn cited_designs() -> BTreeMap<&'static str, HwDesign> {
    BTreeMap::from([
        (
            // Hashemi et al., ICCAD'15 — quoted verbatim in the paper.
            "drum6",
            HwDesign {
                speed_gain: 0.47,
                area_saving: 0.50,
                power_saving: 0.59,
                mre: 0.0147,
                sd: 0.01803,
            },
        ),
        (
            // Leon et al., TVLSI'18 (hybrid high-radix encoding family,
            // representative RAD64 point).
            "hrhr",
            HwDesign {
                speed_gain: 0.24,
                area_saving: 0.38,
                power_saving: 0.46,
                mre: 0.0090,
                sd: 0.0113,
            },
        ),
        (
            // Venkatachalam & Ko, TVLSI'17 (approximate partial-product
            // compression, M2 variant).
            "ppam2",
            HwDesign {
                speed_gain: 0.29,
                area_saving: 0.44,
                power_saving: 0.56,
                mre: 0.0283,
                sd: 0.0355,
            },
        ),
        (
            // Yang, Ukezono & Sato, ICCD'17 (tree compressor).
            "treecomp",
            HwDesign {
                speed_gain: 0.18,
                area_saving: 0.27,
                power_saving: 0.33,
                mre: 0.0041,
                sd: 0.0052,
            },
        ),
    ])
}

/// System-level estimate for training one epoch-equivalent workload.
#[derive(Debug, Clone, Copy)]
pub struct SystemGains {
    /// Fraction of total network compute spent in multipliers that the
    /// design accelerates (conv + dense MACs).
    pub mac_share: f64,
    /// Amdahl speedup of the whole training step.
    pub step_speedup: f64,
    /// Fractional training-time saving (1 - 1/speedup).
    pub time_saving: f64,
    /// Energy saving over the multiplier share.
    pub energy_saving: f64,
    /// Area saving of the MAC array.
    pub area_saving: f64,
}

/// The cost model bound to one model preset's MAC table.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Fraction of step time in MAC-dominated layers. The paper uses
    /// the conv share from [12]; we extend it with the dense share from
    /// the manifest MAC table (dense MACs also run on the multiplier).
    mac_time_share: f64,
    /// Forward MACs per sample.
    forward_macs: u64,
}

impl CostModel {
    /// Build from a manifest model. `conv_time_share` is the empirical
    /// conv fraction of total step time ([12]: 0.907); non-conv MAC time
    /// is scaled from the MAC table relative to conv MACs.
    pub fn from_model(model: &ModelManifest, conv_time_share: f64) -> Result<Self> {
        let conv = model.conv_macs() as f64;
        let total = model.forward_macs() as f64;
        if conv <= 0.0 || total <= 0.0 {
            anyhow::bail!("model {} has no MACs", model.preset);
        }
        // Dense layers spend time proportional to their MACs at the
        // same MAC throughput as conv.
        let dense_share = conv_time_share * (total - conv) / conv;
        Ok(CostModel {
            mac_time_share: (conv_time_share + dense_share).min(0.99),
            forward_macs: model.forward_macs(),
        })
    }

    /// Plain constructor for tests / synthetic models.
    pub fn new(mac_time_share: f64, forward_macs: u64) -> Self {
        CostModel { mac_time_share, forward_macs }
    }

    pub fn mac_time_share(&self) -> f64 {
        self.mac_time_share
    }

    pub fn forward_macs(&self) -> u64 {
        self.forward_macs
    }

    /// Training MACs for `steps` steps of batch `b` (fwd + bwd ≈ 3x fwd:
    /// grad wrt activations + grad wrt weights each cost one fwd).
    pub fn training_macs(&self, steps: u64, batch: u64) -> u64 {
        3 * self.forward_macs * steps * batch
    }

    /// Amdahl composition: the design accelerates only the MAC share.
    pub fn system_gains(&self, d: &HwDesign) -> SystemGains {
        let s = self.mac_time_share;
        let mult_speedup = 1.0 / (1.0 - d.speed_gain);
        let step_speedup = 1.0 / ((1.0 - s) + s / mult_speedup);
        SystemGains {
            mac_share: s,
            step_speedup,
            time_saving: 1.0 - 1.0 / step_speedup,
            energy_saving: s * d.power_saving,
            area_saving: d.area_saving,
        }
    }

    /// Gains of a hybrid schedule that runs `approx_epochs` of
    /// `total_epochs` on the approximate design (Table III utilization):
    /// the exact phase gets no gain.
    pub fn hybrid_gains(
        &self,
        d: &HwDesign,
        approx_epochs: u32,
        total_epochs: u32,
    ) -> SystemGains {
        let full = self.system_gains(d);
        let util = approx_epochs as f64 / total_epochs.max(1) as f64;
        // Time: approx phase runs faster, exact phase at 1x.
        let time = util / full.step_speedup + (1.0 - util);
        SystemGains {
            mac_share: full.mac_share,
            step_speedup: 1.0 / time,
            time_saving: 1.0 - time,
            energy_saving: full.energy_saving * util,
            area_saving: full.area_saving, // both chips exist; see paper §IV
        }
    }

    /// Look up a cited design by name.
    pub fn design(name: &str) -> Result<HwDesign> {
        cited_designs()
            .get(name)
            .copied()
            .with_context(|| format!("unknown hardware design {name:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drum() -> HwDesign {
        *cited_designs().get("drum6").unwrap()
    }

    #[test]
    fn amdahl_bounds() {
        let cm = CostModel::new(0.907, 1_000_000);
        let g = cm.system_gains(&drum());
        // Speedup can't exceed the multiplier speedup nor 1/(1-share).
        assert!(g.step_speedup > 1.0);
        assert!(g.step_speedup < 1.0 / (1.0 - 0.907));
        assert!(g.step_speedup < 1.0 / (1.0 - 0.47));
        assert!((0.0..1.0).contains(&g.time_saving));
    }

    #[test]
    fn paper_headline_numbers() {
        // With the paper's 90.7% conv share, DRUM's 47% multiplier
        // speedup composes to ~a 40% step-time saving.
        let cm = CostModel::new(0.907, 1);
        let g = cm.system_gains(&drum());
        assert!((0.35..0.47).contains(&g.time_saving), "{}", g.time_saving);
        assert!((0.50..0.56).contains(&g.energy_saving), "{}", g.energy_saving);
    }

    #[test]
    fn hybrid_scales_with_utilization() {
        let cm = CostModel::new(0.907, 1);
        let d = drum();
        let full = cm.hybrid_gains(&d, 200, 200);
        let half = cm.hybrid_gains(&d, 100, 200);
        let none = cm.hybrid_gains(&d, 0, 200);
        assert!((full.time_saving - cm.system_gains(&d).time_saving).abs() < 1e-12);
        assert!(half.time_saving < full.time_saving);
        assert!(half.time_saving > none.time_saving);
        assert_eq!(none.time_saving, 0.0);
        // Table III row 2: 191/200 epochs approx -> ~95.5% of full gain
        // in energy.
        let t3 = cm.hybrid_gains(&d, 191, 200);
        assert!((t3.energy_saving / full.energy_saving - 0.955).abs() < 1e-9);
    }

    #[test]
    fn training_macs_counts_bwd() {
        let cm = CostModel::new(0.9, 100);
        assert_eq!(cm.training_macs(10, 8), 3 * 100 * 10 * 8);
    }

    #[test]
    fn design_lookup() {
        assert!(CostModel::design("drum6").is_ok());
        assert!(CostModel::design("nope").is_err());
    }
}
