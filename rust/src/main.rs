//! `approxmul` — CLI for the ROBIO'19 reproduction.
//!
//! Subcommands map one-to-one to the paper's artifacts (DESIGN.md §3):
//! `table2` (accuracy vs multiplier error), `table3` (hybrid switch
//! search), `fig2` (error-matrix histogram), `arch` (Figure-1 layer
//! table), `characterize` (bit-accurate designs vs the Gaussian model),
//! `costmodel` (§III hardware-gain mapping), plus `train` and `info`.

use std::io::Write as _;

use anyhow::{bail, Context, Result};

use approxmul::cli::{self, Args, FlagSpec};
use approxmul::config::{
    ErrorSampling, ExecBackend, ExperimentConfig, LrSchedule, MultiplierPolicy,
    WatchdogConfig,
};
use approxmul::coordinator::{HybridSearch, Sweep, Trainer};
use approxmul::costmodel::{cited_designs, CostModel};
use approxmul::error_model::{paper_table2_specs, ErrorConfig, ErrorMatrix};
use approxmul::mult::{
    characterize, characterize_matmul_set, signed, standard_designs, MultSpec,
    OperandDist,
};
use approxmul::report::{ascii_histogram, diff_pct, histogram_csv, pct, Table};
use approxmul::runtime::Engine;
use approxmul::serve::{
    replay, synth_trace, InferenceSession, InferReject, InferRequest, RejectReason,
    Server, SystemClock, TraceSpec,
};
use approxmul::serve::clock::Clock as _;

fn main() {
    init_logger();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(command) = argv.first() else {
        print!("{}", top_help());
        return Ok(());
    };
    let rest = &argv[1..];
    match command.as_str() {
        "info" => cmd_info(rest),
        "train" => cmd_train(rest),
        "table2" => cmd_table2(rest),
        "table3" => cmd_table3(rest),
        "fig2" => cmd_fig2(rest),
        "arch" => cmd_arch(rest),
        "characterize" => cmd_characterize(rest),
        "costmodel" => cmd_costmodel(rest),
        "serve" => cmd_serve(rest),
        "serve-bench" => cmd_serve_bench(rest),
        "validate" => cmd_validate(rest),
        "help" | "--help" | "-h" => {
            print!("{}", top_help());
            Ok(())
        }
        other => bail!("unknown command {other:?}; see `approxmul help`"),
    }
}

fn top_help() -> String {
    "approxmul — Deep Learning Training with Simulated Approximate Multipliers \
     (ROBIO'19 reproduction)\n\ncommands:\n  \
     info          manifest + artifact summary\n  \
     train         run one training experiment\n  \
     table2        accuracy vs multiplier error sweep (paper Table II)\n  \
     table3        hybrid switch-epoch search (paper Table III / Fig. 4)\n  \
     fig2          error-matrix histogram (paper Figure 2)\n  \
     arch          model layer table (paper Figure 1)\n  \
     characterize  bit-accurate approximate-multiplier error stats\n  \
     costmodel     multiplier-level -> system-level gain mapping (§III)\n  \
     serve         resident inference service over NDJSON requests\n  \
     serve-bench   deterministic serving benchmark (BENCH_serve.json)\n  \
     validate      verify artifact hashes against the manifest\n  \
     help          this message\n\nRun `approxmul <cmd> --help` for flags.\n"
        .to_string()
}

// ---------------------------------------------------------------------------
// shared flag groups

fn artifacts_flag() -> FlagSpec {
    FlagSpec {
        name: "artifacts",
        help: "artifacts directory",
        takes_value: true,
        default: Some("artifacts"),
    }
}

fn training_flags() -> Vec<FlagSpec> {
    vec![
        artifacts_flag(),
        FlagSpec {
            name: "backend",
            help: "execution backend: native (pure Rust, no artifacts) | pjrt",
            takes_value: true,
            default: Some("native"),
        },
        FlagSpec { name: "preset", help: "model preset", takes_value: true, default: Some("tiny") },
        FlagSpec { name: "epochs", help: "training epochs", takes_value: true, default: None },
        FlagSpec { name: "train-n", help: "training examples", takes_value: true, default: None },
        FlagSpec { name: "test-n", help: "held-out examples", takes_value: true, default: None },
        FlagSpec { name: "seed", help: "run seed", takes_value: true, default: Some("42") },
        FlagSpec {
            name: "sampling",
            help: "error sampling: fixed | per-step",
            takes_value: true,
            default: Some("fixed"),
        },
        FlagSpec { name: "lr", help: "base learning rate", takes_value: true, default: None },
        FlagSpec { name: "out-dir", help: "checkpoint/log dir", takes_value: true, default: None },
        FlagSpec { name: "no-augment", help: "disable augmentation", takes_value: false, default: None },
        FlagSpec {
            name: "data-noise",
            help: "synthetic-data difficulty (noise/signal)",
            takes_value: true,
            default: None,
        },
    ]
}

fn apply_training_flags(cfg: &mut ExperimentConfig, a: &Args) -> Result<()> {
    cfg.preset = a.get_or("preset", &cfg.preset);
    cfg.backend = ExecBackend::parse(&a.get_or("backend", "native"))?;
    if let Some(e) = a.parse_u64("epochs")? {
        cfg.epochs = e;
    }
    if let Some(n) = a.parse_usize("train-n")? {
        cfg.train_examples = n;
    }
    if let Some(n) = a.parse_usize("test-n")? {
        cfg.test_examples = n;
    }
    if let Some(s) = a.parse_u64("seed")? {
        cfg.seed = s;
    }
    cfg.sampling = ErrorSampling::parse(&a.get_or("sampling", "fixed"))?;
    if let Some(lr) = a.parse_f64("lr")? {
        cfg.lr = LrSchedule::StepDecay { lr, factor: 0.5, every: (cfg.epochs / 2).max(1) };
    }
    if let Some(d) = a.get("out-dir") {
        cfg.out_dir = d.to_string();
    }
    if a.flag("no-augment") {
        cfg.augment = false;
    }
    if let Some(d) = a.parse_f64("data-noise")? {
        cfg.data_noise = d;
    }
    Ok(())
}

fn base_config(a: &Args) -> Result<ExperimentConfig> {
    let preset = a.get_or("preset", "tiny");
    let mut cfg = if preset == "small" {
        ExperimentConfig::preset_small()
    } else {
        let mut c = ExperimentConfig::preset_tiny();
        c.preset = preset.clone();
        c
    };
    apply_training_flags(&mut cfg, a)?;
    cfg.validate()?;
    Ok(cfg)
}

// ---------------------------------------------------------------------------
// commands

fn cmd_info(argv: &[String]) -> Result<()> {
    let specs = vec![artifacts_flag()];
    if wants_help(argv) {
        print!("{}", cli::help("info", "manifest + artifact summary", &specs));
        return Ok(());
    }
    let a = cli::parse(argv, &specs)?;
    let engine = Engine::from_artifacts(a.get_or("artifacts", "artifacts"))?;
    println!("platform: {}", engine.platform_name());
    let mut t = Table::new(&["preset", "inject", "params", "fwd MACs", "batch", "entries"]);
    for (name, m) in &engine.manifest().models {
        t.row(vec![
            name.clone(),
            m.inject.clone(),
            m.total_params.to_string(),
            m.forward_macs().to_string(),
            m.batch.to_string(),
            m.entries.keys().cloned().collect::<Vec<_>>().join(","),
        ]);
    }
    print!("{}", t.to_markdown());
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let mut specs = training_flags();
    specs.extend([
        FlagSpec {
            name: "mult",
            help: "multiplier spec: exact | gaussian:<sd> | drum6 | lut12:drum6 \
                   | sdrum6 | booth8 | slut12:sdrum6 | ...",
            takes_value: true,
            default: None,
        },
        FlagSpec { name: "sigma", help: "gaussian error SD (0 = exact)", takes_value: true, default: Some("0.0") },
        FlagSpec { name: "mre", help: "gaussian error MRE (overrides --sigma)", takes_value: true, default: None },
        FlagSpec {
            name: "switch-epoch",
            help: "hybrid: switch to exact at this epoch",
            takes_value: true,
            default: None,
        },
        FlagSpec { name: "csv", help: "write history CSV here", takes_value: true, default: None },
        FlagSpec {
            name: "watchdog",
            help: "enable the divergence watchdog (rollback on NaN/Inf or \
                   loss spikes; needs --out-dir)",
            takes_value: false,
            default: None,
        },
        FlagSpec {
            name: "escalate",
            help: "comma-separated multiplier ladder for repeated trips \
                   (e.g. drum6,exact); implies --watchdog",
            takes_value: true,
            default: None,
        },
        FlagSpec {
            name: "watchdog-keep",
            help: "verified checkpoints to retain (default 3)",
            takes_value: true,
            default: None,
        },
        FlagSpec {
            name: "watchdog-retries",
            help: "rollback/save retry budget (default 3)",
            takes_value: true,
            default: None,
        },
    ]);
    if wants_help(argv) {
        print!("{}", cli::help("train", "run one training experiment", &specs));
        return Ok(());
    }
    let a = cli::parse(argv, &specs)?;
    let mut cfg = base_config(&a)?;
    let mult = match a.get("mult") {
        Some(spec) => MultSpec::parse(spec)?,
        None => match a.parse_f64("mre")? {
            Some(mre) => MultSpec::gaussian_mre(mre),
            None => MultSpec::gaussian(a.parse_f64("sigma")?.unwrap_or(0.0)),
        },
    };
    cfg.policy = match (mult.is_exact(), a.parse_u64("switch-epoch")?) {
        (true, _) => MultiplierPolicy::Exact,
        (false, None) => MultiplierPolicy::Approximate { mult },
        (false, Some(k)) => MultiplierPolicy::Hybrid { mult, switch_epoch: k },
    };
    if a.flag("watchdog") || a.get("escalate").is_some() {
        let mut w = WatchdogConfig::default();
        if let Some(ladder) = a.get("escalate") {
            w.ladder = ladder
                .split(',')
                .map(|s| MultSpec::parse(s.trim()))
                .collect::<Result<_>>()
                .context("parsing --escalate ladder")?;
        }
        if let Some(k) = a.parse_usize("watchdog-keep")? {
            w.keep = k;
        }
        if let Some(r) = a.parse_u64("watchdog-retries")? {
            w.max_retries = r as u32;
        }
        if cfg.out_dir.is_empty() {
            bail!(
                "--watchdog needs --out-dir: rollback restores from the \
                 checkpoint store"
            );
        }
        // The watchdog can only roll back to what was saved.
        if cfg.checkpoint_every == 0 {
            cfg.checkpoint_every = 1;
        }
        cfg.watchdog = Some(w);
    }
    cfg.validate()?;
    let engine = optional_engine(&cfg, &a)?;
    let mut trainer = match &engine {
        Some(engine) => Trainer::new(engine, cfg.clone())?,
        None => Trainer::native(cfg.clone())?,
    };
    println!(
        "training preset={} backend={} epochs={} policy={:?} sampling={}",
        cfg.preset,
        cfg.backend.name(),
        cfg.epochs,
        cfg.policy,
        cfg.sampling.name()
    );
    let mut hook = |r: &approxmul::metrics::EpochRecord| {
        println!(
            "epoch {:>3}: train loss {:.4} acc {:.3} | test acc {} (sigma {:.3}, lr {:.4}, {:.1}s)",
            r.epoch, r.train_loss, r.train_acc, pct(r.test_acc), r.sigma, r.lr, r.wall_secs
        );
        std::io::stdout().flush().ok();
    };
    let outcome = trainer.run_from(0, Some(&mut hook))?;
    println!(
        "done: best {} final {} in {:.1}s",
        pct(outcome.best_accuracy),
        pct(outcome.final_accuracy),
        outcome.wall_secs
    );
    if !outcome.health.trips.is_empty() || outcome.health.rollbacks > 0 {
        println!("watchdog: {}", outcome.health.summary());
        for t in &outcome.health.trips {
            println!(
                "  trip @ step {} (epoch {}): {} — {}",
                t.step,
                t.epoch,
                t.kind.name(),
                t.detail
            );
        }
    }
    let losses: Vec<f64> =
        outcome.history.records.iter().map(|r| r.train_loss).collect();
    let accs: Vec<f64> =
        outcome.history.records.iter().map(|r| r.test_acc).collect();
    if losses.len() >= 2 {
        println!("\ntrain loss / test accuracy over epochs:");
        print!(
            "{}",
            approxmul::report::line_chart(
                &[("train loss", &losses), ("test acc", &accs)],
                10,
                64
            )
        );
    }
    if let Some(path) = a.get("csv") {
        outcome.history.save_csv(path)?;
        println!("history -> {path}");
    }
    Ok(())
}

fn table2_cases(a: &Args) -> Result<Vec<(u32, MultSpec, f64)>> {
    let all = paper_table2_specs();
    match a.get("cases") {
        None => Ok(all),
        Some(spec) => {
            let want: Vec<u32> = spec
                .split(',')
                .map(|s| s.trim().parse::<u32>().context("bad --cases"))
                .collect::<Result<_>>()?;
            Ok(all.into_iter().filter(|(id, _, _)| want.contains(id)).collect())
        }
    }
}

/// Engine for the configured backend: compiled artifacts for PJRT,
/// nothing for native.
fn optional_engine(cfg: &ExperimentConfig, a: &Args) -> Result<Option<Engine>> {
    Ok(match cfg.backend {
        ExecBackend::Pjrt => {
            Some(Engine::from_artifacts(a.get_or("artifacts", "artifacts"))?)
        }
        ExecBackend::Native => None,
    })
}

fn cmd_table2(argv: &[String]) -> Result<()> {
    let mut specs = training_flags();
    specs.extend([
        FlagSpec {
            name: "cases",
            help: "comma-separated test ids (default: all 9)",
            takes_value: true,
            default: None,
        },
        FlagSpec { name: "csv", help: "write rows CSV here", takes_value: true, default: None },
    ]);
    if wants_help(argv) {
        print!("{}", cli::help("table2", "Table II accuracy sweep", &specs));
        return Ok(());
    }
    let a = cli::parse(argv, &specs)?;
    let cfg = base_config(&a)?;
    let engine = optional_engine(&cfg, &a)?;
    let cases = table2_cases(&a)?;
    println!(
        "Table II sweep: preset={} backend={} epochs={} train={} cases={}",
        cfg.preset,
        cfg.backend.name(),
        cfg.epochs,
        cfg.train_examples,
        cases.len()
    );
    let sweep = match &engine {
        Some(engine) => Sweep::new(engine, cfg),
        None => Sweep::native(cfg),
    };
    let rows = sweep.run(&cases, |id, row| {
        println!("  case {id}: {} -> acc {}", row.config.label(), pct(row.accuracy));
        std::io::stdout().flush().ok();
    })?;

    let mut t = Table::new(&[
        "Test ID", "MRE", "SD(σ)", "Accuracy", "Diff. From Exact", "Paper Acc.", "Paper Diff.",
    ]);
    let paper_base = rows.first().and_then(|r| r.paper_accuracy).unwrap_or(0.936);
    for r in &rows {
        t.row(vec![
            r.test_id.to_string(),
            format!("~{:.1}%", 100.0 * r.config.mre()),
            format!("~{:.1}%", 100.0 * r.config.sigma()),
            pct(r.accuracy),
            if r.test_id == 0 { "N/A".into() } else { diff_pct(r.diff_from_exact) },
            r.paper_accuracy.map(pct).unwrap_or_else(|| "-".into()),
            r.paper_accuracy
                .map(|p| if r.test_id == 0 { "N/A".into() } else { diff_pct(p - paper_base) })
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{}", t.to_markdown());
    println!(
        "shape holds (small error benign, huge error collapses): {}",
        Sweep::shape_holds(&rows)
    );
    if let Some(path) = a.get("csv") {
        let mut csv = String::from("test_id,mre,sd,accuracy,diff,paper_acc\n");
        for r in &rows {
            csv.push_str(&format!(
                "{},{:.4},{:.4},{:.6},{:.6},{}\n",
                r.test_id,
                r.config.mre(),
                r.config.sigma(),
                r.accuracy,
                r.diff_from_exact,
                r.paper_accuracy.map(|p| format!("{p:.4}")).unwrap_or_default()
            ));
        }
        std::fs::write(path, csv)?;
        println!("rows -> {path}");
    }
    Ok(())
}

fn cmd_table3(argv: &[String]) -> Result<()> {
    let mut specs = training_flags();
    specs.extend([
        FlagSpec {
            name: "cases",
            help: "comma-separated test ids",
            takes_value: true,
            default: Some("2,4,6"),
        },
        FlagSpec {
            name: "tolerance",
            help: "accuracy tolerance below baseline",
            takes_value: true,
            default: Some("0.005"),
        },
        FlagSpec { name: "csv", help: "write rows CSV here", takes_value: true, default: None },
    ]);
    if wants_help(argv) {
        print!("{}", cli::help("table3", "hybrid switch-epoch search (Fig. 4)", &specs));
        return Ok(());
    }
    let a = cli::parse(argv, &specs)?;
    let mut cfg = base_config(&a)?;
    if cfg.out_dir.is_empty() {
        cfg.out_dir = "runs/table3".into();
    }
    cfg.tag = "t3".into();
    let engine = optional_engine(&cfg, &a)?;
    let mut search = match &engine {
        Some(engine) => HybridSearch::new(engine, cfg.clone()),
        None => HybridSearch::native(cfg.clone()),
    };
    search.tolerance = a.parse_f64("tolerance")?.unwrap_or(0.005);
    let cases = table2_cases(&a)?;
    let cases: Vec<_> = cases.into_iter().filter(|(id, _, _)| *id != 0).collect();

    println!("baseline (exact) run...");
    let baseline = search.baseline()?;
    println!("baseline accuracy: {}", pct(baseline.final_accuracy));

    let mut t = Table::new(&[
        "Test ID", "MRE", "Approx Epochs", "Exact Epochs", "Utilization",
        "Accuracy", "Paper Util.",
    ]);
    // Paper reference utilizations live in the artifact manifest; a
    // native (artifact-free) run just omits the comparison column.
    let paper_util: std::collections::BTreeMap<u32, f64> = engine
        .as_ref()
        .map(|e| {
            e.manifest()
                .paper
                .table3
                .iter()
                .map(|&(id, _, a_ep, e_ep)| (id, a_ep as f64 / (a_ep + e_ep) as f64))
                .collect()
        })
        .unwrap_or_default();
    let mut csv = String::from(
        "test_id,mre,approx_epochs,exact_epochs,utilization,accuracy,evaluations\n",
    );
    for (id, config, _) in cases {
        println!("case {id}: approximate run ({})...", config.label());
        let (approx_outcome, tag) = search.approx_run(&config)?;
        let outcome = search.search(
            &config,
            baseline.final_accuracy,
            &tag,
            approx_outcome.final_accuracy,
        )?;
        println!(
            "  -> approx {} / exact {} (util {}, acc {}, {} evals)",
            outcome.approx_epochs,
            outcome.exact_epochs,
            pct(outcome.utilization),
            pct(outcome.accuracy),
            outcome.evaluations
        );
        t.row(vec![
            id.to_string(),
            format!("~{:.1}%", 100.0 * config.mre()),
            outcome.approx_epochs.to_string(),
            outcome.exact_epochs.to_string(),
            pct(outcome.utilization),
            pct(outcome.accuracy),
            paper_util.get(&id).map(|u| pct(*u)).unwrap_or_else(|| "-".into()),
        ]);
        csv.push_str(&format!(
            "{},{:.4},{},{},{:.4},{:.6},{}\n",
            id,
            config.mre(),
            outcome.approx_epochs,
            outcome.exact_epochs,
            outcome.utilization,
            outcome.accuracy,
            outcome.evaluations
        ));
    }
    print!("{}", t.to_markdown());
    if let Some(path) = a.get("csv") {
        std::fs::write(path, csv)?;
        println!("rows -> {path}");
    }
    Ok(())
}

fn cmd_fig2(argv: &[String]) -> Result<()> {
    let specs = vec![
        FlagSpec { name: "sigma", help: "error SD", takes_value: true, default: Some("0.045") },
        FlagSpec { name: "mre", help: "error MRE (overrides --sigma)", takes_value: true, default: None },
        FlagSpec { name: "bins", help: "histogram bins", takes_value: true, default: Some("500") },
        FlagSpec { name: "n", help: "samples", takes_value: true, default: Some("1000000") },
        FlagSpec { name: "seed", help: "threefry seed", takes_value: true, default: Some("42") },
        FlagSpec { name: "csv", help: "write histogram CSV here", takes_value: true, default: None },
    ];
    if wants_help(argv) {
        print!("{}", cli::help("fig2", "error-matrix histogram (Figure 2)", &specs));
        return Ok(());
    }
    let a = cli::parse(argv, &specs)?;
    let sigma = match a.parse_f64("mre")? {
        Some(mre) => ErrorConfig::from_mre(mre).sigma,
        None => a.parse_f64("sigma")?.unwrap_or(0.045),
    };
    let bins = a.parse_usize("bins")?.unwrap_or(500);
    let n = a.parse_usize("n")?.unwrap_or(1_000_000);
    let seed = a.parse_u64("seed")?.unwrap_or(42) as u32;
    let m = ErrorMatrix::generate(seed, 0, sigma, n);
    let lim = 4.5 * sigma;
    let (edges, counts) = m.histogram(bins, -lim, lim);
    println!(
        "Figure 2: histogram ({bins} bins) of an error matrix with target \
         MRE {:.2}% SD {:.2}%",
        100.0 * ErrorConfig::from_sigma(sigma).mre(),
        100.0 * sigma
    );
    println!(
        "measured: MRE {:.3}% SD {:.3}% over {n} samples\n",
        100.0 * m.measured_mre(),
        100.0 * m.measured_sd()
    );
    print!("{}", ascii_histogram(&edges, &counts, 60, 33));
    if let Some(path) = a.get("csv") {
        std::fs::write(path, histogram_csv(&edges, &counts))?;
        println!("histogram -> {path}");
    }
    Ok(())
}

fn cmd_arch(argv: &[String]) -> Result<()> {
    let specs = vec![
        artifacts_flag(),
        FlagSpec { name: "preset", help: "model preset", takes_value: true, default: Some("vgg16") },
    ];
    if wants_help(argv) {
        print!("{}", cli::help("arch", "model layer table (Figure 1)", &specs));
        return Ok(());
    }
    let a = cli::parse(argv, &specs)?;
    let engine = Engine::from_artifacts(a.get_or("artifacts", "artifacts"))?;
    let model = engine.manifest().model(&a.get_or("preset", "vgg16"))?;
    println!(
        "{} (inject={}, {} params, {} fwd MACs/sample)",
        model.preset,
        model.inject,
        model.total_params,
        model.forward_macs()
    );
    let mut t = Table::new(&["layer", "type", "output", "params", "MACs", "MAC %"]);
    let total = model.forward_macs().max(1) as f64;
    for l in &model.layers {
        t.row(vec![
            l.name.clone(),
            l.ty.clone(),
            format!("{:?}", l.out),
            l.params.to_string(),
            l.macs.to_string(),
            format!("{:.1}%", 100.0 * l.macs as f64 / total),
        ]);
    }
    print!("{}", t.to_markdown());
    let conv_share = model.conv_macs() as f64 / total;
    println!(
        "conv MAC share: {} (paper [12] reports ~90.7% of *time* in conv)",
        pct(conv_share)
    );
    Ok(())
}

fn cmd_characterize(argv: &[String]) -> Result<()> {
    let specs = vec![
        FlagSpec {
            name: "dist",
            help: "operand distribution: uniform16 | uniform32 | mantissa | small",
            takes_value: true,
            default: Some("uniform16"),
        },
        FlagSpec { name: "n", help: "sample pairs per design", takes_value: true, default: Some("500000") },
        FlagSpec { name: "seed", help: "rng seed", takes_value: true, default: Some("7") },
        FlagSpec {
            name: "threads",
            help: "worker threads (default: all cores)",
            takes_value: true,
            default: None,
        },
        FlagSpec {
            name: "lut",
            help: "also characterize each design through a LUT backend of this bit width",
            takes_value: true,
            default: None,
        },
        FlagSpec {
            name: "gemm",
            help: "characterize on a GEMM shape RxKxC (e.g. 64x128x64) instead of operand pairs",
            takes_value: true,
            default: None,
        },
    ];
    if wants_help(argv) {
        print!("{}", cli::help("characterize", "approximate-multiplier error stats", &specs));
        return Ok(());
    }
    let a = cli::parse(argv, &specs)?;
    let dist = match a.get_or("dist", "uniform16").as_str() {
        "uniform16" => OperandDist::Uniform16,
        "uniform32" => OperandDist::Uniform32,
        "mantissa" => OperandDist::Mantissa,
        "small" => OperandDist::Small,
        other => bail!("unknown distribution {other:?}"),
    };
    let n = a.parse_u64("n")?.unwrap_or(500_000);
    let seed = a.parse_u64("seed")?.unwrap_or(7);
    if let Some(t) = a.parse_usize("threads")? {
        approxmul::parallel::set_max_threads(t);
    }
    let mut designs = standard_designs();
    // The paper's simulation model at DRUM-6's published SD, for the
    // model-vs-hardware comparison.
    designs.push(Box::new(approxmul::mult::GaussianModel::new(0.01803, seed as u32)));
    if let Some(bits) = a.parse_u64("lut")? {
        let luts: Vec<Box<dyn approxmul::mult::Multiplier>> = designs
            .iter()
            .map(|d| {
                approxmul::mult::LutMultiplier::new(d.as_ref(), bits as u32)
                    .map(|l| Box::new(l) as Box<dyn approxmul::mult::Multiplier>)
            })
            .collect::<Result<_>>()?;
        designs.extend(luts);
    }

    let mut signed_designs = signed::standard_signed_designs();
    if let Some(bits) = a.parse_u64("lut")? {
        let sluts: Vec<Box<dyn signed::SignedMultiplier>> = signed_designs
            .iter()
            .map(|d| {
                signed::SignedLut::new(d.as_ref(), bits as u32)
                    .map(|l| Box::new(l) as Box<dyn signed::SignedMultiplier>)
            })
            .collect::<Result<_>>()?;
        signed_designs.extend(sluts);
    }

    if let Some(shape) = a.get("gemm") {
        let dims: Vec<usize> = shape
            .split(['x', ','])
            .map(|s| s.trim().parse::<usize>().context("bad --gemm, want RxKxC"))
            .collect::<Result<_>>()?;
        let [rows, inner, cols] = dims[..] else {
            bail!("--gemm wants three dimensions RxKxC, got {shape:?}");
        };
        let mut t = Table::new(&["design", "out MRE", "out SD", "out bias", "min RE", "max RE"]);
        // One shared exact-reference GEMM per design *set*; the signed
        // set recomputes it from the same seeded matrices (one extra
        // exact GEMM per invocation), so all rows stay directly
        // comparable.
        let stats = characterize_matmul_set(&designs, rows, inner, cols, seed)?;
        let signed_stats = signed::characterize_matmul_signed_set(
            &signed_designs,
            rows,
            inner,
            cols,
            seed,
        )?;
        let names = designs
            .iter()
            .map(|d| d.name())
            .chain(signed_designs.iter().map(|d| d.name()));
        for (name, s) in names.zip(stats.iter().chain(&signed_stats)) {
            t.row(vec![
                name,
                format!("{:.3}%", 100.0 * s.mre),
                format!("{:.3}%", 100.0 * s.sd),
                format!("{:+.3}%", 100.0 * s.mean_re),
                format!("{:+.2}%", 100.0 * s.min_re),
                format!("{:+.2}%", 100.0 * s.max_re),
            ]);
        }
        println!(
            "bit-accurate GEMM characterization: C[{rows}x{cols}] = \
             A[{rows}x{inner}]·B[{inner}x{cols}], stats over output elements\n\
             (GEMM mode samples uniform [-1,1) f32 matrices; --dist and --n \
             do not apply — the sample count is rows x cols; s*/booth* rows \
             run the signed pipeline: operand signs go through the design)"
        );
        print!("{}", t.to_markdown());
        println!(
            "\nPer-product mantissa error accumulates through each k={inner} \
             chain exactly as an approximate FP MAC array would produce it."
        );
        return Ok(());
    }

    let mut t = Table::new(&[
        "design", "MRE", "SD", "bias", "min RE", "max RE", "MRE/SD (0.798=gaussian)",
    ]);
    for d in &designs {
        let s = characterize(d.as_ref(), dist, n, seed);
        t.row(vec![
            d.name(),
            format!("{:.3}%", 100.0 * s.mre),
            format!("{:.3}%", 100.0 * s.sd),
            format!("{:+.3}%", 100.0 * s.mean_re),
            format!("{:+.2}%", 100.0 * s.min_re),
            format!("{:+.2}%", 100.0 * s.max_re),
            format!("{:.3}", s.gaussianity_ratio()),
        ]);
    }
    // Signed designs: same magnitudes, random signs, error routed
    // through the two's-complement pipeline.
    for d in &signed_designs {
        let s = signed::characterize_signed(d.as_ref(), dist, n, seed);
        t.row(vec![
            d.name(),
            format!("{:.3}%", 100.0 * s.mre),
            format!("{:.3}%", 100.0 * s.sd),
            format!("{:+.3}%", 100.0 * s.mean_re),
            format!("{:+.2}%", 100.0 * s.min_re),
            format!("{:+.2}%", 100.0 * s.max_re),
            format!("{:.3}", s.gaussianity_ratio()),
        ]);
    }
    println!(
        "operand distribution: {} ({n} pairs/design; signed rows draw the \
         same magnitudes with random signs)",
        dist.name()
    );
    print!("{}", t.to_markdown());
    println!(
        "\nDRUM [3] published: MRE 1.47%, SD 1.803% — compare rows drum6 and \
         sdrum6 (sign-magnitude, so the signed row matches the unsigned one).\n\
         Gaussian model rows should show MRE/SD ≈ 0.798; one-sided designs \
         (mitchell, trunc*) cannot be represented by the paper's model, and \
         booth<k> rows err by product sign — representable only by the \
         signed pipeline."
    );
    Ok(())
}

fn cmd_costmodel(argv: &[String]) -> Result<()> {
    let specs = vec![
        artifacts_flag(),
        FlagSpec { name: "preset", help: "model preset", takes_value: true, default: Some("vgg16") },
        FlagSpec {
            name: "epochs",
            help: "total epochs for hybrid rows",
            takes_value: true,
            default: Some("200"),
        },
    ];
    if wants_help(argv) {
        print!("{}", cli::help("costmodel", "hardware gain composition (§III)", &specs));
        return Ok(());
    }
    let a = cli::parse(argv, &specs)?;
    let engine = Engine::from_artifacts(a.get_or("artifacts", "artifacts"))?;
    let model = engine.manifest().model(&a.get_or("preset", "vgg16"))?;
    let cm = CostModel::from_model(model, engine.manifest().paper.conv_time_share)?;
    println!(
        "cost model for {}: MAC time share {:.1}%, {} fwd MACs/sample",
        model.preset,
        100.0 * cm.mac_time_share(),
        cm.forward_macs()
    );
    let mut t = Table::new(&[
        "design", "mult speedup", "step speedup", "time saving", "energy saving",
        "area saving", "MRE",
    ]);
    for (name, d) in cited_designs() {
        let g = cm.system_gains(&d);
        t.row(vec![
            name.to_string(),
            format!("{:.0}%", 100.0 * d.speed_gain),
            format!("{:.2}x", g.step_speedup),
            pct(g.time_saving),
            pct(g.energy_saving),
            pct(g.area_saving),
            format!("{:.2}%", 100.0 * d.mre),
        ]);
    }
    print!("{}", t.to_markdown());

    // Hybrid composition using the paper's Table III utilizations.
    let total = a.parse_u64("epochs")?.unwrap_or(200) as u32;
    let drum = CostModel::design("drum6")?;
    let mut t = Table::new(&[
        "Table III row", "MRE", "approx/total", "time saving", "energy saving",
    ]);
    for &(id, mre, a_ep, e_ep) in &engine.manifest().paper.table3 {
        let scale = total as f64 / (a_ep + e_ep) as f64;
        let a_scaled = (a_ep as f64 * scale).round() as u32;
        let g = cm.hybrid_gains(&drum, a_scaled, total);
        t.row(vec![
            id.to_string(),
            format!("~{:.1}%", 100.0 * mre),
            format!("{a_scaled}/{total}"),
            pct(g.time_saving),
            pct(g.energy_saving),
        ]);
    }
    println!("\nhybrid schedules on drum6 (paper Table III utilizations):");
    print!("{}", t.to_markdown());
    Ok(())
}

fn cmd_validate(argv: &[String]) -> Result<()> {
    let specs = vec![artifacts_flag()];
    if wants_help(argv) {
        print!("{}", cli::help("validate", "verify artifact integrity", &specs));
        return Ok(());
    }
    let a = cli::parse(argv, &specs)?;
    let manifest = approxmul::runtime::Manifest::load(a.get_or("artifacts", "artifacts"))?;
    let reports = approxmul::runtime::integrity::validate(&manifest)?;
    let mut t = Table::new(&["preset", "entry", "file", "status"]);
    for r in &reports {
        use approxmul::runtime::integrity::FileStatus;
        let status = match &r.status {
            FileStatus::Ok => "ok".to_string(),
            FileStatus::Missing => "MISSING".to_string(),
            FileStatus::Mismatch { expected, actual } => format!(
                "MISMATCH {}.. != {}..",
                &expected[..8],
                &actual[..8]
            ),
        };
        t.row(vec![r.preset.clone(), r.kind.clone(), r.file.clone(), status]);
    }
    print!("{}", t.to_markdown());
    if approxmul::runtime::integrity::all_ok(&reports) {
        println!("all {} artifacts verified", reports.len());
        Ok(())
    } else {
        bail!("artifact integrity check FAILED — re-run `make artifacts`");
    }
}

// ---------------------------------------------------------------------------
// serve mode

fn serve_session_flags() -> Vec<FlagSpec> {
    vec![
        FlagSpec {
            name: "checkpoint",
            help: "checkpoint directory (omit to serve fresh weights)",
            takes_value: true,
            default: None,
        },
        FlagSpec { name: "tag", help: "checkpoint tag", takes_value: true, default: Some("run") },
        FlagSpec {
            name: "mult",
            help: "comma-separated multiplier specs to keep resident \
                   (first is the default for requests that omit `mult`)",
            takes_value: true,
            default: Some("exact"),
        },
        FlagSpec { name: "preset", help: "model preset for fresh weights", takes_value: true, default: Some("micro") },
        FlagSpec { name: "seed", help: "fresh-weight init seed", takes_value: true, default: Some("42") },
        FlagSpec { name: "seed-err", help: "gaussian weight-error seed", takes_value: true, default: Some("42") },
        FlagSpec { name: "batch-window", help: "batching window (ms)", takes_value: true, default: Some("2") },
        FlagSpec { name: "max-batch", help: "max requests per batch", takes_value: true, default: Some("8") },
        FlagSpec { name: "queue-capacity", help: "admission queue bound", takes_value: true, default: Some("256") },
        FlagSpec { name: "max-specs", help: "resident spec registry bound", takes_value: true, default: Some("8") },
        FlagSpec {
            name: "service-estimate",
            help: "modeled per-batch service time (µs)",
            takes_value: true,
            default: Some("2000"),
        },
    ]
}

fn serve_config_from(a: &Args) -> Result<approxmul::config::ServeConfig> {
    let mut cfg = approxmul::config::ServeConfig::default();
    if let Some(w) = a.parse_u64("batch-window")? {
        cfg.batch_window_us = w * 1_000;
    }
    if let Some(b) = a.parse_usize("max-batch")? {
        cfg.max_batch = b;
    }
    if let Some(q) = a.parse_usize("queue-capacity")? {
        cfg.queue_capacity = q;
    }
    if let Some(m) = a.parse_usize("max-specs")? {
        cfg.max_specs = m;
    }
    if let Some(s) = a.parse_u64("service-estimate")? {
        cfg.service_estimate_us = s;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn serve_specs_from(a: &Args) -> Result<Vec<MultSpec>> {
    a.get_or("mult", "exact")
        .split(',')
        .map(|s| MultSpec::parse(s.trim()))
        .collect::<Result<_>>()
        .context("parsing --mult spec list")
}

fn build_serve_session(
    a: &Args,
    cfg: &approxmul::config::ServeConfig,
) -> Result<InferenceSession> {
    let specs = serve_specs_from(a)?;
    let seed_err = a.parse_u64("seed-err")?.unwrap_or(42) as u32;
    match a.get("checkpoint") {
        Some(dir) => InferenceSession::from_store(
            dir,
            &a.get_or("tag", "run"),
            &specs,
            cfg.max_specs,
            seed_err,
        ),
        None => InferenceSession::from_fresh(
            &a.get_or("preset", "micro"),
            a.parse_u64("seed")?.unwrap_or(42) as u32,
            &specs,
            cfg.max_specs,
            seed_err,
        ),
    }
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let mut specs = serve_session_flags();
    specs.push(FlagSpec {
        name: "input",
        help: "NDJSON request file (default: stdin)",
        takes_value: true,
        default: None,
    });
    if wants_help(argv) {
        print!(
            "{}",
            cli::help(
                "serve",
                "resident inference service: NDJSON requests in, NDJSON \
                 responses/rejections out",
                &specs
            )
        );
        return Ok(());
    }
    let a = cli::parse(argv, &specs)?;
    let cfg = serve_config_from(&a)?;
    let session = build_serve_session(&a, &cfg)?;
    eprintln!(
        "serving preset={} specs=[{}] epoch={} batch-window={}us max-batch={}",
        session.preset(),
        session.specs().join(", "),
        session
            .checkpoint_epoch()
            .map(|e| e.to_string())
            .unwrap_or_else(|| "fresh".into()),
        cfg.batch_window_us,
        cfg.max_batch
    );
    let mut server = Server::new(session, &cfg)?;
    let clock = SystemClock::new();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let mut emit = |out: &mut dyn std::io::Write,
                    r: approxmul::serve::PollResult|
     -> Result<()> {
        for resp in r.responses {
            writeln!(out, "{}", resp.to_value())?;
        }
        for rej in r.rejects {
            writeln!(out, "{}", rej.to_value())?;
        }
        Ok(())
    };
    let reader: Box<dyn std::io::BufRead> = match a.get("input") {
        Some(path) => Box::new(std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path}"))?,
        )),
        None => Box::new(std::io::BufReader::new(std::io::stdin())),
    };
    for line in std::io::BufRead::lines(reader) {
        let line = line.context("reading request line")?;
        if line.trim().is_empty() {
            continue;
        }
        let now = clock.now_us();
        match InferRequest::decode(line.as_bytes(), cfg.max_request_bytes) {
            Ok(req) => {
                if let Err(reject) = server.submit(req, now) {
                    writeln!(out, "{}", reject.to_value())?;
                }
            }
            Err(e) => {
                let reject = InferReject {
                    id: 0,
                    tenant: String::new(),
                    reason: RejectReason::BadInput,
                    detail: format!("{e:#}"),
                };
                writeln!(out, "{}", reject.to_value())?;
            }
        }
        // Fire every batch the arrival made due.
        while let Some(ev) = server.next_event_us(clock.now_us()) {
            if ev > clock.now_us() {
                break;
            }
            let r = server.poll(clock.now_us())?;
            emit(&mut out, r)?;
        }
    }
    // End of input: flush everything still queued.
    let r = server.drain(clock.now_us())?;
    emit(&mut out, r)?;
    out.flush()?;
    let st = server.stats();
    eprintln!(
        "served {} of {} (batches {}, p50 {}us p99 {}us; rejected: queue {}, \
         deadline {}, bad-input {})",
        st.completed,
        st.submitted,
        st.batches,
        st.latency.percentile_us(50.0),
        st.latency.percentile_us(99.0),
        st.rejected_queue,
        st.rejected_deadline,
        st.rejected_bad_input
    );
    Ok(())
}

/// One serve-bench scenario: a synthetic trace plus the server shape
/// it runs against.
struct BenchScenario {
    name: &'static str,
    mean_gap_us: u64,
    deadline_us: u64,
    requests: usize,
    queue_capacity: usize,
}

fn cmd_serve_bench(argv: &[String]) -> Result<()> {
    let specs = vec![
        FlagSpec { name: "preset", help: "model preset", takes_value: true, default: Some("micro") },
        FlagSpec { name: "seed", help: "trace + init seed", takes_value: true, default: Some("42") },
        FlagSpec { name: "requests", help: "requests per scenario", takes_value: true, default: Some("48") },
        FlagSpec {
            name: "mult",
            help: "comma-separated designs to bench",
            takes_value: true,
            default: Some("exact,drum6"),
        },
        FlagSpec {
            name: "json",
            help: "write rows here",
            takes_value: true,
            default: Some("BENCH_serve.json"),
        },
    ];
    if wants_help(argv) {
        print!(
            "{}",
            cli::help(
                "serve-bench",
                "replay deterministic arrival traces through the server; \
                 virtual-time latency percentiles + wall-clock throughput",
                &specs
            )
        );
        return Ok(());
    }
    let a = cli::parse(argv, &specs)?;
    let preset = a.get_or("preset", "micro");
    let seed = a.parse_u64("seed")?.unwrap_or(42);
    let requests = a.parse_usize("requests")?.unwrap_or(48);
    let designs = serve_specs_from(&a)?;
    // `low` must complete everything inside generous deadlines; the
    // `overload` burst must shed deterministically with typed
    // deadline-missed rejections (CI gates on both).
    let scenarios = [
        BenchScenario {
            name: "low",
            mean_gap_us: 4_000,
            deadline_us: 200_000,
            requests,
            queue_capacity: 256,
        },
        BenchScenario {
            name: "overload",
            mean_gap_us: 0, // one burst at t=0
            deadline_us: 1_500,
            requests,
            queue_capacity: 256,
        },
    ];
    let mut json_rows = Vec::new();
    let mut t = Table::new(&[
        "row", "req", "done", "q-rej", "d-rej", "batches", "p50 µs", "p99 µs",
        "req/s",
    ]);
    for design in &designs {
        for sc in &scenarios {
            let cfg = approxmul::config::ServeConfig {
                batch_window_us: 1_000,
                max_batch: 8,
                queue_capacity: sc.queue_capacity,
                max_specs: 4,
                service_estimate_us: 500,
                max_request_bytes: 1 << 20,
            };
            let session = InferenceSession::from_fresh(
                &preset,
                seed as u32,
                std::slice::from_ref(design),
                cfg.max_specs,
                seed as u32,
            )?;
            let mut server = Server::new(session, &cfg)?;
            let trace = synth_trace(
                &TraceSpec {
                    seed,
                    requests: sc.requests,
                    mean_gap_us: sc.mean_gap_us,
                    deadline_us: sc.deadline_us,
                    specs: vec![],
                },
                server.session().input_elems(),
            );
            let t0 = std::time::Instant::now();
            replay(&mut server, &trace)?;
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            let st = server.stats();
            let name = format!("serve/{preset}/{}/{}", design.canonical(), sc.name);
            let sustained_rps = st.completed as f64 / wall;
            t.row(vec![
                name.clone(),
                st.submitted.to_string(),
                st.completed.to_string(),
                st.rejected_queue.to_string(),
                st.rejected_deadline.to_string(),
                st.batches.to_string(),
                st.latency.percentile_us(50.0).to_string(),
                st.latency.percentile_us(99.0).to_string(),
                format!("{sustained_rps:.0}"),
            ]);
            json_rows.push(approxmul::json::object([
                ("name", approxmul::json::Value::from(name)),
                ("preset", approxmul::json::Value::from(preset.clone())),
                ("design", approxmul::json::Value::from(design.canonical())),
                ("scenario", approxmul::json::Value::from(sc.name)),
                ("requests", (st.submitted as usize).into()),
                ("completed", (st.completed as usize).into()),
                ("rejected_queue", (st.rejected_queue as usize).into()),
                ("rejected_deadline", (st.rejected_deadline as usize).into()),
                ("rejected_bad_input", (st.rejected_bad_input as usize).into()),
                ("batches", (st.batches as usize).into()),
                ("p50_us", (st.latency.percentile_us(50.0) as f64).into()),
                ("p95_us", (st.latency.percentile_us(95.0) as f64).into()),
                ("p99_us", (st.latency.percentile_us(99.0) as f64).into()),
                ("max_us", (st.latency.max_us() as f64).into()),
                ("sustained_rps", sustained_rps.into()),
                ("simd", cfg!(feature = "simd").into()),
            ]));
        }
    }
    println!(
        "serve-bench: preset={preset} seed={seed} requests/scenario={requests} \
         (virtual-time latencies; req/s is wall clock)"
    );
    print!("{}", t.to_markdown());
    let path = a.get_or("json", "BENCH_serve.json");
    approxmul::benchkit::save_json(
        &path,
        &approxmul::json::Value::Array(json_rows),
    )?;
    println!("rows -> {path}");
    Ok(())
}

// ---------------------------------------------------------------------------

fn wants_help(argv: &[String]) -> bool {
    argv.iter().any(|a| a == "--help" || a == "-h")
}

/// Tiny env-filtered logger (no external logger crates offline).
fn init_logger() {
    struct Logger(log::LevelFilter);
    impl log::Log for Logger {
        fn enabled(&self, m: &log::Metadata<'_>) -> bool {
            m.level() <= self.0
        }
        fn log(&self, r: &log::Record<'_>) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level().as_str().to_lowercase(), r.args());
            }
        }
        fn flush(&self) {}
    }
    let level = match std::env::var("APPROXMUL_LOG").as_deref() {
        Ok("trace") => log::LevelFilter::Trace,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        Ok("off") => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    };
    // (the vendored `log` has no `std` feature, so no set_boxed_logger)
    static LOGGER: Logger = Logger(log::LevelFilter::Trace);
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}
