//! Synthetic CIFAR surrogate.
//!
//! The sandbox has no network access, so CIFAR-10 itself cannot be
//! downloaded; `data/cifar.rs` loads the real binary format when a copy
//! exists on disk, and this generator provides a drop-in surrogate
//! otherwise (DESIGN.md §5).
//!
//! Construction: each class gets a smooth random "prototype" image
//! (low-frequency mixture of 2-D cosine modes, so conv filters have
//! real spatial structure to learn) plus per-example elastic intensity
//! jitter and pixel noise. Difficulty is controlled by the noise/signal
//! ratio; the defaults make the `small` preset reach high accuracy in a
//! few epochs while keeping class overlap non-trivial, which is what
//! the Table II/III shape reproduction needs (an accuracy metric that
//! *can* be damaged by multiplier error).

use crate::rng::Xoshiro256;

use super::Dataset;

/// Generator parameters.
#[derive(Debug, Clone, Copy)]
pub struct SyntheticCifar {
    pub hw: usize,
    pub channels: usize,
    pub num_classes: usize,
    /// Number of cosine modes per prototype.
    pub modes: usize,
    /// Additive pixel-noise SD relative to signal SD (~difficulty).
    pub noise: f32,
    pub seed: u64,
}

impl Default for SyntheticCifar {
    fn default() -> Self {
        SyntheticCifar {
            hw: 32,
            channels: 3,
            num_classes: 10,
            modes: 4,
            noise: 0.6,
            seed: 0xC1FA_5EED,
        }
    }
}

impl SyntheticCifar {
    /// CIFAR-shaped surrogate for a given model input size.
    pub fn for_input(hw: usize, channels: usize, num_classes: usize, seed: u64) -> Self {
        SyntheticCifar { hw, channels, num_classes, seed, ..Default::default() }
    }

    /// Class prototypes: smooth per-channel fields in [-1, 1].
    fn prototypes(&self, rng: &mut Xoshiro256) -> Vec<Vec<f32>> {
        let e = self.hw * self.hw * self.channels;
        (0..self.num_classes)
            .map(|_| {
                let mut proto = vec![0f32; e];
                for _ in 0..self.modes {
                    // Random 2-D cosine mode with per-channel phase.
                    let fx = 0.5 + 2.5 * rng.next_f32();
                    let fy = 0.5 + 2.5 * rng.next_f32();
                    let phase_xy = std::f32::consts::TAU * rng.next_f32();
                    let amp = 0.4 + 0.6 * rng.next_f32();
                    let chphase: Vec<f32> = (0..self.channels)
                        .map(|_| std::f32::consts::TAU * rng.next_f32())
                        .collect();
                    for y in 0..self.hw {
                        for x in 0..self.hw {
                            let t = fx * x as f32 / self.hw as f32
                                + fy * y as f32 / self.hw as f32;
                            for c in 0..self.channels {
                                let v = amp
                                    * (std::f32::consts::TAU * t + phase_xy + chphase[c])
                                        .cos();
                                proto[(y * self.hw + x) * self.channels + c] += v;
                            }
                        }
                    }
                }
                proto
            })
            .collect()
    }

    /// Generate `n` labelled examples (balanced classes, shuffled).
    pub fn generate(&self, n: usize) -> Dataset {
        let mut rng = Xoshiro256::new(self.seed);
        let protos = self.prototypes(&mut rng);
        let e = self.hw * self.hw * self.channels;

        let mut labels: Vec<i32> =
            (0..n).map(|i| (i % self.num_classes) as i32).collect();
        rng.shuffle(&mut labels);

        let mut images = Vec::with_capacity(n * e);
        for &label in &labels {
            let proto = &protos[label as usize];
            // Per-example global gain/offset jitter + pixel noise.
            let gain = 0.8 + 0.4 * rng.next_f32();
            let offset = 0.2 * (rng.next_f32() - 0.5);
            for &p in proto {
                let noise = self.noise * rng.next_normal() as f32;
                images.push(gain * p + offset + noise);
            }
        }
        let ds = Dataset {
            images,
            labels,
            hw: self.hw,
            channels: self.channels,
            num_classes: self.num_classes,
        };
        debug_assert!(ds.check().is_ok());
        ds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_balance() {
        let g = SyntheticCifar { hw: 8, num_classes: 10, ..Default::default() };
        let ds = g.generate(100);
        ds.check().unwrap();
        assert_eq!(ds.len(), 100);
        assert_eq!(ds.image_elems(), 8 * 8 * 3);
        let mut counts = [0; 10];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn deterministic_in_seed() {
        let g = SyntheticCifar { hw: 8, seed: 7, ..Default::default() };
        let a = g.generate(16);
        let b = g.generate(16);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-prototype classification on clean prototypes must beat
        // chance by a wide margin: the task carries real signal.
        let g = SyntheticCifar { hw: 8, noise: 0.4, seed: 3, ..Default::default() };
        let ds = g.generate(400);
        // Use class-mean images as prototypes.
        let e = ds.image_elems();
        let mut means = vec![vec![0f32; e]; 10];
        let mut counts = vec![0f32; 10];
        for i in 0..ds.len() {
            let l = ds.labels[i] as usize;
            counts[l] += 1.0;
            for (m, &p) in means[l].iter_mut().zip(ds.image(i)) {
                *m += p;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c;
            }
        }
        let mut correct = 0;
        for i in 0..ds.len() {
            let img = ds.image(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 =
                        means[a].iter().zip(img).map(|(m, p)| (m - p).powi(2)).sum();
                    let db: f32 =
                        means[b].iter().zip(img).map(|(m, p)| (m - p).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == ds.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.len() as f64;
        assert!(acc > 0.6, "nearest-mean accuracy only {acc}");
    }

    #[test]
    fn noise_controls_difficulty() {
        let clean = SyntheticCifar { hw: 8, noise: 0.05, seed: 5, ..Default::default() };
        let noisy = SyntheticCifar { hw: 8, noise: 2.5, seed: 5, ..Default::default() };
        let var = |ds: &Dataset| {
            let m: f32 = ds.images.iter().sum::<f32>() / ds.images.len() as f32;
            ds.images.iter().map(|v| (v - m).powi(2)).sum::<f32>()
                / ds.images.len() as f32
        };
        assert!(var(&noisy.generate(64)) > var(&clean.generate(64)));
    }
}
