//! Data pipeline: CIFAR-10 (binary format) loader, a synthetic
//! CIFAR-surrogate generator (no network in this environment — see
//! DESIGN.md §5), augmentation, normalization and a deterministic
//! shuffling batcher.
//!
//! Layout convention matches the compiled graphs: images are NHWC f32,
//! labels i32 class ids.

pub mod augment;
pub mod batcher;
pub mod cifar;
pub mod synthetic;

pub use batcher::Batcher;
pub use synthetic::SyntheticCifar;

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// An in-memory labelled image dataset (NHWC f32, i32 labels).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `n * hw * hw * c` pixels, already normalized.
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub hw: usize,
    pub channels: usize,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image_elems(&self) -> usize {
        self.hw * self.hw * self.channels
    }

    /// Validate internal consistency.
    pub fn check(&self) -> Result<()> {
        if self.images.len() != self.len() * self.image_elems() {
            bail!(
                "dataset: {} pixels for {} images of {} elems",
                self.images.len(),
                self.len(),
                self.image_elems()
            );
        }
        if let Some(&bad) = self
            .labels
            .iter()
            .find(|&&l| l < 0 || l as usize >= self.num_classes)
        {
            bail!("dataset: label {bad} out of range 0..{}", self.num_classes);
        }
        Ok(())
    }

    /// Slice of one image's pixels.
    pub fn image(&self, i: usize) -> &[f32] {
        let e = self.image_elems();
        &self.images[i * e..(i + 1) * e]
    }

    /// Assemble an `[n, hw, hw, c]` batch tensor from example indices
    /// (optionally augmented by the caller beforehand).
    pub fn gather_batch(&self, idx: &[usize]) -> Result<(Tensor, Tensor)> {
        let e = self.image_elems();
        let mut pixels = Vec::with_capacity(idx.len() * e);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            if i >= self.len() {
                bail!("batch index {i} out of range {}", self.len());
            }
            pixels.extend_from_slice(self.image(i));
            labels.push(self.labels[i]);
        }
        let x = Tensor::from_f32(&[idx.len(), self.hw, self.hw, self.channels], pixels)?;
        let y = Tensor::from_i32(&[idx.len()], labels)?;
        Ok((x, y))
    }

    /// Per-channel mean/std normalization in place (the paper's "input
    /// normalization"). Returns the (mean, std) per channel.
    pub fn normalize(&mut self) -> Vec<(f32, f32)> {
        let c = self.channels;
        let mut stats = Vec::with_capacity(c);
        for ch in 0..c {
            let vals: Vec<f32> = self
                .images
                .iter()
                .skip(ch)
                .step_by(c)
                .copied()
                .collect();
            // detlint: allow(D3) -- one-time dataset normalization, sequential in sample order
            let mean = vals.iter().sum::<f32>() / vals.len() as f32;
            // detlint: allow(D3) -- one-time dataset normalization, sequential in sample order
            let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f32>()
                / vals.len() as f32;
            let std = var.sqrt().max(1e-6);
            stats.push((mean, std));
            for (j, v) in self.images.iter_mut().enumerate() {
                if j % c == ch {
                    *v = (*v - mean) / std;
                }
            }
        }
        stats
    }

    /// Copy out the contiguous range `[start, start + n)` as a new
    /// dataset (round-based continual-learning streams use this).
    pub fn slice(&self, start: usize, n: usize) -> Result<Dataset> {
        if start + n > self.len() {
            bail!("slice {start}..{} exceeds {} examples", start + n, self.len());
        }
        let e = self.image_elems();
        Ok(Dataset {
            images: self.images[start * e..(start + n) * e].to_vec(),
            labels: self.labels[start..start + n].to_vec(),
            hw: self.hw,
            channels: self.channels,
            num_classes: self.num_classes,
        })
    }

    /// Split off the last `n` examples as a held-out set.
    pub fn split_tail(mut self, n: usize) -> Result<(Dataset, Dataset)> {
        if n >= self.len() {
            bail!("cannot split {n} from {} examples", self.len());
        }
        let keep = self.len() - n;
        let e = self.image_elems();
        let tail = Dataset {
            images: self.images.split_off(keep * e),
            labels: self.labels.split_off(keep),
            hw: self.hw,
            channels: self.channels,
            num_classes: self.num_classes,
        };
        Ok((self, tail))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ds() -> Dataset {
        Dataset {
            images: (0..2 * 2 * 2 * 3).map(|i| i as f32).collect(),
            labels: vec![0, 1],
            hw: 2,
            channels: 3,
            num_classes: 2,
        }
    }

    #[test]
    fn check_catches_bad_labels() {
        let mut ds = tiny_ds();
        assert!(ds.check().is_ok());
        ds.labels[0] = 5;
        assert!(ds.check().is_err());
    }

    #[test]
    fn gather_batch_shapes() {
        let ds = tiny_ds();
        let (x, y) = ds.gather_batch(&[1, 0]).unwrap();
        assert_eq!(x.shape(), &[2, 2, 2, 3]);
        assert_eq!(y.as_i32().unwrap(), vec![1, 0]);
        assert!(ds.gather_batch(&[7]).is_err());
    }

    #[test]
    fn normalize_zero_means() {
        let mut ds = tiny_ds();
        ds.normalize();
        for ch in 0..3 {
            let vals: Vec<f32> = ds.images.iter().skip(ch).step_by(3).copied().collect();
            let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            assert!(mean.abs() < 1e-5);
        }
    }

    #[test]
    fn split_tail_partitions() {
        let ds = tiny_ds();
        let (a, b) = ds.split_tail(1).unwrap();
        assert_eq!(a.len(), 1);
        assert_eq!(b.len(), 1);
        assert_eq!(b.labels, vec![1]);
    }
}
