//! Training-time augmentation: pad-and-crop + horizontal flip — the
//! standard CIFAR recipe the reference Keras implementation [11] uses.
//! Operates on raw pixel slices so the batcher can apply it per example
//! without copying the dataset.

use crate::rng::Xoshiro256;

/// Augmentation configuration.
#[derive(Debug, Clone, Copy)]
pub struct Augment {
    /// Zero-pad margin before random crop (0 disables cropping).
    pub pad: usize,
    /// Probability of horizontal flip.
    pub flip_prob: f64,
}

impl Default for Augment {
    fn default() -> Self {
        Augment { pad: 4, flip_prob: 0.5 }
    }
}

impl Augment {
    pub fn none() -> Self {
        Augment { pad: 0, flip_prob: 0.0 }
    }

    /// Apply to one HWC image, writing the augmented pixels to `out`.
    pub fn apply(
        &self,
        img: &[f32],
        hw: usize,
        c: usize,
        rng: &mut Xoshiro256,
        out: &mut Vec<f32>,
    ) {
        debug_assert_eq!(img.len(), hw * hw * c);
        let flip = self.flip_prob > 0.0 && rng.next_f64() < self.flip_prob;
        let (dy, dx) = if self.pad > 0 {
            (
                rng.next_below(2 * self.pad + 1) as isize - self.pad as isize,
                rng.next_below(2 * self.pad + 1) as isize - self.pad as isize,
            )
        } else {
            (0, 0)
        };
        for y in 0..hw {
            for x in 0..hw {
                let sx = if flip { hw - 1 - x } else { x };
                let sy = y as isize + dy;
                let sx = sx as isize + dx;
                if sy < 0 || sy >= hw as isize || sx < 0 || sx >= hw as isize {
                    out.extend(std::iter::repeat(0.0).take(c));
                } else {
                    let base = (sy as usize * hw + sx as usize) * c;
                    out.extend_from_slice(&img[base..base + c]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(hw: usize, c: usize) -> Vec<f32> {
        (0..hw * hw * c).map(|i| i as f32).collect()
    }

    #[test]
    fn none_is_identity() {
        let img = ramp(4, 3);
        let mut rng = Xoshiro256::new(0);
        let mut out = Vec::new();
        Augment::none().apply(&img, 4, 3, &mut rng, &mut out);
        assert_eq!(out, img);
    }

    #[test]
    fn output_length_constant() {
        let img = ramp(8, 3);
        let mut rng = Xoshiro256::new(1);
        let aug = Augment::default();
        for _ in 0..20 {
            let mut out = Vec::new();
            aug.apply(&img, 8, 3, &mut rng, &mut out);
            assert_eq!(out.len(), img.len());
        }
    }

    #[test]
    fn flip_reverses_rows() {
        let img = ramp(4, 1);
        let mut rng = Xoshiro256::new(2);
        let aug = Augment { pad: 0, flip_prob: 1.0 };
        let mut out = Vec::new();
        aug.apply(&img, 4, 1, &mut rng, &mut out);
        assert_eq!(&out[0..4], &[3.0, 2.0, 1.0, 0.0]);
    }

    #[test]
    fn crop_shifts_are_bounded_and_zero_padded() {
        let img = vec![1.0f32; 4 * 4];
        let mut rng = Xoshiro256::new(3);
        let aug = Augment { pad: 2, flip_prob: 0.0 };
        for _ in 0..50 {
            let mut out = Vec::new();
            aug.apply(&img, 4, 1, &mut rng, &mut out);
            // all values are 0 (padding) or 1 (original)
            assert!(out.iter().all(|&v| v == 0.0 || v == 1.0));
        }
    }
}
