//! CIFAR-10 binary-format loader (`data_batch_*.bin` / `test_batch.bin`).
//!
//! Format (cs.toronto.edu/~kriz/cifar.html): each record is 1 label byte
//! followed by 3072 pixel bytes in CHW plane order (1024 R, 1024 G,
//! 1024 B), 10000 records per file. We convert to NHWC f32 in [0, 1].
//!
//! The sandbox cannot download the dataset; when a copy exists at
//! `data/cifar-10-batches-bin` (or a caller-supplied path) the loaders
//! below are used by the e2e example instead of the synthetic surrogate
//! — the rest of the pipeline is identical.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;

const RECORD: usize = 1 + 3072;
const HW: usize = 32;
const CH: usize = 3;

/// Parse one CIFAR-10 binary file's bytes.
pub fn parse_bin(bytes: &[u8]) -> Result<Dataset> {
    if bytes.is_empty() || bytes.len() % RECORD != 0 {
        bail!(
            "CIFAR bin size {} is not a multiple of record size {RECORD}",
            bytes.len()
        );
    }
    let n = bytes.len() / RECORD;
    let mut images = Vec::with_capacity(n * HW * HW * CH);
    let mut labels = Vec::with_capacity(n);
    for rec in bytes.chunks_exact(RECORD) {
        let label = rec[0];
        if label > 9 {
            bail!("CIFAR label {label} out of range");
        }
        labels.push(label as i32);
        let planes = &rec[1..];
        // CHW planes -> HWC interleave.
        for y in 0..HW {
            for x in 0..HW {
                for c in 0..CH {
                    let v = planes[c * HW * HW + y * HW + x];
                    images.push(v as f32 / 255.0);
                }
            }
        }
    }
    let ds = Dataset { images, labels, hw: HW, channels: CH, num_classes: 10 };
    ds.check()?;
    Ok(ds)
}

/// Load and concatenate a set of batch files.
pub fn load_files(paths: &[impl AsRef<Path>]) -> Result<Dataset> {
    let mut all: Option<Dataset> = None;
    for p in paths {
        let bytes = std::fs::read(p.as_ref())
            .with_context(|| format!("reading {}", p.as_ref().display()))?;
        let ds = parse_bin(&bytes)
            .with_context(|| format!("parsing {}", p.as_ref().display()))?;
        all = Some(match all {
            None => ds,
            Some(mut acc) => {
                acc.images.extend(ds.images);
                acc.labels.extend(ds.labels);
                acc
            }
        });
    }
    all.context("no CIFAR files given")
}

/// Standard train/test split from a `cifar-10-batches-bin` directory,
/// or `None` if the directory is absent.
pub fn load_standard(dir: impl AsRef<Path>) -> Result<Option<(Dataset, Dataset)>> {
    let dir = dir.as_ref();
    if !dir.join("test_batch.bin").exists() {
        return Ok(None);
    }
    let train_files: Vec<_> =
        (1..=5).map(|i| dir.join(format!("data_batch_{i}.bin"))).collect();
    let train = load_files(&train_files)?;
    let test = load_files(&[dir.join("test_batch.bin")])?;
    Ok(Some((train, test)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_record(label: u8, fill: u8) -> Vec<u8> {
        let mut rec = vec![label];
        rec.extend(std::iter::repeat(fill).take(3072));
        rec
    }

    #[test]
    fn parses_synthetic_records() {
        let mut bytes = fake_record(3, 128);
        bytes.extend(fake_record(9, 255));
        let ds = parse_bin(&bytes).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.labels, vec![3, 9]);
        assert!((ds.image(0)[0] - 128.0 / 255.0).abs() < 1e-6);
        assert_eq!(ds.image(1)[0], 1.0);
    }

    #[test]
    fn plane_interleave_is_hwc() {
        // R plane = 10, G = 20, B = 30: every pixel must be [r,g,b].
        let mut rec = vec![0u8];
        rec.extend(std::iter::repeat(10).take(1024));
        rec.extend(std::iter::repeat(20).take(1024));
        rec.extend(std::iter::repeat(30).take(1024));
        let ds = parse_bin(&rec).unwrap();
        let px = &ds.image(0)[..3];
        assert!((px[0] - 10.0 / 255.0).abs() < 1e-6);
        assert!((px[1] - 20.0 / 255.0).abs() < 1e-6);
        assert!((px[2] - 30.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_sizes_and_labels() {
        assert!(parse_bin(&[0u8; 100]).is_err());
        let rec = fake_record(12, 0);
        assert!(parse_bin(&rec).is_err());
    }

    #[test]
    fn missing_dir_is_none() {
        assert!(load_standard("/nonexistent/path").unwrap().is_none());
    }
}
