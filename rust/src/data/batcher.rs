//! Deterministic shuffling batcher with optional augmentation.
//!
//! Epoch semantics match the reference Keras setup: reshuffle example
//! order every epoch (seeded: epoch `e` of run seed `s` always yields
//! the same order — the checkpoint-resume procedures in the hybrid
//! search rely on this to replay the exact batch sequence).

use anyhow::Result;

use crate::rng::Xoshiro256;
use crate::tensor::Tensor;

use super::augment::Augment;
use super::Dataset;

/// Batch iterator over a dataset for one epoch.
pub struct Batcher<'a> {
    ds: &'a Dataset,
    order: Vec<usize>,
    batch: usize,
    cursor: usize,
    augment: Augment,
    rng: Xoshiro256,
    /// Drop the final short batch (static-shape graphs need full batches).
    drop_last: bool,
}

impl<'a> Batcher<'a> {
    /// Batcher for `epoch` of run `seed`. Drops the final short batch
    /// by default (the static-shape compiled graphs need full batches);
    /// see [`Batcher::with_drop_last`].
    pub fn new(
        ds: &'a Dataset,
        batch: usize,
        seed: u64,
        epoch: u64,
        augment: Augment,
    ) -> Self {
        let mut rng = Xoshiro256::new(seed ^ epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut order: Vec<usize> = (0..ds.len()).collect();
        rng.shuffle(&mut order);
        Batcher { ds, order, batch, cursor: 0, augment, rng, drop_last: true }
    }

    /// Choose whether the final short batch is yielded (`false`) or
    /// dropped (`true`, the default). The native backend has no
    /// static-shape constraint, so it can train on every example of an
    /// epoch whose size is not a multiple of the batch size.
    pub fn with_drop_last(mut self, drop_last: bool) -> Self {
        self.drop_last = drop_last;
        self
    }

    /// Number of batches this epoch will yield (counts the final short
    /// batch when `drop_last` is off).
    pub fn batches_per_epoch(&self) -> usize {
        if self.drop_last {
            self.ds.len() / self.batch
        } else {
            self.ds.len().div_ceil(self.batch)
        }
    }

    /// Next `[batch, hw, hw, c]` / `[batch]` pair, or `None` at epoch end.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Result<Option<(Tensor, Tensor)>> {
        let remaining = self.order.len() - self.cursor;
        if remaining < self.batch && (self.drop_last || remaining == 0) {
            return Ok(None);
        }
        let take = remaining.min(self.batch);
        let idx = &self.order[self.cursor..self.cursor + take];
        self.cursor += take;

        let e = self.ds.image_elems();
        let mut pixels = Vec::with_capacity(take * e);
        let mut labels = Vec::with_capacity(take);
        for &i in idx {
            self.augment.apply(
                self.ds.image(i),
                self.ds.hw,
                self.ds.channels,
                &mut self.rng,
                &mut pixels,
            );
            labels.push(self.ds.labels[i]);
        }
        let x = Tensor::from_f32(
            &[take, self.ds.hw, self.ds.hw, self.ds.channels],
            pixels,
        )?;
        let y = Tensor::from_i32(&[take], labels)?;
        Ok(Some((x, y)))
    }
}

/// Iterate a full dataset in fixed-size eval batches. For static-shape
/// consumers the last batch is padded by repeating example 0 (the pad
/// contribution is subtracted by the caller via the returned
/// true-count); dynamic-batch consumers use [`EvalBatcher::unpadded`]
/// and get the short final batch as-is — no copied pad examples, and
/// no pad rows silently counted into batch statistics.
pub struct EvalBatcher<'a> {
    ds: &'a Dataset,
    batch: usize,
    cursor: usize,
    pad: bool,
}

impl<'a> EvalBatcher<'a> {
    pub fn new(ds: &'a Dataset, batch: usize) -> Self {
        EvalBatcher { ds, batch, cursor: 0, pad: true }
    }

    /// Batcher that yields the final short batch instead of padding it.
    pub fn unpadded(ds: &'a Dataset, batch: usize) -> Self {
        EvalBatcher { ds, batch, cursor: 0, pad: false }
    }

    /// Next `(x, y, true_count)`: `true_count < batch` on the final
    /// batch so metrics can ignore padding (padded mode) — in unpadded
    /// mode it always equals the yielded batch's size.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Result<Option<(Tensor, Tensor, usize)>> {
        if self.cursor >= self.ds.len() {
            return Ok(None);
        }
        let take = (self.ds.len() - self.cursor).min(self.batch);
        let mut idx: Vec<usize> = (self.cursor..self.cursor + take).collect();
        if self.pad {
            idx.resize(self.batch, 0); // pad with example 0
        }
        self.cursor += take;
        let (x, y) = self.ds.gather_batch(&idx)?;
        Ok(Some((x, y, take)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticCifar;

    fn ds() -> Dataset {
        SyntheticCifar::for_input(8, 3, 10, 1).generate(50)
    }

    #[test]
    fn yields_full_batches_only() {
        let ds = ds();
        let mut b = Batcher::new(&ds, 16, 7, 0, Augment::none());
        let mut count = 0;
        while let Some((x, y)) = b.next().unwrap() {
            assert_eq!(x.shape(), &[16, 8, 8, 3]);
            assert_eq!(y.shape(), &[16]);
            count += 1;
        }
        assert_eq!(count, 3); // 50/16
        assert_eq!(b.batches_per_epoch(), 3);
    }

    #[test]
    fn epoch_reshuffles_deterministically() {
        let ds = ds();
        let first = |epoch| {
            let mut b = Batcher::new(&ds, 16, 7, epoch, Augment::none());
            b.next().unwrap().unwrap().1.as_i32().unwrap()
        };
        assert_eq!(first(0), first(0));
        assert_ne!(first(0), first(1));
    }

    #[test]
    fn covers_every_example_once() {
        let ds = ds();
        let mut b = Batcher::new(&ds, 10, 3, 2, Augment::none());
        let mut seen = vec![0u32; ds.len()];
        // Recover coverage through labels is ambiguous; instead check the
        // internal order is a permutation by consuming all batches.
        let mut total = 0;
        while let Some((_, y)) = b.next().unwrap() {
            total += y.len();
        }
        assert_eq!(total, 50);
        // order field covered by construction (shuffle is a permutation);
        // see rng tests.
        let _ = &mut seen;
    }

    #[test]
    fn keep_last_yields_short_final_batch() {
        let ds = ds(); // 50 examples
        let mut b = Batcher::new(&ds, 16, 7, 0, Augment::none()).with_drop_last(false);
        assert_eq!(b.batches_per_epoch(), 4); // ceil(50/16)
        let mut sizes = Vec::new();
        let mut total = 0;
        while let Some((x, y)) = b.next().unwrap() {
            assert_eq!(x.shape()[1..], [8, 8, 3][..]);
            assert_eq!(x.shape()[0], y.len());
            sizes.push(y.len());
            total += y.len();
        }
        assert_eq!(sizes, vec![16, 16, 16, 2]);
        assert_eq!(total, 50); // every example of the epoch is seen
    }

    #[test]
    fn drop_last_modes_agree_on_full_batches() {
        // Same seed/epoch: the first full batches are identical in both
        // modes — only the tail differs.
        let ds = ds();
        let mut keep = Batcher::new(&ds, 16, 9, 1, Augment::none()).with_drop_last(false);
        let mut drop = Batcher::new(&ds, 16, 9, 1, Augment::none());
        for _ in 0..3 {
            let (xk, yk) = keep.next().unwrap().unwrap();
            let (xd, yd) = drop.next().unwrap().unwrap();
            assert_eq!(xk, xd);
            assert_eq!(yk, yd);
        }
        assert!(drop.next().unwrap().is_none());
        let (x, _) = keep.next().unwrap().unwrap();
        assert_eq!(x.shape()[0], 2);
        assert!(keep.next().unwrap().is_none());
    }

    #[test]
    fn eval_batcher_unpadded_yields_short_final() {
        let ds = ds();
        let mut b = EvalBatcher::unpadded(&ds, 16);
        let mut trues = 0;
        let mut shapes = Vec::new();
        while let Some((x, y, t)) = b.next().unwrap() {
            assert_eq!(x.shape()[0], y.len());
            assert_eq!(y.len(), t);
            shapes.push(x.shape()[0]);
            trues += t;
        }
        assert_eq!(shapes, vec![16, 16, 16, 2]);
        assert_eq!(trues, 50);
    }

    #[test]
    fn eval_batcher_pads_final() {
        let ds = ds();
        let mut b = EvalBatcher::new(&ds, 16);
        let mut trues = 0;
        let mut batches = 0;
        while let Some((x, _, t)) = b.next().unwrap() {
            assert_eq!(x.shape()[0], 16);
            trues += t;
            batches += 1;
        }
        assert_eq!(trues, 50);
        assert_eq!(batches, 4); // ceil(50/16)
    }
}
