//! Training metrics: per-epoch history, streaming summaries, CSV export.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// One epoch's record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochRecord {
    pub epoch: u64,
    /// Mean training cross-entropy over the epoch's steps.
    pub train_loss: f64,
    /// Mean minibatch training accuracy.
    pub train_acc: f64,
    /// Held-out accuracy (exact multipliers, per the paper's protocol).
    pub test_acc: f64,
    pub test_loss: f64,
    /// Sigma in force during this epoch (0 = exact phase).
    pub sigma: f64,
    pub lr: f64,
    pub wall_secs: f64,
}

/// Full run history.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub records: Vec<EpochRecord>,
}

impl History {
    pub fn push(&mut self, r: EpochRecord) {
        self.records.push(r);
    }

    pub fn best_test_acc(&self) -> Option<(u64, f64)> {
        self.records
            .iter()
            .map(|r| (r.epoch, r.test_acc))
            .max_by(|a, b| a.1.total_cmp(&b.1))
    }

    pub fn final_test_acc(&self) -> Option<f64> {
        self.records.last().map(|r| r.test_acc)
    }

    /// First epoch whose test accuracy reaches `target`, if any.
    pub fn first_epoch_reaching(&self, target: f64) -> Option<u64> {
        self.records
            .iter()
            .find(|r| r.test_acc >= target)
            .map(|r| r.epoch)
    }

    /// CSV serialization (header + one row per epoch).
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("epoch,train_loss,train_acc,test_loss,test_acc,sigma,lr,wall_secs\n");
        for r in &self.records {
            let _ = writeln!(
                out,
                "{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.3}",
                r.epoch,
                r.train_loss,
                r.train_acc,
                r.test_loss,
                r.test_acc,
                r.sigma,
                r.lr,
                r.wall_secs
            );
        }
        out
    }

    pub fn save_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_csv())
            .with_context(|| format!("writing {}", path.as_ref().display()))
    }

    /// Parse back a CSV produced by [`History::to_csv`].
    pub fn from_csv(text: &str) -> Result<Self> {
        let mut records = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if i == 0 || line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            anyhow::ensure!(f.len() == 8, "line {i}: {} fields", f.len());
            records.push(EpochRecord {
                epoch: f[0].parse()?,
                train_loss: f[1].parse()?,
                train_acc: f[2].parse()?,
                test_loss: f[3].parse()?,
                test_acc: f[4].parse()?,
                sigma: f[5].parse()?,
                lr: f[6].parse()?,
                wall_secs: f[7].parse()?,
            });
        }
        Ok(History { records })
    }
}

/// Classified training-runtime failure — the watchdog's trip taxonomy
/// ([`crate::coordinator::health`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// NaN/Inf in the loss, parameters, state or optimizer momentum.
    NonFinite,
    /// Finite but spiking loss (windowed heuristic).
    Divergence,
    /// The checkpoint store failed to save or restore.
    CheckpointIo,
}

impl FailureKind {
    pub fn name(self) -> &'static str {
        match self {
            FailureKind::NonFinite => "non-finite",
            FailureKind::Divergence => "divergence",
            FailureKind::CheckpointIo => "checkpoint-io",
        }
    }
}

/// One watchdog trip.
#[derive(Debug, Clone, PartialEq)]
pub struct HealthEvent {
    pub epoch: u64,
    /// Global step (epoch * steps_per_epoch + step_in_epoch).
    pub step: u64,
    pub kind: FailureKind,
    pub detail: String,
}

/// Aggregate runtime-health record of one training run. Empty (all
/// zeros) whenever the watchdog is off or never tripped.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HealthLog {
    /// Steps the health monitor inspected.
    pub steps_checked: u64,
    pub trips: Vec<HealthEvent>,
    /// Rollbacks to a checkpoint (or to scratch) performed.
    pub rollbacks: u64,
    /// `(global step, spec escalated to)` per ladder advance.
    pub escalations: Vec<(u64, String)>,
    /// Checkpoint saves that needed a backoff retry.
    pub save_retries: u64,
}

impl HealthLog {
    /// One-line operator summary.
    pub fn summary(&self) -> String {
        let esc: Vec<String> = self
            .escalations
            .iter()
            .map(|(step, spec)| format!("{spec}@{step}"))
            .collect();
        format!(
            "{} steps checked, {} trips, {} rollbacks, escalations [{}], {} save retries",
            self.steps_checked,
            self.trips.len(),
            self.rollbacks,
            esc.join(", "),
            self.save_retries
        )
    }
}

/// Streaming mean (loss/accuracy accumulation inside an epoch).
#[derive(Debug, Clone, Copy, Default)]
pub struct Mean {
    sum: f64,
    n: u64,
}

impl Mean {
    pub fn add(&mut self, v: f64) {
        self.sum += v;
        self.n += 1;
    }

    pub fn get(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(epoch: u64, acc: f64) -> EpochRecord {
        EpochRecord {
            epoch,
            train_loss: 1.0,
            train_acc: 0.5,
            test_loss: 1.2,
            test_acc: acc,
            sigma: 0.0,
            lr: 0.05,
            wall_secs: 1.5,
        }
    }

    #[test]
    fn best_and_reaching() {
        let mut h = History::default();
        h.push(rec(0, 0.3));
        h.push(rec(1, 0.8));
        h.push(rec(2, 0.7));
        assert_eq!(h.best_test_acc(), Some((1, 0.8)));
        assert_eq!(h.first_epoch_reaching(0.75), Some(1));
        assert_eq!(h.first_epoch_reaching(0.9), None);
        assert_eq!(h.final_test_acc(), Some(0.7));
    }

    #[test]
    fn csv_roundtrip() {
        let mut h = History::default();
        h.push(rec(0, 0.25));
        h.push(rec(1, 0.5));
        let parsed = History::from_csv(&h.to_csv()).unwrap();
        assert_eq!(parsed.records.len(), 2);
        assert!((parsed.records[1].test_acc - 0.5).abs() < 1e-9);
    }

    #[test]
    fn mean_streaming() {
        let mut m = Mean::default();
        assert_eq!(m.get(), 0.0);
        m.add(1.0);
        m.add(3.0);
        assert_eq!(m.get(), 2.0);
        assert_eq!(m.count(), 2);
    }
}
