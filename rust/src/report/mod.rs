//! Report rendering: markdown tables, ASCII histograms, and the
//! paper-vs-measured comparison layouts used by the table2/table3/fig2
//! harnesses.

use std::fmt::Write as _;

/// A simple markdown/ASCII table builder with right-aligned numeric
/// columns.
#[derive(Debug, Default, Clone)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as a GitHub-flavoured markdown table.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(line, " {:>w$} |", c, w = w);
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push('|');
        for w in &widths {
            let _ = write!(out, "{:-<w$}|", "", w = w + 2);
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Render an ASCII histogram (the Figure-2 reproduction's terminal
/// form; the CSV twin is written next to it for plotting).
pub fn ascii_histogram(
    edges: &[f64],
    counts: &[u64],
    width: usize,
    max_rows: usize,
) -> String {
    assert_eq!(edges.len(), counts.len());
    let mut out = String::new();
    if counts.is_empty() {
        return out;
    }
    // Downsample bins to at most max_rows rows by summing groups.
    let group = counts.len().div_ceil(max_rows);
    let peak = counts
        .chunks(group)
        .map(|c| c.iter().sum::<u64>())
        .max()
        .unwrap_or(1)
        .max(1);
    for (i, chunk) in counts.chunks(group).enumerate() {
        let total: u64 = chunk.iter().sum();
        let bar = (total as f64 / peak as f64 * width as f64).round() as usize;
        let lo = edges[i * group];
        let _ = writeln!(
            out,
            "{:>8.4} | {:<width$} {}",
            lo,
            "#".repeat(bar),
            total,
            width = width
        );
    }
    out
}

/// Render an ASCII line chart of one or more labelled series over a
/// shared x axis (epoch loss/accuracy curves; the terminal twin of the
/// CSVs the trainer writes).
pub fn line_chart(
    series: &[(&str, &[f64])],
    height: usize,
    width: usize,
) -> String {
    let mut out = String::new();
    let n = series.iter().map(|(_, v)| v.len()).max().unwrap_or(0);
    if n == 0 || height < 2 {
        return out;
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for (_, v) in series {
        for &y in *v {
            lo = lo.min(y);
            hi = hi.max(y);
        }
    }
    if !lo.is_finite() || hi <= lo {
        hi = lo + 1.0;
    }
    let marks: &[char] = &['*', 'o', '+', 'x', '#'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, v)) in series.iter().enumerate() {
        for (i, &y) in v.iter().enumerate() {
            let cx = if n == 1 { 0 } else { i * (width - 1) / (n - 1) };
            let fy = (y - lo) / (hi - lo);
            let cy = height - 1 - ((fy * (height - 1) as f64).round() as usize);
            grid[cy.min(height - 1)][cx.min(width - 1)] = marks[si % marks.len()];
        }
    }
    for (ri, row) in grid.iter().enumerate() {
        let label = if ri == 0 {
            format!("{hi:>9.3}")
        } else if ri == height - 1 {
            format!("{lo:>9.3}")
        } else {
            " ".repeat(9)
        };
        let _ = writeln!(out, "{label} | {}", row.iter().collect::<String>());
    }
    let _ = writeln!(out, "{:>9}   {}", "", "-".repeat(width));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {}", marks[i % marks.len()], name))
        .collect();
    let _ = writeln!(out, "{:>12}{}", "", legend.join("   "));
    out
}

/// CSV for (edges, counts) histograms.
pub fn histogram_csv(edges: &[f64], counts: &[u64]) -> String {
    let mut out = String::from("bin_lo,count\n");
    for (e, c) in edges.iter().zip(counts) {
        let _ = writeln!(out, "{e:.6},{c}");
    }
    out
}

/// Format a fraction as a percent string like `93.53%`.
pub fn pct(v: f64) -> String {
    format!("{:.2}%", 100.0 * v)
}

/// Format a signed accuracy delta like the paper's "Diff." column.
pub fn diff_pct(v: f64) -> String {
    format!("{:+.2}%", 100.0 * v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["id", "value"]);
        t.row(vec!["1".into(), "93.6".into()]);
        t.row(vec!["22".into(), "5".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| id | value |"));
        assert_eq!(md.lines().count(), 4);
        let lines: Vec<&str> = md.lines().collect();
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        Table::new(&["a", "b"]).row(vec!["1".into()]);
    }

    #[test]
    fn histogram_shapes() {
        let edges: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let counts: Vec<u64> = (0..10).map(|i| i * 10).collect();
        let h = ascii_histogram(&edges, &counts, 20, 5);
        assert_eq!(h.lines().count(), 5);
        let csv = histogram_csv(&edges, &counts);
        assert_eq!(csv.lines().count(), 11);
    }

    #[test]
    fn line_chart_renders() {
        let a = [3.0, 2.0, 1.0, 0.5];
        let b = [2.5, 2.0, 1.8, 1.7];
        let c = line_chart(&[("exact", &a), ("approx", &b)], 8, 40);
        assert_eq!(c.lines().count(), 10);
        assert!(c.contains("exact"));
        assert!(c.contains('*') && c.contains('o'));
        assert!(line_chart(&[("empty", &[])], 8, 40).is_empty());
    }

    #[test]
    fn line_chart_constant_series() {
        let a = [1.0, 1.0, 1.0];
        let c = line_chart(&[("flat", &a)], 4, 10);
        assert!(!c.is_empty());
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.9353), "93.53%");
        assert_eq!(diff_pct(-0.0007), "-0.07%");
        assert_eq!(diff_pct(0.001), "+0.10%");
    }
}
