//! Failure classification and backoff policy for the resilient trainer.
//!
//! The watchdog reacts differently to different failures:
//!
//! * **non-finite / divergence** — training-path failures; roll back to
//!   the newest verified checkpoint, and if the *same* step trips again
//!   after a clean (bit-identical) replay, escalate the multiplier one
//!   rung up the configured ladder — a deterministic trip will recur
//!   deterministically, so a second trip at the same step is evidence
//!   of a systematic numeric failure, not a transient.
//! * **checkpoint-IO** — store failures; retried with exponential
//!   backoff at the save site, fatal if the budget is exhausted
//!   (rolling back onto a broken store would loop forever).
//!
//! Classification is typed, not string-matched: every failure the
//! runtime can raise carries a marker in its `anyhow` chain
//! ([`health::Trip`], [`runtime::NonFiniteLoss`], the checkpoint
//! store's `CkptFault`), recovered here by downcast.

// detlint: allow(D2) -- Duration is the backoff-delay type only; recovery replay itself is step-indexed, not clocked
use std::time::Duration;

use crate::checkpoint;
use crate::metrics::FailureKind;
use crate::runtime::NonFiniteLoss;

use super::health::Trip;

/// A classified training failure, extracted from an error chain.
#[derive(Debug, Clone)]
pub struct TripReport {
    pub kind: FailureKind,
    /// Global step at the failure, when the failing layer knew it
    /// (checkpoint-store errors don't).
    pub step: Option<u64>,
    pub detail: String,
}

/// Classify an error as a recoverable training failure. `None` means
/// the error is not a health trip (config error, bug, ...) and must
/// surface unchanged rather than trigger a rollback.
pub fn classify_failure(err: &anyhow::Error) -> Option<TripReport> {
    for cause in err.chain() {
        if let Some(trip) = cause.downcast_ref::<Trip>() {
            return Some(TripReport {
                kind: trip.kind,
                step: Some(trip.step),
                detail: trip.detail.clone(),
            });
        }
        if let Some(nf) = cause.downcast_ref::<NonFiniteLoss>() {
            return Some(TripReport {
                kind: FailureKind::NonFinite,
                step: Some(nf.step),
                detail: format!("{nf}"),
            });
        }
    }
    if let Some(class) = checkpoint::classify(err) {
        return Some(TripReport {
            kind: FailureKind::CheckpointIo,
            step: None,
            detail: format!("checkpoint store failure ({})", class.name()),
        });
    }
    None
}

/// Exponential backoff: `base_ms << attempt`, capped at 5 s so an
/// exhausted retry budget is reached in bounded wall time.
pub fn backoff_delay(base_ms: u64, attempt: u32) -> Duration {
    let ms = base_ms.saturating_mul(1u64 << attempt.min(16));
    Duration::from_millis(ms.min(5_000))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::{FailureClass, Store, StoreFault};

    #[test]
    fn classifies_trips_through_context_chains() {
        let base = anyhow::Error::new(Trip {
            kind: FailureKind::Divergence,
            epoch: 2,
            step: 17,
            detail: "loss spike".into(),
        })
        .context("epoch 2 failed")
        .context("training run aborted");
        let report = classify_failure(&base).unwrap();
        assert_eq!(report.kind, FailureKind::Divergence);
        assert_eq!(report.step, Some(17));
    }

    #[test]
    fn classifies_session_non_finite_loss() {
        let err = anyhow::Error::new(NonFiniteLoss { step: 9 }).context("step failed");
        let report = classify_failure(&err).unwrap();
        assert_eq!(report.kind, FailureKind::NonFinite);
        assert_eq!(report.step, Some(9));
    }

    #[test]
    fn classifies_checkpoint_store_failures() {
        let dir = std::env::temp_dir().join(format!("axm-recovery-{}", std::process::id()));
        let store = Store::new(&dir).unwrap();
        store.inject_fault(Some(StoreFault::FailNextSave));
        let meta = checkpoint::Meta {
            preset: "p".into(),
            epoch: 1,
            step: 1,
            sigma: 0.0,
            mult: "exact".into(),
            tag: "t".into(),
            escalated_from: None,
        };
        let named: Vec<(String, &crate::tensor::Tensor)> = Vec::new();
        let err = store.save(&meta, &named).unwrap_err();
        let report = classify_failure(&err).unwrap();
        assert_eq!(report.kind, FailureKind::CheckpointIo);
        assert_eq!(report.step, None);
        assert!(report.detail.contains(FailureClass::Io.name()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unrelated_errors_stay_unclassified() {
        let err = anyhow::anyhow!("bad config: epochs must be >= 1");
        assert!(classify_failure(&err).is_none());
    }

    #[test]
    fn backoff_doubles_and_caps() {
        assert_eq!(backoff_delay(50, 0), Duration::from_millis(50));
        assert_eq!(backoff_delay(50, 1), Duration::from_millis(100));
        assert_eq!(backoff_delay(50, 3), Duration::from_millis(400));
        assert_eq!(backoff_delay(50, 30), Duration::from_millis(5_000));
        assert_eq!(backoff_delay(0, 5), Duration::from_millis(0));
    }
}
