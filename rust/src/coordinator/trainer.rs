//! The training orchestrator (paper Figure 3).
//!
//! Owns: data pipeline, the PJRT train session, the per-epoch loop with
//! multiplier policy + error sampling + lr schedule, exact-multiplier
//! evaluation, checkpointing and early stopping. Everything epoch-level
//! is decided *here*; the compiled graph only sees scalar knobs.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::checkpoint::{Meta, Store};
use crate::config::{ErrorSampling, ExperimentConfig};
use crate::data::augment::Augment;
use crate::data::batcher::{Batcher, EvalBatcher};
use crate::data::{Dataset, SyntheticCifar};
use crate::metrics::{EpochRecord, History, Mean};
use crate::runtime::session::StepInputs;
use crate::runtime::{Engine, TrainSession};

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub history: History,
    pub best_accuracy: f64,
    pub final_accuracy: f64,
    pub epochs_run: u64,
    pub wall_secs: f64,
}

/// Callback invoked after every epoch (progress logging, live plots).
pub type EpochHook<'h> = dyn FnMut(&EpochRecord) + 'h;

/// The training orchestrator.
pub struct Trainer<'e> {
    engine: &'e Engine,
    cfg: ExperimentConfig,
    train_ds: Dataset,
    test_ds: Dataset,
    session: TrainSession,
    store: Option<Store>,
    /// Derived sub-seeds (stable functions of cfg.seed).
    seed_init: u32,
    seed_err_base: u32,
}

impl<'e> Trainer<'e> {
    /// Build a trainer with synthetic data sized for the preset
    /// (real CIFAR-10 can be supplied via [`Trainer::with_data`]).
    pub fn new(engine: &'e Engine, cfg: ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let model = engine.manifest().model(&cfg.preset)?;
        let mut gen = SyntheticCifar::for_input(
            model.input_hw,
            model.in_ch,
            model.num_classes,
            cfg.seed ^ 0xDA7A,
        );
        gen.noise = cfg.data_noise as f32;
        // Test size rounded up to a multiple of the eval batch so the
        // static-shape eval graph never sees padding.
        let test_n = cfg.test_examples.div_ceil(model.eval_batch) * model.eval_batch;
        let mut train_ds = gen.generate(cfg.train_examples + test_n);
        train_ds.normalize();
        let (train_ds, test_ds) = train_ds.split_tail(test_n)?;
        Self::with_data(engine, cfg, train_ds, test_ds)
    }

    /// Build a trainer over caller-provided datasets.
    pub fn with_data(
        engine: &'e Engine,
        cfg: ExperimentConfig,
        train_ds: Dataset,
        test_ds: Dataset,
    ) -> Result<Self> {
        cfg.validate()?;
        train_ds.check()?;
        test_ds.check()?;
        let model = engine.manifest().model(&cfg.preset)?;
        anyhow::ensure!(
            test_ds.len() % model.eval_batch == 0,
            "test set ({}) must be a multiple of eval batch ({})",
            test_ds.len(),
            model.eval_batch
        );
        let seed_init = (cfg.seed as u32) ^ ((cfg.seed >> 32) as u32);
        let session = TrainSession::new(engine, &cfg.preset, seed_init)
            .context("creating train session")?;
        let store = if cfg.out_dir.is_empty() {
            None
        } else {
            Some(Store::new(&cfg.out_dir)?)
        };
        Ok(Trainer {
            engine,
            cfg,
            train_ds,
            test_ds,
            session,
            store,
            seed_init,
            seed_err_base: seed_init.wrapping_mul(0x9E37_79B9) ^ 0xE44E,
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn session(&self) -> &TrainSession {
        &self.session
    }

    /// Restore session state from a checkpoint's tensors (hybrid resume).
    pub fn restore_state(&mut self, tensors: Vec<crate::tensor::Tensor>) -> Result<()> {
        self.session.restore(tensors)
    }

    /// Exact-multiplier accuracy on the held-out set (paper protocol).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let mut eb = EvalBatcher::new(&self.test_ds, self.session.eval_batch_size());
        let mut correct = 0i64;
        let mut loss_sum = 0f64;
        let mut total = 0usize;
        while let Some((x, y, t)) = eb.next()? {
            debug_assert_eq!(t, self.session.eval_batch_size());
            let s = self.session.eval_batch(x, y)?;
            correct += s.correct;
            loss_sum += s.loss_sum as f64;
            total += t;
        }
        Ok((correct as f64 / total as f64, loss_sum / total as f64))
    }

    /// Run the configured number of epochs. `resume_from` skips the
    /// first `n` epochs (data order and seeds replay identically — the
    /// hybrid search relies on this).
    pub fn run_from(
        &mut self,
        resume_from: u64,
        mut hook: Option<&mut EpochHook<'_>>,
    ) -> Result<TrainOutcome> {
        let started = Instant::now();
        let mut history = History::default();
        let mut best = f64::MIN;
        let mut best_epoch = 0u64;
        let augment = if self.cfg.augment { Augment::default() } else { Augment::none() };
        let batch = self.session.batch_size();
        let steps_per_epoch = (self.train_ds.len() / batch) as u64;

        for epoch in resume_from..self.cfg.epochs {
            let epoch_started = Instant::now();
            let sigma = self.cfg.policy.sigma_at(epoch) as f32;
            let lr = self.cfg.lr.at_epoch(epoch) as f32;
            let mut loss_mean = Mean::default();
            let mut acc_mean = Mean::default();

            let mut batcher =
                Batcher::new(&self.train_ds, batch, self.cfg.seed, epoch, augment);
            let mut step_in_epoch = 0u64;
            while let Some((x, y)) = batcher.next()? {
                let global_step = epoch * steps_per_epoch + step_in_epoch;
                let seed_err = match self.cfg.sampling {
                    // Fixed per run: the paper's Figure-3 procedure.
                    ErrorSampling::FixedPerRun => self.seed_err_base,
                    // Fresh field each step.
                    ErrorSampling::PerStep => {
                        self.seed_err_base.wrapping_add(global_step as u32)
                    }
                };
                let stats = self.session.step(
                    x,
                    y,
                    StepInputs {
                        seed_err,
                        seed_drop: (self.seed_init ^ 0xD409).wrapping_add(global_step as u32),
                        sigma,
                        lr,
                    },
                )?;
                loss_mean.add(stats.loss as f64);
                acc_mean.add(stats.accuracy as f64);
                step_in_epoch += 1;
            }

            let (test_acc, test_loss) = self.evaluate()?;
            let record = EpochRecord {
                epoch,
                train_loss: loss_mean.get(),
                train_acc: acc_mean.get(),
                test_acc,
                test_loss,
                sigma: sigma as f64,
                lr: lr as f64,
                wall_secs: epoch_started.elapsed().as_secs_f64(),
            };
            log::info!(
                "[{}] epoch {:>3}: loss {:.4} train_acc {:.3} test_acc {:.4} (sigma {:.3}, lr {:.4})",
                self.cfg.tag, epoch, record.train_loss, record.train_acc,
                record.test_acc, record.sigma, record.lr
            );
            if let Some(h) = hook.as_deref_mut() {
                h(&record);
            }
            history.push(record);

            if test_acc > best {
                best = test_acc;
                best_epoch = epoch;
            }

            if let Some(store) = &self.store {
                let due = self.cfg.checkpoint_every > 0
                    && (epoch + 1) % self.cfg.checkpoint_every == 0;
                if due || epoch + 1 == self.cfg.epochs {
                    self.save_checkpoint(store, epoch, sigma as f64)?;
                }
            }

            if self.cfg.patience > 0 && epoch - best_epoch >= self.cfg.patience {
                log::info!(
                    "[{}] early stop at epoch {epoch} (best {best:.4} at {best_epoch})",
                    self.cfg.tag
                );
                break;
            }
        }

        let final_accuracy = history.final_test_acc().unwrap_or(0.0);
        Ok(TrainOutcome {
            best_accuracy: if history.records.is_empty() { 0.0 } else { best },
            final_accuracy,
            epochs_run: history.records.len() as u64,
            wall_secs: started.elapsed().as_secs_f64(),
            history,
        })
    }

    /// Run all epochs from scratch.
    pub fn run(&mut self) -> Result<TrainOutcome> {
        self.run_from(0, None)
    }

    fn save_checkpoint(&self, store: &Store, epoch: u64, sigma: f64) -> Result<()> {
        let model = self.engine.manifest().model(&self.cfg.preset)?;
        let names: Vec<String> = model
            .params
            .iter()
            .map(|p| format!("param:{}", p.name))
            .chain(model.state.iter().map(|s| format!("state:{}", s.name)))
            .chain(model.params.iter().map(|p| format!("opt:{}", p.name)))
            .collect();
        let named: Vec<(String, &crate::tensor::Tensor)> = names
            .into_iter()
            .zip(self.session.state_tensors())
            .collect();
        let meta = Meta {
            preset: self.cfg.preset.clone(),
            epoch: epoch + 1, // checkpoint taken *after* this many epochs
            step: self.session.steps_run(),
            sigma,
            tag: self.cfg.tag.clone(),
        };
        store.save(&meta, &named)?;
        Ok(())
    }
}
