//! The training orchestrator (paper Figure 3).
//!
//! Owns: data pipeline, the train session (PJRT- or native-backed), the
//! per-epoch loop with multiplier policy + error sampling + lr
//! schedule, exact-multiplier evaluation, checkpointing and early
//! stopping. Everything epoch-level is decided *here*; the backend only
//! sees scalar knobs.
//!
//! Per-step sub-seeds (error matrices, dropout) are derived from the
//! run seed by Threefry counter splitting ([`rng::counter_split`]):
//! each consumer gets its own domain-tagged, statistically independent
//! stream, replacing the old `base.wrapping_add(step)` arithmetic
//! whose streams were shifts of each other and collided structurally.
//!
//! # Resilient mode
//!
//! With `cfg.watchdog` set, the run is wrapped in a
//! rollback-and-escalate loop: every committed step is health-checked
//! ([`super::health`]), checkpoints are verified on write and retained
//! last-K, and a trip rolls the session back to the newest valid
//! checkpoint. Because all per-step randomness is a pure function of
//! `(run seed, domain, global step)`, the rolled-back replay is
//! bit-identical to the original trajectory — so a trip that recurs at
//! the *same* global step is deterministic, and the watchdog responds
//! by escalating the multiplier one rung up the configured ladder
//! (e.g. `drum6 -> exact`) instead of looping forever. With the
//! watchdog off, the step loop is byte-for-byte the historical one:
//! golden trajectories are unchanged.

use std::path::PathBuf;
// detlint: allow(D2) -- wall-clock is telemetry-only here (wall_secs in History); no step math reads it
use std::time::Instant;

use anyhow::{Context, Result};

use crate::checkpoint::{Meta, Store};
use crate::config::{
    ErrorSampling, ExecBackend, ExperimentConfig, MultiplierPolicy, WatchdogConfig,
};
use crate::data::augment::Augment;
use crate::data::batcher::{Batcher, EvalBatcher};
use crate::data::{Dataset, SyntheticCifar};
use crate::metrics::{EpochRecord, FailureKind, HealthEvent, HealthLog, History};
use crate::mult::MultSpec;
use crate::rng::{counter_split, STREAM_DROP, STREAM_ERR, STREAM_INIT};
use crate::runtime::session::StepInputs;
use crate::runtime::{BackendModel, Engine, NativeBackend, TrainSession};
use crate::testkit::faults::FaultPlan;

use super::health::WatchCtx;
use super::recovery;

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub history: History,
    pub best_accuracy: f64,
    pub final_accuracy: f64,
    pub epochs_run: u64,
    pub wall_secs: f64,
    /// Watchdog activity (all-zero when the watchdog is off or idle).
    pub health: HealthLog,
}

/// Callback invoked after every epoch (progress logging, live plots).
/// In resilient mode it also fires for *replayed* epochs after a
/// rollback — consumers keyed on `record.epoch` are idempotent.
pub type EpochHook<'h> = dyn FnMut(&EpochRecord) + 'h;

/// Build the session the config asks for. The engine is only needed
/// for the PJRT backend; the native backend is self-contained.
fn make_session(engine: Option<&Engine>, cfg: &ExperimentConfig) -> Result<TrainSession> {
    let seed_init = counter_split(cfg.seed, STREAM_INIT, 0);
    match cfg.backend {
        ExecBackend::Native => {
            let spec = cfg.policy.mult().cloned().unwrap_or(MultSpec::Exact);
            let backend = NativeBackend::new(&cfg.preset, spec)?;
            TrainSession::with_backend(Box::new(backend), seed_init)
        }
        ExecBackend::Pjrt => {
            let engine = engine.context(
                "the PJRT backend needs an Engine (compiled artifacts); \
                 set backend: native or construct the trainer with one",
            )?;
            TrainSession::new(engine, &cfg.preset, seed_init)
        }
    }
}

/// The training orchestrator.
pub struct Trainer {
    cfg: ExperimentConfig,
    model: BackendModel,
    train_ds: Dataset,
    test_ds: Dataset,
    session: TrainSession,
    store: Option<Store>,
    /// Canonical spec of the multiplier the run *started* with, set on
    /// the first watchdog escalation and recorded in checkpoint meta so
    /// a resumed run knows its trajectory is post-recovery.
    escalated_from: Option<String>,
}

impl Trainer {
    /// Build a trainer with synthetic data sized for the preset
    /// (real CIFAR-10 can be supplied via [`Trainer::with_data`]).
    /// Respects `cfg.backend`; the engine is untouched for native runs.
    pub fn new(engine: &Engine, cfg: ExperimentConfig) -> Result<Self> {
        Self::build(Some(engine), cfg, None)
    }

    /// Engine-free constructor: forces the native backend.
    pub fn native(mut cfg: ExperimentConfig) -> Result<Self> {
        cfg.backend = ExecBackend::Native;
        Self::build(None, cfg, None)
    }

    /// Build a trainer over caller-provided datasets.
    pub fn with_data(
        engine: &Engine,
        cfg: ExperimentConfig,
        train_ds: Dataset,
        test_ds: Dataset,
    ) -> Result<Self> {
        Self::build(Some(engine), cfg, Some((train_ds, test_ds)))
    }

    /// Engine-free [`Trainer::with_data`] on the native backend.
    pub fn native_with_data(
        mut cfg: ExperimentConfig,
        train_ds: Dataset,
        test_ds: Dataset,
    ) -> Result<Self> {
        cfg.backend = ExecBackend::Native;
        Self::build(None, cfg, Some((train_ds, test_ds)))
    }

    fn build(
        engine: Option<&Engine>,
        cfg: ExperimentConfig,
        data: Option<(Dataset, Dataset)>,
    ) -> Result<Self> {
        cfg.validate()?;
        let session = make_session(engine, &cfg).context("creating train session")?;
        let model = session.model().clone();
        let (train_ds, test_ds) = match data {
            Some((train_ds, test_ds)) => {
                train_ds.check()?;
                test_ds.check()?;
                // Static-shape graphs can only pad the final eval batch
                // by repeating examples, which skews the metrics;
                // dynamic-batch backends evaluate it unpadded instead.
                anyhow::ensure!(
                    session.supports_dynamic_batch()
                        || test_ds.len() % model.eval_batch == 0,
                    "test set ({}) must be a multiple of eval batch ({})",
                    test_ds.len(),
                    model.eval_batch
                );
                (train_ds, test_ds)
            }
            None => {
                let mut gen = SyntheticCifar::for_input(
                    model.input_hw,
                    model.in_ch,
                    model.num_classes,
                    cfg.seed ^ 0xDA7A,
                );
                gen.noise = cfg.data_noise as f32;
                // Test size rounded up to a multiple of the eval batch so
                // the static-shape eval graph never sees padding.
                let test_n =
                    cfg.test_examples.div_ceil(model.eval_batch) * model.eval_batch;
                let mut train_ds = gen.generate(cfg.train_examples + test_n);
                train_ds.normalize();
                train_ds.split_tail(test_n)?
            }
        };
        let store = if cfg.out_dir.is_empty() {
            None
        } else {
            Some(Store::new(&cfg.out_dir)?)
        };
        Ok(Trainer { cfg, model, train_ds, test_ds, session, store, escalated_from: None })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn session(&self) -> &TrainSession {
        &self.session
    }

    /// The checkpoint store, when `out_dir` is set. Fault-injection
    /// tests reach through this to corrupt files between epochs.
    pub fn store(&self) -> Option<&Store> {
        self.store.as_ref()
    }

    /// Arm a deterministic training-path fault on the backend
    /// ([`crate::testkit::faults`]). Test harness hook.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) -> Result<()> {
        self.session.set_fault_plan(plan)
    }

    /// Restore session state from a checkpoint's tensors (hybrid resume).
    pub fn restore_state(&mut self, tensors: Vec<crate::tensor::Tensor>) -> Result<()> {
        self.session.restore(tensors)
    }

    /// Exact-multiplier accuracy on the held-out set (paper protocol).
    ///
    /// Runs through one [`TrainSession::eval_pass`], so per-pass setup
    /// (the native backend's weight-plane decomposition) happens once
    /// for the whole set, not once per batch. Dynamic-batch backends
    /// evaluate the final short batch directly instead of padding it
    /// with copied examples.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let pass = self.session.eval_pass()?;
        let batch = self.session.eval_batch_size();
        let mut eb = if self.session.supports_dynamic_batch() {
            EvalBatcher::unpadded(&self.test_ds, batch)
        } else {
            EvalBatcher::new(&self.test_ds, batch)
        };
        let mut correct = 0i64;
        let mut loss_sum = 0f64;
        let mut total = 0usize;
        while let Some((x, y, t)) = eb.next()? {
            let s = pass.eval_batch(x, y)?;
            correct += s.correct;
            loss_sum += s.loss_sum as f64;
            total += t;
        }
        Ok((correct as f64 / total as f64, loss_sum / total as f64))
    }

    /// Training steps one epoch takes under the current batching mode.
    fn steps_per_epoch(&self) -> u64 {
        let batch = self.session.batch_size();
        if self.session.supports_dynamic_batch() {
            self.train_ds.len().div_ceil(batch) as u64
        } else {
            (self.train_ds.len() / batch) as u64
        }
    }

    /// Run the configured number of epochs. `resume_from` skips the
    /// first `n` epochs (data order and seeds replay identically — the
    /// hybrid search relies on this). With `cfg.watchdog` set, the run
    /// is supervised: see the module docs.
    pub fn run_from(
        &mut self,
        resume_from: u64,
        mut hook: Option<&mut EpochHook<'_>>,
    ) -> Result<TrainOutcome> {
        let started = Instant::now(); // detlint: allow(D2) -- run-level wall_secs telemetry, never fed back into training
        let mut history = History::default();
        let mut health = HealthLog::default();
        match self.cfg.watchdog.clone() {
            None => self.run_span(resume_from, &mut history, &mut hook, None)?,
            Some(w) => {
                self.run_resilient(resume_from, &mut history, &mut hook, &w, &mut health)?
            }
        }
        let best_accuracy = history
            .records
            .iter()
            .map(|r| r.test_acc)
            .fold(f64::MIN, f64::max);
        Ok(TrainOutcome {
            best_accuracy: if history.records.is_empty() { 0.0 } else { best_accuracy },
            final_accuracy: history.final_test_acc().unwrap_or(0.0),
            epochs_run: history.records.len() as u64,
            wall_secs: started.elapsed().as_secs_f64(),
            health,
            history,
        })
    }

    /// Run all epochs from scratch.
    pub fn run(&mut self) -> Result<TrainOutcome> {
        self.run_from(0, None)
    }

    /// One uninterrupted span of epochs `start..cfg.epochs`. This is
    /// the historical epoch loop; `watch` (resilient mode only) adds
    /// post-step health checks and verified/retained checkpointing but
    /// never alters the trajectory itself.
    fn run_span(
        &mut self,
        start: u64,
        history: &mut History,
        hook: &mut Option<&mut EpochHook<'_>>,
        mut watch: Option<&mut WatchCtx<'_>>,
    ) -> Result<()> {
        // Re-seed the early-stopping state from records that survived a
        // rollback, so patience counts from the true best epoch.
        let (mut best, mut best_epoch) =
            history.records.iter().fold((f64::MIN, 0u64), |(b, be), r| {
                if r.test_acc > b { (r.test_acc, r.epoch) } else { (b, be) }
            });
        let augment = if self.cfg.augment { Augment::default() } else { Augment::none() };
        let batch = self.session.batch_size();
        // Dynamic-batch backends train the final short batch instead of
        // dropping it; static-shape graphs keep the drop-last behavior.
        let drop_last = !self.session.supports_dynamic_batch();
        let steps_per_epoch = self.steps_per_epoch();

        for epoch in start..self.cfg.epochs {
            let epoch_started = Instant::now(); // detlint: allow(D2) -- per-epoch wall_secs telemetry, never fed back into training
            let approx = self.cfg.policy.active_at(epoch);
            let sigma = self.cfg.policy.sigma_at(epoch) as f32;
            let lr = self.cfg.lr.at_epoch(epoch) as f32;
            // Per-example weighting: with drop_last off, the short
            // final batch must not count as a full batch in the epoch
            // means.
            let mut loss_sum = 0f64;
            let mut acc_sum = 0f64;
            let mut seen = 0usize;

            let mut batcher =
                Batcher::new(&self.train_ds, batch, self.cfg.seed, epoch, augment)
                    .with_drop_last(drop_last);
            let mut step_in_epoch = 0u64;
            while let Some((x, y)) = batcher.next()? {
                let batch_n = y.len();
                let global_step = epoch * steps_per_epoch + step_in_epoch;
                let seed_err = match self.cfg.sampling {
                    // Fixed per run: the paper's Figure-3 procedure.
                    ErrorSampling::FixedPerRun => {
                        counter_split(self.cfg.seed, STREAM_ERR, 0)
                    }
                    // Fresh field each step.
                    ErrorSampling::PerStep => {
                        counter_split(self.cfg.seed, STREAM_ERR, global_step)
                    }
                };
                let seed_drop =
                    counter_split(self.cfg.seed, STREAM_DROP, global_step);
                let stats = self.session.step(
                    x,
                    y,
                    StepInputs { seed_err, seed_drop, sigma, lr, approx, step: global_step },
                )?;
                if let Some(w) = watch.as_deref_mut() {
                    w.observe(
                        epoch,
                        global_step,
                        stats.loss as f64,
                        self.session.state_tensors(),
                    )?;
                }
                loss_sum += stats.loss as f64 * batch_n as f64;
                acc_sum += stats.accuracy as f64 * batch_n as f64;
                seen += batch_n;
                step_in_epoch += 1;
            }

            let (test_acc, test_loss) = self.evaluate()?;
            let denom = seen.max(1) as f64;
            let record = EpochRecord {
                epoch,
                train_loss: loss_sum / denom,
                train_acc: acc_sum / denom,
                test_acc,
                test_loss,
                sigma: sigma as f64,
                lr: lr as f64,
                wall_secs: epoch_started.elapsed().as_secs_f64(),
            };
            log::info!(
                "[{}] epoch {:>3}: loss {:.4} train_acc {:.3} test_acc {:.4} (mult {}, lr {:.4})",
                self.cfg.tag, epoch, record.train_loss, record.train_acc,
                record.test_acc, self.cfg.policy.spec_at(epoch).canonical(), record.lr
            );
            if let Some(h) = hook.as_deref_mut() {
                h(&record);
            }
            history.push(record);

            if test_acc > best {
                best = test_acc;
                best_epoch = epoch;
            }

            if let Some(store) = &self.store {
                let due = self.cfg.checkpoint_every > 0
                    && (epoch + 1) % self.cfg.checkpoint_every == 0;
                if due || epoch + 1 == self.cfg.epochs {
                    match watch.as_deref_mut() {
                        Some(w) => {
                            self.save_checkpoint_watched(store, epoch, sigma as f64, w)?
                        }
                        None => {
                            self.save_checkpoint(store, epoch, sigma as f64)?;
                        }
                    }
                }
            }

            if self.cfg.patience > 0 && epoch - best_epoch >= self.cfg.patience {
                log::info!(
                    "[{}] early stop at epoch {epoch} (best {best:.4} at {best_epoch})",
                    self.cfg.tag
                );
                break;
            }
        }
        Ok(())
    }

    /// The watchdog's supervision loop: run spans until one completes,
    /// classifying each failure and responding with rollback (training
    /// failures), escalation (a failure that recurs at the same global
    /// step after a bit-identical replay), or a bounded bail-out
    /// (checkpoint-IO failures, exhausted budgets, unclassified errors).
    fn run_resilient(
        &mut self,
        resume_from: u64,
        history: &mut History,
        hook: &mut Option<&mut EpochHook<'_>>,
        w: &WatchdogConfig,
        health: &mut HealthLog,
    ) -> Result<()> {
        let mut start = resume_from;
        let mut rung = 0usize;
        let mut last_trip: Option<u64> = None;
        let steps_per_epoch = self.steps_per_epoch().max(1);
        loop {
            let result = {
                let mut watch = WatchCtx::new(w, &mut *health);
                self.run_span(start, history, hook, Some(&mut watch))
            };
            let err = match result {
                Ok(()) => return Ok(()),
                Err(e) => e,
            };
            let Some(report) = recovery::classify_failure(&err) else {
                // Not a health failure (config error, bug, ...): never
                // roll back over it, surface it unchanged.
                return Err(err);
            };
            let step = report.step.unwrap_or(0);
            let epoch = step / steps_per_epoch;
            health.trips.push(HealthEvent {
                epoch,
                step,
                kind: report.kind,
                detail: report.detail.clone(),
            });
            log::warn!(
                "[{}] watchdog trip at step {step} (epoch {epoch}): {} — {}",
                self.cfg.tag,
                report.kind.name(),
                report.detail
            );
            if report.kind == FailureKind::CheckpointIo {
                // The save path already retried with backoff; a store
                // that still fails can't anchor a rollback.
                return Err(err.context(
                    "checkpoint store unrecoverable: watchdog cannot roll back onto it",
                ));
            }
            if health.rollbacks >= w.max_retries as u64 {
                return Err(err.context(format!(
                    "watchdog retry budget exhausted ({})",
                    health.summary()
                )));
            }
            if last_trip == Some(step) {
                // The replay after a clean rollback re-tripped at the
                // same global step: deterministic trajectories make
                // that a systematic numeric failure, so escalate the
                // multiplier instead of rolling back forever.
                let Some(spec) = w.ladder.get(rung).cloned() else {
                    return Err(err.context(format!(
                        "escalation ladder exhausted ({})",
                        health.summary()
                    )));
                };
                rung += 1;
                self.escalate_to(&spec)?;
                health.escalations.push((step, spec.canonical()));
                log::warn!(
                    "[{}] escalating multiplier to {} after repeated trip at step {step}",
                    self.cfg.tag,
                    spec.canonical()
                );
            }
            last_trip = Some(step);
            start = self.rollback(w)?;
            health.rollbacks += 1;
            // Replayed epochs re-push their records; drop the stale ones.
            history.records.retain(|r| r.epoch < start);
        }
    }

    /// Restore the newest valid checkpoint (scanning past corrupt
    /// files), or re-initialize from the run seed when none exists.
    /// Returns the epoch to resume from. Per-step seeds need no
    /// re-derivation: they are pure functions of the global step.
    fn rollback(&mut self, w: &WatchdogConfig) -> Result<u64> {
        let mut attempt = 0u32;
        loop {
            let loaded = self
                .store
                .as_ref()
                .context("watchdog rollback requires a checkpoint store (out_dir)")?
                .latest_valid(&self.cfg.tag);
            match loaded {
                Ok(Some((epoch, meta, tensors))) => {
                    log::warn!(
                        "[{}] rolling back to checkpoint epoch {epoch} (step {})",
                        self.cfg.tag,
                        meta.step
                    );
                    self.session
                        .restore(tensors.into_iter().map(|(_, t)| t).collect())?;
                    self.session.set_steps_run(meta.step);
                    return Ok(epoch);
                }
                Ok(None) => {
                    log::warn!(
                        "[{}] no valid checkpoint to roll back to — reinitializing from seed",
                        self.cfg.tag
                    );
                    self.session.reinit(counter_split(self.cfg.seed, STREAM_INIT, 0))?;
                    return Ok(0);
                }
                Err(e) => {
                    attempt += 1;
                    if attempt > w.max_retries {
                        return Err(e.context("checkpoint store unreadable during rollback"));
                    }
                    std::thread::sleep(recovery::backoff_delay(w.backoff_ms, attempt - 1));
                }
            }
        }
    }

    /// Swap the active multiplier for `spec` (one watchdog ladder
    /// rung). The native backend bakes its design in, so it is rebuilt
    /// around the session's current tensors; PJRT consumes sigma as a
    /// runtime scalar and needs no rebuild. Rebuilding intentionally
    /// drops any armed fault plan — the escalated replay runs clean.
    fn escalate_to(&mut self, spec: &MultSpec) -> Result<()> {
        if self.escalated_from.is_none() {
            self.escalated_from = Some(
                self.cfg
                    .policy
                    .mult()
                    .map(|m| m.canonical())
                    .unwrap_or_else(|| "exact".to_string()),
            );
        }
        self.cfg.policy = match &self.cfg.policy {
            MultiplierPolicy::Hybrid { switch_epoch, .. } => MultiplierPolicy::Hybrid {
                mult: spec.clone(),
                switch_epoch: *switch_epoch,
            },
            _ => MultiplierPolicy::Approximate { mult: spec.clone() },
        };
        if matches!(self.cfg.backend, ExecBackend::Native) {
            let backend = NativeBackend::new(&self.cfg.preset, spec.clone())?;
            let steps = self.session.steps_run();
            let tensors = self.session.state_tensors().to_vec();
            let mut session =
                TrainSession::with_backend_tensors(Box::new(backend), tensors)?;
            session.set_steps_run(steps);
            self.session = session;
        }
        Ok(())
    }

    fn save_checkpoint(&self, store: &Store, epoch: u64, sigma: f64) -> Result<PathBuf> {
        let named: Vec<(String, &crate::tensor::Tensor)> = self
            .model
            .tensor_names()
            .into_iter()
            .zip(self.session.state_tensors())
            .collect();
        let meta = Meta {
            preset: self.cfg.preset.clone(),
            epoch: epoch + 1, // checkpoint taken *after* this many epochs
            step: self.session.steps_run(),
            sigma,
            mult: self.cfg.policy.spec_at(epoch).canonical(),
            tag: self.cfg.tag.clone(),
            escalated_from: self.escalated_from.clone(),
        };
        store.save(&meta, &named)
    }

    /// Resilient-mode checkpointing: save, read the file straight back
    /// (a checkpoint only counts once it parses and its CRC verifies —
    /// this is what catches a torn write immediately instead of at the
    /// next rollback), then apply last-K retention. Failures retry with
    /// exponential backoff up to the watchdog budget.
    fn save_checkpoint_watched(
        &self,
        store: &Store,
        epoch: u64,
        sigma: f64,
        w: &mut WatchCtx<'_>,
    ) -> Result<()> {
        let mut attempt = 0u32;
        loop {
            let result = self
                .save_checkpoint(store, epoch, sigma)
                .and_then(|path| store.load_path(&path).map(|_| ()));
            match result {
                Ok(()) => {
                    store.gc_keep_last(&self.cfg.tag, w.keep)?;
                    return Ok(());
                }
                Err(e) => {
                    attempt += 1;
                    if attempt > w.retries {
                        return Err(e.context(format!(
                            "checkpoint save failed after {attempt} attempts"
                        )));
                    }
                    w.health.save_retries += 1;
                    log::warn!(
                        "[{}] checkpoint save/verify failed (attempt {attempt}): {e:#}; retrying",
                        self.cfg.tag
                    );
                    std::thread::sleep(recovery::backoff_delay(w.backoff_ms, attempt - 1));
                }
            }
        }
    }
}
