//! The training orchestrator (paper Figure 3).
//!
//! Owns: data pipeline, the train session (PJRT- or native-backed), the
//! per-epoch loop with multiplier policy + error sampling + lr
//! schedule, exact-multiplier evaluation, checkpointing and early
//! stopping. Everything epoch-level is decided *here*; the backend only
//! sees scalar knobs.
//!
//! Per-step sub-seeds (error matrices, dropout) are derived from the
//! run seed by Threefry counter splitting ([`rng::counter_split`]):
//! each consumer gets its own domain-tagged, statistically independent
//! stream, replacing the old `base.wrapping_add(step)` arithmetic
//! whose streams were shifts of each other and collided structurally.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::checkpoint::{Meta, Store};
use crate::config::{ErrorSampling, ExecBackend, ExperimentConfig};
use crate::data::augment::Augment;
use crate::data::batcher::{Batcher, EvalBatcher};
use crate::data::{Dataset, SyntheticCifar};
use crate::metrics::{EpochRecord, History};
use crate::mult::MultSpec;
use crate::rng::{counter_split, STREAM_DROP, STREAM_ERR, STREAM_INIT};
use crate::runtime::session::StepInputs;
use crate::runtime::{BackendModel, Engine, NativeBackend, TrainSession};

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub history: History,
    pub best_accuracy: f64,
    pub final_accuracy: f64,
    pub epochs_run: u64,
    pub wall_secs: f64,
}

/// Callback invoked after every epoch (progress logging, live plots).
pub type EpochHook<'h> = dyn FnMut(&EpochRecord) + 'h;

/// Build the session the config asks for. The engine is only needed
/// for the PJRT backend; the native backend is self-contained.
fn make_session(engine: Option<&Engine>, cfg: &ExperimentConfig) -> Result<TrainSession> {
    let seed_init = counter_split(cfg.seed, STREAM_INIT, 0);
    match cfg.backend {
        ExecBackend::Native => {
            let spec = cfg.policy.mult().cloned().unwrap_or(MultSpec::Exact);
            let backend = NativeBackend::new(&cfg.preset, spec)?;
            TrainSession::with_backend(Box::new(backend), seed_init)
        }
        ExecBackend::Pjrt => {
            let engine = engine.context(
                "the PJRT backend needs an Engine (compiled artifacts); \
                 set backend: native or construct the trainer with one",
            )?;
            TrainSession::new(engine, &cfg.preset, seed_init)
        }
    }
}

/// The training orchestrator.
pub struct Trainer {
    cfg: ExperimentConfig,
    model: BackendModel,
    train_ds: Dataset,
    test_ds: Dataset,
    session: TrainSession,
    store: Option<Store>,
}

impl Trainer {
    /// Build a trainer with synthetic data sized for the preset
    /// (real CIFAR-10 can be supplied via [`Trainer::with_data`]).
    /// Respects `cfg.backend`; the engine is untouched for native runs.
    pub fn new(engine: &Engine, cfg: ExperimentConfig) -> Result<Self> {
        Self::build(Some(engine), cfg, None)
    }

    /// Engine-free constructor: forces the native backend.
    pub fn native(mut cfg: ExperimentConfig) -> Result<Self> {
        cfg.backend = ExecBackend::Native;
        Self::build(None, cfg, None)
    }

    /// Build a trainer over caller-provided datasets.
    pub fn with_data(
        engine: &Engine,
        cfg: ExperimentConfig,
        train_ds: Dataset,
        test_ds: Dataset,
    ) -> Result<Self> {
        Self::build(Some(engine), cfg, Some((train_ds, test_ds)))
    }

    /// Engine-free [`Trainer::with_data`] on the native backend.
    pub fn native_with_data(
        mut cfg: ExperimentConfig,
        train_ds: Dataset,
        test_ds: Dataset,
    ) -> Result<Self> {
        cfg.backend = ExecBackend::Native;
        Self::build(None, cfg, Some((train_ds, test_ds)))
    }

    fn build(
        engine: Option<&Engine>,
        cfg: ExperimentConfig,
        data: Option<(Dataset, Dataset)>,
    ) -> Result<Self> {
        cfg.validate()?;
        let session = make_session(engine, &cfg).context("creating train session")?;
        let model = session.model().clone();
        let (train_ds, test_ds) = match data {
            Some((train_ds, test_ds)) => {
                train_ds.check()?;
                test_ds.check()?;
                // Static-shape graphs can only pad the final eval batch
                // by repeating examples, which skews the metrics;
                // dynamic-batch backends evaluate it unpadded instead.
                anyhow::ensure!(
                    session.supports_dynamic_batch()
                        || test_ds.len() % model.eval_batch == 0,
                    "test set ({}) must be a multiple of eval batch ({})",
                    test_ds.len(),
                    model.eval_batch
                );
                (train_ds, test_ds)
            }
            None => {
                let mut gen = SyntheticCifar::for_input(
                    model.input_hw,
                    model.in_ch,
                    model.num_classes,
                    cfg.seed ^ 0xDA7A,
                );
                gen.noise = cfg.data_noise as f32;
                // Test size rounded up to a multiple of the eval batch so
                // the static-shape eval graph never sees padding.
                let test_n =
                    cfg.test_examples.div_ceil(model.eval_batch) * model.eval_batch;
                let mut train_ds = gen.generate(cfg.train_examples + test_n);
                train_ds.normalize();
                train_ds.split_tail(test_n)?
            }
        };
        let store = if cfg.out_dir.is_empty() {
            None
        } else {
            Some(Store::new(&cfg.out_dir)?)
        };
        Ok(Trainer { cfg, model, train_ds, test_ds, session, store })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn session(&self) -> &TrainSession {
        &self.session
    }

    /// Restore session state from a checkpoint's tensors (hybrid resume).
    pub fn restore_state(&mut self, tensors: Vec<crate::tensor::Tensor>) -> Result<()> {
        self.session.restore(tensors)
    }

    /// Exact-multiplier accuracy on the held-out set (paper protocol).
    ///
    /// Runs through one [`TrainSession::eval_pass`], so per-pass setup
    /// (the native backend's weight-plane decomposition) happens once
    /// for the whole set, not once per batch. Dynamic-batch backends
    /// evaluate the final short batch directly instead of padding it
    /// with copied examples.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let pass = self.session.eval_pass()?;
        let batch = self.session.eval_batch_size();
        let mut eb = if self.session.supports_dynamic_batch() {
            EvalBatcher::unpadded(&self.test_ds, batch)
        } else {
            EvalBatcher::new(&self.test_ds, batch)
        };
        let mut correct = 0i64;
        let mut loss_sum = 0f64;
        let mut total = 0usize;
        while let Some((x, y, t)) = eb.next()? {
            let s = pass.eval_batch(x, y)?;
            correct += s.correct;
            loss_sum += s.loss_sum as f64;
            total += t;
        }
        Ok((correct as f64 / total as f64, loss_sum / total as f64))
    }

    /// Run the configured number of epochs. `resume_from` skips the
    /// first `n` epochs (data order and seeds replay identically — the
    /// hybrid search relies on this).
    pub fn run_from(
        &mut self,
        resume_from: u64,
        mut hook: Option<&mut EpochHook<'_>>,
    ) -> Result<TrainOutcome> {
        let started = Instant::now();
        let mut history = History::default();
        let mut best = f64::MIN;
        let mut best_epoch = 0u64;
        let augment = if self.cfg.augment { Augment::default() } else { Augment::none() };
        let batch = self.session.batch_size();
        // Dynamic-batch backends train the final short batch instead of
        // dropping it; static-shape graphs keep the drop-last behavior.
        let drop_last = !self.session.supports_dynamic_batch();
        let steps_per_epoch = if drop_last {
            (self.train_ds.len() / batch) as u64
        } else {
            self.train_ds.len().div_ceil(batch) as u64
        };

        for epoch in resume_from..self.cfg.epochs {
            let epoch_started = Instant::now();
            let approx = self.cfg.policy.active_at(epoch);
            let sigma = self.cfg.policy.sigma_at(epoch) as f32;
            let lr = self.cfg.lr.at_epoch(epoch) as f32;
            // Per-example weighting: with drop_last off, the short
            // final batch must not count as a full batch in the epoch
            // means.
            let mut loss_sum = 0f64;
            let mut acc_sum = 0f64;
            let mut seen = 0usize;

            let mut batcher =
                Batcher::new(&self.train_ds, batch, self.cfg.seed, epoch, augment)
                    .with_drop_last(drop_last);
            let mut step_in_epoch = 0u64;
            while let Some((x, y)) = batcher.next()? {
                let batch_n = y.len();
                let global_step = epoch * steps_per_epoch + step_in_epoch;
                let seed_err = match self.cfg.sampling {
                    // Fixed per run: the paper's Figure-3 procedure.
                    ErrorSampling::FixedPerRun => {
                        counter_split(self.cfg.seed, STREAM_ERR, 0)
                    }
                    // Fresh field each step.
                    ErrorSampling::PerStep => {
                        counter_split(self.cfg.seed, STREAM_ERR, global_step)
                    }
                };
                let seed_drop =
                    counter_split(self.cfg.seed, STREAM_DROP, global_step);
                let stats = self.session.step(
                    x,
                    y,
                    StepInputs { seed_err, seed_drop, sigma, lr, approx },
                )?;
                loss_sum += stats.loss as f64 * batch_n as f64;
                acc_sum += stats.accuracy as f64 * batch_n as f64;
                seen += batch_n;
                step_in_epoch += 1;
            }

            let (test_acc, test_loss) = self.evaluate()?;
            let denom = seen.max(1) as f64;
            let record = EpochRecord {
                epoch,
                train_loss: loss_sum / denom,
                train_acc: acc_sum / denom,
                test_acc,
                test_loss,
                sigma: sigma as f64,
                lr: lr as f64,
                wall_secs: epoch_started.elapsed().as_secs_f64(),
            };
            log::info!(
                "[{}] epoch {:>3}: loss {:.4} train_acc {:.3} test_acc {:.4} (mult {}, lr {:.4})",
                self.cfg.tag, epoch, record.train_loss, record.train_acc,
                record.test_acc, self.cfg.policy.spec_at(epoch).canonical(), record.lr
            );
            if let Some(h) = hook.as_deref_mut() {
                h(&record);
            }
            history.push(record);

            if test_acc > best {
                best = test_acc;
                best_epoch = epoch;
            }

            if let Some(store) = &self.store {
                let due = self.cfg.checkpoint_every > 0
                    && (epoch + 1) % self.cfg.checkpoint_every == 0;
                if due || epoch + 1 == self.cfg.epochs {
                    self.save_checkpoint(store, epoch, sigma as f64)?;
                }
            }

            if self.cfg.patience > 0 && epoch - best_epoch >= self.cfg.patience {
                log::info!(
                    "[{}] early stop at epoch {epoch} (best {best:.4} at {best_epoch})",
                    self.cfg.tag
                );
                break;
            }
        }

        let final_accuracy = history.final_test_acc().unwrap_or(0.0);
        Ok(TrainOutcome {
            best_accuracy: if history.records.is_empty() { 0.0 } else { best },
            final_accuracy,
            epochs_run: history.records.len() as u64,
            wall_secs: started.elapsed().as_secs_f64(),
            history,
        })
    }

    /// Run all epochs from scratch.
    pub fn run(&mut self) -> Result<TrainOutcome> {
        self.run_from(0, None)
    }

    fn save_checkpoint(&self, store: &Store, epoch: u64, sigma: f64) -> Result<()> {
        let named: Vec<(String, &crate::tensor::Tensor)> = self
            .model
            .tensor_names()
            .into_iter()
            .zip(self.session.state_tensors())
            .collect();
        let meta = Meta {
            preset: self.cfg.preset.clone(),
            epoch: epoch + 1, // checkpoint taken *after* this many epochs
            step: self.session.steps_run(),
            sigma,
            mult: self.cfg.policy.spec_at(epoch).canonical(),
            tag: self.cfg.tag.clone(),
        };
        store.save(&meta, &named)?;
        Ok(())
    }
}
