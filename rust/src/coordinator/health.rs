//! Step-level training health monitoring — the watchdog's sensor.
//!
//! After every committed step the monitor checks (1) loss finiteness,
//! (2) a windowed loss-spike heuristic (finite but exploding loss —
//! what accumulated approximate-multiplication error looks like before
//! it reaches NaN, cf. arXiv:2007.10500), and (3) bit-level finiteness
//! of every state tensor (params ++ BN state ++ momentum), which
//! catches the insidious case where a poisoned gradient commits NaN
//! parameters behind a perfectly finite loss. A failed check raises a
//! typed [`Trip`] through the `anyhow` chain; recovery
//! ([`super::recovery`]) classifies and reacts, the monitor only
//! detects.

use std::collections::VecDeque;
use std::fmt;

use anyhow::Result;

use crate::config::WatchdogConfig;
use crate::metrics::{FailureKind, HealthLog};
use crate::tensor::Tensor;

/// Typed watchdog trip, carried through the error chain so
/// [`super::recovery::classify_failure`] can recover it without string
/// matching.
#[derive(Debug, Clone)]
pub struct Trip {
    pub kind: FailureKind,
    pub epoch: u64,
    /// Global step (epoch * steps_per_epoch + step_in_epoch).
    pub step: u64,
    pub detail: String,
}

impl fmt::Display for Trip {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "watchdog trip at step {} (epoch {}): {} — {}",
            self.step,
            self.epoch,
            self.kind.name(),
            self.detail
        )
    }
}

impl std::error::Error for Trip {}

/// Windowed loss monitor. Purely observational: it never touches the
/// training state, so running it changes no trajectory.
#[derive(Debug, Clone)]
pub struct HealthMonitor {
    window: usize,
    spike_factor: f64,
    recent: VecDeque<f64>,
}

impl HealthMonitor {
    pub fn new(window: usize, spike_factor: f64) -> Self {
        HealthMonitor { window, spike_factor, recent: VecDeque::with_capacity(window) }
    }

    /// Feed one step's loss; `Some` classifies a failure. The window
    /// only accumulates healthy losses, so one spike can't drag the
    /// baseline up and mask the next.
    pub fn observe_loss(&mut self, loss: f64) -> Option<(FailureKind, String)> {
        if !loss.is_finite() {
            self.recent.clear();
            return Some((FailureKind::NonFinite, format!("loss is {loss}")));
        }
        if self.recent.len() == self.window {
            let mean: f64 = self.recent.iter().sum::<f64>() / self.window as f64;
            if mean > 0.0 && loss > self.spike_factor * mean {
                self.recent.clear();
                return Some((
                    FailureKind::Divergence,
                    format!(
                        "loss {loss:.4} exceeds {:.1}x the {}-step mean {mean:.4}",
                        self.spike_factor, self.window
                    ),
                ));
            }
            self.recent.pop_front();
        }
        self.recent.push_back(loss);
        None
    }
}

/// One resilient span's watch state: the loss monitor plus a borrow of
/// the run-wide [`HealthLog`] and the recovery knobs the trainer's save
/// path needs. Rebuilt per rollback span, so the spike window never
/// carries stale pre-rollback losses.
pub struct WatchCtx<'a> {
    monitor: HealthMonitor,
    pub health: &'a mut HealthLog,
    /// Checkpoint-IO retry budget (mirrors `WatchdogConfig`).
    pub retries: u32,
    pub backoff_ms: u64,
    /// Checkpoints to retain after each verified save.
    pub keep: usize,
}

impl<'a> WatchCtx<'a> {
    pub fn new(cfg: &WatchdogConfig, health: &'a mut HealthLog) -> Self {
        WatchCtx {
            monitor: HealthMonitor::new(cfg.window, cfg.spike_factor),
            health,
            retries: cfg.max_retries,
            backoff_ms: cfg.backoff_ms,
            keep: cfg.keep,
        }
    }

    /// Inspect one committed step: its loss and the post-step state
    /// tensors. Raises a [`Trip`] on any failed check.
    pub fn observe(
        &mut self,
        epoch: u64,
        step: u64,
        loss: f64,
        tensors: &[Tensor],
    ) -> Result<()> {
        self.health.steps_checked += 1;
        let found = self.monitor.observe_loss(loss).or_else(|| {
            tensors.iter().position(|t| !t.all_finite()).map(|i| {
                (
                    FailureKind::NonFinite,
                    format!("state tensor #{i} contains NaN/Inf after the step"),
                )
            })
        });
        match found {
            Some((kind, detail)) => {
                Err(anyhow::Error::new(Trip { kind, epoch, step, detail }))
            }
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finite_stable_loss_never_trips() {
        let mut m = HealthMonitor::new(4, 3.0);
        for i in 0..50 {
            let loss = 2.0 - 0.01 * i as f64;
            assert!(m.observe_loss(loss).is_none(), "step {i}");
        }
    }

    #[test]
    fn non_finite_loss_trips_immediately() {
        let mut m = HealthMonitor::new(4, 3.0);
        let (kind, _) = m.observe_loss(f64::NAN).unwrap();
        assert_eq!(kind, FailureKind::NonFinite);
        let (kind, _) = m.observe_loss(f64::INFINITY).unwrap();
        assert_eq!(kind, FailureKind::NonFinite);
    }

    #[test]
    fn loss_spike_classifies_as_divergence() {
        let mut m = HealthMonitor::new(4, 3.0);
        for _ in 0..4 {
            assert!(m.observe_loss(1.0).is_none());
        }
        // 2x the mean: tolerated (normal minibatch noise).
        assert!(m.observe_loss(2.0).is_none());
        // >3x the mean: divergence. (The LUT-bit-flip fault shows up
        // exactly like this — finite but exploding loss.)
        let (kind, detail) = m.observe_loss(30.0).unwrap();
        assert_eq!(kind, FailureKind::Divergence);
        assert!(detail.contains("exceeds"));
        // Window cleared on trip: the next steps re-warm-up.
        assert!(m.observe_loss(30.0).is_none());
    }

    #[test]
    fn spike_needs_a_full_window() {
        let mut m = HealthMonitor::new(8, 3.0);
        // Early training: loss can swing wildly before the window
        // fills; no divergence verdict yet.
        for loss in [5.0, 1.0, 40.0, 2.0] {
            assert!(m.observe_loss(loss).is_none());
        }
    }

    #[test]
    fn watch_ctx_scans_tensors_and_counts_steps() {
        let cfg = WatchdogConfig::default();
        let mut log = HealthLog::default();
        let mut w = WatchCtx::new(&cfg, &mut log);
        let good = Tensor::from_f32(&[2], vec![1.0, -1.0]).unwrap();
        let bad = Tensor::from_f32(&[2], vec![1.0, f32::NAN]).unwrap();
        assert!(w.observe(0, 0, 1.0, &[good.clone()]).is_ok());
        let err = w.observe(0, 1, 1.0, &[good, bad]).unwrap_err();
        let trip = err.downcast_ref::<Trip>().unwrap();
        assert_eq!(trip.kind, FailureKind::NonFinite);
        assert_eq!(trip.step, 1);
        assert!(trip.detail.contains("#1"));
        assert_eq!(log.steps_checked, 2);
    }
}
