//! The hybrid switch-epoch search (paper Figure 4 / Table III).
//!
//! Procedure:
//! 1. Train the full schedule with approximate multipliers, saving a
//!    checkpoint after *every* epoch (one approximate run total).
//! 2. For a candidate switch epoch `k`, restore the epoch-`k`
//!    checkpoint and train epochs `k..total` with exact multipliers,
//!    then evaluate. Accuracy is (noisily) non-increasing in `k`, so a
//!    binary search over `k` finds the largest `k` whose final accuracy
//!    still reaches the target (baseline − tolerance) — i.e. the
//!    maximal approximate-multiplier utilization, the paper's Table III
//!    objective.
//!
//! The approximate multiplier is any [`MultSpec`] — the Gaussian
//! surrogate on either backend, or a bit-accurate design (`drum6`,
//! `lut12:drum6`, ...) on the native backend, which is how the search
//! produces Table-III rows for *real* hardware designs.

use anyhow::{bail, Context, Result};

use crate::checkpoint::{self, Meta, Store};
use crate::config::{ExecBackend, ExperimentConfig, MultiplierPolicy};
use crate::mult::MultSpec;
use crate::runtime::Engine;
use crate::tensor::Tensor;

use super::trainer::{TrainOutcome, Trainer};

/// Result for one multiplier configuration (a Table III row).
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub config: MultSpec,
    /// Epochs trained with the approximate multiplier.
    pub approx_epochs: u64,
    /// Exact-multiplier tail length.
    pub exact_epochs: u64,
    /// Utilization = approx / total (Table III's last column).
    pub utilization: f64,
    /// Accuracy achieved by the selected hybrid schedule.
    pub accuracy: f64,
    /// The target it had to reach.
    pub target: f64,
    /// Candidate evaluations performed by the search.
    pub evaluations: u32,
}

/// The search driver.
pub struct HybridSearch<'e> {
    engine: Option<&'e Engine>,
    base: ExperimentConfig,
    /// Accuracy tolerance below baseline (paper: 0.0002 = 0.02%).
    pub tolerance: f64,
}

impl<'e> HybridSearch<'e> {
    /// Search over an engine-backed config (PJRT unless `base.backend`
    /// says otherwise).
    pub fn new(engine: &'e Engine, base: ExperimentConfig) -> Self {
        HybridSearch { engine: Some(engine), base, tolerance: 0.0002 }
    }

    /// Engine-free search on the native backend.
    pub fn native(mut base: ExperimentConfig) -> HybridSearch<'static> {
        base.backend = ExecBackend::Native;
        HybridSearch { engine: None, base, tolerance: 0.0002 }
    }

    fn trainer(&self, cfg: ExperimentConfig) -> Result<Trainer> {
        match self.engine {
            Some(engine) => Trainer::new(engine, cfg),
            None => Trainer::native(cfg),
        }
    }

    /// Train the exact baseline and return its final accuracy.
    pub fn baseline(&self) -> Result<TrainOutcome> {
        let mut cfg = self.base.clone();
        cfg.tag = format!("{}-baseline", self.base.tag);
        cfg.policy = MultiplierPolicy::Exact;
        self.trainer(cfg)?.run()
    }

    /// Phase 1: full approximate run with per-epoch checkpoints.
    /// Returns (outcome, checkpoint tag).
    pub fn approx_run(&self, config: &MultSpec) -> Result<(TrainOutcome, String)> {
        anyhow::ensure!(!self.base.out_dir.is_empty(), "search needs an out_dir");
        let tag = format!("{}-approx-{}", self.base.tag, config.file_tag());
        let mut cfg = self.base.clone();
        cfg.tag = tag.clone();
        cfg.policy = MultiplierPolicy::Approximate { mult: config.clone() };
        cfg.checkpoint_every = 1;
        let outcome = self.trainer(cfg)?.run()?;
        Ok((outcome, tag))
    }

    /// Phase 2 evaluation of one candidate: resume from the epoch-`k`
    /// approximate checkpoint and finish exactly. If the epoch-`k` file
    /// is corrupt/unreadable, the nearest earlier intact checkpoint is
    /// substituted (a smaller, still-valid candidate) — returns the
    /// `(epoch actually used, final accuracy)` pair so the search can
    /// adapt its bracket.
    fn try_switch_epoch(
        &self,
        config: &MultSpec,
        tag: &str,
        k: u64,
    ) -> Result<(u64, f64)> {
        let store = Store::new(&self.base.out_dir)?;
        let (used, _meta, tensors) = self.load_candidate(&store, config, tag, k)?;
        let mut cfg = self.base.clone();
        cfg.tag = format!("{}-tail{used}", tag);
        cfg.policy =
            MultiplierPolicy::Hybrid { mult: config.clone(), switch_epoch: used };
        cfg.checkpoint_every = 0;
        let mut trainer = self.trainer(cfg)?;
        trainer.restore_state(tensors.into_iter().map(|(_, t)| t).collect())?;
        let outcome = trainer.run_from(used, None)?;
        Ok((used, outcome.final_accuracy))
    }

    /// Load the epoch-`k` checkpoint for `tag`, scanning backward to
    /// the nearest earlier epoch whose file is intact when `k`'s is
    /// not. Each skip is logged with its classified failure
    /// ([`checkpoint::classify`]); only when *no* epoch at or below `k`
    /// loads does the search abort, and then with the classified cause
    /// and file path rather than a bare I/O error.
    fn load_candidate(
        &self,
        store: &Store,
        config: &MultSpec,
        tag: &str,
        k: u64,
    ) -> Result<(u64, Meta, Vec<(String, Tensor)>)> {
        let candidates: Vec<u64> = store
            .list_epochs(tag)
            .with_context(|| format!("listing checkpoints for {tag}"))?
            .into_iter()
            .filter(|&e| e <= k)
            .collect();
        let mut last_err: Option<anyhow::Error> = None;
        for epoch in candidates.into_iter().rev() {
            match store.load(tag, epoch) {
                Ok((meta, tensors)) => {
                    // The checkpoint must come from the same multiplier
                    // we are searching: a resumed tail under a different
                    // design would silently produce a Table-III row for
                    // nothing in particular. This is a config error, not
                    // a corrupt file — never skip past it.
                    if meta.mult != config.canonical() {
                        bail!(
                            "checkpoint {tag} epoch {epoch} was trained with {:?}, \
                             search is for {:?}",
                            meta.mult,
                            config.canonical()
                        );
                    }
                    if epoch < k {
                        log::warn!(
                            "search {}: candidate epoch {k} unreadable, \
                             substituting intact epoch {epoch}",
                            config.canonical()
                        );
                    }
                    return Ok((epoch, meta, tensors));
                }
                Err(e) => {
                    let class = checkpoint::classify(&e)
                        .map(|c| c.name())
                        .unwrap_or("unclassified");
                    log::warn!(
                        "search {}: skipping checkpoint {} ({class}): {e:#}",
                        config.canonical(),
                        store.path_for(tag, epoch).display()
                    );
                    last_err = Some(e);
                }
            }
        }
        match last_err {
            Some(e) => Err(e.context(format!(
                "no loadable {tag} checkpoint at or below epoch {k}"
            ))),
            None => bail!("no checkpoints found for {tag} at or below epoch {k}"),
        }
    }

    /// Full Figure-4 search for one multiplier configuration.
    ///
    /// `baseline_acc` is the exact run's final accuracy; `approx_tag`
    /// and `approx_final` come from [`HybridSearch::approx_run`].
    pub fn search(
        &self,
        config: &MultSpec,
        baseline_acc: f64,
        approx_tag: &str,
        approx_final: f64,
    ) -> Result<SearchOutcome> {
        let total = self.base.epochs;
        let target = baseline_acc - self.tolerance;
        let mut evaluations = 0u32;

        // Fully-approximate already reaches target (paper row 1).
        if approx_final >= target {
            return Ok(SearchOutcome {
                config: config.clone(),
                approx_epochs: total,
                exact_epochs: 0,
                utilization: 1.0,
                accuracy: approx_final,
                target,
                evaluations,
            });
        }

        // Binary search the largest k in [0, total-1] reaching target.
        // Invariant: lo is known-good (k=0 is the pure-exact run, which
        // meets the target by construction up to run noise), hi is
        // known-bad (k=total misses — checked above).
        let mut lo = 0u64;
        let mut hi = total;
        let mut best_acc = baseline_acc;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            // `used <= mid`: a corrupt mid-checkpoint falls back to the
            // nearest intact earlier epoch.
            let (used, acc) = self.try_switch_epoch(config, approx_tag, mid)?;
            evaluations += 1;
            log::info!(
                "search {}: switch@{used} -> acc {:.4} (target {:.4})",
                config.canonical(),
                acc,
                target
            );
            if acc >= target {
                best_acc = acc;
                if used > lo {
                    lo = used;
                } else {
                    // Everything in (lo, mid] was unreadable and fell
                    // back to lo itself: those epochs can never be
                    // resumed from, so conservatively shrink the
                    // bracket and keep the known-good lo.
                    hi = mid;
                }
            } else {
                // Accuracy is non-increasing in the switch epoch, so a
                // miss at `used` rules out every k >= used.
                hi = used.max(lo + 1);
            }
        }
        Ok(SearchOutcome {
            config: config.clone(),
            approx_epochs: lo,
            exact_epochs: total - lo,
            utilization: lo as f64 / total as f64,
            accuracy: best_acc,
            target,
            evaluations,
        })
    }
}
