//! Table II sweep: train once per error configuration, compare final
//! accuracy to the exact baseline.

use anyhow::Result;

use crate::config::{ExperimentConfig, MultiplierPolicy};
use crate::error_model::ErrorConfig;
use crate::runtime::Engine;

use super::trainer::Trainer;

/// One sweep row (mirrors Table II's columns).
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub test_id: u32,
    pub config: ErrorConfig,
    pub accuracy: f64,
    /// accuracy - baseline accuracy (the paper's "Diff. From Exact").
    pub diff_from_exact: f64,
    /// Paper's reported accuracy for this row (percent/100), if any.
    pub paper_accuracy: Option<f64>,
    pub epochs_run: u64,
    pub wall_secs: f64,
}

/// The sweep runner.
pub struct Sweep<'e> {
    engine: &'e Engine,
    base: ExperimentConfig,
}

impl<'e> Sweep<'e> {
    /// `base` supplies everything except the multiplier policy, which
    /// the sweep overrides per row.
    pub fn new(engine: &'e Engine, base: ExperimentConfig) -> Self {
        Sweep { engine, base }
    }

    /// Run the given error configurations (id, config, paper accuracy).
    /// The exact baseline must be the first row (id 0 / sigma 0), as in
    /// the paper's table.
    pub fn run(
        &self,
        cases: &[(u32, ErrorConfig, f64)],
        mut progress: impl FnMut(u32, &SweepRow),
    ) -> Result<Vec<SweepRow>> {
        let mut rows: Vec<SweepRow> = Vec::with_capacity(cases.len());
        let mut baseline: Option<f64> = None;
        for &(id, config, paper_acc) in cases {
            let mut cfg = self.base.clone();
            cfg.tag = format!("{}-case{id}", self.base.tag);
            cfg.policy = if config.is_exact() {
                MultiplierPolicy::Exact
            } else {
                MultiplierPolicy::Approximate { error: config }
            };
            let mut trainer = Trainer::new(self.engine, cfg)?;
            let outcome = trainer.run()?;
            let accuracy = outcome.final_accuracy;
            let base = *baseline.get_or_insert(accuracy);
            let row = SweepRow {
                test_id: id,
                config,
                accuracy,
                diff_from_exact: accuracy - base,
                paper_accuracy: (paper_acc > 0.0).then_some(paper_acc / 100.0),
                epochs_run: outcome.epochs_run,
                wall_secs: outcome.wall_secs,
            };
            progress(id, &row);
            rows.push(row);
        }
        Ok(rows)
    }

    /// Shape checks that define a successful Table II reproduction
    /// (DESIGN.md §6): small error barely hurts, huge error collapses.
    pub fn shape_holds(rows: &[SweepRow]) -> bool {
        let Some(base) = rows.first() else { return false };
        let small_ok = rows
            .iter()
            .filter(|r| r.config.sigma > 0.0 && r.config.sigma <= 0.06)
            .all(|r| r.accuracy >= base.accuracy - 0.05);
        let collapse = rows
            .iter()
            .filter(|r| r.config.sigma >= 0.48)
            .all(|r| r.accuracy < base.accuracy - 0.10);
        small_ok && collapse && rows.len() >= 3
    }
}
