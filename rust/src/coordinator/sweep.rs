//! Table II sweep: train once per multiplier configuration, compare
//! final accuracy to the exact baseline.
//!
//! Sweep points are independent training runs, so they execute on a
//! worker pool ([`crate::parallel`]). PJRT points share one [`Engine`]
//! — the engine's per-entry compile slots mean the executables are
//! compiled once and reused by every point; native points are
//! self-contained. Cases are full [`MultSpec`]s, so a sweep can mix the
//! paper's Gaussian rows with bit-accurate designs. Rows, the baseline
//! diff and the progress callback all keep the original case order
//! regardless of completion order.

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{bail, Result};

use crate::config::{ExecBackend, ExperimentConfig, MultiplierPolicy};
use crate::mult::MultSpec;
use crate::parallel;
use crate::runtime::Engine;

use super::trainer::Trainer;

/// One sweep row (mirrors Table II's columns).
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub test_id: u32,
    pub config: MultSpec,
    pub accuracy: f64,
    /// accuracy - baseline accuracy (the paper's "Diff. From Exact").
    pub diff_from_exact: f64,
    /// Paper's reported accuracy for this row (percent/100), if any.
    pub paper_accuracy: Option<f64>,
    pub epochs_run: u64,
    pub wall_secs: f64,
}

/// The sweep runner.
pub struct Sweep<'e> {
    engine: Option<&'e Engine>,
    base: ExperimentConfig,
    /// Worker threads for independent sweep points (default:
    /// [`parallel::max_threads`]; set 1 for strictly serial execution).
    pub parallelism: usize,
}

impl<'e> Sweep<'e> {
    /// `base` supplies everything except the multiplier policy, which
    /// the sweep overrides per row.
    pub fn new(engine: &'e Engine, base: ExperimentConfig) -> Self {
        Sweep { engine: Some(engine), base, parallelism: parallel::max_threads() }
    }

    /// Engine-free sweep on the native backend. Each point already
    /// parallelizes its GEMMs internally, so points run serially by
    /// default — set [`Sweep::parallelism`] to oversubscribe.
    pub fn native(mut base: ExperimentConfig) -> Sweep<'static> {
        base.backend = ExecBackend::Native;
        Sweep { engine: None, base, parallelism: 1 }
    }

    /// Run the given multiplier configurations (id, spec, paper
    /// accuracy percent) on up to [`Sweep::parallelism`] workers. The
    /// exact baseline must be the first row (id 0 / `exact`), as in the
    /// paper's table; the progress callback fires in case order once
    /// results are in (a parallel sweep has no meaningful mid-flight
    /// row to report). A failing point cancels the not-yet-started
    /// points instead of burning hours training the rest.
    pub fn run(
        &self,
        cases: &[(u32, MultSpec, f64)],
        mut progress: impl FnMut(u32, &SweepRow),
    ) -> Result<Vec<SweepRow>> {
        // Index of the temporally-first failing point (usize::MAX =
        // none): later points cancel themselves, and that index — not a
        // string marker — is what the error reporting surfaces.
        let first_failure = AtomicUsize::new(usize::MAX);
        let outcomes = parallel::par_map(cases, self.parallelism, |idx, case| {
            let (id, config, _) = case;
            if first_failure.load(Ordering::Relaxed) != usize::MAX {
                bail!("sweep case {id} cancelled after an earlier failure");
            }
            let result = (|| {
                let mut cfg = self.base.clone();
                cfg.tag = format!("{}-case{id}", self.base.tag);
                cfg.policy = if config.is_exact() {
                    MultiplierPolicy::Exact
                } else {
                    MultiplierPolicy::Approximate { mult: config.clone() }
                };
                let mut trainer = match self.engine {
                    Some(engine) => Trainer::new(engine, cfg)?,
                    None => Trainer::native(cfg)?,
                };
                trainer.run()
            })();
            if result.is_err() {
                let _ = first_failure.compare_exchange(
                    usize::MAX,
                    idx,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                );
            }
            result
        });
        // Surface the root failure, not a cancellation marker. The slot
        // at `root` is guaranteed Err: only a worker whose own result
        // failed can have won the compare-exchange.
        let root = first_failure.load(Ordering::Relaxed);
        if root != usize::MAX {
            let mut outcomes = outcomes;
            return Err(outcomes.swap_remove(root).unwrap_err());
        }
        let mut rows: Vec<SweepRow> = Vec::with_capacity(cases.len());
        let mut baseline: Option<f64> = None;
        for ((id, config, paper_acc), outcome) in cases.iter().zip(outcomes) {
            let outcome = outcome?;
            let accuracy = outcome.final_accuracy;
            let base = *baseline.get_or_insert(accuracy);
            let row = SweepRow {
                test_id: *id,
                config: config.clone(),
                accuracy,
                diff_from_exact: accuracy - base,
                paper_accuracy: (*paper_acc > 0.0).then_some(*paper_acc / 100.0),
                epochs_run: outcome.epochs_run,
                wall_secs: outcome.wall_secs,
            };
            progress(*id, &row);
            rows.push(row);
        }
        Ok(rows)
    }

    /// Shape checks that define a successful Table II reproduction
    /// (DESIGN.md §6): small error barely hurts, huge error collapses.
    pub fn shape_holds(rows: &[SweepRow]) -> bool {
        let Some(base) = rows.first() else { return false };
        let small_ok = rows
            .iter()
            .filter(|r| r.config.sigma() > 0.0 && r.config.sigma() <= 0.06)
            .all(|r| r.accuracy >= base.accuracy - 0.05);
        let collapse = rows
            .iter()
            .filter(|r| r.config.sigma() >= 0.48)
            .all(|r| r.accuracy < base.accuracy - 0.10);
        small_ok && collapse && rows.len() >= 3
    }
}
