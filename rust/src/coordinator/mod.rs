//! L3 coordinator: the paper's training-control contribution.
//!
//! * [`trainer`] — the epoch/step orchestrator (Figure 3's procedure):
//!   multiplier policy + error-sampling mode + lr schedule are applied
//!   per step by varying the compiled graph's scalar inputs; evaluation
//!   always runs exact (the paper removes the error layers for testing).
//! * [`health`] / [`recovery`] — the resilient-training runtime: a
//!   per-step divergence watchdog, typed failure classification, and
//!   the rollback-and-escalate policy the trainer runs under
//!   `cfg.watchdog`.
//! * [`sweep`] — Table II regeneration: one full training run per
//!   (MRE, SD) configuration, accuracy vs the exact baseline.
//! * [`search`] — Figure 4's hybrid switch-epoch search: a single
//!   approximate run checkpointed every epoch, then exact tails resumed
//!   from candidate epochs to find the maximal approximate utilization
//!   that still reaches the target accuracy (Table III).

pub mod health;
pub mod recovery;
pub mod search;
pub mod sweep;
pub mod trainer;

pub use health::{HealthMonitor, Trip, WatchCtx};
pub use recovery::{classify_failure, TripReport};
pub use search::{HybridSearch, SearchOutcome};
pub use sweep::{Sweep, SweepRow};
pub use trainer::{TrainOutcome, Trainer};
