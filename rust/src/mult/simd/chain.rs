//! The register-blocked GEMM chain microkernel (`simd` feature).
//!
//! `approx_matmul_prepared{,_signed}` call [`unsigned_chain_sum`] /
//! [`signed_chain_sum`] once per output element when the design
//! exposes a kernel descriptor: the operand-class test, the mantissa
//! products, and the sign/exponent renormalization all run [`LANES`]
//! k-positions at a time, writing each term's f32 **bits** into a
//! per-task buffer; the final accumulation then walks that buffer
//! scalar, in strict k-order, so every output is bit-identical to the
//! scalar-batch chain (and therefore to `approx_matmul_reference`).
//!
//! Why a full per-k term buffer instead of the scalar engine's compact
//! lists: flushed, skipped and padding lanes store `+0.0`, and adding
//! `+0.0` to an f32 accumulator is a bit-level no-op — the accumulator
//! can never be `-0.0` mid-chain (it starts at `+0.0`, and IEEE
//! round-to-nearest only produces `-0.0` from `(-0.0) + (-0.0)`). The
//! scalar engine's skipping of flushed terms relies on the very same
//! argument, so the two paths agree bit for bit
//! (`tools/check_simd_recipes.py` checks the equivalence on chains
//! seeded with inf/NaN/signed-zero/subnormal terms). Non-finite
//! k-positions are patched into the buffer scalar, with the same
//! native-f32 product fallback as the scalar engine.

use std::simd::prelude::*;

use crate::mult::prepared::{element_value, EXP_FLUSHED, EXP_NONFINITE};

use super::batch::{
    booth_block, drum_block, exact_block, mitchell_block, sdrum_block, trunc_block,
};
use super::{I32s, I64s, SignedKernel, U32s, U64s, UnsignedKernel, LANES};

/// In-range dummy mantissa routed into masked-off lanes: keeps every
/// kernel's lane math (shifts, flat-table indices) well-defined
/// without affecting results — dummy lanes are selected away after the
/// block. `EXP_NONFINITE` elements in particular carry raw f32 bits in
/// the mantissa plane, which must never reach a table gather.
const DUMMY_MANT: u32 = 1 << 23;

/// The vector transcription of `matmul::renorm(sign, esum, 0, p)`,
/// returning f32 bits per lane. Select order matters and mirrors the
/// scalar early-returns in reverse: packed → overflow → underflow →
/// `p == 0` last.
#[inline]
fn renorm_bits(sign: U32s, esum: I32s, p: U64s) -> U32s {
    let pz = p.simd_eq(U64s::splat(0));
    // Zero lanes run on a dummy 1 so `63 - leading_zeros` stays valid.
    let pp = pz.select(U64s::splat(1), p);
    let q = U64s::splat(63) - pp.leading_zeros();
    let gt = q.simd_gt(U64s::splat(23));
    // Both mantissa legs with clamped shifts, then select — `23 - q`
    // would be out of range on `gt` lanes and vice versa.
    let shr = gt.select(q - U64s::splat(23), U64s::splat(0));
    let mant_hi = (pp >> shr).cast::<u32>();
    let gt32 = gt.cast::<i32>();
    let shl = gt32.select(U32s::splat(0), U32s::splat(23) - q.cast::<u32>());
    let mant_lo = pp.cast::<u32>() << shl;
    let mant = gt32.select(mant_hi, mant_lo);
    let er = esum + q.cast::<i32>() - I32s::splat(173);
    let sign31 = sign << U32s::splat(31);
    let packed =
        sign31 | (er.cast::<u32>() << U32s::splat(23)) | (mant & U32s::splat(0x007F_FFFF));
    let bits = er
        .simd_ge(I32s::splat(255))
        .select(sign31 | U32s::splat(0x7F80_0000), packed);
    let bits = er.simd_le(I32s::splat(0)).select(sign31, bits);
    pz.cast::<i32>().select(sign31, bits)
}

/// Flat-table LUT products on mantissa-domain lanes (`[2^23, 2^24)`):
/// the LUT's dynamic-range reduction collapses to the constant shift
/// `24 - bits` per operand, so the product table itself is the inner
/// loop, followed by the lane-wise `shift_saturating` recombination.
#[inline]
fn lut_flat_block(table: &[u64], bits: u32, ma: U32s, mb: U32s) -> U64s {
    let shift = U32s::splat(24 - bits);
    let idx = ((ma >> shift) << U32s::splat(bits)) | (mb >> shift);
    let mut pa = [0u64; LANES];
    for (p, ix) in pa.iter_mut().zip(idx.to_array()) {
        *p = table[ix as usize];
    }
    let v = U64s::from_array(pa);
    let total = U64s::splat(2 * (24 - bits) as u64);
    let ok = v.leading_zeros().simd_ge(total);
    let r = ok.select(v << total, U64s::splat(u64::MAX));
    v.simd_eq(U64s::splat(0)).select(U64s::splat(0), r)
}

/// Signed twin of [`lut_flat_block`]: `|v| ∈ [2^23, 2^24)` lanes make
/// the signed reduction the constant magnitude shift `25 - bits`, with
/// the sign folded back before the `(ia + half, ib + half)` table
/// index, then the lane-wise `shift_signed_saturating` recombination
/// (`total >= 26 > 0`, so its shift-by-zero leg never applies here).
#[inline]
fn slut_flat_block(table: &[i64], bits: u32, half: i32, ma: I32s, mb: I32s) -> I64s {
    let shift = U32s::splat(25 - bits);
    let sa = ma >> I32s::splat(31);
    let sb = mb >> I32s::splat(31);
    let mag_a = (((ma ^ sa) - sa).cast::<u32>() >> shift).cast::<i32>();
    let mag_b = (((mb ^ sb) - sb).cast::<u32>() >> shift).cast::<i32>();
    let ia = ((mag_a ^ sa) - sa) + I32s::splat(half);
    let ib = ((mag_b ^ sb) - sb) + I32s::splat(half);
    let idx = (ia.cast::<u32>() << U32s::splat(bits)) | ib.cast::<u32>();
    let mut pa = [0i64; LANES];
    for (p, ix) in pa.iter_mut().zip(idx.to_array()) {
        *p = table[ix as usize];
    }
    let v = I64s::from_array(pa);
    let total = 2 * (25 - bits);
    let negm = v >> I64s::splat(63);
    let mag = ((v ^ negm) - negm).cast::<u64>();
    let ok = mag.leading_zeros().simd_gt(U64s::splat(total as u64));
    let sat = v
        .simd_lt(I64s::splat(0))
        .select(I64s::splat(i64::MIN), I64s::splat(i64::MAX));
    let r = ok.cast::<i64>().select(v << I64s::splat(total as i64), sat);
    v.simd_eq(I64s::splat(0)).select(I64s::splat(0), r)
}

/// One [`LANES`]-wide block of an unsigned k-chain: class test, dummy
/// routing, mantissa products, vector renorm. Returns each lane's term
/// as f32 bits (`+0.0` for flushed/skipped lanes) plus a bitmask of
/// the lanes needing the scalar non-finite fallback.
#[inline]
fn chain_block(
    kernel: UnsignedKernel<'_>,
    ex: I32s,
    ey: I32s,
    mx: U32s,
    my: U32s,
    sx: U32s,
    sy: U32s,
) -> (U32s, u64) {
    let zero = I32s::splat(0);
    let nf = I32s::splat(EXP_NONFINITE);
    let both = ex.simd_gt(zero) & ex.simd_ne(nf) & ey.simd_gt(zero) & ey.simd_ne(nf);
    let dm = U32s::splat(DUMMY_MANT);
    let p = match kernel {
        UnsignedKernel::Exact => exact_block(both.select(mx, dm), both.select(my, dm)),
        UnsignedKernel::Drum { k } => {
            drum_block(both.select(mx, dm), both.select(my, dm), U32s::splat(k))
        }
        UnsignedKernel::Trunc { k } => {
            trunc_block(both.select(mx, dm), both.select(my, dm), U32s::splat(!0u32 << k))
        }
        UnsignedKernel::Mitchell => {
            mitchell_block(both.select(mx, dm), both.select(my, dm))
        }
        UnsignedKernel::Flat { table, bits } => {
            lut_flat_block(table, bits, both.select(mx, dm), both.select(my, dm))
        }
    };
    let bits = renorm_bits(sx ^ sy, ex + ey, p);
    // A non-finite exponent on either side excludes the lane from
    // `both` by construction, so the two masks are disjoint.
    let nonf = ex.simd_eq(nf) | ey.simd_eq(nf);
    (both.select(bits, U32s::splat(0)), nonf.to_bitmask())
}

/// Signed twin of [`chain_block`]: the product's own sign drives the
/// renorm (`renorm_signed`), operands come from the signed-mantissa
/// plane.
#[inline]
fn signed_chain_block(
    kernel: SignedKernel<'_>,
    ex: I32s,
    ey: I32s,
    vx: I32s,
    vy: I32s,
) -> (U32s, u64) {
    let zero = I32s::splat(0);
    let nf = I32s::splat(EXP_NONFINITE);
    let both = ex.simd_gt(zero) & ex.simd_ne(nf) & ey.simd_gt(zero) & ey.simd_ne(nf);
    let dm = I32s::splat(DUMMY_MANT as i32);
    let ka = both.select(vx, dm);
    let kb = both.select(vy, dm);
    let p = match kernel {
        SignedKernel::Exact => ka.cast::<i64>() * kb.cast::<i64>(),
        SignedKernel::SDrum { k } => sdrum_block(ka, kb, U32s::splat(k)),
        SignedKernel::Booth { k } => booth_block(ka, kb, k),
        SignedKernel::Flat { table, bits, half } => {
            slut_flat_block(table, bits, half, ka, kb)
        }
    };
    // renorm_signed: sign from the product, magnitude via the same
    // wrapping conditional negate (`i64::MIN` → `2^63` == unsigned_abs).
    let negm = p >> I64s::splat(63);
    let mag = ((p ^ negm) - negm).cast::<u64>();
    let sign = (negm & I64s::splat(1)).cast::<u32>();
    let bits = renorm_bits(sign, ex + ey, mag);
    let nonf = ex.simd_eq(nf) | ey.simd_eq(nf);
    (both.select(bits, U32s::splat(0)), nonf.to_bitmask())
}

/// Patch the non-finite lanes of one block into the term buffer: the
/// same native-f32 product fallback the scalar engine uses, replayed
/// at the exact k position.
#[inline]
fn patch_nonfinite(
    mut nfm: u64,
    k0: usize,
    a_row: (&[u8], &[i32], &[u32]),
    b_row: (&[u8], &[i32], &[u32]),
    terms: &mut [u32],
) {
    let (sa, ea, ma) = a_row;
    let (sb, eb, mb) = b_row;
    while nfm != 0 {
        let k = k0 + nfm.trailing_zeros() as usize;
        nfm &= nfm - 1;
        let x = element_value(sa[k], ea[k], ma[k]);
        let y = element_value(sb[k], eb[k], mb[k]);
        terms[k] = (x * y).to_bits();
    }
}

/// One output element's unsigned k-chain through the vector
/// microkernel. `terms` is the caller's per-task scratch (`len ==
/// inner`); the return value is bit-identical to the scalar-batch
/// engine's sum.
pub(crate) fn unsigned_chain_sum(
    kernel: UnsignedKernel<'_>,
    a_row: (&[u8], &[i32], &[u32]),
    b_row: (&[u8], &[i32], &[u32]),
    terms: &mut [u32],
) -> f32 {
    let (sa, ea, ma) = a_row;
    let (sb, eb, mb) = b_row;
    let inner = ea.len();
    debug_assert_eq!(terms.len(), inner);
    let mut k0 = 0usize;
    while k0 + LANES <= inner {
        let (bits, nfm) = chain_block(
            kernel,
            I32s::from_slice(&ea[k0..]),
            I32s::from_slice(&eb[k0..]),
            U32s::from_slice(&ma[k0..]),
            U32s::from_slice(&mb[k0..]),
            Simd::<u8, LANES>::from_slice(&sa[k0..]).cast::<u32>(),
            Simd::<u8, LANES>::from_slice(&sb[k0..]).cast::<u32>(),
        );
        bits.copy_to_slice(&mut terms[k0..k0 + LANES]);
        patch_nonfinite(nfm, k0, a_row, b_row, terms);
        k0 += LANES;
    }
    if k0 < inner {
        // Tail block padded with flushed exponents and dummy mantissas:
        // padding lanes classify as skipped and store `+0.0`.
        let n = inner - k0;
        let mut ex = [EXP_FLUSHED; LANES];
        let mut ey = [EXP_FLUSHED; LANES];
        let mut mx = [DUMMY_MANT; LANES];
        let mut my = [DUMMY_MANT; LANES];
        let mut sx = [0u8; LANES];
        let mut sy = [0u8; LANES];
        ex[..n].copy_from_slice(&ea[k0..]);
        ey[..n].copy_from_slice(&eb[k0..]);
        mx[..n].copy_from_slice(&ma[k0..]);
        my[..n].copy_from_slice(&mb[k0..]);
        sx[..n].copy_from_slice(&sa[k0..]);
        sy[..n].copy_from_slice(&sb[k0..]);
        let (bits, nfm) = chain_block(
            kernel,
            I32s::from_array(ex),
            I32s::from_array(ey),
            U32s::from_array(mx),
            U32s::from_array(my),
            Simd::<u8, LANES>::from_array(sx).cast::<u32>(),
            Simd::<u8, LANES>::from_array(sy).cast::<u32>(),
        );
        terms[k0..].copy_from_slice(&bits.to_array()[..n]);
        patch_nonfinite(nfm, k0, a_row, b_row, terms);
    }
    // Strict k-order scalar accumulation — the determinism contract.
    let mut acc = 0f32;
    for &t in terms[..inner].iter() {
        acc += f32::from_bits(t);
    }
    acc
}

/// Signed twin of [`unsigned_chain_sum`]; `a_row`/`b_row` additionally
/// carry the signed-mantissa plane (the sign/mantissa planes are only
/// read for the non-finite fallback).
pub(crate) fn signed_chain_sum(
    kernel: SignedKernel<'_>,
    a_row: (&[u8], &[i32], &[u32], &[i32]),
    b_row: (&[u8], &[i32], &[u32], &[i32]),
    terms: &mut [u32],
) -> f32 {
    let (sa, ea, ma, va) = a_row;
    let (sb, eb, mb, vb) = b_row;
    let inner = ea.len();
    debug_assert_eq!(terms.len(), inner);
    let mut k0 = 0usize;
    while k0 + LANES <= inner {
        let (bits, nfm) = signed_chain_block(
            kernel,
            I32s::from_slice(&ea[k0..]),
            I32s::from_slice(&eb[k0..]),
            I32s::from_slice(&va[k0..]),
            I32s::from_slice(&vb[k0..]),
        );
        bits.copy_to_slice(&mut terms[k0..k0 + LANES]);
        patch_nonfinite(nfm, k0, (sa, ea, ma), (sb, eb, mb), terms);
        k0 += LANES;
    }
    if k0 < inner {
        let n = inner - k0;
        let mut ex = [EXP_FLUSHED; LANES];
        let mut ey = [EXP_FLUSHED; LANES];
        let mut vx = [DUMMY_MANT as i32; LANES];
        let mut vy = [DUMMY_MANT as i32; LANES];
        ex[..n].copy_from_slice(&ea[k0..]);
        ey[..n].copy_from_slice(&eb[k0..]);
        vx[..n].copy_from_slice(&va[k0..]);
        vy[..n].copy_from_slice(&vb[k0..]);
        let (bits, nfm) = signed_chain_block(
            kernel,
            I32s::from_array(ex),
            I32s::from_array(ey),
            I32s::from_array(vx),
            I32s::from_array(vy),
        );
        terms[k0..].copy_from_slice(&bits.to_array()[..n]);
        patch_nonfinite(nfm, k0, (sa, ea, ma), (sb, eb, mb), terms);
    }
    let mut acc = 0f32;
    for &t in terms[..inner].iter() {
        acc += f32::from_bits(t);
    }
    acc
}
