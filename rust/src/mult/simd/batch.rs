//! Vector batch kernels: the `simd`-feature bodies of the hot designs'
//! `mul_batch`, plus the per-block cores the GEMM chain microkernel
//! ([`super::chain`]) reuses on mantissa lanes.
//!
//! Every public function must stay bit-identical to the design's
//! scalar `mul` loop (`tests/simd_parity.rs` pins this across the full
//! operand edge set; `tools/check_simd_recipes.py` cross-validates the
//! branchless recipes). Tails are handled by zero-padding the final
//! sub-[`LANES`] block: a zero operand produces a zero product in
//! every kernel here, so padding lanes are inert and their outputs are
//! simply not copied back.

use std::simd::prelude::*;

use super::{I32s, I64s, U32s, U64s, LANES};

/// DRUM's dynamic-range reduction, lane-wise: `(kept bits with forced
/// LSB, shift)` per lane; zero lanes reduce to `(0, 0)`.
#[inline]
pub(super) fn drum_reduce(v: U32s, k: U32s) -> (U32s, U32s) {
    let zero = U32s::splat(0);
    let nz = v.simd_ne(zero);
    // Zero lanes run the core on a dummy 1 (msb 0, never reduced) and
    // are zeroed again at the end — keeps `31 - leading_zeros` and the
    // shifts in range without per-lane branches.
    let vv = nz.select(v, U32s::splat(1));
    let msb = U32s::splat(31) - vv.leading_zeros();
    let big = msb.simd_ge(k);
    let shift = big.select(msb + U32s::splat(1) - k, zero);
    let t = big.select((vv >> shift) | U32s::splat(1), vv);
    (nz.select(t, zero), shift)
}

/// One block of DRUM-k products. `k >= 3` (enforced by `Drum::new`)
/// bounds each operand shift at 29, so the recombination shift stays
/// below 64.
#[inline]
pub(super) fn drum_block(a: U32s, b: U32s, k: U32s) -> U64s {
    let (ta, sa) = drum_reduce(a, k);
    let (tb, sb) = drum_reduce(b, k);
    (ta.cast::<u64>() * tb.cast::<u64>()) << (sa + sb).cast::<u64>()
}

/// One block of truncation products: mask the low k bits, multiply.
#[inline]
pub(super) fn trunc_block(a: U32s, b: U32s, mask: U32s) -> U64s {
    (a & mask).cast::<u64>() * (b & mask).cast::<u64>()
}

const FRAC_MASK: u64 = (1u64 << 32) - 1;

/// Mitchell's 32-bit fixed-point log2, lane-wise; callers route zero
/// lanes to a dummy 1 first (`msb = 0` keeps the `32 - msb` shift at
/// most 32, in range for u64 lanes).
#[inline]
fn log2_fixed(v: U32s) -> U64s {
    let msb = U32s::splat(31) - v.leading_zeros();
    let frac =
        (v.cast::<u64>() << (U32s::splat(32) - msb).cast::<u64>()) & U64s::splat(FRAC_MASK);
    (msb.cast::<u64>() << U64s::splat(32)) | frac
}

/// One block of Mitchell products: log-add-antilog with both antilog
/// shift legs computed clamped and selected, zero lanes forced to 0.
#[inline]
pub(super) fn mitchell_block(a: U32s, b: U32s) -> U64s {
    let zero32 = U32s::splat(0);
    let nza = a.simd_ne(zero32);
    let nzb = b.simd_ne(zero32);
    let one = U32s::splat(1);
    let l = log2_fixed(nza.select(a, one)) + log2_fixed(nzb.select(b, one));
    let int = l >> U64s::splat(32);
    let mant = U64s::splat(1u64 << 32) | (l & U64s::splat(FRAC_MASK));
    let ge = int.simd_ge(U64s::splat(32));
    let shl = ge.select(int - U64s::splat(32), U64s::splat(0));
    let shr = ge.select(U64s::splat(0), U64s::splat(32) - int);
    let p = (mant << shl) >> shr;
    (nza & nzb).cast::<i64>().select(p, U64s::splat(0))
}

/// One block of exact 24×24 widening products.
#[inline]
pub(super) fn exact_block(a: U32s, b: U32s) -> U64s {
    a.cast::<u64>() * b.cast::<u64>()
}

/// One block of signed-DRUM products: bit-preserving conditional
/// negate to magnitudes (`i32::MIN` maps to `2^31`, exactly
/// `unsigned_abs`), the DRUM core, then a sign-mask conditional negate
/// of the widened product.
#[inline]
pub(super) fn sdrum_block(a: I32s, b: I32s, k: U32s) -> I64s {
    let sa = a >> I32s::splat(31); // arithmetic: 0 or -1 per lane
    let sb = b >> I32s::splat(31);
    let mag_a = ((a ^ sa) - sa).cast::<u32>();
    let mag_b = ((b ^ sb) - sb).cast::<u32>();
    // DRUM's overestimate keeps the magnitude below 2^63 (the scalar
    // path debug-asserts it), so the i64 cast is value-preserving.
    let mag = drum_block(mag_a, mag_b, k).cast::<i64>();
    let neg = (sa ^ sb).cast::<i64>(); // sign-extends to 0 or -1
    (mag ^ neg) - neg
}

/// One block of radix-4 Booth products with k-bit column truncation.
/// The recoding loop runs all 16 digit positions unconditionally —
/// `d == 0` lanes contribute a zero partial product, no branch needed.
/// Worst-case accumulator magnitude is `~2^61.4`, comfortably in i64.
#[inline]
pub(super) fn booth_block(a: I32s, b: I32s, k: u32) -> I64s {
    let a64 = a.cast::<i64>();
    // Two's-complement bit pattern of b, zero-extended to u64 lanes.
    let bits = b.cast::<u32>().cast::<u64>();
    let one = U64s::splat(1);
    let kk = I64s::splat(k as i64);
    let mut acc = I64s::splat(0);
    let mut prev = U64s::splat(0);
    for i in 0..16u64 {
        let b0 = (bits >> U64s::splat(2 * i)) & one;
        let b1 = (bits >> U64s::splat(2 * i + 1)) & one;
        let d = (b0 + prev).cast::<i64>() - (b1 + b1).cast::<i64>();
        prev = b1;
        let pp = (d * a64) << I64s::splat(2 * i as i64);
        acc += (pp >> kk) << kk;
    }
    acc
}

/// Zero-pad a sub-[`LANES`] remainder pair into full blocks.
#[inline]
fn tail_u32(a: &[u32], b: &[u32]) -> (U32s, U32s) {
    let mut ta = [0u32; LANES];
    let mut tb = [0u32; LANES];
    ta[..a.len()].copy_from_slice(a);
    tb[..b.len()].copy_from_slice(b);
    (U32s::from_array(ta), U32s::from_array(tb))
}

/// Signed twin of [`tail_u32`].
#[inline]
fn tail_i32(a: &[i32], b: &[i32]) -> (I32s, I32s) {
    let mut ta = [0i32; LANES];
    let mut tb = [0i32; LANES];
    ta[..a.len()].copy_from_slice(a);
    tb[..b.len()].copy_from_slice(b);
    (I32s::from_array(ta), I32s::from_array(tb))
}

/// DRUM-k over paired slices (lengths validated by the caller's
/// `check_batch_lens`).
pub(crate) fn drum_mul_batch(k: u32, a: &[u32], b: &[u32], out: &mut [u64]) {
    let kk = U32s::splat(k);
    let mut i = 0;
    while i + LANES <= a.len() {
        let p = drum_block(U32s::from_slice(&a[i..]), U32s::from_slice(&b[i..]), kk);
        p.copy_to_slice(&mut out[i..i + LANES]);
        i += LANES;
    }
    if i < a.len() {
        let (ta, tb) = tail_u32(&a[i..], &b[i..]);
        let p = drum_block(ta, tb, kk).to_array();
        out[i..].copy_from_slice(&p[..a.len() - i]);
    }
}

/// Truncation-k over paired slices.
pub(crate) fn trunc_mul_batch(k: u32, a: &[u32], b: &[u32], out: &mut [u64]) {
    let mask = U32s::splat(!0u32 << k);
    let mut i = 0;
    while i + LANES <= a.len() {
        let p = trunc_block(U32s::from_slice(&a[i..]), U32s::from_slice(&b[i..]), mask);
        p.copy_to_slice(&mut out[i..i + LANES]);
        i += LANES;
    }
    if i < a.len() {
        let (ta, tb) = tail_u32(&a[i..], &b[i..]);
        let p = trunc_block(ta, tb, mask).to_array();
        out[i..].copy_from_slice(&p[..a.len() - i]);
    }
}

/// Mitchell over paired slices.
pub(crate) fn mitchell_mul_batch(a: &[u32], b: &[u32], out: &mut [u64]) {
    let mut i = 0;
    while i + LANES <= a.len() {
        let p = mitchell_block(U32s::from_slice(&a[i..]), U32s::from_slice(&b[i..]));
        p.copy_to_slice(&mut out[i..i + LANES]);
        i += LANES;
    }
    if i < a.len() {
        let (ta, tb) = tail_u32(&a[i..], &b[i..]);
        let p = mitchell_block(ta, tb).to_array();
        out[i..].copy_from_slice(&p[..a.len() - i]);
    }
}

/// Signed DRUM-k over paired slices.
pub(crate) fn sdrum_mul_batch(k: u32, a: &[i32], b: &[i32], out: &mut [i64]) {
    let kk = U32s::splat(k);
    let mut i = 0;
    while i + LANES <= a.len() {
        let p = sdrum_block(I32s::from_slice(&a[i..]), I32s::from_slice(&b[i..]), kk);
        p.copy_to_slice(&mut out[i..i + LANES]);
        i += LANES;
    }
    if i < a.len() {
        let (ta, tb) = tail_i32(&a[i..], &b[i..]);
        let p = sdrum_block(ta, tb, kk).to_array();
        out[i..].copy_from_slice(&p[..a.len() - i]);
    }
}

/// Booth-k over paired slices.
pub(crate) fn booth_mul_batch(k: u32, a: &[i32], b: &[i32], out: &mut [i64]) {
    let mut i = 0;
    while i + LANES <= a.len() {
        let p = booth_block(I32s::from_slice(&a[i..]), I32s::from_slice(&b[i..]), k);
        p.copy_to_slice(&mut out[i..i + LANES]);
        i += LANES;
    }
    if i < a.len() {
        let (ta, tb) = tail_i32(&a[i..], &b[i..]);
        let p = booth_block(ta, tb, k).to_array();
        out[i..].copy_from_slice(&p[..a.len() - i]);
    }
}
