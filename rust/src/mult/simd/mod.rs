//! Explicit `std::simd` microkernels for the hot multiplier designs
//! (`simd` cargo feature, nightly-only: `#![feature(portable_simd)]`).
//!
//! Two layers live here, both pinned **bit-identical** to the scalar
//! paths they replace (`tests/simd_parity.rs`; the branchless recipes
//! themselves are cross-validated against scalar transcriptions by
//! `tools/check_simd_recipes.py`):
//!
//! * **Batch kernels** ([`batch`]) — the `simd`-feature bodies of
//!   `mul_batch` for `drum`/`trunc`/`mitchell` and the signed
//!   `sdrum`/`booth`: [`LANES`]-wide vector loops over the operand
//!   slices with a zero-padded final block (zero operands are inert in
//!   every design — product 0 — so padding lanes never leak).
//! * **Chain kernels** ([`chain`]) — the register-blocked microkernel
//!   `approx_matmul_prepared{,_signed}` dispatch to when the design
//!   reports an [`UnsignedKernel`] / [`SignedKernel`]: vectorized
//!   operand-class test, mantissa products, and sign/exponent
//!   renormalization, with the final f32 accumulation kept strict
//!   k-order scalar so trajectories stay bit-identical and
//!   thread-count invariant.
//!
//! Lane discipline throughout: no per-lane control flow. Zero and
//! masked-off lanes are routed through inert dummy operands by
//! selects, and every vector shift amount is select-clamped into
//! range *before* the shift (out-of-range lanes in a vector shift are
//! undefined behavior, unlike scalar Rust's panic).

use std::simd::prelude::*;

pub(crate) mod batch;
pub(crate) mod chain;

pub(crate) use batch::{
    booth_mul_batch, drum_mul_batch, mitchell_mul_batch, sdrum_mul_batch,
    trunc_mul_batch,
};
pub(crate) use chain::{signed_chain_sum, unsigned_chain_sum};

/// Vector width of every kernel, in 32-bit lanes. Eight lanes keeps
/// the widened 64-bit intermediates at 512 bits — two AVX2 registers
/// or one AVX-512/SVE register — without spilling on 128-bit NEON
/// (four 128-bit ops), and the tail handling cheap for the short
/// k-chains dense layers produce.
pub const LANES: usize = 8;

pub(crate) type U32s = Simd<u32, LANES>;
pub(crate) type I32s = Simd<i32, LANES>;
pub(crate) type U64s = Simd<u64, LANES>;
pub(crate) type I64s = Simd<i64, LANES>;

/// Which vector core evaluates an unsigned design's mantissa products
/// inside the prepared GEMM ([`Multiplier::simd_kernel`] returns one).
///
/// Only meaningful in the GEMM's mantissa domain — every operand in
/// `[2^23, 2^24)`. `Flat` in particular turns the LUT's dynamic-range
/// reduction into a *constant* shift (`24 - bits` per operand, the
/// leading-one reduction for exactly that domain), making the product
/// table the inner loop; it is **not** a general-domain `mul`.
///
/// [`Multiplier::simd_kernel`]: super::Multiplier::simd_kernel
#[derive(Clone, Copy)]
pub enum UnsignedKernel<'a> {
    /// Exact 24×24 widening product.
    Exact,
    /// DRUM-k leading-one truncation with forced LSB.
    Drum { k: u32 },
    /// Low-k mask-and-multiply truncation.
    Trunc { k: u32 },
    /// Mitchell's log/antilog approximation.
    Mitchell,
    /// Flat product-table GEMM over the LUT's own table.
    Flat { table: &'a [u64], bits: u32 },
}

/// Signed twin of [`UnsignedKernel`], over two's-complement mantissa
/// lanes ([`SignedMultiplier::simd_kernel`] returns one). Same
/// mantissa-domain caveat: `Flat` assumes `|v| ∈ [2^23, 2^24)`.
///
/// [`SignedMultiplier::simd_kernel`]: super::signed::SignedMultiplier::simd_kernel
#[derive(Clone, Copy)]
pub enum SignedKernel<'a> {
    /// Exact signed widening product.
    Exact,
    /// Sign-magnitude DRUM-k core.
    SDrum { k: u32 },
    /// Radix-4 Booth recoding with k-bit column truncation.
    Booth { k: u32 },
    /// Flat signed product-table GEMM.
    Flat { table: &'a [i64], bits: u32, half: i32 },
}
