//! Signed RoBA (Zendegani et al., TVLSI 2017): the published
//! architecture is natively signed — sign-detection blocks route the
//! operand *magnitudes* through the rounding/shift datapath and a
//! final conditional negation restores the product sign. Like
//! [`super::SignedDrum`], this makes the design exactly
//! sign-symmetric: `sroba(−a, b) = −sroba(a, b)` always.

use super::super::Multiplier as _;
use super::super::Roba;
use super::SignedMultiplier;

/// RoBA over two's-complement operands (published signed form).
#[derive(Debug, Clone, Copy, Default)]
pub struct SignedRoba;

impl SignedMultiplier for SignedRoba {
    fn name(&self) -> String {
        "sroba".into()
    }

    fn mul(&self, a: i32, b: i32) -> i64 {
        // Magnitude datapath: |i32::MIN| fits u32; RoBA's bounded
        // overestimate (|RE| <= ~11%) keeps the magnitude below 2^63.
        let mag = Roba.mul(a.unsigned_abs(), b.unsigned_abs());
        debug_assert!(mag <= i64::MAX as u64, "magnitude {mag:#x} overflows i64");
        let p = mag as i64;
        if (a < 0) != (b < 0) {
            -p
        } else {
            p
        }
    }
    // `mul_batch` default suffices: the shift-expansion kernel has
    // nothing to hoist.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn powers_of_two_exact_in_all_quadrants() {
        for i in 0..16 {
            for j in 0..16 {
                let (a, b) = (1i32 << i, 1i32 << j);
                for (x, y) in [(a, b), (-a, b), (a, -b), (-a, -b)] {
                    assert_eq!(SignedRoba.mul(x, y), x as i64 * y as i64, "{x}*{y}");
                }
            }
        }
    }

    #[test]
    fn matches_unsigned_core_on_magnitudes() {
        let mut rng = Xoshiro256::new(23);
        for _ in 0..20_000 {
            let a = rng.next_u32() as i32;
            let b = rng.next_u32() as i32;
            let want = Roba.mul(a.unsigned_abs(), b.unsigned_abs()) as i64;
            let want = if (a < 0) != (b < 0) { -want } else { want };
            assert_eq!(SignedRoba.mul(a, b), want, "{a}*{b}");
        }
    }

    #[test]
    fn zero_and_extreme_operands() {
        assert_eq!(SignedRoba.mul(0, -17), 0);
        assert_eq!(SignedRoba.mul(i32::MIN, 0), 0);
        let p = SignedRoba.mul(i32::MIN, i32::MIN);
        assert_eq!(p, (1i64 << 31) * (1i64 << 31)); // power of two: exact
        let q = SignedRoba.mul(i32::MIN, i32::MAX);
        assert!(q < 0);
        let exact = i32::MIN as i64 * i32::MAX as i64;
        assert!((q as f64 - exact as f64).abs() <= 0.12 * exact.abs() as f64);
    }
}
