//! The signed prepared GEMM: `C = A·B` where every scalar product's
//! **sign travels through the multiplier**.
//!
//! The unsigned kernel ([`super::super::matmul`]) splits each f32 into
//! sign / exponent / magnitude, multiplies magnitudes, and re-applies
//! `sx ^ sy` outside the design — correct for unsigned hardware,
//! incapable of sign-dependent error. This kernel feeds each
//! [`SignedMultiplier`] the two's-complement signed mantissas from the
//! [`PreparedMatrix`] signed plane and takes the product's sign from
//! the returned `i64`: whatever the design does across the four sign
//! quadrants is what training sees.
//!
//! Everything else deliberately mirrors the unsigned kernel, structure
//! for structure: decompose-once planes, input-derived row blocks ×
//! [`GEMM_COL_BLOCK`]-column packed panels, one `mul_batch` per
//! k-chain, strict k-order reassembly of batched and non-finite
//! fallback terms, fused bias / column-sum epilogues, thread-count
//! invariance. [`approx_matmul_reference_signed`] is the pinned scalar
//! oracle (one [`approx_mul_f32_signed`] per product);
//! `tests/signed_gemm.rs` pins blocked ≡ scalar per design × operand
//! layout × thread count. Under the `simd` cargo feature, designs
//! exposing a [`SignedMultiplier::simd_kernel`] descriptor run the
//! vector chain microkernel ([`crate::mult::simd`]) instead of the
//! scalar-batch engine — same strict k-order accumulation, same bits.
//!
//! One convention is new: if a signed design returns a product of
//! exactly `0`, the term contributes `+0.0` — the operand signs were
//! consumed by the design, so there is no external sign left to give
//! the zero. (No shipped design produces `0` from normal mantissas,
//! whose magnitudes are at least `2^23`.)

use anyhow::{bail, Result};

use crate::parallel;

use super::super::matmul::{
    decompose, gemm_row_block, output_error_stats, renorm, seeded_matrices,
    GemmOutput, GEMM_COL_BLOCK,
};
use super::super::prepared::{element_value, EXP_NONFINITE};
use super::super::{ErrorStats, Exact, PreparedMatrix};
use super::{signed_mantissa, SignedMultiplier};

/// Renormalize a signed approximate mantissa product: the sign is the
/// product's own, the magnitude goes through the shared truncating
/// renormalizer. `p == 0` yields `+0.0` (see the module docs).
#[inline]
fn renorm_signed(esum: i32, p: i64) -> f32 {
    renorm((p < 0) as u32, esum, 0, p.unsigned_abs())
}

/// One bit-accurate signed approximate f32 product: `m` multiplies the
/// signed mantissas, the exponent add is exact, the sign comes out of
/// the design.
pub fn approx_mul_f32_signed(m: &dyn SignedMultiplier, x: f32, y: f32) -> f32 {
    if !x.is_finite() || !y.is_finite() {
        return x * y;
    }
    match (decompose(x), decompose(y)) {
        (Some((sx, ex, mx)), Some((sy, ey, my))) => {
            let p = m.mul(
                signed_mantissa(sx as u8, mx),
                signed_mantissa(sy as u8, my),
            );
            renorm_signed(ex + ey, p)
        }
        // A flushed operand never reaches the design: the term is a
        // signed zero, as in the unsigned pipeline.
        _ => f32::from_bits((x.to_bits() ^ y.to_bits()) & 0x8000_0000),
    }
}

/// Per-task staging buffers for the signed scalar-batch chain engine —
/// the signed twin of the unsigned kernel's `ChainBufs`: signed
/// mantissa pairs, their products, the exponent sum and k index of
/// each batched term, and the non-finite fallback terms.
struct SignedChainBufs {
    ma: Vec<i32>,
    mb: Vec<i32>,
    prod: Vec<i64>,
    esum: Vec<i32>,
    slot: Vec<u32>,
    extra_k: Vec<u32>,
    extra_v: Vec<f32>,
}

impl SignedChainBufs {
    fn new(inner: usize) -> Self {
        SignedChainBufs {
            ma: vec![0i32; inner],
            mb: vec![0i32; inner],
            prod: vec![0i64; inner],
            esum: vec![0i32; inner],
            slot: vec![0u32; inner],
            extra_k: Vec::new(),
            extra_v: Vec::new(),
        }
    }
}

/// One output element's k-chain through the signed scalar-batch
/// engine: class-test every k, batch the signed mantissa products of
/// the both-normal terms through one `mul_batch` call, then reassemble
/// batched and non-finite fallback terms in strict k-order. Row tuples
/// are `(signs, exps, mants, smants)` — the unsigned planes feed the
/// non-finite fallback, the signed plane feeds the design.
fn chain_sum_signed(
    m: &dyn SignedMultiplier,
    a_row: (&[u8], &[i32], &[u32], &[i32]),
    b_row: (&[u8], &[i32], &[u32], &[i32]),
    bufs: &mut SignedChainBufs,
) -> f32 {
    let (sa, ea, mta, sma) = a_row;
    let (sb, eb, mtb, smb) = b_row;
    let inner = ea.len();
    let mut active = 0usize;
    bufs.extra_k.clear();
    bufs.extra_v.clear();
    for k in 0..inner {
        let (ex, ey) = (ea[k], eb[k]);
        if ex > 0 && ex != EXP_NONFINITE && ey > 0 && ey != EXP_NONFINITE {
            // Both operands normal: batch the signed mantissa product.
            bufs.ma[active] = sma[k];
            bufs.mb[active] = smb[k];
            bufs.esum[active] = ex + ey;
            bufs.slot[active] = k as u32;
            active += 1;
        } else if ex == EXP_NONFINITE || ey == EXP_NONFINITE {
            // Native product fallback, replayed at its k position below.
            let x = element_value(sa[k], ex, mta[k]);
            let y = element_value(sb[k], ey, mtb[k]);
            bufs.extra_k.push(k as u32);
            bufs.extra_v.push(x * y);
        }
        // Flushed terms contribute a signed zero — a no-op in the
        // k-order accumulation.
    }
    m.mul_batch(&bufs.ma[..active], &bufs.mb[..active], &mut bufs.prod[..active]);
    // Reassemble the chain in strict k-order: both term lists are
    // k-sorted, so merge them.
    let mut acc = 0f32;
    let (mut t, mut e) = (0usize, 0usize);
    while t < active || e < bufs.extra_k.len() {
        let kt = if t < active { bufs.slot[t] } else { u32::MAX };
        let ke = if e < bufs.extra_k.len() {
            bufs.extra_k[e]
        } else {
            u32::MAX
        };
        if kt < ke {
            acc += renorm_signed(bufs.esum[t], bufs.prod[t]);
            t += 1;
        } else {
            acc += bufs.extra_v[e];
            e += 1;
        }
    }
    acc
}

/// The blocked decompose-once **signed** kernel: `C = A·B` over
/// prepared planes with optional fused epilogues — the signed twin of
/// [`super::super::approx_matmul_prepared`], same operand layouts,
/// same determinism contract.
///
/// Both operands must carry the signed-mantissa plane
/// ([`PreparedMatrix::with_signed_mantissas`]); preparing it once per
/// operand is exactly the decompose-once discipline the unsigned path
/// follows.
pub fn approx_matmul_prepared_signed(
    m: &dyn SignedMultiplier,
    a: &PreparedMatrix,
    b_packed: &PreparedMatrix,
    bias: Option<&[f32]>,
    with_col_sums: bool,
) -> Result<GemmOutput> {
    let rows = a.rows();
    let inner = a.cols();
    let cols = b_packed.rows();
    if b_packed.cols() != inner {
        bail!(
            "approx_matmul_prepared_signed: A is [{rows}x{inner}] but packed B \
             holds length-{} panels",
            b_packed.cols()
        );
    }
    if !a.has_signed_mantissas() || !b_packed.has_signed_mantissas() {
        bail!(
            "approx_matmul_prepared_signed: operands lack the signed-mantissa \
             plane; prepare them with PreparedMatrix::with_signed_mantissas"
        );
    }
    if let Some(b) = bias {
        if b.len() != cols {
            bail!(
                "approx_matmul_prepared_signed: bias has {} entries for {cols} \
                 columns",
                b.len()
            );
        }
    }
    if rows == 0 || cols == 0 {
        return Ok(GemmOutput {
            out: vec![0f32; rows * cols],
            col_sums: with_col_sums.then(|| vec![0f32; cols]),
        });
    }

    let threads = parallel::max_threads();
    let block = gemm_row_block(rows);
    // Resolve the design's explicit-SIMD kernel descriptor once per
    // GEMM; `None` keeps every element on the scalar-batch engine.
    #[cfg(feature = "simd")]
    let kernel = m.simd_kernel();
    let mut out = vec![0f32; rows * cols];
    let partials: Vec<Option<Vec<f32>>> =
        parallel::par_chunks_mut(&mut out, block * cols, threads, |bi, chunk| {
            let mut bufs = SignedChainBufs::new(inner);
            #[cfg(feature = "simd")]
            let mut terms = vec![0u32; inner];
            let mut sums = with_col_sums.then(|| vec![0f32; cols]);

            let r0 = bi * block;
            let block_rows = chunk.len() / cols;
            let mut j0 = 0usize;
            while j0 < cols {
                let j1 = (j0 + GEMM_COL_BLOCK).min(cols);
                for ri in 0..block_rows {
                    let (sa, ea, mta) = a.row(r0 + ri);
                    let sma = a.smant_row(r0 + ri);
                    for j in j0..j1 {
                        let (sb, eb, mtb) = b_packed.row(j);
                        let smb = b_packed.smant_row(j);
                        let a_row = (sa, ea, mta, sma);
                        let b_row = (sb, eb, mtb, smb);
                        #[cfg(feature = "simd")]
                        let acc = match kernel {
                            Some(sk) => crate::mult::simd::signed_chain_sum(
                                sk, a_row, b_row, &mut terms,
                            ),
                            None => chain_sum_signed(m, a_row, b_row, &mut bufs),
                        };
                        #[cfg(not(feature = "simd"))]
                        let acc = chain_sum_signed(m, a_row, b_row, &mut bufs);
                        let v = match bias {
                            Some(b) => acc + b[j],
                            None => acc,
                        };
                        chunk[ri * cols + j] = v;
                        if let Some(s) = sums.as_mut() {
                            s[j] += v;
                        }
                    }
                }
                j0 = j1;
            }
            sums
        });

    let col_sums = if with_col_sums {
        let mut total = vec![0f32; cols];
        for p in partials.into_iter().flatten() {
            for (t, v) in total.iter_mut().zip(&p) {
                *t += *v;
            }
        }
        Some(total)
    } else {
        None
    };
    Ok(GemmOutput { out, col_sums })
}

/// `C[rows×cols] = A[rows×inner] · B[inner×cols]` (row-major slices)
/// through the signed blocked kernel — the signed twin of
/// [`super::super::approx_matmul`].
pub fn approx_matmul_signed(
    m: &dyn SignedMultiplier,
    a: &[f32],
    b: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
) -> Result<Vec<f32>> {
    if a.len() != rows * inner || b.len() != inner * cols {
        bail!(
            "approx_matmul_signed: ({rows}x{inner})·({inner}x{cols}) needs {} \
             and {} elements, got {} and {}",
            rows * inner,
            inner * cols,
            a.len(),
            b.len()
        );
    }
    let ap = PreparedMatrix::prepare_strided(a, rows, inner, inner, 1)?
        .with_signed_mantissas();
    let bp = PreparedMatrix::prepare_strided(b, cols, inner, 1, cols)?
        .with_signed_mantissas();
    Ok(approx_matmul_prepared_signed(m, &ap, &bp, None, false)?.out)
}

/// `C = Aᵀ·B` with `a` stored untransposed `[inner×rows]` — the signed
/// twin of [`super::super::approx_matmul_tn`], same bit-identity
/// contract against the explicit transpose.
pub fn approx_matmul_signed_tn(
    m: &dyn SignedMultiplier,
    a: &[f32],
    b: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
) -> Result<Vec<f32>> {
    if a.len() != inner * rows || b.len() != inner * cols {
        bail!(
            "approx_matmul_signed_tn: ({inner}x{rows})ᵀ·({inner}x{cols}) needs \
             {} and {} elements, got {} and {}",
            inner * rows,
            inner * cols,
            a.len(),
            b.len()
        );
    }
    let ap = PreparedMatrix::prepare_strided(a, rows, inner, 1, rows)?
        .with_signed_mantissas();
    let bp = PreparedMatrix::prepare_strided(b, cols, inner, 1, cols)?
        .with_signed_mantissas();
    Ok(approx_matmul_prepared_signed(m, &ap, &bp, None, false)?.out)
}

/// `C = A·Bᵀ` with `b` stored untransposed `[cols×inner]` — the signed
/// twin of [`super::super::approx_matmul_nt`].
pub fn approx_matmul_signed_nt(
    m: &dyn SignedMultiplier,
    a: &[f32],
    b: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
) -> Result<Vec<f32>> {
    if a.len() != rows * inner || b.len() != cols * inner {
        bail!(
            "approx_matmul_signed_nt: ({rows}x{inner})·({cols}x{inner})ᵀ needs \
             {} and {} elements, got {} and {}",
            rows * inner,
            cols * inner,
            a.len(),
            b.len()
        );
    }
    let ap = PreparedMatrix::prepare_strided(a, rows, inner, inner, 1)?
        .with_signed_mantissas();
    let bp = PreparedMatrix::prepare_strided(b, cols, inner, inner, 1)?
        .with_signed_mantissas();
    Ok(approx_matmul_prepared_signed(m, &ap, &bp, None, false)?.out)
}

/// The signed scalar reference kernel: one [`approx_mul_f32_signed`]
/// per product, f32 accumulation in strict k-order, no batching, no
/// blocking, no parallelism. Slow by construction — it exists as the
/// bit-identity oracle for the blocked signed kernel
/// (`tests/signed_gemm.rs` pins blocked ≡ this for every signed design
/// × operand layout × thread count).
pub fn approx_matmul_reference_signed(
    m: &dyn SignedMultiplier,
    a: &[f32],
    b: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
) -> Result<Vec<f32>> {
    if a.len() != rows * inner || b.len() != inner * cols {
        bail!(
            "approx_matmul_reference_signed: ({rows}x{inner})·({inner}x{cols}) \
             needs {} and {} elements, got {} and {}",
            rows * inner,
            inner * cols,
            a.len(),
            b.len()
        );
    }
    let mut out = vec![0f32; rows * cols];
    for i in 0..rows {
        for j in 0..cols {
            let mut acc = 0f32;
            for k in 0..inner {
                acc += approx_mul_f32_signed(m, a[i * inner + k], b[k * cols + j]);
            }
            out[i * cols + j] = acc;
        }
    }
    Ok(out)
}

/// Signed model-vs-bit-accurate comparison on a real GEMM shape: each
/// design and the exact pipeline run on the same seeded `[-1, 1)`
/// matrices (shared with the unsigned harness, so signed and unsigned
/// rows of the characterization tables are directly comparable).
/// Returns stats in design order.
pub fn characterize_matmul_signed_set(
    designs: &[Box<dyn SignedMultiplier>],
    rows: usize,
    inner: usize,
    cols: usize,
    seed: u64,
) -> Result<Vec<ErrorStats>> {
    if rows == 0 || inner == 0 || cols == 0 {
        bail!("characterize_matmul_signed: empty shape {rows}x{inner}x{cols}");
    }
    let (a, b) = seeded_matrices(rows, inner, cols, seed);
    // The exact signed pipeline is bit-identical to the exact unsigned
    // one (sign-magnitude with an exact core), so the unsigned exact
    // GEMM is the shared reference.
    let exact = super::super::approx_matmul(&Exact, &a, &b, rows, inner, cols)?;
    designs
        .iter()
        .map(|d| {
            let approx = approx_matmul_signed(d.as_ref(), &a, &b, rows, inner, cols)?;
            Ok(output_error_stats(&approx, &exact))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::{Booth, SignedDrum, SignedExact};
    use super::*;
    use crate::mult::{approx_mul_f32, Drum};
    use crate::rng::Xoshiro256;

    #[test]
    fn exact_signed_pipeline_matches_exact_unsigned_pipeline() {
        // Sign through the design (SignedExact) ≡ sign outside the
        // design (Exact): for an exact core the routing is invisible.
        let mut rng = Xoshiro256::new(19);
        for _ in 0..50_000 {
            let x = f32::from_bits(rng.next_u32());
            let y = f32::from_bits(rng.next_u32());
            let s = approx_mul_f32_signed(&SignedExact, x, y);
            let u = approx_mul_f32(&Exact, x, y);
            assert!(
                s.to_bits() == u.to_bits() || (s.is_nan() && u.is_nan()),
                "{x} * {y}: signed {s} vs unsigned {u}"
            );
        }
    }

    #[test]
    fn sdrum_pipeline_matches_drum_pipeline() {
        // Sign-magnitude signed DRUM ≡ unsigned DRUM + external sign:
        // the refactor moves the sign without changing one bit.
        let sd = SignedDrum::new(6).unwrap();
        let ud = Drum::new(6).unwrap();
        let mut rng = Xoshiro256::new(29);
        for _ in 0..50_000 {
            let x = 4.0 * rng.next_f32() - 2.0;
            let y = 4.0 * rng.next_f32() - 2.0;
            let s = approx_mul_f32_signed(&sd, x, y);
            let u = approx_mul_f32(&ud, x, y);
            assert_eq!(s.to_bits(), u.to_bits(), "{x} * {y}");
        }
    }

    #[test]
    fn booth_pipeline_is_sign_asymmetric() {
        // The property the signed path exists for: negating one operand
        // does NOT negate the approximate product. k = 24 keeps the
        // floor-vs-ceil gap of the truncated partials (a multiple of
        // 2^24 on an odd mantissa) above the renormalizer's own 24-bit
        // truncation, so the asymmetry survives into the f32 result.
        let m = Booth::new(24).unwrap();
        let (x, y) = (1.2345678f32, 1.7654321f32);
        let pp = approx_mul_f32_signed(&m, x, y);
        let np = approx_mul_f32_signed(&m, -x, y);
        assert_ne!(np.to_bits(), (-pp).to_bits(), "booth came out sign-symmetric");
        // And both stay close to the true product.
        assert!((pp - x * y).abs() < 1e-2 * (x * y).abs());
        assert!((np + x * y).abs() < 1e-2 * (x * y).abs());
    }

    #[test]
    fn blocked_kernel_matches_scalar_reference() {
        let d = Booth::new(8).unwrap();
        let mut rng = Xoshiro256::new(31);
        let (rows, inner, cols) = (137usize, 19usize, GEMM_COL_BLOCK + 5);
        let a: Vec<f32> = (0..rows * inner).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..inner * cols).map(|_| rng.next_f32() - 0.5).collect();
        let fast = approx_matmul_signed(&d, &a, &b, rows, inner, cols).unwrap();
        let slow =
            approx_matmul_reference_signed(&d, &a, &b, rows, inner, cols).unwrap();
        assert_eq!(fast, slow);
    }

    #[test]
    fn fused_bias_and_col_sums_match_unfused() {
        let d = SignedDrum::new(6).unwrap();
        let mut rng = Xoshiro256::new(37);
        let (rows, inner, cols) = (73usize, 13usize, 6usize);
        let a: Vec<f32> = (0..rows * inner).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..inner * cols).map(|_| rng.next_f32() - 0.5).collect();
        let bias: Vec<f32> = (0..cols).map(|_| rng.next_f32() - 0.5).collect();
        let ap = PreparedMatrix::prepare(&a, rows, inner)
            .unwrap()
            .with_signed_mantissas();
        let bp = PreparedMatrix::prepare_strided(&b, cols, inner, 1, cols)
            .unwrap()
            .with_signed_mantissas();
        let fused =
            approx_matmul_prepared_signed(&d, &ap, &bp, Some(&bias), true).unwrap();
        let mut plain = approx_matmul_signed(&d, &a, &b, rows, inner, cols).unwrap();
        for r in 0..rows {
            for c in 0..cols {
                plain[r * cols + c] += bias[c];
            }
        }
        assert_eq!(fused.out, plain);
        let sums = fused.col_sums.unwrap();
        let mut want = vec![0f32; cols];
        for blk in plain.chunks(gemm_row_block(rows) * cols) {
            let mut part = vec![0f32; cols];
            for row in blk.chunks(cols) {
                for (p, &v) in part.iter_mut().zip(row) {
                    *p += v;
                }
            }
            for (w, p) in want.iter_mut().zip(&part) {
                *w += p;
            }
        }
        assert_eq!(sums, want);
    }

    #[test]
    fn kernel_requires_the_signed_plane() {
        let ap = PreparedMatrix::prepare(&[1.0f32; 6], 2, 3).unwrap();
        let bp = PreparedMatrix::prepare(&[1.0f32; 6], 2, 3).unwrap();
        let r = approx_matmul_prepared_signed(&SignedExact, &ap, &bp, None, false);
        let err = match r {
            Ok(_) => panic!("kernel accepted operands without the signed plane"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("signed-mantissa plane"), "{err:#}");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let m = SignedExact;
        assert!(approx_matmul_signed(&m, &[0.0; 5], &[0.0; 6], 2, 3, 2).is_err());
        assert!(
            approx_matmul_reference_signed(&m, &[0.0; 5], &[0.0; 6], 2, 3, 2).is_err()
        );
        assert!(characterize_matmul_signed_set(&[], 2, 0, 2, 1).is_err());
    }

    #[test]
    fn gemm_error_tracks_design_error() {
        let designs: Vec<Box<dyn SignedMultiplier>> = vec![
            Box::new(SignedExact),
            Box::new(SignedDrum::new(6).unwrap()),
            Box::new(Booth::new(24).unwrap()),
        ];
        let stats = characterize_matmul_signed_set(&designs, 16, 32, 16, 5).unwrap();
        assert_eq!(stats[0].mre, 0.0, "sexact must be error-free");
        assert!(stats[1].mre > 1e-4 && stats[1].mre < 0.25, "sdrum6 {}", stats[1].mre);
        assert!(stats[2].mre > 1e-7, "booth24 {}", stats[2].mre);
    }
}
