//! Radix-4 Booth-encoded approximate multiplier with a truncated
//! partial-product tree — the approximate fixed-width Booth family
//! (e.g. Jiang, Liu & Lombardi, TCAS-I 2016; the "positive/negative"
//! designs of Spantidi et al., arXiv:2107.09366, are built the same
//! way).
//!
//! The multiplicand `b` is recoded into 16 radix-4 Booth digits
//! `d_i ∈ {−2, −1, 0, 1, 2}` with `b = Σ d_i·4^i` (exact for 32-bit
//! two's complement). Each partial product `p_i = d_i·a·4^i` is a
//! shift/negate of `a`; the approximation is structural: the `k`
//! least-significant **columns** of the partial-product array are not
//! generated, i.e. each partial product is truncated to a multiple of
//! `2^k` before the adder tree. Truncating a two's-complement value
//! floors it toward −∞, so every generated partial loses `[0, 2^k)` —
//! the summed product **always under-runs the exact one**:
//!
//! * positive products come out low  → negative relative error;
//! * negative products come out more negative → their magnitude is
//!   *over*-estimated → positive relative error.
//!
//! That is a sign-asymmetric error profile, and it also breaks
//! negation symmetry: `booth(−a, b) ≠ −booth(a, b)` in general (the
//! recoded digits of `b` meet a negated multiplicand whose truncated
//! partials floor differently). `tests/signed_mult.rs` documents both
//! properties; they are the reason this design cannot be expressed by
//! the sign-externalized unsigned pipeline.
//!
//! `booth0` generates every column and is exact — the identity the
//! tests anchor on.

use anyhow::{bail, Result};

use super::SignedMultiplier;

/// Radix-4 Booth multiplier with the low `k` partial-product columns
/// truncated.
#[derive(Debug, Clone, Copy)]
pub struct Booth {
    k: u32,
}

impl Booth {
    /// `k` in `[0, 32]` — truncated low columns (`0` = exact Booth).
    pub fn new(k: u32) -> Result<Self> {
        if k > 32 {
            bail!("Booth truncation k must be in [0, 32], got {k}");
        }
        Ok(Booth { k })
    }
}

impl SignedMultiplier for Booth {
    fn name(&self) -> String {
        format!("booth{}", self.k)
    }

    fn mul(&self, a: i32, b: i32) -> i64 {
        let a = a as i64;
        let bits = b as u32 as u64; // two's-complement bit pattern of b
        let mut acc = 0i64;
        let mut prev = 0u64; // b[2i-1]; b[-1] = 0
        for i in 0..16 {
            let b0 = (bits >> (2 * i)) & 1;
            let b1 = (bits >> (2 * i + 1)) & 1;
            // d = -2*b[2i+1] + b[2i] + b[2i-1]; for i = 15, b[31] is the
            // sign bit, which is exactly the radix-4 recoding of two's
            // complement.
            let d = (b0 + prev) as i64 - 2 * b1 as i64;
            prev = b1;
            if d != 0 {
                // Partial product in its final column position; the low
                // k columns are never generated (>> floors, like the
                // missing adder cells).
                let pp = (d * a) << (2 * i);
                acc += (pp >> self.k) << self.k;
            }
        }
        acc
    }
    // Scalar builds keep the `mul_batch` default: the recoding loop is
    // already branch-light and monomorphizes per k.

    /// Explicit vector kernel (`simd` feature): the 16 recoding steps
    /// run unconditionally across lanes (`d == 0` contributes a zero
    /// partial) — bit-identical to the default loop
    /// (`tests/simd_parity.rs`).
    #[cfg(feature = "simd")]
    fn mul_batch(&self, a: &[i32], b: &[i32], out: &mut [i64]) {
        super::check_signed_batch_lens(a, b, out);
        crate::mult::simd::booth_mul_batch(self.k, a, b, out);
    }

    #[cfg(feature = "simd")]
    fn simd_kernel(&self) -> Option<crate::mult::simd::SignedKernel<'_>> {
        Some(crate::mult::simd::SignedKernel::Booth { k: self.k })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn booth0_is_exact() {
        let m = Booth::new(0).unwrap();
        let mut rng = Xoshiro256::new(11);
        for _ in 0..50_000 {
            let a = rng.next_u32() as i32;
            let b = rng.next_u32() as i32;
            assert_eq!(m.mul(a, b), a as i64 * b as i64, "{a}*{b}");
        }
        for &(a, b) in &[
            (i32::MIN, i32::MIN),
            (i32::MIN, i32::MAX),
            (i32::MIN, -1),
            (-1, -1),
            (0, i32::MIN),
            (i32::MAX, i32::MAX),
        ] {
            assert_eq!(m.mul(a, b), a as i64 * b as i64, "{a}*{b}");
        }
    }

    #[test]
    fn truncation_never_overestimates_the_signed_product() {
        // Each generated partial is floored, so acc <= exact always —
        // the mechanism behind the sign-asymmetric relative error.
        let m = Booth::new(8).unwrap();
        let mut rng = Xoshiro256::new(13);
        for _ in 0..50_000 {
            let a = rng.next_u32() as i32;
            let b = rng.next_u32() as i32;
            let exact = a as i64 * b as i64;
            let approx = m.mul(a, b);
            assert!(approx <= exact, "{a}*{b}: {approx} > {exact}");
            // At most 16 partials each short by < 2^k.
            assert!(exact - approx < 16i64 << 8, "{a}*{b}: gap {}", exact - approx);
        }
    }

    #[test]
    fn larger_k_is_less_accurate() {
        let err = |k: u32| {
            let m = Booth::new(k).unwrap();
            let mut rng = Xoshiro256::new(17);
            let mut sum = 0f64;
            for _ in 0..20_000 {
                let a = (rng.next_u32() >> 16) as i32 - 32768;
                let b = (rng.next_u32() >> 16) as i32 - 32768;
                sum += m.relative_error(a, b).abs();
            }
            sum
        };
        assert!(err(4) < err(8));
        assert!(err(8) < err(12));
    }

    #[test]
    fn rejects_bad_k() {
        assert!(Booth::new(33).is_err());
        assert!(Booth::new(32).is_ok());
    }
}
