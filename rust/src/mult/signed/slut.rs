//! Signed-domain lookup-table backend: the ApproxTrain idea
//! (arXiv:2209.04161) extended to two's-complement operands.
//!
//! [`SignedLut`] tabulates any [`SignedMultiplier`] over the full
//! signed square `[−2^(bits−1), 2^(bits−1))²` — all four sign
//! quadrants, `2^bits × 2^bits` products (128 MiB of `i64` at 12×12).
//! This is the capability an unsigned LUT structurally lacks: its
//! table is one quadrant, so any design it wraps is forced
//! sign-symmetric. A signed table carries whatever sign-asymmetry the
//! inner design has — `slut12:booth8` preserves Booth's floor-biased
//! quadrants bit for bit inside the domain.
//!
//! Out-of-domain operands take the same leading-one reduction as the
//! unsigned LUT, applied to the **magnitude** (sign preserved, product
//! rescaled by the combined shift). Fidelity contract, mirroring
//! `mult::lut` (pinned by `tests/signed_mult.rs`):
//!
//! * both operands in-domain — bit-identical to the inner design;
//! * `sdrum<k>` with `k < bits − 1` (strict; the magnitude field is
//!   `bits − 1` wide) — bit-identical over the full `i32` range, by
//!   the same reduce-composition argument as DRUM-through-unsigned-LUT;
//! * otherwise — the inner design on magnitude-reduced operands,
//!   rescaled with sign-aware saturation.

use anyhow::{bail, Result};

use super::{check_signed_batch_lens, SignedMultiplier};

/// Lookup-table backend over the signed operand domain.
pub struct SignedLut {
    name: String,
    bits: u32,
    /// `2^(bits-1)` — operands in `[-half, half)` index the table
    /// directly.
    half: i32,
    /// Row-major products over the offset-encoded domain:
    /// `table[((a + half) << bits) | (b + half)] = inner.mul(a, b)`.
    table: Vec<i64>,
}

impl SignedLut {
    /// Widest supported operand, matching the unsigned backend: 12×12
    /// is a 128 MiB table.
    pub const MAX_BITS: u32 = 12;

    /// Tabulate `inner` over the signed `bits`-wide domain.
    pub fn new(inner: &dyn SignedMultiplier, bits: u32) -> Result<Self> {
        if !(2..=Self::MAX_BITS).contains(&bits) {
            bail!(
                "signed LUT operand width must be in [2, {}], got {bits}",
                Self::MAX_BITS
            );
        }
        let size = 1usize << bits;
        let half = (size / 2) as i32;
        let cols: Vec<i32> = (-half..half).collect();
        let mut row_a = vec![0i32; size];
        let mut table = vec![0i64; size * size];
        for (r, a) in (-half..half).enumerate() {
            row_a.fill(a);
            inner.mul_batch(&row_a, &cols, &mut table[r * size..(r + 1) * size]);
        }
        Ok(SignedLut {
            name: format!("slut{bits}:{}", inner.name()),
            bits,
            half,
            table,
        })
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Leading-one reduction of the magnitude to the table's signed
    /// domain: `(index, shift)` with `value ≈ index << shift` and
    /// `index ∈ [-half, half)`. The in-domain test is on the *signed*
    /// value, not the magnitude: `-half` is a tabulated operand (table
    /// row 0) and must hit the table directly, while `+half` is out of
    /// domain and reduces.
    #[inline]
    fn reduce(&self, v: i32) -> (i32, u32) {
        if (-self.half..self.half).contains(&v) {
            return (v, 0);
        }
        let mag = v.unsigned_abs();
        let msb = 31 - mag.leading_zeros();
        let shift = msb + 2 - self.bits; // magnitude field is bits-1 wide
        let red = (mag >> shift) as i32;
        (if v < 0 { -red } else { red }, shift)
    }

    #[inline]
    fn lookup(&self, ia: i32, ib: i32) -> i64 {
        let r = (ia + self.half) as usize;
        let c = (ib + self.half) as usize;
        self.table[(r << self.bits) | c]
    }

    /// Fault-injection hook ([`crate::testkit::faults`]), the signed
    /// analogue of `LutMultiplier::flip_table_bit`: flip one bit of the
    /// tabulated product for signed operand pair `(a, b)`. The i64
    /// products are stored two's-complement, so `bit == 63` flips the
    /// sign — the harshest single-cell ROM fault.
    pub fn flip_table_bit(&mut self, a: i32, b: i32, bit: u32) -> Result<()> {
        if !(-self.half..self.half).contains(&a) || !(-self.half..self.half).contains(&b)
        {
            bail!(
                "signed LUT fault operands ({a}, {b}) outside table domain \
                 [{}, {})",
                -self.half,
                self.half
            );
        }
        if bit >= 64 {
            bail!("signed LUT fault bit {bit} outside i64 product");
        }
        let r = (a + self.half) as usize;
        let c = (b + self.half) as usize;
        self.table[(r << self.bits) | c] ^= 1i64 << bit;
        Ok(())
    }
}

/// Rescale a table product by the reduction shifts, saturating on
/// magnitude overflow instead of wrapping (the signed analogue of the
/// unsigned backend's `shift_saturating`). Exact for every design
/// whose in-table magnitudes stay below `2^(63 - shift)` — all the
/// deterministic hardware designs at training-relevant widths.
#[inline]
fn shift_signed_saturating(value: i64, shift: u32) -> i64 {
    if value == 0 || shift == 0 {
        return value;
    }
    let mag = value.unsigned_abs();
    if mag.leading_zeros() > shift {
        value << shift
    } else if value < 0 {
        i64::MIN
    } else {
        i64::MAX
    }
}

impl SignedMultiplier for SignedLut {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn mul(&self, a: i32, b: i32) -> i64 {
        let (ia, sa) = self.reduce(a);
        let (ib, sb) = self.reduce(b);
        shift_signed_saturating(self.lookup(ia, ib), sa + sb)
    }

    /// Reduce + load loop, bit-identical to the scalar LUT path. Kept
    /// scalar even under the `simd` feature for the same reason as the
    /// unsigned backend: only the GEMM's mantissa domain makes the
    /// reduction a constant shift, and there [`SignedLut::simd_kernel`]
    /// hands the prepared kernel the flat table directly.
    fn mul_batch(&self, a: &[i32], b: &[i32], out: &mut [i64]) {
        check_signed_batch_lens(a, b, out);
        for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
            let (ix, sx) = self.reduce(x);
            let (iy, sy) = self.reduce(y);
            *o = shift_signed_saturating(self.lookup(ix, iy), sx + sy);
        }
    }

    #[cfg(feature = "simd")]
    fn simd_kernel(&self) -> Option<crate::mult::simd::SignedKernel<'_>> {
        Some(crate::mult::simd::SignedKernel::Flat {
            table: &self.table,
            bits: self.bits,
            half: self.half,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::{by_name, Booth, SignedDrum, SignedExact};
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn exhaustive_identity_inside_the_signed_domain() {
        // All four quadrants: the LUT is the design, bit for bit.
        // booth6 is deliberately *inexact at the -half edge* (-32 is a
        // single floored partial: booth6(-32, 1) = -64): a reduction
        // that wrongly routed -half around the table would return -128
        // here, so this pins the domain boundary, not just the bulk.
        let booth = Booth::new(6).unwrap();
        assert_eq!(booth.mul(-32, 1), -64);
        let designs: [&dyn SignedMultiplier; 2] = [&SignedExact, &booth];
        for d in designs {
            let lut = SignedLut::new(d, 6).unwrap();
            for a in -32i32..32 {
                for b in -32i32..32 {
                    assert_eq!(lut.mul(a, b), d.mul(a, b), "{} {a}*{b}", lut.name());
                }
            }
        }
    }

    #[test]
    fn sdrum_identity_over_full_signed_range() {
        // sdrum6 through an 8-bit signed LUT (magnitude field 7 > 6):
        // identical on arbitrary operands, including the extremes.
        let d = SignedDrum::new(6).unwrap();
        let lut = SignedLut::new(&d, 8).unwrap();
        let mut rng = Xoshiro256::new(21);
        for _ in 0..20_000 {
            let (a, b) = (rng.next_u32() as i32, rng.next_u32() as i32);
            assert_eq!(lut.mul(a, b), d.mul(a, b), "{a}*{b}");
        }
        for &(a, b) in &[
            (i32::MIN, i32::MIN),
            (i32::MIN, i32::MAX),
            (i32::MIN, -1),
            (-1, -1),
            (127, -128),
            (-128, -128),
        ] {
            assert_eq!(lut.mul(a, b), d.mul(a, b), "{a}*{b}");
        }
    }

    #[test]
    fn preserves_sign_asymmetry_of_the_inner_design() {
        // booth8 in-domain: the (+,+) and (-,+) quadrants err
        // differently, and the signed table reproduces both exactly.
        let d = Booth::new(8).unwrap();
        let lut = SignedLut::new(&d, 12).unwrap();
        let (a, b) = (1499i32, 1733i32);
        assert_eq!(lut.mul(a, b), d.mul(a, b));
        assert_eq!(lut.mul(-a, b), d.mul(-a, b));
        assert_ne!(d.mul(-a, b), -d.mul(a, b), "expected asymmetric operand pair");
    }

    #[test]
    fn wide_operands_use_magnitude_reduction() {
        let lut = SignedLut::new(&SignedExact, 8).unwrap();
        let a = -0x0001_2345i32; // 17-bit magnitude -> reduced by 10
        let b = 0x0000_007Fi32; // fits
        // The reduction shifts the *magnitude* (an arithmetic `a >> 10`
        // would floor to -73, not -72).
        let red = -((a.unsigned_abs() >> 10) as i32);
        assert_eq!(red, -72);
        assert_eq!(lut.mul(a, b), SignedExact.mul(red, b) << 10);
    }

    #[test]
    fn flipped_table_bit_corrupts_exactly_that_product() {
        let d = SignedDrum::new(4).unwrap();
        let mut faulty = SignedLut::new(&d, 6).unwrap();
        let clean = SignedLut::new(&d, 6).unwrap();
        // Negative row, sign bit: the harshest single-cell fault.
        faulty.flip_table_bit(-13, 7, 63).unwrap();
        assert_eq!(faulty.mul(-13, 7), clean.mul(-13, 7) ^ (1i64 << 63));
        for a in -32i32..32 {
            for b in -32i32..32 {
                if (a, b) != (-13, 7) {
                    assert_eq!(faulty.mul(a, b), clean.mul(a, b), "{a}*{b}");
                }
            }
        }
    }

    #[test]
    fn flip_rejects_out_of_domain_faults() {
        let mut lut = SignedLut::new(&SignedExact, 6).unwrap();
        assert!(lut.flip_table_bit(32, 0, 0).is_err());
        assert!(lut.flip_table_bit(0, -33, 0).is_err());
        assert!(lut.flip_table_bit(0, 0, 64).is_err());
        // -32 is table row 0 — a valid fault target.
        assert!(lut.flip_table_bit(-32, -32, 5).is_ok());
    }

    #[test]
    fn saturates_instead_of_wrapping() {
        struct Overshoot;
        impl SignedMultiplier for Overshoot {
            fn name(&self) -> String {
                "overshoot".into()
            }
            fn mul(&self, a: i32, b: i32) -> i64 {
                (a as i64 * b as i64) * 3
            }
        }
        let lut = SignedLut::new(&Overshoot, 8).unwrap();
        assert_eq!(lut.mul(i32::MAX, i32::MAX), i64::MAX);
        assert_eq!(lut.mul(i32::MIN, i32::MAX), i64::MIN);
        // In-range products are untouched by the saturation guard.
        assert_eq!(lut.mul(100, -100), -30_000);
    }

    #[test]
    fn rejects_bad_widths() {
        assert!(SignedLut::new(&SignedExact, 1).is_err());
        assert!(SignedLut::new(&SignedExact, 13).is_err());
    }

    #[test]
    fn zero_operands() {
        let lut = by_name("slut4:sexact").unwrap();
        assert_eq!(lut.mul(0, -999), 0);
        assert_eq!(lut.mul(999, 0), 0);
    }
}
