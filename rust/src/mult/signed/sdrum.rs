//! Signed DRUM (Hashemi, Bahar & Reda, ICCAD 2015, §III.C): the
//! published design handles signed operands with a sign-magnitude
//! front end — detect the signs, run the unsigned dynamic-range core
//! on the magnitudes, and conditionally negate the output.
//!
//! Consequence: signed DRUM is exactly **sign-symmetric** —
//! `sdrum(−a, b) = −sdrum(a, b)` for every operand pair — so its
//! signed relative-error distribution is the unsigned one, mirrored
//! through the product sign. `tests/signed_mult.rs` pins both the
//! symmetry and the equivalence to the unsigned core on magnitudes;
//! the contrast is [`super::Booth`], which deliberately breaks the
//! symmetry.

use anyhow::Result;

use super::super::Drum;
use super::super::Multiplier as _;
use super::SignedMultiplier;

/// DRUM-k over two's-complement operands (sign-magnitude front end).
#[derive(Debug, Clone, Copy)]
pub struct SignedDrum {
    core: Drum,
}

impl SignedDrum {
    /// `k` in `[3, 32]`, as for the unsigned core.
    pub fn new(k: u32) -> Result<Self> {
        Ok(SignedDrum { core: Drum::new(k)? })
    }
}

impl SignedMultiplier for SignedDrum {
    fn name(&self) -> String {
        format!("s{}", self.core.name())
    }

    fn mul(&self, a: i32, b: i32) -> i64 {
        // |i32::MIN| = 2^31 overflows i32 but not u32; the magnitude
        // product (with DRUM's forced-bit overestimate, ≤ ~1.56x at
        // k = 3) stays below 2^63, so the cast back is exact.
        let mag = self.core.mul(a.unsigned_abs(), b.unsigned_abs());
        debug_assert!(mag <= i64::MAX as u64, "magnitude {mag:#x} overflows i64");
        let p = mag as i64;
        if (a < 0) != (b < 0) {
            -p
        } else {
            p
        }
    }
    // Scalar builds keep the `mul_batch` default: the monomorphized
    // loop over `mul` is already the abs + leading-zero + shift kernel.

    /// Explicit vector kernel (`simd` feature) — bit-identical to the
    /// default loop (`tests/simd_parity.rs`).
    #[cfg(feature = "simd")]
    fn mul_batch(&self, a: &[i32], b: &[i32], out: &mut [i64]) {
        super::check_signed_batch_lens(a, b, out);
        crate::mult::simd::sdrum_mul_batch(self.core.k(), a, b, out);
    }

    #[cfg(feature = "simd")]
    fn simd_kernel(&self) -> Option<crate::mult::simd::SignedKernel<'_>> {
        Some(crate::mult::simd::SignedKernel::SDrum { k: self.core.k() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn small_operands_exact_in_all_quadrants() {
        let d = SignedDrum::new(6).unwrap();
        for a in -40i32..40 {
            for b in -40i32..40 {
                assert_eq!(d.mul(a, b), a as i64 * b as i64, "{a}*{b}");
            }
        }
    }

    #[test]
    fn matches_unsigned_core_on_magnitudes() {
        let d = SignedDrum::new(6).unwrap();
        let core = Drum::new(6).unwrap();
        let mut rng = Xoshiro256::new(3);
        for _ in 0..20_000 {
            let a = rng.next_u32() as i32;
            let b = rng.next_u32() as i32;
            let want = core.mul(a.unsigned_abs(), b.unsigned_abs()) as i64;
            let want = if (a < 0) != (b < 0) { -want } else { want };
            assert_eq!(d.mul(a, b), want, "{a}*{b}");
        }
    }

    #[test]
    fn extreme_magnitudes_do_not_overflow() {
        for k in [3u32, 6, 32] {
            let d = SignedDrum::new(k).unwrap();
            for &(a, b) in &[
                (i32::MIN, i32::MIN),
                (i32::MIN, i32::MAX),
                (i32::MAX, i32::MAX),
                (i32::MIN, -1),
                (i32::MIN, 1),
            ] {
                let p = d.mul(a, b);
                let exact = a as i64 * b as i64;
                // Within DRUM's published error band, right sign.
                assert!(
                    (p as f64 - exact as f64).abs()
                        <= 0.6 * exact.unsigned_abs() as f64 + 1.0,
                    "sdrum{k}: {a}*{b} = {p} vs {exact}"
                );
                assert!(p.signum() * exact.signum() >= 0, "{a}*{b}");
            }
        }
    }
}
