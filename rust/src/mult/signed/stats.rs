//! Signed error characterization: the same chunk-scheduled
//! deterministic parallel reduction as [`crate::mult::characterize`],
//! over sign-symmetric operand distributions.
//!
//! Operands reuse the unsigned [`OperandDist`] families for the
//! *magnitude* (clamped to the `i32` range) and draw the sign from the
//! same per-chunk stream — so `sdrum6`'s signed MRE lands on the
//! unsigned `drum6` row (sign-symmetric design, symmetric operands)
//! while `booth<k>`'s does not (its error depends on the operand
//! signs). The chunk schedule depends only on `(n, seed)`, never the
//! worker count, so results are bit-reproducible at any parallelism
//! level (pinned by `tests/signed_mult.rs`).

use crate::parallel;
use crate::rng::{SplitMix64, Xoshiro256};

use super::super::stats::{Welford, CHUNK_SAMPLES};
use super::super::{ErrorStats, OperandDist};
use super::SignedMultiplier;

/// Operand/product staging length (matches the unsigned harness).
const BATCH: usize = 4096;

/// One signed operand: a `dist` magnitude (clamped into `i32`, the
/// `Uniform32` top bit folds away) with a fresh sign bit from the same
/// stream.
pub fn sample_signed(dist: OperandDist, rng: &mut Xoshiro256) -> i32 {
    let mag = (dist.sample(rng) & 0x7FFF_FFFF).max(1) as i32;
    if rng.next_u32() & 1 == 1 {
        -mag
    } else {
        mag
    }
}

/// Decorrelated per-chunk RNG seed (same scheme as the unsigned
/// harness, domain-separated by the constant).
fn chunk_seed(seed: u64, chunk: u64) -> u64 {
    SplitMix64::new(seed ^ chunk.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64()
}

/// One chunk: draw `len` signed operand pairs, run the batched fast
/// path, and accumulate locally.
fn run_chunk(
    m: &dyn SignedMultiplier,
    dist: OperandDist,
    len: u64,
    seed: u64,
) -> Welford {
    let mut rng = Xoshiro256::new(seed);
    let mut acc = Welford::new();
    let mut a = [0i32; BATCH];
    let mut b = [0i32; BATCH];
    let mut out = [0i64; BATCH];
    let mut left = len;
    while left > 0 {
        let k = left.min(BATCH as u64) as usize;
        for i in 0..k {
            a[i] = sample_signed(dist, &mut rng);
            b[i] = sample_signed(dist, &mut rng);
        }
        m.mul_batch(&a[..k], &b[..k], &mut out[..k]);
        for i in 0..k {
            let exact = a[i] as i64 * b[i] as i64;
            let re = if exact == 0 {
                0.0
            } else {
                (out[i] as f64 - exact as f64) / exact as f64
            };
            acc.push(re);
        }
        left -= k as u64;
    }
    acc
}

/// Characterize `m` over `n` random signed operand pairs, in parallel
/// over [`parallel::max_threads`] workers. Deterministic in `(n, seed)`
/// regardless of worker count (all signed designs are stateless).
pub fn characterize_signed(
    m: &dyn SignedMultiplier,
    dist: OperandDist,
    n: u64,
    seed: u64,
) -> ErrorStats {
    characterize_signed_threads(m, dist, n, seed, parallel::max_threads())
}

/// [`characterize_signed`] with an explicit worker count.
pub fn characterize_signed_threads(
    m: &dyn SignedMultiplier,
    dist: OperandDist,
    n: u64,
    seed: u64,
    threads: usize,
) -> ErrorStats {
    if n == 0 {
        return Welford::new().finish();
    }
    let chunks: Vec<(u64, u64)> = (0..n.div_ceil(CHUNK_SAMPLES))
        .map(|c| {
            let start = c * CHUNK_SAMPLES;
            (c, (n - start).min(CHUNK_SAMPLES))
        })
        .collect();
    let accs = parallel::par_map(&chunks, threads, |_, &(c, len)| {
        run_chunk(m, dist, len, chunk_seed(seed, c))
    });
    // Merge in chunk order — deterministic floating-point reduction.
    accs.into_iter().fold(Welford::new(), Welford::merge).finish()
}

#[cfg(test)]
mod tests {
    use super::super::{Booth, SignedDrum, SignedExact};
    use super::*;
    use crate::mult::{characterize, Drum};

    #[test]
    fn sexact_has_zero_error() {
        let s = characterize_signed(&SignedExact, OperandDist::Uniform16, 10_000, 1);
        assert_eq!(s.mre, 0.0);
        assert_eq!(s.sd, 0.0);
        assert_eq!(s.samples, 10_000);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let d = SignedDrum::new(6).unwrap();
        let seq =
            characterize_signed_threads(&d, OperandDist::Uniform16, 200_000, 9, 1);
        let par =
            characterize_signed_threads(&d, OperandDist::Uniform16, 200_000, 9, 8);
        assert_eq!(seq.mre, par.mre);
        assert_eq!(seq.sd, par.sd);
        assert_eq!(seq.mean_re, par.mean_re);
        assert_eq!(seq.min_re, par.min_re);
        assert_eq!(seq.max_re, par.max_re);
    }

    #[test]
    fn sdrum_signed_mre_matches_unsigned_core_band() {
        // Sign-symmetric design + sign-symmetric operands: the signed
        // MRE must land in the unsigned design's band (not equal —
        // different operand streams — but the same statistic).
        let s = characterize_signed(
            &SignedDrum::new(6).unwrap(),
            OperandDist::Uniform16,
            200_000,
            7,
        );
        let u = characterize(&Drum::new(6).unwrap(), OperandDist::Uniform16, 200_000, 7);
        assert!((s.mre - u.mre).abs() < 0.004, "signed {} vs unsigned {}", s.mre, u.mre);
        assert!(s.mean_re.abs() < 0.004, "bias {:.4}", s.mean_re);
    }

    #[test]
    fn booth_error_is_sign_asymmetric() {
        // Booth truncation under-runs the signed product: relative
        // error is negative on positive products, positive on negative
        // ones. On symmetric operands the extremes must straddle zero
        // with comparable magnitude — and a paired-sign sweep shows the
        // quadrant dependence directly.
        let m = Booth::new(16).unwrap();
        let s = characterize_signed(&m, OperandDist::Uniform16, 100_000, 3);
        assert!(s.min_re < -1e-3, "min {:.5}", s.min_re);
        assert!(s.max_re > 1e-3, "max {:.5}", s.max_re);
        let mut rng = Xoshiro256::new(5);
        for _ in 0..1000 {
            let a = 1 + rng.next_below(60_000) as i32;
            let b = 1 + rng.next_below(60_000) as i32;
            assert!(m.relative_error(a, b) <= 0.0, "{a}*{b}");
            assert!(m.relative_error(-a, b) >= 0.0, "-{a}*{b}");
        }
    }

    #[test]
    fn zero_samples_is_well_defined() {
        let s = characterize_signed(&SignedExact, OperandDist::Small, 0, 3);
        assert_eq!(s.samples, 0);
        assert_eq!(s.mre, 0.0);
    }
}
