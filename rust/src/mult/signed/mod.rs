//! Signed-operand approximate multipliers: two's-complement designs
//! whose **sign handling is part of the simulated hardware**, not
//! bookkeeping around it.
//!
//! ## Why a second trait
//!
//! The unsigned [`super::Multiplier`] pipeline strips the sign of every
//! f32 operand up front: `approx_mul_f32` multiplies the *magnitudes*
//! (24-bit mantissas) through the design and re-applies `sx ^ sy` to
//! the result. That is exactly right for designs published on unsigned
//! operands — but it makes sign-dependent error **unrepresentable**:
//! under sign-externalized routing, `(−a)·b = −(a·b)` holds for every
//! possible design, by construction. Real signed hardware is not so
//! constrained. Spantidi et al. (arXiv:2107.09366) characterize
//! "positive/negative" multipliers whose error flips sign with the
//! product's sign, and truncated two's-complement partial-product trees
//! (the Booth family) floor toward −∞, overestimating the magnitude of
//! negative products while underestimating positive ones.
//!
//! [`SignedMultiplier`] therefore takes two's-complement `i32` operands
//! and returns an `i64` product whose sign **comes out of the design**.
//! The signed GEMM path ([`approx_mul_f32_signed`],
//! [`approx_matmul_prepared_signed`]) feeds it signed mantissas
//! (`±(1.m × 2^23)`) and takes the result's sign from the returned
//! product — the exponent add stays exact, but the sign no longer
//! bypasses the multiplier. See [`signed_mantissa`] /
//! `PreparedMatrix::with_signed_mantissas` for the plane layout.
//!
//! ## Designs
//!
//! * [`SignedDrum`] (`sdrum<k>`) — DRUM's published signed form
//!   (Hashemi, Bahar & Reda, ICCAD'15 §III.C): a sign-magnitude front
//!   end around the unsigned DRUM core; sign-symmetric by design.
//! * [`Booth`] (`booth<k>`) — radix-4 Booth-encoded multiplier with
//!   the `k` least-significant columns of each partial product
//!   truncated (the approximate fixed-width Booth family, e.g. Jiang
//!   et al., TCAS-I'16). Two's-complement end to end; truncation
//!   floors, so the error is **sign-asymmetric** — the case the
//!   unsigned pipeline cannot express.
//! * [`SignedRoba`] (`sroba`) — RoBA's published signed form
//!   (Zendegani et al., TVLSI'17): sign detect, magnitude datapath,
//!   sign re-application; sign-symmetric.
//! * [`SignedLut`] (`slut<bits>:<inner>`) — ApproxTrain-style table
//!   over the full **signed** domain `[−2^(bits−1), 2^(bits−1))²`.
//!   Because each (sign, sign) quadrant is tabulated separately, a
//!   signed LUT can carry sign-asymmetric error — an unsigned LUT
//!   cannot, whatever it wraps.
//!
//! [`SignedExact`] (`sexact`) closes the set for baselines and tests.
//!
//! Everything here follows the unsigned subsystem's contracts:
//! `mul_batch` is the monomorphized fast path and must stay
//! bit-identical to `mul` (pinned by `tests/signed_mult.rs`), and
//! [`characterize_signed`] is the same chunk-scheduled deterministic
//! parallel reduction as [`super::characterize`], over sign-symmetric
//! operand distributions.

mod booth;
mod sdrum;
mod slut;
mod sroba;
mod stats;

pub(crate) mod matmul;

pub use booth::Booth;
pub use matmul::{
    approx_matmul_prepared_signed, approx_matmul_reference_signed,
    approx_matmul_signed, approx_matmul_signed_nt, approx_matmul_signed_tn,
    approx_mul_f32_signed, characterize_matmul_signed_set,
};
pub use sdrum::SignedDrum;
pub use slut::SignedLut;
pub use sroba::SignedRoba;
pub use stats::{characterize_signed, characterize_signed_threads, sample_signed};

use anyhow::{bail, Result};

/// An (approximate) signed integer multiplier over two's-complement
/// operands. The product's sign is produced by the design itself —
/// nothing external corrects it.
pub trait SignedMultiplier: Send + Sync {
    /// Design name, e.g. `sdrum6`.
    fn name(&self) -> String;

    /// Approximate product of two signed operands.
    fn mul(&self, a: i32, b: i32) -> i64;

    /// Exact reference for error accounting. Like
    /// [`super::Multiplier::exact`], the harnesses inline this on hot
    /// paths; do not override.
    fn exact(&self, a: i32, b: i32) -> i64 {
        a as i64 * b as i64
    }

    /// Signed relative error of one product (0 when the exact product
    /// is 0, matching the MRE definition's implicit exclusion).
    fn relative_error(&self, a: i32, b: i32) -> f64 {
        let exact = self.exact(a, b);
        if exact == 0 {
            return 0.0;
        }
        (self.mul(a, b) as f64 - exact as f64) / exact as f64
    }

    /// Approximate products of paired slices: `out[i] = mul(a[i], b[i])`.
    /// Same contract as [`super::Multiplier::mul_batch`]: one virtual
    /// call per slice, monomorphized inner loop, bit-identical to the
    /// scalar path.
    ///
    /// # Panics
    /// Panics when the three slices differ in length.
    fn mul_batch(&self, a: &[i32], b: &[i32], out: &mut [i64]) {
        check_signed_batch_lens(a, b, out);
        for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
            *o = self.mul(x, y);
        }
    }

    /// Signed twin of [`super::Multiplier::simd_kernel`]: the
    /// explicit-SIMD GEMM kernel descriptor, when one exists (`simd`
    /// feature only); `None` keeps the prepared signed GEMM on the
    /// scalar-batch chain engine.
    #[cfg(feature = "simd")]
    fn simd_kernel(&self) -> Option<crate::mult::simd::SignedKernel<'_>> {
        None
    }
}

/// Shared length guard for `mul_batch` implementations.
#[inline]
pub(crate) fn check_signed_batch_lens(a: &[i32], b: &[i32], out: &[i64]) {
    assert!(
        a.len() == b.len() && a.len() == out.len(),
        "mul_batch: slice lengths differ ({}, {}, {})",
        a.len(),
        b.len(),
        out.len()
    );
}

/// Exact signed multiplier (baseline / LUT tabulation reference).
#[derive(Debug, Clone, Copy, Default)]
pub struct SignedExact;

impl SignedMultiplier for SignedExact {
    fn name(&self) -> String {
        "sexact".into()
    }

    fn mul(&self, a: i32, b: i32) -> i64 {
        a as i64 * b as i64
    }
    // `mul_batch` default: already a monomorphized widening-multiply
    // loop for this impl.

    #[cfg(feature = "simd")]
    fn simd_kernel(&self) -> Option<crate::mult::simd::SignedKernel<'_>> {
        Some(crate::mult::simd::SignedKernel::Exact)
    }
}

/// The signed mantissa a prepared f32 element feeds a
/// [`SignedMultiplier`]: `±(1.m × 2^23)` as a two's-complement `i32`
/// (the 25-bit signed value every magnitude in `[2^23, 2^24)` maps to).
#[inline]
pub(crate) fn signed_mantissa(sign: u8, mant: u32) -> i32 {
    if sign != 0 {
        -(mant as i32)
    } else {
        mant as i32
    }
}

/// Purely syntactic test: does `spec` belong to the signed grammar?
/// The signed and unsigned prefixes never overlap, so this decides
/// which `by_name` a spec resolves against without building anything
/// (a `slut12` table is 128 MiB — far too heavy for spec routing).
pub fn is_signed_spec(spec: &str) -> bool {
    spec == "sexact"
        || spec == "sroba"
        || spec.starts_with("sdrum")
        || spec.starts_with("booth")
        || spec.starts_with("slut")
}

/// Build a signed multiplier from a spec string: `sexact`,
/// `sdrum<k>`, `booth<k>`, `sroba`, or `slut<bits>:<inner>` for the
/// signed-domain LUT backend of any of the above (e.g. `slut12:sdrum6`).
/// The unsigned grammar lives in [`super::by_name`]; the two prefixes
/// never overlap.
pub fn by_name(spec: &str) -> Result<Box<dyn SignedMultiplier>> {
    if let Some(rest) = spec.strip_prefix("slut") {
        if let Some((bits, inner)) = rest.split_once(':') {
            let bits: u32 = bits.parse()?;
            let inner = by_name(inner)?;
            return Ok(Box::new(SignedLut::new(inner.as_ref(), bits)?));
        }
    }
    if spec == "sexact" {
        return Ok(Box::new(SignedExact));
    }
    if spec == "sroba" {
        return Ok(Box::new(SignedRoba));
    }
    if let Some(k) = spec.strip_prefix("sdrum") {
        let k: u32 = k.parse()?;
        return Ok(Box::new(SignedDrum::new(k)?));
    }
    if let Some(k) = spec.strip_prefix("booth") {
        let k: u32 = k.parse()?;
        return Ok(Box::new(Booth::new(k)?));
    }
    bail!(
        "unknown signed multiplier spec {spec:?} (expected sexact | sdrum<k> \
         | booth<k> | sroba | slut<bits>:<inner>; unsigned designs like \
         drum<k> live in mult::by_name)"
    )
}

/// The signed design set the characterization harness sweeps by
/// default (mirrors [`super::standard_designs`]).
pub fn standard_signed_designs() -> Vec<Box<dyn SignedMultiplier>> {
    vec![
        Box::new(SignedExact),
        Box::new(SignedDrum::new(4).unwrap()),
        Box::new(SignedDrum::new(6).unwrap()),
        Box::new(SignedDrum::new(8).unwrap()),
        Box::new(Booth::new(8).unwrap()),
        Box::new(Booth::new(12).unwrap()),
        Box::new(SignedRoba),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sexact_is_exact() {
        let m = SignedExact;
        assert_eq!(m.mul(0, 0), 0);
        assert_eq!(m.mul(-3, 7), -21);
        assert_eq!(m.mul(i32::MIN, i32::MIN), (i32::MIN as i64).pow(2));
        assert_eq!(m.relative_error(-12345, 6789), 0.0);
    }

    #[test]
    fn by_name_parses() {
        assert_eq!(by_name("sexact").unwrap().name(), "sexact");
        assert_eq!(by_name("sdrum6").unwrap().name(), "sdrum6");
        assert_eq!(by_name("booth8").unwrap().name(), "booth8");
        assert_eq!(by_name("sroba").unwrap().name(), "sroba");
        assert_eq!(by_name("slut8:sdrum6").unwrap().name(), "slut8:sdrum6");
        assert!(by_name("sdrum").is_err());
        assert!(by_name("drum6").is_err()); // unsigned grammar
        assert!(by_name("slut99:sdrum6").is_err());
        assert!(by_name("slut8:drum6").is_err()); // unsigned inner
    }

    #[test]
    fn signed_mantissa_maps_both_signs() {
        assert_eq!(signed_mantissa(0, 0x0080_0000), 1 << 23);
        assert_eq!(signed_mantissa(1, 0x0080_0000), -(1 << 23));
        assert_eq!(signed_mantissa(1, 0x00FF_FFFF), -0x00FF_FFFF);
    }

    #[test]
    fn default_mul_batch_matches_scalar() {
        let m = by_name("booth8").unwrap();
        let a = [0i32, 1, -77, i32::MIN, i32::MAX, -1];
        let b = [5i32, 0, -123_456, -1, i32::MIN, -1];
        let mut out = [0i64; 6];
        m.mul_batch(&a, &b, &mut out);
        for i in 0..a.len() {
            assert_eq!(out[i], m.mul(a[i], b[i]));
        }
    }

    #[test]
    #[should_panic(expected = "slice lengths differ")]
    fn mul_batch_length_mismatch_panics() {
        let mut out = [0i64; 2];
        SignedExact.mul_batch(&[1, 2, 3], &[4, 5, 6], &mut out);
    }

    #[test]
    fn standard_set_has_unique_names() {
        let designs = standard_signed_designs();
        let mut names: Vec<String> = designs.iter().map(|d| d.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), designs.len());
    }
}
