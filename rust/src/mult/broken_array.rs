//! Broken-Array Multiplier (Mahdiani et al., 2010): omit the lowest
//! `d` carry-save rows *and* columns of the partial-product array —
//! the structural truncation the tree-compressor designs (the paper's
//! [6], Yang et al. ICCD'17) refine. Unlike operand truncation
//! ([`super::Truncation`]) the cut is on the *product array*, so the
//! error scales with the product magnitude rather than the operand
//! magnitude — a different (still one-sided) error shape for the
//! model-vs-hardware comparison.

use anyhow::{bail, Result};

use super::Multiplier;

/// Broken-array multiplier dropping partial products below column `d`.
#[derive(Debug, Clone, Copy)]
pub struct BrokenArray {
    d: u32,
}

impl BrokenArray {
    /// `d` in `[1, 47]`: lowest product column retained is `d`.
    pub fn new(d: u32) -> Result<Self> {
        if !(1..=47).contains(&d) {
            bail!("broken-array depth must be in [1, 47], got {d}");
        }
        Ok(BrokenArray { d })
    }
}

impl Multiplier for BrokenArray {
    fn name(&self) -> String {
        format!("bam{}", self.d)
    }

    fn mul(&self, a: u32, b: u32) -> u64 {
        // Partial product row i (bit i of b set) contributes a << i.
        // Dropping array cells below column d means each row keeps
        // only the part of (a << i) at columns >= d:
        //   kept_i = ((a >> max(0, d - i)) << max(0, d - i)) << i
        // i.e. clear the low (d - i) bits of a for rows i < d.
        let mut acc = 0u64;
        let mut bb = b;
        while bb != 0 {
            let i = bb.trailing_zeros();
            bb &= bb - 1;
            let cut = self.d.saturating_sub(i);
            let kept = if cut >= 32 { 0 } else { (a >> cut) << cut };
            acc += (kept as u64) << i;
        }
        acc
    }
    // `mul_batch` default suffices: the row-accumulation inner loop is
    // data-dependent, so the batched win is the amortized dispatch the
    // monomorphized default already provides.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::{characterize, OperandDist};

    #[test]
    fn exact_reference_check_small() {
        // Against a direct mask-based model for exhaustive small cases.
        let m = BrokenArray::new(4).unwrap();
        for a in 0..128u32 {
            for b in 0..128u32 {
                let mut expect = 0u64;
                for i in 0..7 {
                    if b >> i & 1 == 1 {
                        let cut = 4u32.saturating_sub(i);
                        expect += (((a >> cut) << cut) as u64) << i;
                    }
                }
                assert_eq!(m.mul(a, b), expect, "{a}*{b}");
            }
        }
    }

    #[test]
    fn never_exceeds_exact() {
        let m = BrokenArray::new(8).unwrap();
        let mut rng = crate::rng::Xoshiro256::new(5);
        for _ in 0..10_000 {
            let a = rng.next_u32();
            let b = rng.next_u32();
            assert!(m.mul(a, b) <= m.exact(a, b));
        }
    }

    #[test]
    fn high_rows_unaffected() {
        // If both operands live entirely above the cut, it's exact.
        let m = BrokenArray::new(8).unwrap();
        assert_eq!(m.mul(0x100, 0x100), 0x10000);
        assert_eq!(m.mul(0xFF00, 0xAB00), 0xFF00u64 * 0xAB00);
    }

    #[test]
    fn deeper_cut_more_error() {
        let mre = |d| {
            characterize(&BrokenArray::new(d).unwrap(), OperandDist::Uniform16,
                         50_000, 7)
                .mre
        };
        assert!(mre(12) > mre(6));
        assert!(mre(6) > mre(3));
    }

    #[test]
    fn error_is_one_sided() {
        let s = characterize(&BrokenArray::new(10).unwrap(),
                             OperandDist::Uniform16, 50_000, 9);
        assert!(s.max_re <= 0.0);
        assert!(s.mean_re < 0.0);
    }
}
