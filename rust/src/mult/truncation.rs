//! Static truncation multiplier: zero the low `k` bits of each operand
//! before an exact multiply. The cheapest possible "approximate
//! multiplier" and the standard strawman baseline: unlike DRUM it is
//! *biased* (always underestimates) and its relative error blows up for
//! small operands — both visible in the characterization tables.

use anyhow::{bail, Result};

use super::{check_batch_lens, Multiplier};

/// Truncate-low-k-bits multiplier.
#[derive(Debug, Clone, Copy)]
pub struct Truncation {
    k: u32,
}

impl Truncation {
    /// `k` in `[1, 31]`: number of low bits discarded per operand.
    pub fn new(k: u32) -> Result<Self> {
        if !(1..=31).contains(&k) {
            bail!("truncation k must be in [1, 31], got {k}");
        }
        Ok(Truncation { k })
    }
}

impl Multiplier for Truncation {
    fn name(&self) -> String {
        format!("trunc{}", self.k)
    }

    fn mul(&self, a: u32, b: u32) -> u64 {
        let mask = !0u32 << self.k;
        (a & mask) as u64 * (b & mask) as u64
    }

    /// Mask-and-multiply loop (the ideal auto-vectorization target) or
    /// the explicit vector kernel under the `simd` feature —
    /// bit-identical to the scalar path either way.
    fn mul_batch(&self, a: &[u32], b: &[u32], out: &mut [u64]) {
        check_batch_lens(a, b, out);
        #[cfg(feature = "simd")]
        super::simd::trunc_mul_batch(self.k, a, b, out);
        #[cfg(not(feature = "simd"))]
        {
            let mask = !0u32 << self.k;
            for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
                *o = (x & mask) as u64 * (y & mask) as u64;
            }
        }
    }

    #[cfg(feature = "simd")]
    fn simd_kernel(&self) -> Option<super::simd::UnsignedKernel<'_>> {
        Some(super::simd::UnsignedKernel::Trunc { k: self.k })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::{characterize, Multiplier, OperandDist};

    #[test]
    fn underestimates_always() {
        let t = Truncation::new(8).unwrap();
        let stats = characterize(&t, OperandDist::Uniform16, 50_000, 9);
        assert!(stats.max_re <= 0.0);
        assert!(stats.mean_re < 0.0);
    }

    #[test]
    fn small_operands_zeroed() {
        let t = Truncation::new(8).unwrap();
        assert_eq!(t.mul(200, 200), 0); // both < 2^8
    }

    #[test]
    fn aligned_operands_exact() {
        let t = Truncation::new(4).unwrap();
        assert_eq!(t.mul(0x10, 0x20), 0x200);
    }

    #[test]
    fn more_truncation_more_error() {
        let mre = |k| {
            characterize(&Truncation::new(k).unwrap(), OperandDist::Mantissa, 50_000, 3)
                .mre
        };
        assert!(mre(16) > mre(8));
    }
}
