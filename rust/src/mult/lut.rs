//! ApproxTrain-style lookup-table multiplier backend (arXiv:2209.04161).
//!
//! ApproxTrain reaches CNN-training scale by replacing the bit-level
//! simulation of an approximate multiplier with a precomputed product
//! table over the operand mantissas. [`LutMultiplier`] is the host-side
//! twin: it tabulates *any* [`Multiplier`] over a configurable operand
//! width `bits` (table of `2^bits × 2^bits` products, e.g. 512 KiB at
//! 8×8) and serves each product with two leading-one reductions and a
//! single load.
//!
//! Fidelity contract (pinned by `tests/mult_batch.rs`):
//!
//! * operands `< 2^bits` — bit-identical to the wrapped design;
//! * DRUM-k with `k < bits` (strict!) — bit-identical over the full
//!   32-bit range: DRUM only inspects the top `k` bits from the
//!   leading one, which the reduction preserves. At `k == bits` the
//!   identity breaks — a pre-reduced `bits`-wide operand fits DRUM's
//!   window exactly, so its forced steering bit (`(v >> s) | 1`) is
//!   never applied inside the table;
//! * otherwise — the wrapped design evaluated on leading-one-truncated
//!   operands, exactly the approximation ApproxTrain's mantissa LUTs
//!   make.

use anyhow::{bail, Result};

use super::{check_batch_lens, Multiplier};

/// Lookup-table backend for any multiplier design.
pub struct LutMultiplier {
    name: String,
    bits: u32,
    /// `1 << bits` — operands below this index the table directly.
    size: u32,
    /// Row-major products: `table[(a << bits) | b] = inner.mul(a, b)`.
    table: Vec<u64>,
}

impl LutMultiplier {
    /// Widest supported operand: 12×12 is a 128 MiB table; anything
    /// wider stops being a cache-resident win.
    pub const MAX_BITS: u32 = 12;

    /// Tabulate `inner` over `bits`-wide operands.
    pub fn new(inner: &dyn Multiplier, bits: u32) -> Result<Self> {
        if !(2..=Self::MAX_BITS).contains(&bits) {
            bail!("LUT operand width must be in [2, {}], got {bits}", Self::MAX_BITS);
        }
        let size = 1usize << bits;
        let cols: Vec<u32> = (0..size as u32).collect();
        let mut row_a = vec![0u32; size];
        let mut table = vec![0u64; size * size];
        for a in 0..size {
            row_a.fill(a as u32);
            inner.mul_batch(&row_a, &cols, &mut table[a * size..(a + 1) * size]);
        }
        Ok(LutMultiplier {
            name: format!("lut{bits}:{}", inner.name()),
            bits,
            size: size as u32,
            table,
        })
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Leading-one reduction to a table index: `(index, shift)` with
    /// `value ≈ index << shift` and `index < 2^bits`.
    #[inline]
    fn reduce(&self, v: u32) -> (u32, u32) {
        if v < self.size {
            return (v, 0);
        }
        let msb = 31 - v.leading_zeros();
        let shift = msb + 1 - self.bits;
        (v >> shift, shift)
    }

    #[inline]
    fn lookup(&self, ia: u32, ib: u32) -> u64 {
        self.table[((ia << self.bits) | ib) as usize]
    }

    /// Fault-injection hook ([`crate::testkit::faults`]): flip one bit
    /// of the tabulated product for operand pair `(a, b)`. Models a
    /// stuck/soft-errored cell in a hardware product ROM; every lookup
    /// that reduces to `(a, b)` then returns the corrupted product, so
    /// training sees a deterministic, persistent numeric fault rather
    /// than a crash.
    pub fn flip_table_bit(&mut self, a: u32, b: u32, bit: u32) -> Result<()> {
        if a >= self.size || b >= self.size {
            bail!(
                "LUT fault operands ({a}, {b}) outside table domain [0, {})",
                self.size
            );
        }
        if bit >= 64 {
            bail!("LUT fault bit {bit} outside u64 product");
        }
        self.table[((a << self.bits) | b) as usize] ^= 1u64 << bit;
        Ok(())
    }
}

/// Rescale a table product by the reduction shifts, saturating instead
/// of wrapping: an *overestimating* inner design (e.g. the Gaussian
/// model) can tabulate products >= 2^(2*bits), and on wide operands
/// `value << (sa + sb)` would silently lose the top bits. Saturation
/// matches [`super::GaussianModel`]'s own u64 clamp. Exact for every
/// design whose table stays below 2^(2*bits) (all the deterministic
/// hardware designs).
#[inline]
fn shift_saturating(value: u64, shift: u32) -> u64 {
    if value == 0 {
        return 0;
    }
    if value.leading_zeros() >= shift {
        value << shift
    } else {
        u64::MAX
    }
}

impl Multiplier for LutMultiplier {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn mul(&self, a: u32, b: u32) -> u64 {
        let (ia, sa) = self.reduce(a);
        let (ib, sb) = self.reduce(b);
        shift_saturating(self.lookup(ia, ib), sa + sb)
    }

    /// Reduce + load loop, bit-identical to the scalar LUT path. Kept
    /// scalar even under the `simd` feature: general-domain operands
    /// need the data-dependent leading-one reduction, and gathers
    /// don't pay there. The GEMM's mantissa domain is different — its
    /// reduction is a constant shift, so [`LutMultiplier::simd_kernel`]
    /// hands the prepared kernel the flat table instead.
    fn mul_batch(&self, a: &[u32], b: &[u32], out: &mut [u64]) {
        check_batch_lens(a, b, out);
        for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
            let (ix, sx) = self.reduce(x);
            let (iy, sy) = self.reduce(y);
            *o = shift_saturating(self.lookup(ix, iy), sx + sy);
        }
    }

    #[cfg(feature = "simd")]
    fn simd_kernel(&self) -> Option<super::simd::UnsignedKernel<'_>> {
        Some(super::simd::UnsignedKernel::Flat {
            table: &self.table,
            bits: self.bits,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::{by_name, Drum, Exact, Mitchell};
    use crate::rng::Xoshiro256;

    #[test]
    fn exhaustive_identity_below_table_width() {
        // Inside the table domain the LUT is the design, bit for bit.
        let designs: [&dyn Multiplier; 2] = [&Mitchell, &Exact];
        for d in designs {
            let lut = LutMultiplier::new(d, 6).unwrap();
            for a in 0..64u32 {
                for b in 0..64u32 {
                    assert_eq!(lut.mul(a, b), d.mul(a, b), "{} {a}*{b}", lut.name());
                }
            }
        }
    }

    #[test]
    fn drum_identity_over_full_range() {
        // DRUM-6 through an 8-bit LUT: identical on arbitrary operands.
        let d = Drum::new(6).unwrap();
        let lut = LutMultiplier::new(&d, 8).unwrap();
        let mut rng = Xoshiro256::new(21);
        for _ in 0..20_000 {
            let (a, b) = (rng.next_u32(), rng.next_u32());
            assert_eq!(lut.mul(a, b), d.mul(a, b), "{a}*{b}");
        }
    }

    #[test]
    fn wide_operands_use_leading_one_truncation() {
        // Outside the contract the LUT equals the design applied to the
        // reduced operands, rescaled.
        let lut = LutMultiplier::new(&Mitchell, 8).unwrap();
        let a = 0x0001_2345u32; // 17 bits -> reduced by 9
        let b = 0x0000_00FFu32; // fits
        assert_eq!(lut.mul(a, b), Mitchell.mul(a >> 9, b) << 9);
    }

    #[test]
    fn batch_matches_scalar() {
        let lut = by_name("lut8:mitchell").unwrap();
        let mut rng = Xoshiro256::new(5);
        let a: Vec<u32> = (0..4096).map(|_| rng.next_u32()).collect();
        let b: Vec<u32> = (0..4096).map(|_| rng.next_u32()).collect();
        let mut out = vec![0u64; a.len()];
        lut.mul_batch(&a, &b, &mut out);
        for i in 0..a.len() {
            assert_eq!(out[i], lut.mul(a[i], b[i]), "idx {i}");
        }
    }

    #[test]
    fn rejects_bad_widths() {
        assert!(LutMultiplier::new(&Exact, 1).is_err());
        assert!(LutMultiplier::new(&Exact, 13).is_err());
    }

    #[test]
    fn zero_operands() {
        let lut = LutMultiplier::new(&Mitchell, 4).unwrap();
        assert_eq!(lut.mul(0, 999), 0);
        assert_eq!(lut.mul(999, 0), 0);
    }

    #[test]
    fn flipped_table_bit_corrupts_exactly_that_product() {
        let d = Drum::new(4).unwrap();
        let mut faulty = LutMultiplier::new(&d, 6).unwrap();
        let clean = LutMultiplier::new(&d, 6).unwrap();
        faulty.flip_table_bit(36, 17, 3).unwrap();
        // The faulted cell differs by exactly the flipped bit...
        assert_eq!(faulty.mul(36, 17), clean.mul(36, 17) ^ (1 << 3));
        // ...and every other in-domain product is untouched.
        for a in 0..64u32 {
            for b in 0..64u32 {
                if (a, b) != (36, 17) {
                    assert_eq!(faulty.mul(a, b), clean.mul(a, b), "{a}*{b}");
                }
            }
        }
        // Out-of-domain operands that *reduce* onto the faulted cell
        // inherit the corruption (rescaled by the reduction shift):
        // 36 << 6 has msb 11, so reduce() keeps the top 6 bits = 36.
        assert_eq!(faulty.mul(36 << 6, 17), (clean.mul(36, 17) ^ (1 << 3)) << 6);
    }

    #[test]
    fn flip_rejects_out_of_domain_faults() {
        let mut lut = LutMultiplier::new(&Exact, 6).unwrap();
        assert!(lut.flip_table_bit(64, 0, 0).is_err());
        assert!(lut.flip_table_bit(0, 64, 0).is_err());
        assert!(lut.flip_table_bit(0, 0, 64).is_err());
    }

    #[test]
    fn overestimating_inner_design_saturates_instead_of_wrapping() {
        // A model whose products exceed 2^(2*bits) must clamp at
        // u64::MAX on wide operands, never wrap into a small value.
        struct Overshoot;
        impl Multiplier for Overshoot {
            fn name(&self) -> String {
                "overshoot".into()
            }
            fn mul(&self, a: u32, b: u32) -> u64 {
                (a as u64 * b as u64) * 3
            }
        }
        let lut = LutMultiplier::new(&Overshoot, 8).unwrap();
        let (a, b) = (u32::MAX, u32::MAX); // shifts total 48
        let got = lut.mul(a, b);
        assert_eq!(got, u64::MAX, "wrapped to {got:#x}");
        // In-range products are untouched by the saturation guard.
        assert_eq!(lut.mul(100, 100), 30_000);
    }
}
