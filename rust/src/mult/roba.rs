//! RoBA — Rounding-Based Approximate multiplier (Zendegani et al.,
//! TVLSI 2017), representative of the "round to nearest power of two"
//! family the approximate-multiplier literature benchmarks against.
//!
//! Idea: with `ar`, `br` the operands rounded to their nearest powers
//! of two, expand `a*b ≈ ar*b + a*br − ar*br`. Every term multiplies
//! by a power of two (shifts only — no partial-product array at all),
//! which is where the hardware win comes from. The error is bounded
//! and *sign-oscillating* (near-zero mean), making RoBA a second
//! real design (besides DRUM) that the paper's zero-mean Gaussian
//! model approximates well — the characterization harness quantifies
//! how well.

use super::Multiplier;

/// RoBA approximate multiplier (unsigned variant).
#[derive(Debug, Clone, Copy, Default)]
pub struct Roba;

impl Roba {
    /// Round to the nearest power of two. Ties (exact midpoint
    /// `3·2^(m-1)`) round up, matching the published RTL.
    #[inline]
    fn round_pow2(v: u32) -> u64 {
        debug_assert!(v > 0);
        let msb = 31 - v.leading_zeros();
        let base = 1u64 << msb;
        if msb == 0 {
            return base;
        }
        // v = 2^msb + rest; round up iff rest >= 2^(msb-1).
        let rest = v as u64 - base;
        if rest >= (1u64 << (msb - 1)) {
            base << 1
        } else {
            base
        }
    }
}

impl Multiplier for Roba {
    fn name(&self) -> String {
        "roba".into()
    }

    fn mul(&self, a: u32, b: u32) -> u64 {
        if a == 0 || b == 0 {
            return 0;
        }
        let ar = Self::round_pow2(a);
        let br = Self::round_pow2(b);
        // ar*b + a*br - ar*br, all shifts. The sum can transiently
        // exceed the true product; compute in i128 to keep the
        // subtraction exact, then clamp at 0 (hardware saturates).
        let v = ar as i128 * b as i128 + a as i128 * br as i128
            - ar as i128 * br as i128;
        v.max(0) as u64
    }
    // `mul_batch` default suffices: the monomorphized loop over `mul`
    // is already the shift-expansion kernel, nothing to hoist.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::{characterize, OperandDist};

    #[test]
    fn powers_of_two_exact() {
        let m = Roba;
        for i in 0..16 {
            for j in 0..16 {
                let (a, b) = (1u32 << i, 1u32 << j);
                assert_eq!(m.mul(a, b), a as u64 * b as u64, "{a}*{b}");
            }
        }
    }

    #[test]
    fn round_pow2_cases() {
        assert_eq!(Roba::round_pow2(1), 1);
        assert_eq!(Roba::round_pow2(3), 4); // tie rounds up
        assert_eq!(Roba::round_pow2(5), 4);
        assert_eq!(Roba::round_pow2(6), 8);
        assert_eq!(Roba::round_pow2(0xFFFF_FFFF), 1 << 32);
    }

    #[test]
    fn error_is_bounded_and_nearly_unbiased() {
        // Published RoBA error: |RE| <= 11.1%, mean close to zero on
        // uniform operands (oscillating sign).
        let s = characterize(&Roba, OperandDist::Uniform16, 200_000, 3);
        assert!(s.max_re < 0.12, "max {:.4}", s.max_re);
        assert!(s.min_re > -0.12, "min {:.4}", s.min_re);
        assert!(s.mean_re.abs() < 0.02, "bias {:.4}", s.mean_re);
        assert!((0.01..0.06).contains(&s.mre), "mre {:.4}", s.mre);
    }

    #[test]
    fn zero_operands() {
        assert_eq!(Roba.mul(0, 17), 0);
        assert_eq!(Roba.mul(17, 0), 0);
    }
}
