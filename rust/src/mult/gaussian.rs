//! The paper's simulation model as a `Multiplier`: exact product times
//! `(1 + sigma * eps)`, `eps ~ N(0,1)` from the shared Threefry stream.
//!
//! This is the host-side twin of the L1 `error_inject` kernel. Running
//! it through the same characterization harness as the bit-accurate
//! designs quantifies how well the Gaussian model imitates each real
//! design (mean/SD match DRUM well; it cannot represent Mitchell's
//! one-sided bias — see EXPERIMENTS.md §characterize).

use std::sync::atomic::{AtomicU32, Ordering};

use crate::rng::threefry::normal_pair;

use super::cast::sat_f64_to_u64;
use super::{check_batch_lens, Multiplier};

/// Threefry stream nonce for multiplier noise ("mult" in ASCII).
const NONCE: u32 = 0x6d75_6c74;

/// Gaussian relative-error model multiplier with SD `sigma`.
#[derive(Debug)]
pub struct GaussianModel {
    sigma: f64,
    seed: u32,
    counter: AtomicU32,
}

impl GaussianModel {
    pub fn new(sigma: f64, seed: u32) -> Self {
        GaussianModel { sigma, seed, counter: AtomicU32::new(0) }
    }

    pub fn sigma(&self) -> f64 {
        self.sigma
    }
}

impl Multiplier for GaussianModel {
    fn name(&self) -> String {
        format!("gauss{:.4}", self.sigma)
    }

    fn mul(&self, a: u32, b: u32) -> u64 {
        let exact = a as u64 * b as u64;
        let ctr = self.counter.fetch_add(1, Ordering::Relaxed);
        let (z, _) = normal_pair(self.seed, NONCE, ctr, 0);
        let v = exact as f64 * (1.0 + self.sigma * z as f64);
        // Clamp into the representable product range (a real multiplier
        // cannot return a negative or > 64-bit product).
        sat_f64_to_u64(v)
    }

    /// Reserves the whole noise-counter range with one atomic add, then
    /// evaluates it monomorphically — a fresh instance produces the
    /// same sequence batched as it would through scalar `mul` calls.
    fn mul_batch(&self, a: &[u32], b: &[u32], out: &mut [u64]) {
        check_batch_lens(a, b, out);
        let base = self.counter.fetch_add(out.len() as u32, Ordering::Relaxed);
        for (i, ((&x, &y), o)) in a.iter().zip(b).zip(out.iter_mut()).enumerate() {
            let exact = x as u64 * y as u64;
            let (z, _) = normal_pair(self.seed, NONCE, base.wrapping_add(i as u32), 0);
            let v = exact as f64 * (1.0 + self.sigma * z as f64);
            *o = sat_f64_to_u64(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::{characterize, OperandDist};

    #[test]
    fn sigma_zero_is_exact() {
        let g = GaussianModel::new(0.0, 1);
        assert_eq!(g.mul(12345, 678), 12345u64 * 678);
    }

    #[test]
    fn mre_tracks_sigma() {
        // sigma = 1.803% (DRUM-6's published SD) must give MRE ~1.44%.
        let g = GaussianModel::new(0.01803, 2);
        let stats = characterize(&g, OperandDist::Mantissa, 200_000, 11);
        let expect = 0.01803 * crate::HALF_NORMAL_MEAN;
        assert!(
            (stats.mre - expect).abs() < 0.0008,
            "mre {:.5} vs expected {:.5}",
            stats.mre,
            expect
        );
        assert!(stats.mean_re.abs() < 0.001);
    }
}
