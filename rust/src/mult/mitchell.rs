//! Mitchell's logarithmic multiplier (J. N. Mitchell, 1962).
//!
//! Approximates `log2(v) ≈ msb + frac` with the linear mantissa
//! interpolation, adds the two logs, and takes the linear antilog. The
//! classic cheap multiplier the approximate-computing literature
//! baselines against; its error is **one-sided** (always ≤ 0, up to
//! ~-11.1%), i.e. *not* zero-mean Gaussian — which makes it the
//! counterexample design for the paper's error model and an instructive
//! ablation row in `characterize`.

use super::{check_batch_lens, Multiplier};

/// Fixed-point fractional bits used for the log representation.
const FRAC_BITS: u32 = 32;

/// Mitchell logarithmic approximate multiplier.
#[derive(Debug, Clone, Copy, Default)]
pub struct Mitchell;

impl Mitchell {
    /// `log2(v)` in fixed point: integer part = msb index, fraction =
    /// mantissa bits below the leading one (linear approximation).
    #[inline]
    fn log2_fixed(v: u32) -> u64 {
        debug_assert!(v > 0);
        let msb = 31 - v.leading_zeros();
        // Fraction: bits below the leading one, left-aligned to FRAC_BITS.
        let frac = ((v as u64) << (FRAC_BITS - msb)) & ((1u64 << FRAC_BITS) - 1);
        ((msb as u64) << FRAC_BITS) | frac
    }

    /// Linear antilog: `2^(int + frac) ≈ (1 + frac) << int`.
    #[inline]
    fn antilog_fixed(l: u64) -> u64 {
        let int = (l >> FRAC_BITS) as u32;
        let frac = l & ((1u64 << FRAC_BITS) - 1);
        let mantissa = (1u64 << FRAC_BITS) | frac; // 1.frac
        if int >= FRAC_BITS {
            mantissa << (int - FRAC_BITS)
        } else {
            mantissa >> (FRAC_BITS - int)
        }
    }
}

impl Multiplier for Mitchell {
    fn name(&self) -> String {
        "mitchell".into()
    }

    fn mul(&self, a: u32, b: u32) -> u64 {
        if a == 0 || b == 0 {
            return 0;
        }
        Self::antilog_fixed(Self::log2_fixed(a) + Self::log2_fixed(b))
    }

    /// Explicit batch loop: the scalar build keeps the fused
    /// log-add-antilog body with the zero test decided per element
    /// before any kernel work; the `simd` build runs the branchless
    /// vector kernel. Bit-identical to `mul` either way
    /// (`tests/mult_batch.rs`, `tests/simd_parity.rs`).
    fn mul_batch(&self, a: &[u32], b: &[u32], out: &mut [u64]) {
        check_batch_lens(a, b, out);
        #[cfg(feature = "simd")]
        super::simd::mitchell_mul_batch(a, b, out);
        #[cfg(not(feature = "simd"))]
        for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
            *o = if x == 0 || y == 0 {
                0
            } else {
                Self::antilog_fixed(Self::log2_fixed(x) + Self::log2_fixed(y))
            };
        }
    }

    #[cfg(feature = "simd")]
    fn simd_kernel(&self) -> Option<super::simd::UnsignedKernel<'_>> {
        Some(super::simd::UnsignedKernel::Mitchell)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::{characterize, OperandDist};

    #[test]
    fn powers_of_two_exact() {
        let m = Mitchell;
        for i in 0..16 {
            for j in 0..16 {
                let (a, b) = (1u32 << i, 1u32 << j);
                assert_eq!(m.mul(a, b), a as u64 * b as u64, "{a}*{b}");
            }
        }
    }

    #[test]
    fn error_is_one_sided_negative() {
        let m = Mitchell;
        let stats = characterize(&m, OperandDist::Uniform16, 100_000, 5);
        // Mitchell underestimates: worst case -(1 - 2*(sqrt(2)-1)) ~ -11.1%.
        assert!(stats.max_re <= 1e-12, "positive error {:.5}", stats.max_re);
        assert!(stats.min_re > -0.12, "error too negative {:.5}", stats.min_re);
        assert!(stats.mean_re < -0.01, "should be biased, got {:.5}", stats.mean_re);
    }

    #[test]
    fn zero_operands() {
        assert_eq!(Mitchell.mul(0, 123), 0);
        assert_eq!(Mitchell.mul(123, 0), 0);
    }

    #[test]
    fn no_overflow_at_extremes() {
        let m = Mitchell;
        let r = m.mul(u32::MAX, u32::MAX);
        let exact = u32::MAX as u64 * u32::MAX as u64;
        let rel = (r as f64 - exact as f64) / exact as f64;
        assert!(rel.abs() < 0.12, "rel {rel}");
    }
}
