//! Bit-accurate approximate-multiplier substrate.
//!
//! The paper characterizes approximate multipliers only by their (MRE,
//! SD) and cites hardware designs ([3]-[6]) for the speed/power/area
//! numbers. To close the loop we implement the cited designs (or their
//! closest published form) **bit-accurately** on unsigned integers:
//!
//! * [`Drum`] — DRUM (Hashemi, Bahar & Reda, ICCAD'15): dynamic-range
//!   unbiased truncation to `k` significant bits. DRUM-6's published
//!   error (MRE ≈ 1.47%, near-zero mean) is reproduced by
//!   `examples/characterize_multipliers.rs` and pinned by tests.
//! * [`Mitchell`] — Mitchell's logarithmic multiplier (1962), the
//!   classic log-domain approximation (biased negative).
//! * [`Truncation`] — static low-bit truncation (the naive baseline).
//! * [`GaussianModel`] — the paper's own *simulation* model: exact
//!   product times `(1 + sigma*eps)` from the shared Threefry stream.
//!   Comparing its statistics against the bit-accurate designs is what
//!   justifies (or indicts) the paper's modelling shortcut.
//!
//! Floating-point relevance: an f32/f16 multiply is an exact exponent
//! add plus a mantissa multiply, so the *relative* error of the mantissa
//! multiplier equals the relative error of the float product. The
//! [`OperandDist::Mantissa`] distribution (uniform over `[2^23, 2^24)`)
//! therefore characterizes exactly the error a CNN training MAC would
//! see — this is the bridge between these integer designs and the
//! Gaussian sigma fed to the compiled graphs.

mod broken_array;
mod drum;
mod gaussian;
mod mitchell;
mod roba;
mod stats;
mod truncation;

pub use broken_array::BrokenArray;
pub use drum::Drum;
pub use gaussian::GaussianModel;
pub use mitchell::Mitchell;
pub use roba::Roba;
pub use stats::{characterize, ErrorStats, OperandDist};
pub use truncation::Truncation;

use anyhow::{bail, Result};

/// An (approximate) unsigned integer multiplier.
pub trait Multiplier: Send + Sync {
    /// Design name, e.g. `drum6`.
    fn name(&self) -> String;

    /// Approximate product of two unsigned operands.
    fn mul(&self, a: u32, b: u32) -> u64;

    /// Exact reference for error accounting.
    fn exact(&self, a: u32, b: u32) -> u64 {
        a as u64 * b as u64
    }

    /// Signed relative error of one product (0 when the exact product
    /// is 0, matching the MRE definition's implicit exclusion).
    fn relative_error(&self, a: u32, b: u32) -> f64 {
        let exact = self.exact(a, b);
        if exact == 0 {
            return 0.0;
        }
        (self.mul(a, b) as f64 - exact as f64) / exact as f64
    }
}

/// Exact multiplier (the paper's second training phase).
#[derive(Debug, Clone, Copy, Default)]
pub struct Exact;

impl Multiplier for Exact {
    fn name(&self) -> String {
        "exact".into()
    }

    fn mul(&self, a: u32, b: u32) -> u64 {
        a as u64 * b as u64
    }
}

/// Build a multiplier from a spec string: `exact`, `drum<k>`,
/// `mitchell`, `trunc<k>`, `gauss<sigma-percent>`.
pub fn by_name(spec: &str) -> Result<Box<dyn Multiplier>> {
    if spec == "exact" {
        return Ok(Box::new(Exact));
    }
    if spec == "mitchell" {
        return Ok(Box::new(Mitchell));
    }
    if spec == "roba" {
        return Ok(Box::new(Roba));
    }
    if let Some(d) = spec.strip_prefix("bam") {
        let d: u32 = d.parse()?;
        return Ok(Box::new(BrokenArray::new(d)?));
    }
    if let Some(k) = spec.strip_prefix("drum") {
        let k: u32 = k.parse()?;
        return Ok(Box::new(Drum::new(k)?));
    }
    if let Some(k) = spec.strip_prefix("trunc") {
        let k: u32 = k.parse()?;
        return Ok(Box::new(Truncation::new(k)?));
    }
    if let Some(p) = spec.strip_prefix("gauss") {
        let pct: f64 = p.parse()?;
        return Ok(Box::new(GaussianModel::new(pct / 100.0, 0)));
    }
    bail!(
        "unknown multiplier spec {spec:?} \
         (expected exact | drum<k> | mitchell | roba | bam<d> | trunc<k> | gauss<pct>)"
    )
}

/// The design set the characterization harness sweeps by default.
pub fn standard_designs() -> Vec<Box<dyn Multiplier>> {
    vec![
        Box::new(Exact),
        Box::new(Drum::new(4).unwrap()),
        Box::new(Drum::new(6).unwrap()),
        Box::new(Drum::new(8).unwrap()),
        Box::new(Mitchell),
        Box::new(Roba),
        Box::new(BrokenArray::new(8).unwrap()),
        Box::new(BrokenArray::new(12).unwrap()),
        Box::new(Truncation::new(8).unwrap()),
        Box::new(Truncation::new(12).unwrap()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_is_exact() {
        let m = Exact;
        assert_eq!(m.mul(0, 0), 0);
        assert_eq!(m.mul(u32::MAX, u32::MAX), u32::MAX as u64 * u32::MAX as u64);
        assert_eq!(m.relative_error(12345, 6789), 0.0);
    }

    #[test]
    fn by_name_parses() {
        assert_eq!(by_name("exact").unwrap().name(), "exact");
        assert_eq!(by_name("drum6").unwrap().name(), "drum6");
        assert_eq!(by_name("trunc8").unwrap().name(), "trunc8");
        assert_eq!(by_name("mitchell").unwrap().name(), "mitchell");
        assert_eq!(by_name("roba").unwrap().name(), "roba");
        assert_eq!(by_name("bam8").unwrap().name(), "bam8");
        assert!(by_name("drum").is_err());
        assert!(by_name("bogus").is_err());
    }

    #[test]
    fn relative_error_zero_product() {
        let m = by_name("drum6").unwrap();
        assert_eq!(m.relative_error(0, 12345), 0.0);
    }
}
